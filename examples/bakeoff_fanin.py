"""One bake-off row, narrated: why every abstraction short of MXDAG
loses the oversubscribed fan-in.

The scenario (``builders.oversubscribed_fanin(4, 4:1,
critical_flow_size=2)``): four rack-0 senders each push one flow across
a 4:1-oversubscribed core (shared uplink capacity 1.0) to a consumer on
rack 1.  Flow ``f0`` is *twice* the size of the others and feeds an
8-second compute — the critical path; ``f1..f3`` feed 1-second computes.
The optimal play is obvious from the DAG: give ``f0`` the whole uplink
first.  Each abstraction sees a different slice of that information:

- **fair sharing** sees nothing: the uplink splits 4 ways and the
  critical flow crawls;
- **SEBF (Varys)** sees bytes per link but no DAG: smallest effective
  bottleneck *first* means the big critical flow goes *last* — the
  ordering is exactly wrong on this input;
- **the dependency-coflow greedy (Shafiee & Ghaderi)** adds coflow
  precedence, but these four flows are mutually independent, so
  precedence never fires and it degenerates to SEBF;
- **Graphene** packs computes hard-stuff-first — but the computes here
  never contend for slots; the network, where the game is decided,
  fair-shares (the compute-only-DAG blind spot of Fig. 1(b));
- **Metaflow** orders flows by network-DAG depth — all four flows are
  depth 0, so every class ties and it, too, degenerates to fair
  sharing;
- **MXDAG** sees both sides: analytic slack puts ``f0`` in the most
  urgent class, it gets the uplink to itself, and the 8-second compute
  starts as early as physics allows.

Every scheduler emits an ordinary ``Schedule`` (priority classes +
coflow groups) executed by the *same* simulator — the bake-off measures
abstractions, not implementations.  The full matrix is
``benchmarks/bakeoff.py``; CI pins this gap via the
``bakeoff.*.mxdag_wins`` rows in ``benchmarks/baseline.json``.

Run:  PYTHONPATH=src python examples/bakeoff_fanin.py
"""
import sys
sys.path.insert(0, "src")

from repro.core import MXDAGScheduler
from repro.core.baselines import BASELINES, effective_bottleneck
from repro.core.builders import oversubscribed_fanin

g, cluster = oversubscribed_fanin(4, oversubscription=4.0,
                                  critical_flow_size=2.0)
uplink = cluster.topology.capacity("rack0.up")
print(f"{g.name}: 4 cross-rack flows on a shared uplink of capacity "
      f"{uplink:g} (4:1 oversubscribed)")
print("  f0: size 2.0, feeds the 8s critical compute;"
      " f1..f3: size 1.0, feed 1s computes\n")

# SEBF's view of the world: per-flow effective bottleneck Γ
for i in range(4):
    gamma = effective_bottleneck({f"f{i}"}, g, cluster)
    print(f"  Γ(f{i}) = {gamma:g} s" +
          ("   <- biggest Γ, so SEBF sends the critical flow LAST"
           if i == 0 else ""))
print()

schedulers = dict(BASELINES)
schedulers["mxdag"] = lambda: MXDAGScheduler(try_pipelining=False)
results = {}
for name, factory in schedulers.items():
    s = factory().schedule(g, cluster)
    results[name] = s.simulate(cluster).makespan
    note = {
        "fair": "uplink split 4 ways",
        "sebf": "critical flow last (ascending Γ)",
        "sg_coflow": "no precedence between the flows -> same as SEBF",
        "graphene": "computes never contend; network fair-shares",
        "metaflow": "all flows depth 0 -> one class -> fair sharing",
        "mxdag": "slack puts f0 first; 8s compute starts at t=2",
    }[name]
    print(f"  {name:<10} makespan {results[name]:6.2f} s   ({note})")

best_base = min(v for k, v in results.items() if k != "mxdag")
assert results["mxdag"] < best_base - 1e-9, \
    "MXDAG must strictly beat every baseline on this scenario"
print(f"\n  MXDAG beats the best baseline by "
      f"{best_base / results['mxdag']:.2f}x "
      f"({best_base:g} s -> {results['mxdag']:g} s)")
