"""Sharding rules: param / batch / cache PartitionSpecs per arch × mesh.

Strategy (DESIGN.md §6):
- TP over "model": attention heads and FFN hidden; EP over "model" for MoE
  expert banks; vocab over "model" for embed/lm_head.
- DP over ("pod","data"): batch; with ``RunConfig.fsdp`` also params'
  non-TP dim (ZeRO-3-style weight sharding — GSPMD inserts the per-layer
  all-gathers).
- Decode caches: batch over dp when divisible, cache sequence over
  "model" (and over dp too when batch==1, e.g. long_500k).

Every rule is divisibility-guarded: a dim that doesn't divide the axis
size falls back to replication rather than failing to lower.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, RunConfig
from repro.launch.mesh import dp_axes as _dp_axes

Params = dict


def _axsize(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _maybe(mesh, axes, dim: int):
    """axes if dim divides evenly, else None (replicate)."""
    return axes if axes and dim % _axsize(mesh, axes) == 0 else None


def _path_names(path) -> list[str]:
    out = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            out.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return out


def param_spec_for(path, shape, cfg: ArchConfig, run: RunConfig,
                   mesh) -> P:
    names = _path_names(path)
    name = names[-1]
    nd = len(shape)
    if run.batch_axes == "all":
        # pure-DP regime (tiny models): replicate params, batch owns the
        # whole mesh; optionally FSDP over all axes
        if run.fsdp:
            for i, d in enumerate(shape):
                if d % _axsize(mesh, mesh.axis_names) == 0:
                    return P(*([None] * i + [mesh.axis_names]
                               + [None] * (nd - i - 1)))
        return P(*([None] * nd))
    dp = _dp_axes(mesh)
    fsdp = dp if run.fsdp else None

    def spec(*entries):
        # pad leading None for stacked layer axes
        lead = nd - len(entries)
        return P(*([None] * lead + list(entries)))

    m = "model"
    if name == "embed":
        # vocab-sharded ONLY: fsdp on the d axis makes the token gather
        # reshard pathologically (SPMD "involuntary full remat" warning)
        return P(_maybe(mesh, m, shape[0]), None)
    if name == "lm_head":
        return P(_maybe(mesh, fsdp, shape[0]), _maybe(mesh, m, shape[1]))
    if name == "vis_proj":
        return P(None, _maybe(mesh, m, shape[1]))

    # --- MoE expert banks: [.., E, d, f] / router [.., d, E] -----------
    if "moe" in names:
        if name in ("w_in", "w_gate"):
            return spec(_maybe(mesh, m, shape[-3]),
                        _maybe(mesh, fsdp, shape[-2]), None)
        if name == "w_out":
            return spec(_maybe(mesh, m, shape[-3]),
                        _maybe(mesh, fsdp, shape[-2]), None)
        if name == "router":
            return spec(_maybe(mesh, fsdp, shape[-2]), None)
        if name == "shared_in" or name == "shared_gate":
            return spec(_maybe(mesh, fsdp, shape[-2]),
                        _maybe(mesh, m, shape[-1]))
        if name == "shared_out":
            return spec(_maybe(mesh, m, shape[-2]),
                        _maybe(mesh, fsdp, shape[-1]))

    # --- attention ------------------------------------------------------
    # head-aware TP (§Perf internvl2 iter 4 + whisper regression fix):
    #   heads % tp == 0  -> aligned shard (ideal)
    #   heads >= tp      -> flat shard (heads split across shards; the
    #                       resharding cost beats 16x replicated compute —
    #                       measured: whisper prefill 20 heads @ tp=16)
    #   heads <  tp      -> replicate (flat sharding scatters single heads
    #                       over 2+ shards and gathers per use — measured:
    #                       internvl2 kv=8 @ tp=16 per-q-block all-gathers)
    tp = mesh.shape["model"] if "model" in mesh.axis_names else 1
    q_ok = cfg.n_heads >= tp if cfg.n_heads else False
    kv_ok = cfg.n_kv_heads >= tp if cfg.n_kv_heads else False
    if name in ("wq", "wq_b"):
        return spec(_maybe(mesh, fsdp, shape[-2]),
                    _maybe(mesh, m if q_ok else None, shape[-1]))
    if name in ("wk", "wv"):
        return spec(_maybe(mesh, fsdp, shape[-2]),
                    _maybe(mesh, m if kv_ok else None, shape[-1]))
    if name == "wkv_b":     # MLA: output is per-head (H)
        return spec(_maybe(mesh, fsdp, shape[-2]),
                    _maybe(mesh, m if q_ok else None, shape[-1]))
    if name in ("wq_a", "wkv_a"):
        return spec(_maybe(mesh, fsdp, shape[-2]),
                    _maybe(mesh, m, shape[-1]))
    if name == "wo":
        return spec(_maybe(mesh, m if q_ok else None, shape[-2]),
                    _maybe(mesh, fsdp, shape[-1]))

    # --- dense MLP -------------------------------------------------------
    if name in ("w_in", "w_gate"):
        return spec(_maybe(mesh, fsdp, shape[-2]),
                    _maybe(mesh, m, shape[-1]))
    if name == "w_out":
        return spec(_maybe(mesh, m, shape[-2]),
                    _maybe(mesh, fsdp, shape[-1]))
    if name == "proj":                           # mtp 2d->d projection
        return spec(_maybe(mesh, fsdp, shape[-2]),
                    _maybe(mesh, m, shape[-1]))

    # --- SSM -------------------------------------------------------------
    if name == "in_proj":
        return spec(_maybe(mesh, fsdp, shape[-2]),
                    _maybe(mesh, m, shape[-1]))
    if name == "out_proj":
        return spec(_maybe(mesh, m, shape[-2]),
                    _maybe(mesh, fsdp, shape[-1]))
    if name == "conv_w":
        return spec(None, _maybe(mesh, m, shape[-1]))
    if name in ("conv_b", "norm_w"):
        return spec(_maybe(mesh, m, shape[-1]))

    # --- norms / scalars / vectors → replicate ---------------------------
    return P(*([None] * nd))


def param_shardings(params_shapes: Any, cfg: ArchConfig, run: RunConfig,
                    mesh) -> Any:
    """Pytree of NamedSharding matching a pytree of ShapeDtypeStruct."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    out = []
    for path, leaf in flat:
        spec = param_spec_for(path, leaf.shape, cfg, run, mesh)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def opt_state_shardings(opt_shapes: Any, params_shapes: Any,
                        cfg: ArchConfig, run: RunConfig, mesh) -> Any:
    """Optimizer moments follow their parameter's sharding (8-bit scale
    tensors drop the last dim's sharding entry)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(opt_shapes)
    out = []
    for path, leaf in flat:
        names = _path_names(path)
        if names and names[0] == "step":
            out.append(NamedSharding(mesh, P()))
            continue
        # path looks like ('m'|'v', <param path...>[, 'q'|'s'])
        sub = [p for p in path[1:]]
        if names[-1] in ("q", "s"):
            sub = sub[:-1]
        spec = param_spec_for(sub, leaf.shape, cfg, run, mesh) \
            if sub else P()
        entries = list(spec)
        if names[-1] == "s":                     # scale: last dim is 1
            entries = (entries + [None] * (len(leaf.shape) - len(entries)))
            entries = entries[:len(leaf.shape)]
            if entries:
                entries[-1] = None
        # pad/trim to rank
        entries = (entries + [None] * (len(leaf.shape) - len(entries)))
        entries = entries[:len(leaf.shape)]
        out.append(NamedSharding(mesh, P(*entries)))
    return jax.tree_util.tree_unflatten(treedef, out)


# ----------------------------------------------------------------------
# batch / cache
# ----------------------------------------------------------------------
def batch_shardings(batch_shapes: Any, mesh,
                    run: Optional[RunConfig] = None) -> Any:
    dp = _dp_axes(mesh) if run is None or run.batch_axes != "all" \
        else tuple(mesh.axis_names)

    def one(leaf):
        if not leaf.shape:
            return NamedSharding(mesh, P())
        # largest prefix of dp axes that divides the batch dim — a batch
        # of 32 on a 256-chip mesh still shards 16-way over "data" instead
        # of replicating outright (§Perf mamba2 iter 1: the old
        # all-or-nothing fallback replicated prefill activations 256×)
        axes: list = []
        size = 1
        for a in dp:
            if leaf.shape[0] % (size * mesh.shape[a]) == 0:
                axes.append(a)
                size *= mesh.shape[a]
            else:
                break
        if axes:
            return NamedSharding(
                mesh, P(tuple(axes), *([None] * (len(leaf.shape) - 1))))
        return NamedSharding(mesh, P(*([None] * len(leaf.shape))))

    return jax.tree.map(one, batch_shapes)


def cache_shardings(cache_shapes: Any, cfg: ArchConfig, mesh) -> Any:
    """Decode caches: [R, B, T, ...] (attn) / [R, B, ...] (ssm).

    B over dp when divisible; the cache sequence dim T over "model", and
    over ("data","model") combined when B==1 (long-context single-stream).
    """
    dp = _dp_axes(mesh)

    def one(path, leaf):
        names = _path_names(path)
        shape = leaf.shape
        nd = len(shape)
        entries: list = [None] * nd
        leaf_name = names[-1]
        if leaf_name in ("k", "v", "ckv", "kr"):     # [R,B,T,...]
            b_ax = _maybe(mesh, dp, shape[1])
            entries[1] = b_ax
            seq_axes = ("model",) if b_ax else tuple(
                a for a in mesh.axis_names)
            entries[2] = _maybe(mesh, seq_axes, shape[2])
        elif leaf_name in ("conv", "state"):          # [R,B,...]
            entries[1] = _maybe(mesh, dp, shape[1])
        return NamedSharding(mesh, P(*entries))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    out = [one(path, leaf) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, out)
