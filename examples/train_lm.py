"""End-to-end training driver: a real LM trained for a few hundred steps
on the synthetic pipeline, with checkpoint/restart fault tolerance and
the MXDAG-planned gradient sync.

The model is the deepseek-7b architecture scaled to ~20M params (CPU
container; the full configs are exercised by the dry-run).  Loss descends
from ~8.3 to <1 on the learnable synthetic stream; a simulated failure at
step 120 exercises the restart path.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 240]
"""
import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax

from repro import configs
from repro.configs.base import RunConfig
from repro.data import DataConfig, SyntheticLM
from repro.launch.train import init_train_state, make_train_step
from repro.models import Model
from repro.optim import AdamW, AdamWConfig, cosine_schedule
from repro.runtime import LoopConfig, StepMonitor, run_training
from repro.sync.plan import plan_sync
from repro.configs.base import SHAPES


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=240)
    p.add_argument("--ckpt-dir", default="/tmp/repro_example_ckpt")
    args = p.parse_args()

    # deepseek-7b family at ~20M params
    cfg = dataclasses.replace(
        configs.get("deepseek-7b"), name="deepseek-20m",
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=8, head_dim=32,
        d_ff=1024, vocab_size=4096)
    n = cfg.param_counts()["total"]
    print(f"arch: {cfg.name} ({n/1e6:.1f}M params)")

    # the MXDAG plan for this arch at PRODUCTION scale (what the paper's
    # scheduler decides for the real 256-chip run)
    plan = plan_sync(configs.get("deepseek-7b"), SHAPES["train_4k"])
    print(f"MXDAG sync plan @256 chips: mode={plan.mode}, "
          f"predicted {plan.predicted_barrier:.3f}s -> "
          f"{plan.predicted_bucketed:.3f}s "
          f"({(plan.predicted_speedup-1)*100:.1f}% step-time win), "
          f"order={plan.order[:4]}...")

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    run = RunConfig(sync_mode=plan.mode, remat=True, microbatches=1)
    model = Model(cfg, run, mesh=mesh)
    opt = AdamW(AdamWConfig(
        lr=cosine_schedule(1e-3, warmup=20, total=args.steps),
        weight_decay=0.01))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=256,
                                  global_batch=8))

    step_fn = jax.jit(make_train_step(model, opt, run), donate_argnums=0)
    monitor = StepMonitor()

    def on_step(step, metrics):
        if step % 20 == 0 or step == args.steps - 1:
            print(f"  step {step:4d}  loss {float(metrics['loss']):.4f}")

    t0 = time.monotonic()
    summary = run_training(
        LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                   ckpt_every=60, fail_at_step=120),   # injected failure!
        train_step=step_fn,
        init_state=lambda: init_train_state(model, opt, run,
                                            jax.random.PRNGKey(0)),
        batch_at=data.batch_at,
        monitor=monitor,
        on_step=on_step)
    dt = time.monotonic() - t0
    first, last = summary["loss_history"][0], summary["loss_history"][-1]
    print(f"\ndone: {args.steps} steps in {dt:.0f}s, "
          f"restarts={summary['restarts']} (failure injected at step 120, "
          f"resumed from checkpoint), loss {first:.3f} -> {last:.3f}")
    assert summary["restarts"] == 1 and last < first


if __name__ == "__main__":
    main()
