"""Co-scheduling on an oversubscribed multi-tier fabric.

The paper's single-switch figures charge a flow only against its endpoint
NICs.  Real clusters are oversubscribed: a rack's uplink carries a fraction
of its hosts' NIC bandwidth (4:1 here), so cross-rack flows contend *inside*
the fabric — contention a big-switch model cannot even represent.  This
example shows:

1. on a 4:1 oversubscribed two-tier core, MXDAG priority co-scheduling
   strictly beats fair sharing (the critical flow gets the whole uplink
   first instead of 1/4 of it),
2. ``whatif.resize_fabric`` answers "is this job core-bound?": fair sharing
   would need 4x the fabric to match what co-scheduling achieves on the
   oversubscribed core with zero extra hardware.

Run:  PYTHONPATH=src python examples/oversubscribed_fabric.py
"""
import sys
sys.path.insert(0, "src")

from repro.core import FairShareScheduler, MXDAGScheduler, WhatIf
from repro.core.builders import oversubscribed_fanin

OVERSUB = 4.0
g, cluster = oversubscribed_fanin(n_senders=4, oversubscription=OVERSUB)
uplink = cluster.topology.capacity("rack0.up")
print(f"{g.name}: 4 cross-rack flows, rack0 uplink capacity {uplink:g} "
      f"({OVERSUB:g}:1 oversubscribed)")
print(f"  flow f0 feeds the critical 8s compute; f1..f3 feed 1s computes\n")

fair = FairShareScheduler().schedule(g, cluster).simulate(cluster)
sched = MXDAGScheduler(try_pipelining=False).schedule(g, cluster)
mx = sched.simulate(cluster)
print(f"  fair sharing makespan:      {fair.makespan:.3f} s "
      "(uplink split 4 ways; critical flow crawls)")
print(f"  MXDAG priority makespan:    {mx.makespan:.3f} s "
      f"(critical path {sched.meta['critical_path']})")
assert mx.makespan < fair.makespan - 1e-9, \
    "priority co-scheduling must strictly beat fair sharing here"
print(f"  speedup: {fair.makespan / mx.makespan:.2f}x\n")

# what-if: how much fabric would fair sharing need to catch up?
fair_whatif = WhatIf(g, cluster, scheduler=FairShareScheduler())
r = fair_whatif.resize_fabric(scale=OVERSUB)       # undo the oversubscription
print(f"  fair @ full bisection (resize_fabric x{OVERSUB:g}): "
      f"{r.variant:.3f} s  (was {r.baseline:.3f} s)")
mx_whatif = WhatIf(g, cluster)                     # MXDAG scheduler default
r2 = mx_whatif.resize_fabric(scale=OVERSUB)
print(f"  MXDAG @ full bisection:                        "
      f"{r2.variant:.3f} s  (was {r2.baseline:.3f} s)")
assert abs(r2.variant - r2.baseline) < 1e-9
print("\n  => co-scheduling already achieves the full-bisection makespan "
      "on the 4:1 core:\n     the job is core-bound only under fair "
      "sharing, not under MXDAG priorities.")
