"""Numerical reproductions of every worked example in the paper.

The paper has no measured-evaluation section; its claims are the five
worked examples (Figs. 1, 2, 3, 6, 7).  Each function below reproduces
one of them in the discrete-event simulator and returns
(name, value_us, derived) rows for the CSV driver, where `derived`
states the claim being validated.
"""
from __future__ import annotations

from repro.core import (
    AltruisticMultiScheduler, CoflowConfig, FairShareScheduler, MXDAG,
    MXDAGScheduler, simulate,
)
from repro.core import builders


def fig1():
    """Fig. 1: network-compute co-scheduling beats fair sharing."""
    g = builders.fig1_jobs()
    fair = FairShareScheduler().schedule(g).simulate()
    mx = MXDAGScheduler().schedule(g).simulate()
    rows = [
        ("fig1.fair_share_T1", fair.makespan,
         "network-aware fair sharing (Fig. 1b)"),
        ("fig1.coschedule_T2", mx.makespan,
         "MXDAG co-scheduling (Fig. 1c)"),
        ("fig1.claim_T2_lt_T1", float(mx.makespan < fair.makespan),
         "paper claim: task on C starts earlier (1.0 = validated)"),
    ]
    return rows


def fig2():
    """Fig. 2: every coflow grouping of an asymmetric DAG is suboptimal."""
    rows = []
    g = builders.fig2a(t1=3.0, t2=1.0)
    mx = MXDAGScheduler().schedule(g).simulate()
    cof = CoflowConfig(builders.fig2a_coflows()).schedule(g).simulate()
    rows += [
        ("fig2a.mxdag", mx.makespan, "per-flow optimal (Fig. 2c left)"),
        ("fig2a.coflow", cof.makespan, "coflow {f1,f2},{f3,f4} (Fig. 2c)"),
        ("fig2a.claim", float(mx.makespan < cof.makespan),
         "asymmetric compute times: coflow suboptimal (1.0 = validated)"),
    ]
    g = builders.fig2b()
    mx = MXDAGScheduler().schedule(g).simulate()
    rows.append(("fig2b.mxdag", mx.makespan,
                 "per-flow optimal (Fig. 2d left)"))
    for v in ("b1", "b2", "b3"):
        cof = CoflowConfig(builders.fig2b_coflows(v)).schedule(g).simulate()
        rows.append((f"fig2b.coflow_{v}", cof.makespan,
                     f"grouping {v} of Fig. 2(b{v[1]})"))
        rows.append((f"fig2b.claim_{v}",
                     float(mx.makespan < cof.makespan),
                     "all three ambiguous groupings suboptimal"))
    return rows


def fig3():
    """Fig. 3: pipelining — no-op off the critical path, win on it,
    loss when it induces NIC contention on it."""
    prio = MXDAGScheduler(try_pipelining=False) \
        .schedule(builders.fig3_case(0)).priorities
    ms = {c: simulate(builders.fig3_case(c), policy="priority",
                      priorities=prio).makespan for c in range(4)}
    sched = MXDAGScheduler(try_pipelining=True).schedule(builders.fig3())
    rows = [
        ("fig3.baseline", ms[0], "no pipelining (Fig. 3b)"),
        ("fig3.case1", ms[1], "pipeline flow4 off critical path (Fig. 3c)"),
        ("fig3.case2", ms[2], "+ pipeline flow1 on critical path (Fig. 3d)"),
        ("fig3.case3", ms[3], "+ pipeline flow3: NIC contention (Fig. 3e)"),
        ("fig3.claim_case1_noop", float(abs(ms[1] - ms[0]) < 1e-9),
         "case1 == baseline (1.0 = validated)"),
        ("fig3.claim_case2_wins", float(ms[2] < ms[0]),
         "case2 < baseline (1.0 = validated)"),
        ("fig3.claim_case3_hurts", float(ms[3] > ms[0]),
         "case3 > baseline (1.0 = validated)"),
        ("fig3.scheduler_choice", sched.simulate().makespan,
         f"Principle-1 greedy keeps only helpful pipelines "
         f"{sched.meta['pipelined']}"),
    ]
    return rows


def fig6():
    """Fig. 6 / §4.1.1: layer-wise DDL sync recovers ByteScheduler."""
    g = builders.ddl(4, push=2.0, pull=2.0)
    fair = FairShareScheduler().schedule(g).simulate()
    sched = MXDAGScheduler(try_pipelining=False).schedule(g)
    mx = sched.simulate()
    pr = {k: v for k, v in sched.priorities.items()
          if k.startswith("push")}
    order = sorted(pr, key=lambda k: pr[k])
    bytescheduler_order = [f"push{i}" for i in range(4)]
    rows = [
        ("fig6.fair", fair.makespan, "FIFO/fair gradient sync"),
        ("fig6.mxdag", mx.makespan, "MXDAG critical-path priorities"),
        ("fig6.claim_order", float(order == bytescheduler_order),
         f"priority order {order} == ByteScheduler lower-layer-first"),
        ("fig6.claim_speedup", fair.makespan / mx.makespan,
         "comm-bound speedup from co-scheduling (>1)"),
    ]
    # the production-scale plan for an assigned arch (sync/plan.py)
    from repro.configs import get, SHAPES
    from repro.sync.plan import plan_sync
    plan = plan_sync(get("deepseek-coder-33b"), SHAPES["train_4k"])
    rows.append(("fig6.plan_33b_speedup", plan.predicted_speedup,
                 f"deepseek-coder-33b train_4k @256 chips: mode="
                 f"{plan.mode}, bucketed {plan.predicted_bucketed:.3f}s "
                 f"vs barrier {plan.predicted_barrier:.3f}s"))
    return rows


def fig7():
    """Fig. 7 / §4.2.1: altruistic multi-job scheduling."""
    j1, j2 = builders.mapreduce_pair()
    merged = MXDAG("merged")
    for t in list(j1) + list(j2):
        merged.add(t)
    for e in list(j1.edges.values()) + list(j2.edges.values()):
        merged.add_edge(e.src, e.dst)
    naive = simulate(merged, policy="fair")
    alt = AltruisticMultiScheduler().schedule([j1, j2]).simulate()
    rows = [
        ("fig7.naive_job1", naive.jct("job1"), "fair sharing"),
        ("fig7.naive_job2_T2", naive.jct("job2"), "fair sharing"),
        ("fig7.altruistic_job1", alt.jct("job1"), "Principle 2"),
        ("fig7.altruistic_job2_T1", alt.jct("job2"), "Principle 2"),
        ("fig7.claim_job2_faster", float(alt.jct("job2") < naive.jct("job2")),
         "job2 finishes at T1 < T2 (1.0 = validated)"),
        ("fig7.claim_job1_unharmed",
         float(alt.jct("job1") <= naive.jct("job1") + 1e-9),
         "job1 completion unchanged (1.0 = validated)"),
    ]
    return rows


ALL = [fig1, fig2, fig3, fig6, fig7]


# ----------------------------------------------------------------------
# bake-off figure (pure-stdlib SVG; matplotlib is not a dependency)
# ----------------------------------------------------------------------

#: algo → (fill, legend label); order = drawing order within a group.
#: Colors are a colorblind-safe qualitative palette (Tol bright);
#: baselines in muted tones, MXDAG the saturated green contender.
_BAR_STYLE = [
    ("fair", "#bbbbbb", "fair sharing"),
    ("sebf", "#4477aa", "SEBF (Varys)"),
    ("sg_coflow", "#66ccee", "coflow DAG (S&amp;G)"),
    ("graphene", "#ee6677", "Graphene"),
    ("metaflow", "#ccbb44", "Metaflow"),
    ("mxdag", "#228833", "MXDAG"),
]


def bakeoff_figure(results: dict, path: str) -> None:
    """Write the bake-off comparison as a grouped-bar SVG.

    One group per scenario, one bar per scheduler, height = makespan
    normalized to MXDAG's on that scenario (so the 3-second shuffle and
    the 489-second DDL step share an axis; MXDAG is the 1.0 reference
    line and a taller bar means a slower schedule).  Bars more than 2%
    above 1.0 carry their ratio as a label.  Pure string assembly — no
    plotting dependency — and a pure function of ``results``, so the
    committed ``docs/bakeoff.svg`` is reproducible byte-for-byte.

    :param results: scenario → algo → makespan, as from
        :func:`benchmarks.bakeoff.sweep`.
    :param path: output ``.svg`` path.
    """
    scen = list(results)
    bw, gap, group_gap = 13, 2, 26           # bar/intra/inter spacing
    gw = len(_BAR_STYLE) * (bw + gap) - gap  # one group's width
    ml, mr, mt, mb = 46, 10, 34, 78          # margins (mb: tilted labels)
    w = ml + mr + len(scen) * (gw + group_gap) - group_gap
    h, ph = 330, 200                         # total / plot height
    ymax = 2.0
    for name, res in results.items():
        ymax = max(ymax, max(res.values()) / res["mxdag"])
    ymax = (int(ymax * 4) + 1) / 4           # headroom, 0.25 grid step

    def y(v: float) -> float:
        return mt + ph * (1.0 - v / ymax)

    out = [f'<svg xmlns="http://www.w3.org/2000/svg" width="{w}" '
           f'height="{h}" viewBox="0 0 {w} {h}" '
           f'font-family="sans-serif" font-size="11">',
           f'<rect width="{w}" height="{h}" fill="white"/>',
           '<text x="6" y="16" font-size="13" font-weight="bold">'
           'Makespan relative to MXDAG (lower is better)</text>']
    grid = [i / 4 for i in range(int(ymax * 4) + 1)]
    for v in grid:
        yy = y(v)
        stroke = 'stroke="#888888" stroke-dasharray="4 3"' \
            if v == 1.0 else 'stroke="#e0e0e0"'
        out.append(f'<line x1="{ml}" y1="{yy:.1f}" x2="{w - mr}" '
                   f'y2="{yy:.1f}" {stroke}/>')
        if v * 2 == int(v * 2):              # label only 0.5 steps
            out.append(f'<text x="{ml - 6}" y="{yy + 4:.1f}" '
                       f'text-anchor="end" fill="#555555">'
                       f'{v:g}&#215;</text>')
    for si, name in enumerate(scen):
        x0 = ml + si * (gw + group_gap)
        ref = results[name]["mxdag"]
        for bi, (algo, fill, _) in enumerate(_BAR_STYLE):
            ratio = results[name][algo] / ref
            bx = x0 + bi * (bw + gap)
            by = y(ratio)
            out.append(f'<rect x="{bx}" y="{by:.1f}" width="{bw}" '
                       f'height="{y(0) - by:.1f}" fill="{fill}"/>')
            if ratio > 1.02:
                out.append(f'<text x="{bx + bw / 2:.1f}" '
                           f'y="{by - 3:.1f}" text-anchor="middle" '
                           f'font-size="9" fill="#333333">'
                           f'{ratio:.2f}</text>')
        lx, ly = x0 + gw / 2, y(0) + 12
        out.append(f'<text x="{lx:.1f}" y="{ly:.1f}" '
                   f'text-anchor="end" fill="#333333" transform='
                   f'"rotate(-30 {lx:.1f} {ly:.1f})">{name}</text>')
    lx = ml
    ly = h - 12
    for algo, fill, label in _BAR_STYLE:
        out.append(f'<rect x="{lx}" y="{ly - 9}" width="10" height="10" '
                   f'fill="{fill}"/>')
        out.append(f'<text x="{lx + 14}" y="{ly}">{label}</text>')
        lx += 14 + 7 * len(label) + 18
    out.append('</svg>')
    with open(path, "w") as f:
        f.write("\n".join(out) + "\n")
