"""Cluster resource model: hosts with processor pools, NICs, and an
optional link-level fabric topology.

Resource naming convention (matches ``MXTask.resources()``):

- ``"<host>.<proc>"``   — a processor pool with an integer slot count
  (compute tasks occupy one slot exclusively, non-preemptively),
- ``"<host>.nic_out"`` / ``"<host>.nic_in"`` — NIC directions with a float
  capacity (flows share them; rate allocation is policy-driven and
  preemptible, reflecting the paper's observation that network tasks cannot
  be isolated the way compute tasks can),
- any other name — a fabric link (ToR uplink, spine link, ...) owned by the
  cluster's :class:`~repro.core.fabric.Topology`.

Without a topology a flow occupies exactly its two endpoint NICs (the seed
"big switch" model).  With one, it occupies every link on its static route,
of which the endpoint NICs are the first and last — so single-switch
topologies reproduce the endpoint-only results exactly.

Capacities are normalized: a flow of ``size`` seconds completes in ``size``
seconds when allocated rate 1.0.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional

from repro.core.fabric import Topology
from repro.core.graph import MXDAG
from repro.core.task import MXTask, TaskKind


@dataclasses.dataclass(frozen=True)
class Host:
    """One machine: processor slot pools plus NIC capacities."""

    name: str
    procs: Mapping[str, int] = dataclasses.field(
        default_factory=lambda: {"cpu": 1})
    nic_in: float = 1.0
    nic_out: float = 1.0


class Cluster:
    """The resource model: named hosts, optionally under a Topology."""

    def __init__(self, hosts: list[Host],
                 topology: Optional[Topology] = None) -> None:
        self.hosts = {h.name: h for h in hosts}
        self.topology = topology
        if topology is not None:
            missing = [h for h in self.hosts if h not in topology.hosts()]
            if missing:
                raise ValueError(f"hosts not in topology: {missing}")

    @classmethod
    def homogeneous(cls, names: list[str], *, procs: Mapping[str, int] | None = None,
                    nic: float = 1.0) -> "Cluster":
        """A cluster of identical hosts (no fabric)."""
        return cls([Host(n, procs=dict(procs or {"cpu": 1}),
                         nic_in=nic, nic_out=nic) for n in names])

    @classmethod
    def for_graph(cls, g: MXDAG, *, nic: float = 1.0,
                  topology: Optional[Topology] = None) -> "Cluster":
        """Build a sufficient homogeneous cluster for a graph's placements."""
        names: set[str] = set()
        procs: dict[str, int] = {}
        for t in g:
            if not t.bound:
                raise ValueError(
                    f"cannot build a cluster for {g.name}: task {t.name} "
                    f"is unbound (apply a placement with MXDAG.bind, or "
                    f"let MXDAGScheduler place it on an explicit cluster)")
            if t.kind is TaskKind.COMPUTE:
                names.add(t.host)  # type: ignore[arg-type]
                procs[t.proc] = 1
            else:
                names.add(t.src)   # type: ignore[arg-type]
                names.add(t.dst)   # type: ignore[arg-type]
        procs = procs or {"cpu": 1}
        if topology is not None:
            if nic != 1.0:
                raise ValueError("with a topology, NIC capacities come "
                                 "from its links; don't pass nic")
            return cls.from_topology(topology, procs=procs).restricted(names)
        return cls.homogeneous(sorted(names), procs=procs, nic=nic)

    @classmethod
    def from_topology(cls, topology: Topology, *,
                      procs: Mapping[str, int] | None = None) -> "Cluster":
        """One host per topology endpoint; NIC caps read off the NIC links."""
        hosts = [Host(h, procs=dict(procs or {"cpu": 1}),
                      nic_in=topology.capacity(f"{h}.nic_in"),
                      nic_out=topology.capacity(f"{h}.nic_out"))
                 for h in topology.hosts()]
        return cls(hosts, topology=topology)

    def restricted(self, names: set[str]) -> "Cluster":
        """The sub-cluster of ``names`` (topology, with its full link set,
        is kept — other hosts' flows just never appear)."""
        return Cluster([h for n, h in self.hosts.items() if n in names],
                       topology=self.topology)

    # ------------------------------------------------------------------
    def slots(self, resource: str) -> int:
        """Slot count of a ``<host>.<pool>`` processor resource."""
        host, pool = resource.rsplit(".", 1)
        return int(self.hosts[host].procs.get(pool, 0))

    def bandwidth(self, resource: str) -> float:
        """Capacity of a NIC or fabric link (topology wins when present)."""
        if self.topology is not None and resource in self.topology.links:
            return self.topology.capacity(resource)
        host, direction = resource.rsplit(".", 1)
        h = self.hosts[host]
        return h.nic_out if direction == "nic_out" else h.nic_in

    def bandwidths(self, resources) -> dict[str, float]:
        """Capacity index for a set of links, resolved once.

        The simulator's event loop rebuilds residual capacities at every
        rate reallocation; resolving each link's capacity through the
        topology/NIC lookup there would re-parse resource names per event.
        """
        return {r: self.bandwidth(r) for r in set(resources)}

    def resources_for(self, task: MXTask,
                      route: Optional[tuple[str, ...]] = None,
                      ) -> tuple[str, ...]:
        """The resources ``task`` occupies on *this* cluster.

        Compute tasks: their processor pool.  Flows: the full link path
        under the cluster's topology, or the two endpoint NICs without
        one.  ``route`` overrides a flow's path with an explicit link
        tuple — a per-flow routing decision (normally one member of
        :meth:`candidate_routes`) that wins over the topology's static
        ECMP pick.
        """
        if route is not None:
            if task.kind is not TaskKind.NETWORK:
                raise ValueError(f"{task.name}: only network tasks "
                                 f"take a route override")
            return tuple(route)
        if task.kind is TaskKind.COMPUTE or self.topology is None:
            return task.resources()
        return task.resources(self.topology)

    def candidate_routes(self, task: MXTask) -> tuple[tuple[str, ...], ...]:
        """All routes a flow could take on this cluster (the ECMP group
        under a fabric topology; just the endpoint-NIC path without one).
        ``resources_for(task)`` is always a member."""
        if task.kind is not TaskKind.NETWORK:
            raise ValueError(f"{task.name}: compute tasks are not routed")
        if self.topology is None:
            return (task.resources(),)
        return self.topology.paths(task.src, task.dst)

    def without_hosts(self, names: set[str]) -> "Cluster":
        """The surviving cluster after losing ``names`` (the fault-model
        complement of :meth:`restricted`).  The topology keeps its full
        link set — a dead host's links simply carry no flows, exactly as
        the replanner's belief should model a crashed-but-cabled machine."""
        return Cluster([h for n, h in self.hosts.items() if n not in names],
                       topology=self.topology)

    def degraded(self, links: Mapping[str, float]) -> "Cluster":
        """A copy with the given link capacities (absolute values, NICs
        included) — the replanner's belief of a degraded fabric.  Works
        with or without a topology: fabric links are resized through it,
        NIC entries also patch the Host records so big-switch clusters
        (whose compile reads NIC caps off the hosts) degrade identically."""
        topo = self.topology
        if topo is not None:
            in_topo = {k: v for k, v in links.items() if k in topo.links}
            if in_topo:
                topo = topo.resized(links=in_topo)
            unknown = [k for k in links if k not in self.topology.links]
        else:
            unknown = list(links)
        hosts = []
        for h in self.hosts.values():
            ni = links.get(f"{h.name}.nic_in", h.nic_in)
            no = links.get(f"{h.name}.nic_out", h.nic_out)
            unknown = [k for k in unknown
                       if k not in (f"{h.name}.nic_in", f"{h.name}.nic_out")]
            hosts.append(h if (ni == h.nic_in and no == h.nic_out)
                         else dataclasses.replace(h, nic_in=ni, nic_out=no))
        if unknown:
            raise KeyError(f"unknown links: {sorted(unknown)}")
        return Cluster(hosts, topology=topo)

    def with_topology(self, topology: Optional[Topology]) -> "Cluster":
        """Same hosts, different fabric (used by what-if queries)."""
        return Cluster(list(self.hosts.values()), topology=topology)

    def signature(self) -> tuple:
        """Hashable identity: hosts (with pools and NIC caps) and fabric
        links.  Two clusters with equal signatures produce identical
        simulations for any graph; keys what-if memo caches (and any
        other cache that must distinguish cluster variants, e.g. resized
        fabrics, without holding object identity)."""
        topo = self.topology
        return (tuple(sorted((h.name, tuple(sorted(h.procs.items())),
                              h.nic_in, h.nic_out)
                             for h in self.hosts.values())),
                None if topo is None else tuple(sorted(topo.links.items())))
