"""Mega-batch event loop differentials.

The array engine's batched loop (``batch=True``, the default) pops
every event sharing the next timestamp and runs vectorized
integration/completion/start passes over the batch; ``batch=False`` is
the per-event loop kept verbatim as the differential oracle.  These
tests pin the contract:

1. batched == per-event **exactly** (per-task start/finish, makespan,
   job completion) on every builder scenario family, and both agree
   with the event-calendar core to EPS;
2. :class:`ResumableSim` pause / checkpoint / restore-fork at batch
   boundaries is bit-exact under the batched loop, including with
   nemesis mutators applied mid-run (same mutations under both loops
   ⇒ same results);
3. a hypothesis sweep over random layered DAGs (skipped when
   hypothesis isn't installed).

Without numpy the batched passes degrade to the scalar loop, so the
equalities hold trivially — the file stays meaningful in the
numpy-free core CI lane via the calendar-core comparisons.
"""
import math

import pytest

from repro.core import Cluster, builders
from repro.core.arraysim import ResumableSim, array_run
from repro.core.schedule import MXDAGScheduler
from repro.core.simulator import Simulator


def scenarios():
    """(name, Simulator factory) covering every builder family —
    coflows, pipelining, priorities, releases, fabrics, routing."""
    def fanin():
        g, cl = builders.oversubscribed_fanin(8, oversubscription=4.0)
        return Simulator(g, cl)

    def fanin_prio():
        g, cl = builders.oversubscribed_fanin(6, oversubscription=6.0)
        s = MXDAGScheduler(try_pipelining=False).schedule(g, cl)
        return Simulator(s.graph, cl, policy=s.policy,
                         priorities=s.priorities, releases=s.releases)

    def shuffle():
        g, cl = builders.fat_tree_shuffle(8, stride=2)
        return Simulator(g, cl)

    def ddl():
        g = builders.ddl(8, push=2.0, pull=2.0, unit_frac=0.25)
        return Simulator(g, Cluster.for_graph(g))

    def layered():
        g = builders.random_layered(300, n_hosts=16, min_width=4,
                                    max_width=16, seed=5)
        return Simulator(g, Cluster.for_graph(g))

    def coflows():
        g = builders.fig2a()
        return Simulator(g, coflows=builders.fig2a_coflows())

    def mapreduce():
        return Simulator(builders.mapreduce("mr", 8, 8, unit_frac=0.125))

    return [("fanin", fanin), ("fanin_prio", fanin_prio),
            ("shuffle", shuffle), ("ddl_pipelined", ddl),
            ("layered", layered), ("coflows", coflows),
            ("mapreduce_piped", mapreduce)]


def assert_bitexact(a, b):
    assert a.start == b.start
    assert a.finish == b.finish
    assert a.makespan == b.makespan
    assert a.job_completion == b.job_completion


@pytest.mark.parametrize("name,mk", scenarios())
class TestBatchedEqualsPerEvent:
    def test_batch_vs_perevent_vs_calendar(self, name, mk):
        batched = mk().run(batch=True)
        perevent = mk().run(batch=False)
        assert_bitexact(batched, perevent)
        cal = mk().calendar_run()
        for n in cal.finish:
            assert batched.finish[n] == pytest.approx(cal.finish[n],
                                                      abs=1e-9), n
        assert batched.makespan == pytest.approx(cal.makespan, abs=1e-9)

    def test_array_run_batch_flag(self, name, mk):
        assert_bitexact(array_run(mk(), batch=True),
                        array_run(mk(), batch=False))


@pytest.mark.parametrize("name,mk", scenarios())
class TestResumableBatchBoundaries:
    """Pausing cuts between batches, never through one — so a paused,
    checkpointed or forked batched session must replay bit-exactly."""

    def test_paused_run_bitexact(self, name, mk):
        ref = array_run(mk(), batch=True)
        rs = ResumableSim(mk(), batch=True)
        t, status = 0.0, "paused"
        while status == "paused":
            status = rs.run_until(t)
            t += 0.5
        assert status == "done"
        assert_bitexact(rs.result(), ref)

    def test_checkpoint_fork_bitexact(self, name, mk):
        ref = array_run(mk(), batch=True)
        rs = ResumableSim(mk(), batch=True)
        rs.run_until(ref.makespan * 0.4)
        snap = rs.checkpoint()
        assert rs.run_until(math.inf) == "done"
        assert_bitexact(rs.result(), ref)
        rs.restore(snap)
        assert rs.run_until(math.inf) == "done"
        assert_bitexact(rs.result(), ref)

    def test_mutators_agree_across_loops(self, name, mk):
        """The same nemesis mutations applied at the same pause point
        must produce identical runs under both loops — faults don't
        re-introduce a batched/per-event divergence."""
        ref = array_run(mk(), batch=True)
        sample = mk()
        victims = sorted(sample.g.tasks)[: 2]

        def faulted(batch):
            rs = ResumableSim(mk(), batch=batch)
            rs.run_until(ref.makespan * 0.3)
            for v in victims:
                rs.set_speed(v, 0.5)
            assert rs.run_until(math.inf) == "done"
            return rs.result()

        assert_bitexact(faulted(True), faulted(False))


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # the numpy-free core lane installs it, but
    HAVE_HYPOTHESIS = False  # a bare checkout may not


if HAVE_HYPOTHESIS:
    class TestBatchedProperty:
        @given(seed=st.integers(0, 10_000),
               n=st.integers(40, 220))
        @settings(max_examples=15, deadline=None)
        def test_random_layered_bitexact(self, seed, n):
            g = builders.random_layered(n, n_hosts=8, min_width=2,
                                        max_width=10, seed=seed)
            cl = Cluster.for_graph(g)
            assert_bitexact(Simulator(g, cl).run(batch=True),
                            Simulator(g, cl).run(batch=False))
