"""Small-mesh dry-run smoke: the full lowering machinery (sharding rules,
input specs, train/serve step assembly, roofline extraction) exercised on
an 8-device mesh in a subprocess, for one arch per family."""
import json
import os
import subprocess
import sys

import pytest

pytestmark = [pytest.mark.slow, pytest.mark.jax]

_PROBE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.configs.base import RunConfig, ShapeConfig
from repro.launch import hlo_analysis, sharding as shard_lib
from repro.launch.mesh import dp_axes
from repro.launch.specs import decode_specs, input_specs
from repro.launch.train import (init_train_state, make_train_step,
                                model_flops, state_shardings)
from repro.launch.serve import make_serve_step
from repro.models import Model
from repro.optim import AdamW, AdamWConfig

out = {}
mesh = jax.make_mesh((4, 2), ("data", "model"))
shape = ShapeConfig("tiny_train", 64, 8, "train")
dshape = ShapeConfig("tiny_decode", 64, 8, "decode")

for arch in ["deepseek-7b", "olmoe-1b-7b", "mamba2-130m",
             "whisper-large-v3", "internvl2-2b"]:
    cfg = dataclasses.replace(
        configs.get_smoke(arch), vocab_size=512)
    run = RunConfig(remat=True, microbatches=2)
    model = Model(cfg, run, mesh=mesh, dp_axes=dp_axes(mesh))
    rec = {}
    with mesh:
        opt = AdamW(AdamWConfig())
        ss = jax.eval_shape(lambda: init_train_state(
            model, opt, run, jax.random.PRNGKey(0)))
        batch = input_specs(cfg, shape)
        comp = jax.jit(make_train_step(model, opt, run),
                       in_shardings=(state_shardings(ss, cfg, run, mesh),
                                     shard_lib.batch_shardings(batch, mesh,
                                                               run)),
                       donate_argnums=0).lower(ss, batch).compile()
        roof = hlo_analysis.analyze(comp, 8,
                                    model_flops=model_flops(cfg, shape))
        rec["train"] = {"flops": roof.flops, "bytes": roof.hbm_bytes,
                        "coll": roof.coll_bytes,
                        "mem": hlo_analysis.memory_summary(comp)[
                            "peak_estimate_bytes"]}
        # decode
        ps = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        tokens, cache, index = decode_specs(model, cfg, dshape)
        comp2 = jax.jit(make_serve_step(model),
                        in_shardings=(
                            shard_lib.param_shardings(ps, cfg, run, mesh),
                            shard_lib.cache_shardings(cache, cfg, mesh),
                            shard_lib.batch_shardings(tokens, mesh, run),
                            NamedSharding(mesh, P())),
                        donate_argnums=1
                        ).lower(ps, cache, tokens, index).compile()
        roof2 = hlo_analysis.analyze(comp2, 8)
        rec["decode_flops"] = roof2.flops
    out[arch] = rec
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def probe():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    res = subprocess.run([sys.executable, "-c", _PROBE],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))),
                         timeout=1200)
    assert res.returncode == 0, res.stderr[-3000:]
    return json.loads(res.stdout.strip().splitlines()[-1])


ARCHS = ["deepseek-7b", "olmoe-1b-7b", "mamba2-130m", "whisper-large-v3",
         "internvl2-2b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_lowers_with_positive_terms(probe, arch):
    r = probe[arch]["train"]
    assert r["flops"] > 0 and r["bytes"] > 0
    assert r["mem"] > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_lowers(probe, arch):
    assert probe[arch]["decode_flops"] > 0


def test_train_has_collectives_on_multi_device_mesh(probe):
    # TP/grad reductions must appear for the dense arch
    assert probe["deepseek-7b"]["train"]["coll"] > 0
