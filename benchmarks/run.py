"""Benchmark driver.  Prints ``name,value,derived`` CSV rows:

- one section per paper figure (figures.py — the paper's only
  quantitative claims are its worked examples),
- the fabric section (fabric.py — co-scheduling vs fair sharing across
  core oversubscription ratios),
- scheduler micro-benchmarks (wall-time of the Principle-1 scheduler and
  the DES on generated DAGs),
- the scale sweep (scale.py — flat-array DES + memoized scheduler on
  large mapreduce/DDL/fat-tree/layered DAGs up to ~20k tasks, with
  event-calendar and seed-implementation comparison rows),
- the baseline bake-off (bakeoff.py — fair sharing, SEBF, dependency-
  graph coflows, Graphene and Metaflow vs MXDAG on the scenario ×
  topology matrix; ``mxdag_wins`` claim rows gated by check_perf.py),
- the fault-injection recovery matrix (nemesis.py — replan vs
  no-replan vs clairvoyant oracle under host loss, stragglers and link
  degradation; ``replan_wins``/``detected``/``ref_match`` rows gated),
- the online multi-job service sweep (online.py — sustained Poisson
  arrivals through the admission front end; dict-vs-array altruistic
  ``ref_match``, the altruistic-beats-FIFO/fair ``jct_wins`` row and
  the >=3x ``speedup_replan_loop`` floor all gated),
- the roofline summary per dry-run cell (roofline.py; populated by
  ``python -m repro.launch.dryrun --all``).

``--json PATH`` additionally dumps the rows as JSON (the CI smoke step
uploads it as an artifact and diffs it against benchmarks/baseline.json
via check_perf.py); ``--smoke`` skips the roofline section, which is only
meaningful after a dry-run populated its measurement files; ``--no-seed``
skips the slow seed-implementation rows of the scale sweep.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)        # so `python benchmarks/run.py` works too


from benchmarks._util import timeit_us as _timeit  # noqa: E402


def scheduler_micro():
    from repro.core import MXDAGScheduler, simulate
    from repro.core import builders
    rows = []
    g = builders.mapreduce("mr", 8, 8)
    rows.append(("micro.schedule_mr8x8_us",
                 _timeit(lambda: MXDAGScheduler(
                     try_pipelining=False).schedule(g)),
                 "Principle-1 scheduling of an 8x8 shuffle (80 tasks)"))
    rows.append(("micro.simulate_mr8x8_us",
                 _timeit(lambda: simulate(g)),
                 "DES of the same DAG"))
    g2 = builders.ddl(32, push=2.0, pull=2.0)
    rows.append(("micro.schedule_ddl32_us",
                 _timeit(lambda: MXDAGScheduler(
                     try_pipelining=False).schedule(g2)),
                 "Principle-1 scheduling of a 32-layer DDL step"))
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="skip the roofline section (needs dry-run data)")
    ap.add_argument("--no-seed", action="store_true",
                    help="skip the slow seed-implementation scale rows")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the rows as JSON to PATH")
    args = ap.parse_args(argv)

    from benchmarks import (
        bakeoff, fabric, figures, nemesis, online, roofline, scale,
    )

    rows = []
    for fig in figures.ALL:
        rows += fig()
    rows += fabric.bench_rows()
    rows += scheduler_micro()
    rows += scale.bench_rows(seed_rows=not args.no_seed)
    rows += bakeoff.bench_rows()
    rows += nemesis.bench_rows()
    rows += online.bench_rows(smoke=args.smoke)
    if not args.smoke:
        rows += roofline.bench_rows()

    if args.json:        # artifact first: survives a closed stdout pipe
        with open(args.json, "w") as f:
            json.dump([{"name": n, "value": v, "derived": str(d)}
                       for n, v, d in rows], f, indent=2)

    print("name,value,derived")
    for name, value, derived in rows:
        d = str(derived).replace(",", ";")
        print(f"{name},{value:.6g},{d}")


if __name__ == "__main__":
    main()
