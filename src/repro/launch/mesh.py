"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16×16 = 256 chips (v5e pod),
axes ("data", "model").  Multi-pod: 2×16×16 = 512 chips, axes
("pod", "data", "model") — the "pod" axis carries pure data parallelism
across the inter-pod links (DCN in practice; the dry-run proves the
program shards over it).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """All data-parallel axes of a mesh (everything except "model")."""
    return tuple(a for a in mesh.axis_names if a != "model")


def n_chips(mesh: jax.sharding.Mesh) -> int:
    return mesh.devices.size
