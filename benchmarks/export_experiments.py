"""Render EXPERIMENTS.md from the recorded results.

Sources:
- benchmarks/results/dryrun_baseline.json   (the 40-cell baseline sweep)
- benchmarks/results/dryrun_<tag>.json      (hillclimb variants)
- benchmarks/results/perf_log.json          (hypothesis→change→measure log,
                                             appended by the perf loop)
- the paper-figure benchmark rows (figures.py, run live)

Usage: PYTHONPATH=src python benchmarks/export_experiments.py
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

RESULTS = os.path.join(os.path.dirname(__file__), "results")
OUT = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")


def _load(name):
    p = os.path.join(RESULTS, name)
    if os.path.exists(p):
        with open(p) as f:
            return json.load(f)
    return {}


def figures_section() -> str:
    from benchmarks import figures
    lines = ["## Paper-validation (the paper's worked examples, "
             "reproduced numerically)", "",
             "The paper has no measured evaluation; its claims are the "
             "worked examples of Figs. 1–3, 6, 7.  Each is reproduced in "
             "the discrete-event simulator (`benchmarks/figures.py`); "
             "`claim_* = 1` means validated.", "",
             "| metric | value | meaning |", "|---|---|---|"]
    for fig in figures.ALL:
        for name, value, derived in fig():
            d = str(derived).replace("|", "/")
            lines.append(f"| `{name}` | {value:.4g} | {d} |")
    lines.append("")
    return "\n".join(lines)


def dryrun_section(tag="baseline") -> str:
    data = _load(f"dryrun_{tag}.json")
    ok = sum(1 for v in data.values() if v.get("ok"))
    skipped = sum(1 for v in data.values() if v.get("skipped"))
    failed = sum(1 for v in data.values()
                 if not v.get("ok") and not v.get("skipped"))
    lines = [
        "## Dry-run",
        "",
        f"`python -m repro.launch.dryrun --all` lowers + compiles every "
        f"(arch × shape × mesh) cell on the production meshes "
        f"(single-pod 16×16 = 256 chips; multi-pod 2×16×16 = 512 chips, "
        f"axes (pod, data, model)).",
        "",
        f"**Result: {ok} cells compiled OK, {failed} failed, "
        f"{skipped} skipped** (long_500k for the 8 pure full-attention "
        f"archs, per the assignment; noted in DESIGN.md §4).",
        "",
        "Per-cell dry-run facts (per-device; from `memory_analysis()` and "
        "the trip-count-aware HLO cost model `repro/launch/hlo_cost.py` — "
        "XLA's `cost_analysis()` counts while bodies once, validated in "
        "`tests/test_hlo_cost.py`):",
        "",
        "| cell | step | args GB | temp GB | fits 16GiB | lower+compile s |",
        "|---|---|---|---|---|---|",
    ]
    for key, rec in sorted(data.items()):
        if not rec.get("ok"):
            continue
        m = rec["memory"]
        lines.append(
            f"| {key} | {rec['kind']} | "
            f"{m['argument_size_in_bytes'] / 2**30:.2f} | "
            f"{m['temp_size_in_bytes'] / 2**30:.2f} | "
            f"{'yes' if m['fits_hbm'] else 'NO'} | "
            f"{rec['lower_s']}+{rec['compile_s']} |")
    lines.append("")
    return "\n".join(lines)


def roofline_section(tag="baseline") -> str:
    from benchmarks import roofline
    lines = [
        "## Roofline",
        "",
        "Three terms per cell (TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM, "
        "50 GB/s/link ICI).  `compute = flops/(chip·peak)`, `memory = "
        "bytes/(chip·bw)`, `collective = wire-bytes/(chip·link-bw)` — all "
        "per-device from the partitioned module, trip-count-scaled.  "
        "`useful` = MODEL_FLOPS/(HLO flops × chips) with MODEL_FLOPS = "
        "6·N_active·tokens (train) or 2·N_active·tokens (inference); "
        "`frac` = useful-compute-time / dominant-term (the score).",
        "",
        "```",
        roofline.table(tag),
        "```",
        "",
        "**Reading the baseline.**  Attention-bearing train/prefill cells "
        "are memory-dominated by the S²-shaped softmax-chain tensors the "
        "XLA path materializes in HBM — exactly the traffic the validated "
        "Pallas flash kernel (and SSD kernel for mamba/jamba Q² chains) "
        "keeps in VMEM on the real TPU target.  Decode cells are "
        "weight/cache-streaming bound as expected (useful column ≈ "
        "active-param utilization).  The §Perf log below drives the "
        "dominant terms down per cell.",
        "",
    ]
    return "\n".join(lines)


def perf_section() -> str:
    log = _load("perf_log.json")
    lines = ["## Perf (hypothesis → change → measure → validate)", ""]
    if not log:
        lines.append("_perf log pending_")
        return "\n".join(lines)
    lines += [log.get("intro", ""), ""]
    for cell, entries in log.get("cells", {}).items():
        lines.append(f"### {cell}")
        lines.append("")
        lines.append("| # | hypothesis | change | before (dom term s) | "
                      "after | verdict |")
        lines.append("|---|---|---|---|---|---|")
        for i, e in enumerate(entries, 1):
            lines.append(
                f"| {i} | {e['hypothesis']} | `{e['change']}` | "
                f"{e['before']} | {e['after']} | {e['verdict']} |")
        lines.append("")
    if "summary" in log:
        lines += [log["summary"], ""]
    return "\n".join(lines)


HEADER = """# EXPERIMENTS — MXDAG on a multi-pod TPU v5e mesh

Paper: *MXDAG: A Hybrid Abstraction for Cluster Applications* (Wang et
al., 2021).  Bands: soundness 5/5, repro 5/5.  DESIGN.md records the
paper→TPU mapping; this file records every measured result.

Environment: CPU-only container; TPU v5e is the *target*.  Dry-runs use
512 forced host devices (`--xla_force_host_platform_device_count=512`);
Pallas kernels validated in interpret mode (`tests/test_kernels.py`).
"""


def comparison_section() -> str:
    base = _load("dryrun_baseline.json")
    opt = _load("dryrun_optimized.json")
    lines = ["## Roofline — optimized configuration",
             "",
             "Same grid re-lowered after the §Perf changes "
             "(dryrun_optimized.json).  Per-cell dominant-term bound, "
             "baseline -> optimized:",
             "",
             "| cell | baseline bound s | optimized bound s | speedup | "
             "fits HBM |", "|---|---|---|---|---|"]
    tb = to = 0.0
    fb = fo = 0
    for k in sorted(base):
        b, o = base.get(k, {}), opt.get(k, {})
        if not (b.get("ok") and o.get("ok")):
            continue
        rb, ro = b["roofline"], o["roofline"]
        bb = max(rb["compute_s"], rb["memory_s"], rb["collective_s"])
        bo = max(ro["compute_s"], ro["memory_s"], ro["collective_s"])
        tb += bb; to += bo
        fb += b["memory"]["fits_hbm"]; fo += o["memory"]["fits_hbm"]
        lines.append(f"| {k} | {bb:.2f} | {bo:.2f} | {bb/bo:.2f}x | "
                     f"{'y' if b['memory']['fits_hbm'] else 'N'}->"
                     f"{'y' if o['memory']['fits_hbm'] else 'N'} |")
    lines += ["",
              f"**Total: {tb:.0f} s -> {to:.0f} s ({tb/to:.2f}x); "
              f"fits-HBM {fb} -> {fo} of 64 cells.**",
              "",
              "Kernel-adjusted memory terms for the hillclimbed cells "
              "(chain tensors held in VMEM by the validated Pallas "
              "kernels; benchmarks/results/kernel_adjusted.json):", ""]
    ka = _load("kernel_adjusted.json")
    for cell, v in ka.items():
        lines.append(f"- `{cell}`: raw {v['raw_memory_s']} s, chain "
                     f"{v['chain_bytes_tb']} TB -> adjusted "
                     f"{v['adjusted_memory_s']} s")
    lines.append("")
    return "\n".join(lines)


def main():
    parts = [HEADER, figures_section(), dryrun_section(),
             roofline_section(), comparison_section(), perf_section()]
    with open(OUT, "w") as f:
        f.write("\n".join(parts))
    print(f"wrote {os.path.abspath(OUT)}")


if __name__ == "__main__":
    main()
