"""Integration tests: the paper's figures, validated numerically (§2, §4)."""
import pytest

from repro.core import (
    AltruisticMultiScheduler, CoflowConfig, FairShareScheduler, MXDAG,
    MXDAGScheduler, simulate,
)
from repro.core import builders


class TestFig1:
    """Co-scheduling beats network-aware fair sharing (Fig. 1)."""

    def test_coscheduling_beats_fair_share(self):
        g = builders.fig1_jobs()
        fair = FairShareScheduler().schedule(g).simulate()
        mx = MXDAGScheduler().schedule(g).simulate()
        assert mx.makespan < fair.makespan
        assert mx.makespan == pytest.approx(5.0)
        assert fair.makespan == pytest.approx(6.0)

    def test_task_on_c_starts_earlier(self):
        """T2 < T1: prioritizing f1 over f3 lets c start earlier."""
        g = builders.fig1_jobs()
        fair = FairShareScheduler().schedule(g).simulate()
        mx = MXDAGScheduler().schedule(g).simulate()
        assert mx.start["c"] < fair.start["c"]


class TestFig2:
    """Coflow lacks global view: every grouping is suboptimal (§2.2)."""

    def test_fig2a_asymmetric_compute_times(self):
        g = builders.fig2a(t1=3.0, t2=1.0)
        mx = MXDAGScheduler().schedule(g).simulate()
        cof = CoflowConfig(builders.fig2a_coflows()).schedule(g).simulate()
        fair = FairShareScheduler().schedule(g).simulate()
        assert mx.makespan < cof.makespan
        assert mx.makespan <= fair.makespan

    def test_fig2b_all_three_coflow_groupings_suboptimal(self):
        g = builders.fig2b()
        mx = MXDAGScheduler().schedule(g).simulate()
        for variant in ("b1", "b2", "b3"):
            cof = CoflowConfig(builders.fig2b_coflows(variant)) \
                .schedule(g).simulate()
            assert mx.makespan < cof.makespan, variant

    def test_fig2b_optimal_delays_f4(self):
        """Optimal schedule avoids f3/f4 sharing C's egress NIC."""
        g = builders.fig2b()
        mx = MXDAGScheduler().schedule(g)
        res = mx.simulate()
        f3 = (res.start["f3"], res.finish["f3"])
        f4 = (res.start["f4"], res.finish["f4"])
        overlap = min(f3[1], f4[1]) - max(f3[0], f4[0])
        assert overlap <= 1e-9 or res.makespan == pytest.approx(
            MXDAGScheduler().schedule(g).meta["predicted_makespan"])


class TestFig3:
    """Pipelineability: no-op off the critical path, win on it,
    loss when it induces NIC contention on it (Fig. 3)."""

    @pytest.fixture
    def priorities(self):
        return MXDAGScheduler(try_pipelining=False) \
            .schedule(builders.fig3_case(0)).priorities

    def _run(self, case, priorities):
        return simulate(builders.fig3_case(case), policy="priority",
                        priorities=priorities).makespan

    def test_case1_noncritical_pipelining_no_impact(self, priorities):
        assert self._run(1, priorities) == pytest.approx(
            self._run(0, priorities))

    def test_case2_critical_pipelining_improves(self, priorities):
        assert self._run(2, priorities) < self._run(0, priorities) - 0.5

    def test_case3_critical_pipelining_degrades(self, priorities):
        assert self._run(3, priorities) > self._run(0, priorities) + 0.1

    def test_scheduler_only_applies_helpful_pipelines(self):
        """Principle 1: 'pipelines will only be applied when they can
        shrink the overall execution time'."""
        s = MXDAGScheduler(try_pipelining=True).schedule(builders.fig3())
        assert ("a", "f1") in s.meta["pipelined"]
        assert ("a", "f3") not in s.meta["pipelined"]
        base = MXDAGScheduler(try_pipelining=False) \
            .schedule(builders.fig3()).simulate().makespan
        assert s.simulate().makespan < base


class TestFig6DDL:
    """Layer-wise gradient sync (Fig. 6 / §4.1.1)."""

    def test_mxdag_matches_bytescheduler_priority_order(self):
        g = builders.ddl(4, push=2.0, pull=2.0)
        s = MXDAGScheduler(try_pipelining=False).schedule(g)
        pr = {k: v for k, v in s.priorities.items() if k.startswith("push")}
        order = sorted(pr, key=lambda k: pr[k])
        assert order == ["push0", "push1", "push2", "push3"]

    def test_mxdag_beats_fair_when_comm_bound(self):
        g = builders.ddl(4, push=2.0, pull=2.0)
        fair = FairShareScheduler().schedule(g).simulate()
        mx = MXDAGScheduler(try_pipelining=False).schedule(g).simulate()
        assert mx.makespan < fair.makespan

    def test_compute_bound_ddl_no_network_effect(self):
        # network fast: both schedulers pinned by the FP/BP chain
        g = builders.ddl(4, push=0.1, pull=0.1)
        fair = FairShareScheduler().schedule(g).simulate()
        mx = MXDAGScheduler(try_pipelining=False).schedule(g).simulate()
        assert mx.makespan == pytest.approx(fair.makespan)
        assert mx.makespan == pytest.approx(4 + 0.2 + 4)


class TestFig7Altruism:
    """Principle 2: altruism helps other jobs at no cost to self (§4.2)."""

    def test_altruism_shrinks_job2_without_hurting_job1(self):
        j1, j2 = builders.mapreduce_pair()
        merged = MXDAG("m")
        for t in list(j1) + list(j2):
            merged.add(t)
        for e in list(j1.edges.values()) + list(j2.edges.values()):
            merged.add_edge(e.src, e.dst)
        naive = simulate(merged, policy="fair")
        alt = AltruisticMultiScheduler().schedule([j1, j2]).simulate()
        assert alt.jct("job2") < naive.jct("job2")
        assert alt.jct("job1") <= naive.jct("job1") + 1e-9

    def test_cross_job_name_collision_rejected(self):
        """Regression: merging jobs that reuse a task name must fail
        loudly (naming both jobs), not half-merge the graphs."""
        a = builders.mapreduce("mr", 2, 2, job="jobA")
        b = builders.mapreduce("mr", 2, 2, job="jobB")   # same task names
        with pytest.raises(ValueError) as ei:
            AltruisticMultiScheduler().schedule([a, b])
        msg = str(ei.value)
        assert "collision" in msg and "mr" in msg

    def test_altruism_bounded_by_slack(self):
        """A job never demotes a task whose slack can't absorb the delay."""
        j1, j2 = builders.mapreduce_pair()
        s = AltruisticMultiScheduler().schedule([j1, j2])
        from repro.core.schedule import ALTRUIST_DEMOTED
        demoted = [n for n, p in s.priorities.items()
                   if p == ALTRUIST_DEMOTED]
        slacks = {n: t.slack for n, t in j1.with_slack().items()}
        for n in demoted:
            if n in j1.tasks:
                assert slacks[n] > 0
