"""Property-based tests (hypothesis) for the MXDAG calculus & simulator."""
import math

import pytest

hypothesis = pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed (pip install -e .[test])")
from hypothesis import given, settings, strategies as st

from repro.core import (
    AltruisticMultiScheduler, Cluster, MXDAG, MXDAGScheduler, compute, flow,
    simulate,
)
from repro.core import builders

sizes = st.floats(min_value=0.1, max_value=8.0, allow_nan=False,
                  allow_infinity=False)
unit_counts = st.integers(min_value=2, max_value=6)


def pipelined_chain(unit_times, n_units):
    """Alternating compute/flow chain; task i has n_units units of u_i."""
    tasks = []
    for i, u in enumerate(unit_times):
        size = u * n_units
        if i % 2 == 0:
            tasks.append(compute(f"t{i}", size, f"H{i}", unit=u))
        else:
            tasks.append(flow(f"t{i}", size, f"H{i-1}", f"H{i+1}", unit=u))
    g = MXDAG()
    g.chain(*tasks, pipelined=True)
    return g, tasks


class TestEq2Property:
    @given(us=st.lists(sizes, min_size=2, max_size=5), n=unit_counts)
    @settings(max_examples=40, deadline=None)
    def test_eq2_exact_for_equal_unit_counts(self, us, n):
        """Paper Eq.(2) == DES == analytic recursion on pipelined chains
        with a common unit count (each host/NIC private: no contention)."""
        g, tasks = pipelined_chain(us, n)
        expected = MXDAG.len_pipelined(tasks)
        assert g.makespan() == pytest.approx(expected, rel=1e-6)
        assert simulate(g).makespan == pytest.approx(expected, rel=1e-6)

    @given(us=st.lists(sizes, min_size=2, max_size=4),
           ns=st.lists(unit_counts, min_size=2, max_size=4))
    @settings(max_examples=40, deadline=None)
    def test_des_at_least_analytic_for_unequal_unit_counts(self, us, ns):
        """With heterogeneous unit counts the analytic recursion is an
        optimistic (first-unit-latency) bound; the DES's unit-granular
        gating can only be slower."""
        k = min(len(us), len(ns))
        us, ns = us[:k], ns[:k]
        tasks = []
        for i, (u, n) in enumerate(zip(us, ns)):
            tasks.append(compute(f"t{i}", u * n, f"H{i}", unit=u))
        g = MXDAG()
        g.chain(*tasks, pipelined=True)
        assert simulate(g).makespan >= g.makespan() - 1e-6

    @given(us=st.lists(sizes, min_size=2, max_size=5), n=unit_counts)
    @settings(max_examples=25, deadline=None)
    def test_pipelining_never_slower_than_sequential_chain(self, us, n):
        g, tasks = pipelined_chain(us, n)
        seq = MXDAG.len_sequential(tasks)
        assert simulate(g).makespan <= seq + 1e-6


class TestSchedulerProperties:
    @given(bp=st.lists(sizes, min_size=2, max_size=5),
           comm=st.lists(sizes, min_size=2, max_size=5))
    @settings(max_examples=25, deadline=None)
    def test_principle1_never_worse_than_fair_on_ddl(self, bp, comm):
        """Critical-path-priority scheduling of the Fig. 6 family is never
        worse than fair sharing (flows are preemptible; single GPU chain
        fixes the compute order)."""
        k = min(len(bp), len(comm))
        g = builders.ddl(k, bp=bp[:k], fp=bp[:k],
                         push=comm[:k], pull=comm[:k])
        fair = simulate(g, policy="fair")
        s = MXDAGScheduler(try_pipelining=False).schedule(g)
        mx = s.simulate()
        assert mx.makespan <= fair.makespan + 1e-6

    @given(bp=st.lists(sizes, min_size=3, max_size=4), seed=st.integers(0, 3))
    @settings(max_examples=20, deadline=None)
    def test_greedy_pipelining_monotone(self, bp, seed):
        """try_pipelining=True only keeps strictly-improving edges, so it is
        never worse than no pipelining at all."""
        k = len(bp)
        g = builders.ddl(k, bp=bp, fp=bp, push=2.0, pull=2.0,
                         unit_frac=0.25)
        off = MXDAGScheduler(try_pipelining=False).schedule(g).simulate()
        on = MXDAGScheduler(try_pipelining=True).schedule(g).simulate()
        assert on.makespan <= off.makespan + 1e-6

    @given(a=sizes, b=sizes, d=sizes)
    @settings(max_examples=25, deadline=None)
    def test_altruism_never_hurts_own_jct(self, a, b, d):
        """Principle 2's bound: job1's JCT under altruistic demotion equals
        its JCT when scheduled with strict self-priority."""
        j1 = MXDAG("job1")
        ta = j1.add(compute("a", a + b + 0.5, "Ha", job="job1"))
        tb = j1.add(compute("b", b, "Hb", job="job1"))
        f1 = j1.add(flow("f1", 1.0, "Ha", "Hr", job="job1"))
        f2 = j1.add(flow("f2", 1.0, "Hb", "Hr", job="job1"))
        r1 = j1.add(compute("r1", 1.0, "Hr", job="job1"))
        j1.add_edge(ta, f1); j1.add_edge(tb, f2)
        j1.add_edge(f1, r1); j1.add_edge(f2, r1)
        j2 = MXDAG("job2")
        td = j2.add(compute("d", d, "Hb", job="job2"))
        f3 = j2.add(flow("f3", 1.0, "Hb", "Hr2", job="job2"))
        r2 = j2.add(compute("r2", 1.0, "Hr2", job="job2"))
        j2.add_edge(td, f3); j2.add_edge(f3, r2)

        alt = AltruisticMultiScheduler().schedule([j1, j2]).simulate()
        solo = simulate(j1)
        # own JCT must not exceed the isolated JCT by more than the foreign
        # critical work its demoted tasks' slack was checked against
        assert alt.jct("job1") <= solo.jct("job1") + d + 1.0 + 1e-6

    @given(n=st.integers(2, 4), m=st.integers(2, 4), shuffle=sizes)
    @settings(max_examples=15, deadline=None)
    def test_mapreduce_conservation(self, n, m, shuffle):
        """Every task finishes; makespan bounded below by critical path and
        above by the fully-serialized sum."""
        g = builders.mapreduce("mr", n, m, shuffle_time=shuffle)
        r = simulate(g)
        assert all(f is not None for f in r.finish.values())
        assert r.makespan >= g.makespan() - 1e-9
        total = sum(t.size for t in g)
        assert r.makespan <= total + 1e-6


@st.composite
def equivalence_case(draw):
    """Random DAG + topology + policy for the event-calendar oracle."""
    from repro.core import Cluster, Topology

    n_hosts = draw(st.integers(min_value=2, max_value=5))
    hosts = [f"h{i}" for i in range(n_hosts)]
    topo_kind = draw(st.sampled_from(["none", "two_tier", "leaf_spine"]))
    if topo_kind == "none":
        cluster = None
    else:
        half = max(1, n_hosts // 2)
        racks = [hosts[:half], hosts[half:]]
        if topo_kind == "two_tier":
            topo = Topology.two_tier(
                racks,
                oversubscription=draw(st.sampled_from([1.0, 2.0, 4.0])))
        else:
            topo = Topology.leaf_spine(racks, n_spines=2)
        cluster = Cluster.from_topology(topo)

    size_st = st.sampled_from([0.0, 0.25, 0.5, 1.0, 1.75, 3.0])
    n_tasks = draw(st.integers(min_value=2, max_value=10))
    g = MXDAG("rand")
    names = []
    for i in range(n_tasks):
        size = draw(size_st)
        unit = None
        if size > 0 and draw(st.booleans()):
            unit = size * draw(st.sampled_from([0.25, 0.5, 1.0]))
        if draw(st.booleans()):
            t = compute(f"t{i}", size, draw(st.sampled_from(hosts)),
                        unit=unit)
        else:
            src = draw(st.sampled_from(hosts))
            dst = draw(st.sampled_from([h for h in hosts if h != src]))
            t = flow(f"t{i}", size, src, dst, unit=unit)
        g.add(t)
        names.append(t.name)
    for i in range(1, n_tasks):
        for j in draw(st.lists(st.integers(0, i - 1), max_size=2,
                               unique=True)):
            if (names[j], names[i]) not in g.edges:
                g.add_edge(names[j], names[i],
                           pipelined=draw(st.booleans()))
    policy = draw(st.sampled_from(["fair", "priority"]))
    prio = {n: draw(st.integers(0, 2)) for n in names
            if draw(st.booleans())}
    rel = {n: draw(st.sampled_from([0.5, 1.0, 2.0])) for n in names
           if not g.preds(n) and draw(st.booleans())}
    flows = [t.name for t in g.network_tasks() if t.size > 0]
    coflows = None
    if len(flows) >= 2 and draw(st.booleans()):
        coflows = [set(flows[:2])]
    return g, cluster, policy, prio, rel, coflows


class TestEventCalendarEquivalence:
    """The incremental event-calendar core is a pure optimisation: on any
    random DAG, topology and policy it must reproduce the retained
    reference slow path's per-task trajectory."""

    @given(case=equivalence_case())
    @settings(max_examples=120, deadline=None)
    def test_matches_reference_on_random_dags(self, case):
        from repro.core.simulator import Simulator

        g, cluster, policy, prio, rel, coflows = case
        kw = dict(policy=policy, priorities=prio, releases=rel,
                  coflows=coflows)
        new = Simulator(g, cluster, **kw).run()
        ref = Simulator(g, cluster, **kw)._reference_run()
        for n in g.tasks:
            assert new.start[n] == pytest.approx(ref.start[n],
                                                 abs=1e-6), n
            assert new.finish[n] == pytest.approx(ref.finish[n],
                                                  abs=1e-6), n


class TestCalculusProperties:
    @given(us=st.lists(sizes, min_size=1, max_size=6))
    @settings(max_examples=40, deadline=None)
    def test_eq1_additivity(self, us):
        ts = [compute(f"t{i}", u, "H") for i, u in enumerate(us)]
        assert MXDAG.len_sequential(ts) == pytest.approx(sum(us))

    @given(us=st.lists(sizes, min_size=1, max_size=6), n=unit_counts)
    @settings(max_examples=40, deadline=None)
    def test_eq2_dominated_by_slowest_stage(self, us, n):
        """Eq.(2): the pipelined length is within one fill latency of the
        slowest stage's total time (Fig. 5)."""
        ts = [compute(f"t{i}", u * n, f"H{i}", unit=u)
              for i, u in enumerate(us)]
        ln = MXDAG.len_pipelined(ts)
        slowest = max(u * n for u in us)
        assert ln >= slowest - 1e-9
        assert ln <= slowest + sum(us) + 1e-9

    @given(us=st.lists(sizes, min_size=2, max_size=6), n=unit_counts,
           r=st.floats(min_value=0.2, max_value=1.0))
    @settings(max_examples=30, deadline=None)
    def test_resource_scaling_linear(self, us, n, r):
        """Halving every task's resource doubles both Eq.(1) and Eq.(2)."""
        ts = [compute(f"t{i}", u * n, f"H{i}", unit=u)
              for i, u in enumerate(us)]
        rs = {t.name: r for t in ts}
        assert MXDAG.len_sequential(ts, rs) == pytest.approx(
            MXDAG.len_sequential(ts) / r, rel=1e-9)
        assert MXDAG.len_pipelined(ts, rs) == pytest.approx(
            MXDAG.len_pipelined(ts) / r, rel=1e-9)
