"""Baseline schedulers (repro.core.baselines): goldens + properties.

The golden makespans are hand-checkable on ``oversubscribed_fanin(2,
4:1)``: two 1-unit flows share a 0.5-capacity uplink, f0 feeds an
8-second compute and f1 a 1-second one.

- fair sharing splits the uplink (0.25 each): both flows finish at t=4,
  the critical compute at 4+8 = **12**;
- SEBF / the dependency-coflow greedy serialize the (equal-Γ,
  name-tie-broken) singleton coflows f0-first: f0 lands at t=2, the
  critical compute at 2+8 = **10** — matching MXDAG;
- Graphene prioritizes only computes (which never contend here) and
  Metaflow gives both depth-0 flows one class, so both collapse to
  fair sharing: **12**.

The ``critical_flow_size=2.0`` variant makes f0 the *bigger* flow
(Γ = 4 vs 2), so every bytes-ordered baseline schedules it last and all
five converge on **14** while slack-driven MXDAG still sends it first
(**12**) — the configuration that splits DAG-aware from DAG-blind.
"""
import pytest

from repro.core import Cluster, MXDAG, MXDAGScheduler, compute, flow
from repro.core import builders
from repro.core.baselines import (
    BASELINES,
    DependencyCoflowScheduler,
    GrapheneScheduler,
    MetaflowScheduler,
    SEBFScheduler,
    coflow_dag,
    effective_bottleneck,
    flow_depth,
)
from repro.core.schedule import auto_coflows


def makespans(g, cl):
    """algo → makespan for every baseline plus MXDAG on (g, cl)."""
    out = {a: f().schedule(g, cl).simulate(cl).makespan
           for a, f in BASELINES.items()}
    out["mxdag"] = MXDAGScheduler(
        try_pipelining=False).schedule(g, cl).simulate(cl).makespan
    return out


class TestGoldens:
    def test_fanin2_4to1(self):
        g, cl = builders.oversubscribed_fanin(2, oversubscription=4.0)
        assert makespans(g, cl) == {
            "fair": 12.0, "sebf": 10.0, "sg_coflow": 10.0,
            "graphene": 12.0, "metaflow": 12.0, "mxdag": 10.0}

    def test_fanin2_4to1_heavy_critical_flow(self):
        g, cl = builders.oversubscribed_fanin(
            2, oversubscription=4.0, critical_flow_size=2.0)
        assert makespans(g, cl) == {
            "fair": 14.0, "sebf": 14.0, "sg_coflow": 14.0,
            "graphene": 14.0, "metaflow": 14.0, "mxdag": 12.0}

    def test_mxdag_never_loses_on_the_bakeoff_matrix(self):
        """The claim the CI gate commits, at test scale."""
        for make in (
                lambda: builders.oversubscribed_fanin(
                    4, oversubscription=4.0),
                lambda: (builders.ddl(8, push=2.0, pull=2.0), None),
                lambda: (builders.mapreduce("mr", 4, 4), None)):
            g, cl = make()
            res = makespans(g, cl)
            best_base = min(v for a, v in res.items() if a != "mxdag")
            assert res["mxdag"] <= best_base + 1e-9


class TestMetrics:
    def test_effective_bottleneck_charges_the_uplink(self):
        g, cl = builders.oversubscribed_fanin(4, oversubscription=4.0)
        # 4 unit flows on the 1.0-capacity shared uplink: Γ(all) = 4,
        # a single flow alone still pays the uplink (1/1), not its
        # endpoint NICs (1/1 each as well, so Γ = 1).
        names = [t.name for t in g.network_tasks()]
        assert effective_bottleneck(set(names), g, cl) \
            == pytest.approx(4.0)
        assert effective_bottleneck({names[0]}, g, cl) \
            == pytest.approx(1.0)
        assert effective_bottleneck(set(), g, cl) == 0.0

    def test_effective_bottleneck_no_fabric_uses_nics(self):
        g = MXDAG()
        f = g.add(flow("f", 3.0, "a", "b"))
        cl = Cluster.for_graph(g)
        assert effective_bottleneck({f.name}, g, cl) \
            == pytest.approx(3.0)

    def test_coflow_dag_two_stage_chain(self):
        # m0,m1 -(s0,s1)-> r -(t0)-> sink: stage 2 depends on stage 1
        g = MXDAG()
        m0 = g.add(compute("m0", 1.0, "h0"))
        m1 = g.add(compute("m1", 1.0, "h1"))
        r = g.add(compute("r", 1.0, "h2"))
        sink = g.add(compute("sink", 1.0, "h3"))
        s0 = g.add(flow("s0", 1.0, "h0", "h2"))
        s1 = g.add(flow("s1", 1.0, "h1", "h2"))
        t0 = g.add(flow("t0", 1.0, "h2", "h3"))
        g.add_edge(m0, s0), g.add_edge(m1, s1)
        g.add_edge(s0, r), g.add_edge(s1, r)
        g.add_edge(r, t0), g.add_edge(t0, sink)
        groups = [{"s0", "s1"}, {"t0"}]
        assert coflow_dag(g, groups) == [set(), {0}]
        # independent groups: no precedence either way
        assert coflow_dag(g, [{"s0"}, {"s1"}]) == [set(), set()]

    def test_flow_depth_skips_compute(self):
        g = MXDAG()
        a = g.add(compute("a", 1.0, "h0"))
        f1 = g.add(flow("f1", 1.0, "h0", "h1"))
        b = g.add(compute("b", 1.0, "h1"))
        f2 = g.add(flow("f2", 1.0, "h1", "h2"))
        c = g.add(compute("c", 1.0, "h2"))
        g.chain(a, f1, b, f2, c)
        assert flow_depth(g) == {"f1": 0, "f2": 1}

    def test_auto_coflows_singletons_switch(self):
        g, _ = builders.oversubscribed_fanin(4, oversubscription=4.0)
        # every fan-in flow has a private consumer: all groups are
        # singletons, so the default grouping is empty
        assert auto_coflows(g) == []
        singles = auto_coflows(g, singletons=True)
        assert sorted(map(tuple, map(sorted, singles))) \
            == [("f0",), ("f1",), ("f2",), ("f3",)]


class TestSchedules:
    def test_sebf_orders_ascending_gamma(self):
        g, cl = builders.oversubscribed_fanin(
            2, oversubscription=4.0, critical_flow_size=2.0)
        s = SEBFScheduler().schedule(g, cl)
        assert s.policy == "priority"
        assert s.meta["order"] == [("f1",), ("f0",)]  # big flow last
        assert s.priorities == {"f1": 0.0, "f0": 1.0}
        assert s.coflows is None                      # all singletons

    def test_dependency_scheduler_respects_precedence(self):
        # two-stage shuffle: the stage-2 coflow is tiny (smallest Γ)
        # but must still be ordered after the stage-1 coflow it reads
        g = MXDAG()
        m = g.add(compute("m", 1.0, "h0"))
        r = g.add(compute("r", 1.0, "h1"))
        sink = g.add(compute("sink", 1.0, "h2"))
        big = g.add(flow("big", 9.0, "h0", "h1"))
        tiny = g.add(flow("tiny", 0.1, "h1", "h2"))
        g.chain(m, big, r, tiny, sink)
        s = DependencyCoflowScheduler().schedule(g)
        assert s.meta["order"] == [("big",), ("tiny",)]
        assert s.meta["coflow_dag"] == {("big",): [],
                                        ("tiny",): [("big",)]}
        # plain SEBF gets it backwards — the blind spot under test
        assert SEBFScheduler().schedule(g).meta["order"] \
            == [("tiny",), ("big",)]

    def test_graphene_priorities_compute_only_longest_first(self):
        g, cl = builders.oversubscribed_fanin(2, oversubscription=4.0)
        s = GrapheneScheduler().schedule(g, cl)
        assert set(s.priorities) == {"c0", "c1"}      # no flows
        assert s.priorities["c0"] < s.priorities["c1"]  # 8s chain first

    def test_metaflow_priorities_flows_by_depth(self):
        g = builders.ddl(3, push=2.0, pull=2.0)
        s = MetaflowScheduler().schedule(g)
        depths = flow_depth(g)
        assert s.priorities == {n: float(d) for n, d in depths.items()}
        assert all(g.tasks[n].kind.name == "NETWORK"
                   for n in s.priorities)

    def test_baselines_deterministic(self):
        g, cl = builders.oversubscribed_fanin(4, oversubscription=4.0)
        for factory in BASELINES.values():
            a, b = factory().schedule(g, cl), factory().schedule(g, cl)
            assert a.priorities == b.priorities
            assert a.coflows == b.coflows


class TestEngineRoundTrip:
    """Every baseline's Schedule must mean the same thing to the
    flat-array engine and the event-calendar oracle."""

    def _check(self, g, cl=None):
        for name, factory in BASELINES.items():
            s = factory().schedule(g, cl)
            arr = s.simulate(cl).makespan
            cal = s.simulate(cl, engine="calendar").makespan
            assert arr == pytest.approx(cal, abs=1e-9), name

    def test_fanin_with_fabric(self):
        g, cl = builders.oversubscribed_fanin(4, oversubscription=4.0)
        self._check(g, cl)

    def test_random_layered_property(self):
        hypothesis = pytest.importorskip(
            "hypothesis",
            reason="hypothesis not installed (pip install -e .[test])")
        from hypothesis import given, settings, strategies as st

        @given(seed=st.integers(min_value=0, max_value=2**16),
               n=st.integers(min_value=10, max_value=80))
        @settings(max_examples=15, deadline=None)
        def run(seed, n):
            g = builders.random_layered(
                n, n_hosts=8, min_width=2, max_width=4, seed=seed)
            self._check(g)

        run()
