"""Unit tests: MXDAG graph structure and the §3.2 path calculus."""
import pytest

from repro.core import MXDAG, MXTask, TaskKind, compute, flow
from repro.core import builders


def chain_graph(tasks, pipelined=False):
    g = MXDAG("chain")
    g.chain(*tasks, pipelined=pipelined)
    return g


class TestConstruction:
    def test_duplicate_task_rejected(self):
        g = MXDAG()
        g.add(compute("a", 1.0, "A"))
        with pytest.raises(ValueError):
            g.add(compute("a", 1.0, "A"))

    def test_cycle_rejected(self):
        g = MXDAG()
        g.add(compute("a", 1.0, "A"))
        g.add(compute("b", 1.0, "B"))
        g.add_edge("a", "b")
        with pytest.raises(ValueError):
            g.add_edge("b", "a")

    def test_task_validation(self):
        with pytest.raises(ValueError):
            compute("x", -1.0, "A")
        with pytest.raises(ValueError):
            compute("x", 1.0, "A", unit=2.0)   # unit > size
        # placement fields must match the task kind
        with pytest.raises(ValueError):
            MXTask(name="x", kind=TaskKind.COMPUTE, size=1.0, src="A")
        with pytest.raises(ValueError):
            MXTask(name="f", kind=TaskKind.NETWORK, size=1.0, host="A")

    def test_logical_tasks_are_unbound(self):
        # None placements are legal (bound late); resources() refuses
        # until the task is fully bound
        c = compute("x", 1.0)
        assert not c.bound
        f = flow("f", 1.0, "A", None)          # dst bound late
        assert not f.bound
        with pytest.raises(ValueError, match="unbound"):
            f.resources()
        assert flow("g", 1.0, "A", "B").bound

    def test_topo_order(self):
        g = builders.fig1_jobs()
        order = g.topo_order()
        pos = {n: i for i, n in enumerate(order)}
        for (s, d) in g.edges:
            assert pos[s] < pos[d]

    def test_units(self):
        t = compute("a", 1.0, "A", unit=0.25)
        assert t.pipelineable and t.n_units == 4
        t2 = compute("b", 1.0, "A")
        assert not t2.pipelineable and t2.n_units == 1


class TestCalculus:
    def test_eq1_sequential(self):
        ts = [compute("a", 2.0, "A"), compute("b", 3.0, "B")]
        assert MXDAG.len_sequential(ts) == 5.0
        assert MXDAG.len_sequential(ts, {"a": 0.5}) == 7.0

    def test_eq2_pipelined(self):
        # Fig. 5 style: units u_i, sizes N*u_i (equal unit counts)
        ts = [compute("a", 4.0, "A", unit=1.0),
              compute("b", 8.0, "B", unit=2.0)]
        # sum(units) + max(sizes) - max(units) = 3 + 8 - 2 = 9
        assert MXDAG.len_pipelined(ts) == 9.0

    def test_eq2_throughput_capped_by_slowest_stage(self):
        # paper: "maximum throughput of the flow can be restricted by the
        # CPU processing speed when pipeline is used"
        cpu = compute("c", 10.0, "A", unit=1.0)   # slow producer
        f = flow("f", 2.0, "A", "B", unit=0.2)    # fast flow
        ln = MXDAG.len_pipelined([cpu, f])
        assert ln == pytest.approx(1.0 + 0.2 + 10.0 - 1.0)

    def test_evaluate_matches_eq1_on_sequential_chain(self):
        ts = [compute(f"t{i}", 1.0 + i, "H") for i in range(4)]
        g = chain_graph(ts)
        timing = g.evaluate()
        assert timing["t3"].completion == pytest.approx(
            MXDAG.len_sequential(ts))

    def test_evaluate_matches_eq2_on_pipelined_chain(self):
        n = 5
        ts = [compute(f"t{i}", (i + 1) * n * 0.5, "H", unit=(i + 1) * 0.5)
              for i in range(3)]
        g = chain_graph(ts, pipelined=True)
        timing = g.evaluate()
        assert timing["t2"].completion == pytest.approx(
            MXDAG.len_pipelined(ts))

    def test_pipelined_edge_into_unpipelineable_consumer_is_barrier(self):
        a = compute("a", 2.0, "A", unit=0.5)
        b = compute("b", 1.0, "B")           # not pipelineable
        g = MXDAG()
        g.chain(a, b, pipelined=True)
        assert g.evaluate()["b"].completion == pytest.approx(3.0)

    def test_partial_resource_scaling(self):
        ts = [compute("a", 2.0, "A")]
        g = chain_graph(ts)
        assert g.evaluate({"a": 0.5})["a"].completion == pytest.approx(4.0)


class TestCriticalPath:
    def test_fig1_critical_path(self):
        g = builders.fig1_jobs()
        assert g.critical_path() == ["a", "f1", "b", "f2", "c"]

    def test_slack_zero_on_critical_path(self):
        g = builders.fig1_jobs()
        timing = g.with_slack()
        for n in g.critical_path():
            assert timing[n].slack == pytest.approx(0.0, abs=1e-9)
        assert timing["f3"].slack > 0

    def test_makespan(self):
        g = builders.fig1_jobs()
        assert g.makespan() == pytest.approx(5.0)


class TestDeepChains:
    """Regression: paths_between/copaths used recursive DFS and raised
    RecursionError on chains deeper than ~1000 tasks (ddl(1024)-scale
    serial DAGs exceed the default recursion limit)."""

    DEPTH = 1500

    def test_paths_between_deep_chain(self):
        g = builders.serial_chain(self.DEPTH)
        head, tail = "t000000", f"t{self.DEPTH - 1:06d}"
        paths = g.paths_between(head, tail)
        assert len(paths) == 1
        assert len(paths[0]) == self.DEPTH
        assert paths[0][0] == head and paths[0][-1] == tail

    def test_copaths_deep_chain(self):
        # a chain has no copaths (single path everywhere); the point is
        # that the enumeration terminates instead of blowing the stack
        g = builders.serial_chain(self.DEPTH)
        assert g.copaths() == {}

    def test_paths_between_order_and_limit_unchanged(self):
        g = builders.fig1_jobs()
        paths = g.paths_between("a", "c")
        # DFS (adjacency) order, exactly as the recursive version emitted
        assert paths == [["a", "f1", "b", "f2", "c"], ["a", "f3", "c"]]
        assert g.paths_between("a", "c", limit=1) == [paths[0]]

    def test_deep_chain_analytics(self):
        g = builders.serial_chain(self.DEPTH)
        timing = g.with_slack()
        assert timing[f"t{self.DEPTH - 1:06d}"].completion == \
            pytest.approx(float(self.DEPTH))
        assert len(g.critical_path()) == self.DEPTH


class TestReleaseThreading:
    """with_slack()/critical_path() accept release= (previously dropped:
    slack of a late-released branch was overstated)."""

    def test_with_slack_release(self):
        g = MXDAG("rel")
        g.add(compute("a", 4.0, "A"))
        g.add(compute("b", 1.0, "B"))
        assert g.with_slack()["b"].slack == pytest.approx(3.0)
        t = g.with_slack(release={"b": 6.0})
        assert t["b"].slack == pytest.approx(0.0)
        assert t["a"].slack == pytest.approx(3.0)

    def test_critical_path_release(self):
        g = MXDAG("rel")
        g.add(compute("a", 4.0, "A"))
        g.add(compute("b", 1.0, "B"))
        assert g.critical_path() == ["a"]
        assert g.critical_path(release={"b": 6.0}) == ["b"]


class TestCopaths:
    def test_fig4a_copath(self):
        g = builders.fig1_jobs()
        cps = g.copaths()
        assert ("a", "c") in cps
        paths = cps[("a", "c")]
        assert sorted(map(tuple, paths)) == [
            ("a", "f1", "b", "f2", "c"), ("a", "f3", "c")]

    def test_copath_members_share_head_and_tail(self):
        g = builders.fig2b()
        for (h, t), paths in g.copaths().items():
            for p in paths:
                assert p[0] == h and p[-1] == t
