"""Flash attention (forward) as a Pallas TPU kernel.

TPU adaptation of the blockwise-softmax algorithm: q blocks of
``block_q`` rows are staged into VMEM via BlockSpec; the kernel streams
k/v in ``block_k`` slices from the VMEM-resident per-(batch,head) K/V
panels and maintains the running (max, denominator, accumulator) online
softmax in fp32 VREGs.  Causal queries skip entire KV blocks beyond the
diagonal (the loop bound depends on the q-block index).

GQA is handled *structurally*: the k/v BlockSpec index_map sends query
head ``h`` to kv head ``h // (H // K)``, so grouped heads share the same
VMEM panel without materializing repeated k/v.

VMEM budget: the per-(b,h) K and V panels are (S, hd) each —
``2·S·hd·bytes ≤ ~4 MiB`` holds for the training shapes this kernel
serves (S ≤ 8k at hd=128 bf16).  Longer sequences use the XLA path.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float,
                  causal: bool, block_k: int):
    block_q, hd = q_ref.shape[2], q_ref.shape[3]
    seq_k = k_ref.shape[2]
    q_idx = pl.program_id(2)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # [bq, hd]

    n_kb = seq_k // block_k
    if causal:
        hi = jnp.minimum(
            (q_idx * block_q + block_q + block_k - 1) // block_k, n_kb)
    else:
        hi = n_kb

    def body(i, carry):
        acc, m, l = carry
        k = k_ref[0, 0, pl.dslice(i * block_k, block_k), :] \
            .astype(jnp.float32)                         # [bk, hd]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            qpos = q_idx * block_q + jax.lax.iota(jnp.int32, block_q)
            kpos = i * block_k + jax.lax.iota(jnp.int32, block_k)
            s = jnp.where(qpos[:, None] >= kpos[None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        v = v_ref[0, 0, pl.dslice(i * block_k, block_k), :] \
            .astype(jnp.float32)
        acc = acc * alpha[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        return acc, m_new, l_new

    acc0 = jnp.zeros((block_q, v_ref.shape[3]), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, hi, body, (acc0, m0, l0))
    l = jnp.maximum(l, 1e-30)
    o_ref[0, 0] = (acc / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True, scale: float | None = None,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = True) -> jax.Array:
    """q: [B,H,S,hd]; k,v: [B,K,T,hd] with H % K == 0.  Returns [B,H,S,hd']."""
    B, H, S, hd = q.shape
    K, T = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0, (S, T, block_q, block_k)

    grid = (B, H, S // block_q)
    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, T, hd), lambda b, h, i: (b, h // G, 0, 0)),
            pl.BlockSpec((1, 1, T, v.shape[3]),
                         lambda b, h, i: (b, h // G, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, v.shape[3]),
                               lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, v.shape[3]), q.dtype),
        interpret=interpret,
    )(q, k, v)
