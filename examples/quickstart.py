"""Quickstart: the MXDAG abstraction in ~60 lines.

Builds the paper's Fig. 1 application (compute tasks on hosts A/B/C plus
explicit network flows), schedules it three ways, and runs the what-if
analysis — the co-scheduling, coflow-suboptimality and pipelineability
claims of the paper, reproduced numerically.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys
sys.path.insert(0, "src")

from repro.core import (
    FairShareScheduler, MXDAG, MXDAGScheduler, WhatIf, compute, flow,
    simulate,
)

# ----------------------------------------------------------------- build
g = MXDAG("jobX")
a = g.add(compute("a", 1.0, host="A"))
b = g.add(compute("b", 1.0, host="B"))
c = g.add(compute("c", 1.0, host="C"))
f1 = g.add(flow("f1", 1.0, src="A", dst="B"))      # network tasks are
f2 = g.add(flow("f2", 1.0, src="B", dst="C"))      # first-class nodes
f3 = g.add(flow("f3", 1.0, src="A", dst="C"))
g.add_edge(a, f1); g.add_edge(a, f3)
g.add_edge(f1, b); g.add_edge(b, f2)
g.add_edge(f2, c); g.add_edge(f3, c)

print("graph:", g)
print("critical path:", " -> ".join(g.critical_path()))

# -------------------------------------------------------------- schedule
fair = FairShareScheduler().schedule(g).simulate()
sched = MXDAGScheduler().schedule(g)
mx = sched.simulate()
print(f"\nnetwork-aware fair sharing (Fig. 1b): JCT = {fair.makespan}")
print(f"MXDAG co-scheduling       (Fig. 1c): JCT = {mx.makespan}")
print(f"task c starts at {mx.start['c']} instead of {fair.start['c']} "
      f"(T2 < T1: the paper's Fig. 1 claim)")

# --------------------------------------------------------------- what-if
w = WhatIf(g)
r = w.repartition({"b": 0.25})
print(f"\nwhat-if: shrink compute b 4x -> JCT {r.baseline} -> {r.variant}")
print("  (no help: the what-if exposes that C's ingress NIC is the real"
      " bottleneck — insight a compute-only DAG cannot give)")

r2 = w.set_unit("f1", 0.25)
g2 = g.copy(); g2.set_pipelined("a", "f1", True)
w2 = WhatIf(g2)
print(f"what-if: pipeline a->f1 in 1/4 units -> JCT "
      f"{w2.set_unit('f1', 0.25).variant}")
