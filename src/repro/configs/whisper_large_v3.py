"""whisper-large-v3 — encoder-decoder audio backbone (frontend stubbed).

[arXiv:2212.04356; unverified]  32L d_model=1280 20H d_ff=5120
vocab=51866.  The conv/mel frontend is a STUB per the assignment:
``input_specs()`` supplies precomputed frame embeddings
(B, 1500, d_model); 32 encoder + 32 decoder layers, GELU MLPs,
decoder cross-attends to encoder states.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,                   # decoder layers
    encoder_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    mlp_type="gelu",
    max_source_positions=1500,
    rope_theta=1e4,
)
