"""Checkpointing: atomic, elastic (mesh-shape independent), async-capable.

Layout: ``<dir>/step_<N>/`` containing one ``arrays.npz`` (flattened
key-path → full array) + ``meta.json``.  Writes go to ``step_<N>.tmp``
then rename — a crashed writer never corrupts the latest checkpoint.

Elasticity: arrays are stored unsharded; ``restore`` re-device_puts onto
whatever shardings the *current* mesh prescribes, so a run checkpointed
on a 2×16×16 mesh restarts unchanged on 16×16 (or any other shape) —
the elastic-scaling requirement.  In a true multi-host deployment each
process would write its addressable shards; the single-file layout keeps
this container honest while the restore path is already mesh-agnostic.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

SEP = "/"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_path_str(p) for p in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind == "V" or str(arr.dtype) in (
                "bfloat16", "float8_e4m3fn", "float8_e5m2"):
            # np.savez stores ml_dtypes as raw void; float32 is an EXACT
            # superset of bf16/fp8, so store the upcast and re-narrow on
            # restore (arr.astype(leaf.dtype))
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def _path_str(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return str(p.idx)
    if isinstance(p, jax.tree_util.GetAttrKey):
        return str(p.name)
    return str(p)


def save(directory: str, step: int, tree: Any,
         meta: Optional[dict] = None, keep: int = 3) -> str:
    """Atomic checkpoint write; prunes to the newest ``keep`` steps."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, **(meta or {})}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _prune(directory, keep)
    return final


def save_async(directory: str, step: int, tree: Any,
               meta: Optional[dict] = None, keep: int = 3
               ) -> threading.Thread:
    """Snapshot to host memory now, write on a background thread (training
    continues while bytes hit disk)."""
    flat = _flatten(tree)           # device_get happens here, synchronously

    def _write():
        os.makedirs(directory, exist_ok=True)
        final = os.path.join(directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, **(meta or {})}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _prune(directory, keep)

    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def _prune(directory: str, keep: int) -> None:
    steps = sorted(all_steps(directory))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


def all_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m:
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: int, target: Any,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``target``; if ``shardings`` (a pytree
    of NamedSharding matching target) is given, arrays are placed sharded —
    this is the elastic re-shard path."""
    path = os.path.join(directory, f"step_{step:08d}", "arrays.npz")
    data = np.load(path)

    leaves_with_path = jax.tree_util.tree_flatten_with_path(target)[0]
    treedef = jax.tree_util.tree_structure(target)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves_with_path))

    new_leaves = []
    for (path_keys, leaf), shd in zip(leaves_with_path, shard_leaves):
        key = SEP.join(_path_str(p) for p in path_keys)
        if key not in data:
            raise KeyError(f"checkpoint missing {key}")
        arr = data[key]
        if arr.shape != tuple(leaf.shape):
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != target {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        if shd is not None:
            new_leaves.append(jax.device_put(arr, shd))
        else:
            new_leaves.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def read_meta(directory: str, step: int) -> dict:
    with open(os.path.join(directory, f"step_{step:08d}", "meta.json")) as f:
        return json.load(f)
