"""Discrete-event simulator for MXDAG execution on a cluster.

Models exactly the behaviours the paper reasons about:

- compute tasks occupy processor slots exclusively and non-preemptively
  (compute "can be easily isolated"),
- network flows share bandwidth on every link of their path — just the two
  endpoint NICs on a big-switch cluster, or the full ToR/spine route when
  the cluster carries a fabric Topology — under a pluggable allocation
  policy ("fair" max-min sharing — the network-aware-DAG baseline of
  Fig. 1(b) — or "priority" — the co-scheduler of Fig. 1(c)); flow rates
  are preemptible and recomputed at every event,
- pipelined edges stream units: the consumer may process its j-th unit only
  once every streaming predecessor has *delivered* input fraction
  ≥ (j+1)/n_units (unit-granular, as in Fig. 5),
- coflows (for the §2.2 baseline): synchronized start, MADD-style coupled
  rates (members' rates proportional to remaining work so they finish
  together), and all-or-nothing downstream gating.

The simulator advances by exact rate integration between events; events are
unit boundaries, task completions, and release times, so no behaviour change
can occur between events and the result is exact for piecewise-constant
rates.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.core.cluster import Cluster
from repro.core.graph import MXDAG
from repro.core.task import MXTask, TaskKind

EPS = 1e-9


def waterfill(group: list[str], paths, weight, residual: dict[str, float],
              rates: dict[str, float]) -> None:
    """Weighted max-min fair allocation of ``group`` over ``residual``.

    ``paths[n]`` is the tuple of links flow n occupies; ``weight(n)`` its
    share weight.  Progressive filling: repeatedly find the bottleneck link
    (minimum residual capacity per unit weight), freeze every flow crossing
    it at its weighted share, subtract along those flows' paths, recurse on
    the rest.  Mutates ``residual`` and ``rates``.
    """
    unfrozen = sorted(group)
    while unfrozen:
        best_r, best_ratio = None, float("inf")
        for r in residual:
            w = sum(weight(n) for n in unfrozen if r in paths[n])
            if w > EPS:
                ratio = residual[r] / w
                if ratio < best_ratio - EPS:
                    best_r, best_ratio = r, ratio
        if best_r is None:
            for n in unfrozen:
                rates[n] = 0.0
            return
        frozen_now = [n for n in unfrozen if best_r in paths[n]]
        for n in frozen_now:
            alloc = weight(n) * best_ratio
            rates[n] = alloc
            for r in paths[n]:
                residual[r] = max(0.0, residual[r] - alloc)
        unfrozen = [n for n in unfrozen if n not in frozen_now]


def max_min_rates(paths, capacity,
                  weights: Optional[dict[str, float]] = None,
                  ) -> dict[str, float]:
    """Weighted max-min fair rates for flows over shared links.

    ``paths``: flow → iterable of links; ``capacity``: link → bandwidth.
    A pure function of its inputs — the Simulator's per-event allocation
    reduces to it within each priority class, and the fabric property
    tests check its invariants directly on random topologies.
    """
    p = {n: tuple(ls) for n, ls in paths.items()}
    residual = {r: float(capacity[r]) for ls in p.values() for r in ls}
    w = weights or {}
    rates: dict[str, float] = {}
    waterfill(sorted(p), p, lambda n: w.get(n, 1.0), residual, rates)
    return rates


@dataclasses.dataclass
class SimResult:
    start: dict[str, float]
    finish: dict[str, float]
    makespan: float
    job_completion: dict[str, float]

    def jct(self, job: str) -> float:
        return self.job_completion[job]


@dataclasses.dataclass
class _State:
    task: MXTask
    work: float = 0.0
    started: Optional[float] = None
    finished: Optional[float] = None
    has_slot: bool = False

    @property
    def done(self) -> bool:
        return self.finished is not None

    def delivered_fraction(self) -> float:
        """Fraction of output delivered downstream (unit granularity)."""
        t = self.task
        if self.done:
            return 1.0
        if t.size <= 0:
            return 1.0
        u = t.effective_unit
        return min(1.0, math.floor(self.work / u + EPS) * u / t.size)


class Simulator:
    def __init__(self, graph: MXDAG, cluster: Optional[Cluster] = None, *,
                 policy: str = "fair",
                 priorities: Optional[dict[str, float]] = None,
                 releases: Optional[dict[str, float]] = None,
                 coflows: Optional[list[set[str]]] = None) -> None:
        if policy not in ("fair", "priority"):
            raise ValueError(f"unknown policy {policy}")
        self.g = graph
        self.cluster = cluster or Cluster.for_graph(graph)
        self.policy = policy
        self.prio = dict(priorities or {})
        self.releases = dict(releases or {})
        self.coflows = [set(c) for c in (coflows or [])]
        # resource paths, resolved once: a compute task's processor pool, a
        # flow's full link path (endpoint NICs only on big-switch clusters)
        self._res: dict[str, tuple[str, ...]] = {
            n: self.cluster.resources_for(t)
            for n, t in graph.tasks.items()}
        self._coflow_of: dict[str, int] = {}
        for i, c in enumerate(self.coflows):
            for n in c:
                if n in self._coflow_of:
                    raise ValueError(f"{n} in two coflows")
                if self.g.tasks[n].kind is not TaskKind.NETWORK:
                    raise ValueError(f"coflow member {n} must be a flow")
                self._coflow_of[n] = i

    # ------------------------------------------------------------------
    def run(self, horizon: float = 1e15) -> SimResult:
        g = self.g
        st = {n: _State(t) for n, t in g.tasks.items()}
        now = 0.0
        slots_free = {f"{h}.{p}": k
                      for h, host in self.cluster.hosts.items()
                      for p, k in host.procs.items()}

        def coflow_done(i: int) -> bool:
            return all(st[m].done for m in self.coflows[i])

        def pred_satisfied_for_start(n: str) -> bool:
            """Can task n begin its first unit now?"""
            for p in g.preds(n):
                e = g.edges[(p, n)]
                ps = st[p]
                ci = self._coflow_of.get(p)
                if ci is not None:
                    if not coflow_done(ci):        # all-or-nothing gating
                        return False
                    continue
                if g.effective_pipelined(e):
                    nu = g.tasks[n].n_units
                    if ps.delivered_fraction() + EPS < 1.0 / nu:
                        return False
                elif not ps.done:
                    return False
            # coflow synchronized start: every member's preds must be done
            ci = self._coflow_of.get(n)
            if ci is not None:
                for m in self.coflows[ci]:
                    for p in g.preds(m):
                        if not st[p].done:
                            return False
            return True

        def work_cap(n: str) -> float:
            """Max work task n may perform given currently delivered inputs.

            Quantized to the *consumer's* unit granularity: unit j may be
            processed only once its full input (fraction (j+1)/n_units) has
            been delivered by every streaming predecessor (Fig. 5).
            """
            t = g.tasks[n]
            cap = t.size
            nu = t.n_units
            for p in g.preds(n):
                e = g.edges[(p, n)]
                if self._coflow_of.get(p) is not None:
                    continue  # gated at start; coflow edges are barriers
                if g.effective_pipelined(e) and not st[p].done:
                    frac = st[p].delivered_fraction()
                    enabled = math.floor(frac * nu + EPS)
                    cap = min(cap, enabled * t.effective_unit)
            return cap

        def release(n: str) -> float:
            return self.releases.get(n, 0.0)

        # main loop ----------------------------------------------------
        guard = 0
        max_iters = 10000 * (len(g.tasks) + 1) + sum(
            t.n_units for t in g.tasks.values())
        while any(not s.done for s in st.values()):
            guard += 1
            if guard > max_iters:
                raise RuntimeError("simulator did not converge (livelock?)")

            # 1) start tasks whose gating allows it
            startable = [n for n, s in st.items()
                         if s.started is None and release(n) <= now + EPS
                         and pred_satisfied_for_start(n)]
            # compute tasks need a free slot; dispatch by (priority, name)
            for n in sorted(startable,
                            key=lambda n: (self.prio.get(n, 0.0), n)):
                t = g.tasks[n]
                if t.kind is TaskKind.COMPUTE:
                    r = t.resources()[0]
                    if slots_free.get(r, 0) >= 1:
                        slots_free[r] -= 1
                        st[n].has_slot = True
                        st[n].started = now
                else:
                    st[n].started = now
                if t.size <= EPS and st[n].started is not None:
                    st[n].finished = now
                    if st[n].has_slot:
                        slots_free[t.resources()[0]] += 1
                        st[n].has_slot = False

            # zero-size completions may unlock more starts immediately
            if any(s.started is not None and s.done and
                   g.tasks[n].size <= EPS for n, s in st.items()):
                # cheap: loop again to re-evaluate gating at same timestamp
                if any(st[n].started is None and release(n) <= now + EPS
                       and pred_satisfied_for_start(n)
                       for n in st):
                    continue

            # 2) rates
            rates = self._allocate_rates(st, work_cap)

            # 3) dt to next boundary
            dt = horizon - now
            progressing = False
            for n, s in st.items():
                if s.done or s.started is None:
                    continue
                r = rates.get(n, 0.0)
                if r <= EPS:
                    continue
                progressing = True
                t = g.tasks[n]
                u = t.effective_unit
                # next unit boundary strictly above current work
                k = math.floor(s.work / u + EPS) + 1
                targets = [min(k * u, t.size), t.size, work_cap(n)]
                for tgt in targets:
                    if tgt > s.work + EPS:
                        dt = min(dt, (tgt - s.work) / r)
            future_rel = [rel for n, rel in self.releases.items()
                          if st[n].started is None and rel > now + EPS]
            if future_rel:
                dt = min(dt, min(future_rel) - now)
            if not progressing:
                if future_rel:
                    now = min(future_rel)
                    continue
                # could be waiting on a compute slot that frees only at a
                # completion — but nothing progresses ⇒ deadlock
                pend = [n for n, s in st.items() if not s.done]
                raise RuntimeError(f"deadlock at t={now:.6g}: {pend}")
            dt = max(dt, 0.0)

            # 4) integrate
            now += dt
            for n, s in st.items():
                if s.done or s.started is None:
                    continue
                r = rates.get(n, 0.0)
                if r > EPS:
                    s.work = min(g.tasks[n].size, s.work + r * dt)

            # 5) completions
            for n, s in st.items():
                t = g.tasks[n]
                if not s.done and s.started is not None \
                        and s.work >= t.size - EPS:
                    s.finished = now
                    if s.has_slot:
                        slots_free[t.resources()[0]] += 1
                        s.has_slot = False

        start = {n: s.started for n, s in st.items()}         # type: ignore
        finish = {n: s.finished for n, s in st.items()}       # type: ignore
        jobs: dict[str, float] = {}
        for n, s in st.items():
            j = g.tasks[n].job
            jobs[j] = max(jobs.get(j, 0.0), s.finished)       # type: ignore
        return SimResult(start=start, finish=finish,
                         makespan=max(finish.values(), default=0.0),
                         job_completion=jobs)

    # ------------------------------------------------------------------
    def _allocate_rates(self, st: dict[str, _State],
                        work_cap) -> dict[str, float]:
        """Instantaneous rates for all runnable tasks.

        Compute tasks: rate 1 while holding a slot and not input-starved.
        Flows: weighted max-min fair within a priority class over every
        link on their path, classes served in strict priority order on
        residual link capacity.  Coflow members get weights ∝ remaining
        work (MADD: finish together).

        Paper semantic (§4.1): a *pipelined* task "enforces the resources to
        be occupied right after the precedent task begins processing, which
        may contend with the tasks on the critical path" — so a flow fed by
        a streaming edge contends in the top priority class once started.
        This is precisely why Principle 1 applies pipelining only when it
        shrinks the makespan (Fig. 3 case 3).
        """
        g = self.g
        rates: dict[str, float] = {}
        flows: list[str] = []
        for n, s in st.items():
            if s.done or s.started is None:
                continue
            if work_cap(n) <= s.work + EPS:
                rates[n] = 0.0           # starved on pipelined input
                continue
            t = g.tasks[n]
            if t.kind is TaskKind.COMPUTE:
                rates[n] = 1.0 if s.has_slot else 0.0
            else:
                flows.append(n)

        if not flows:
            return rates

        residual = {}
        for n in flows:
            for r in self._res[n]:
                residual.setdefault(r, self.cluster.bandwidth(r))

        def weight(n: str) -> float:
            ci = self._coflow_of.get(n)
            if ci is None:
                return 1.0
            rem = {m: g.tasks[m].size - st[m].work for m in self.coflows[ci]
                   if not st[m].done}
            mx = max(rem.values(), default=1.0)
            return max(rem.get(n, 0.0) / mx, 1e-6) if mx > 0 else 1.0

        def flow_class(n: str) -> float:
            # streaming flows occupy bandwidth eagerly (paper §4.1)
            if any(g.effective_pipelined(g.edges[(p, n)])
                   for p in g.preds(n)):
                return 0.0
            return self.prio.get(n, 0.0)

        if self.policy == "priority":
            classes = sorted({flow_class(n) for n in flows})
        else:
            classes = [None]

        for cls in classes:
            group = [n for n in flows
                     if cls is None or flow_class(n) == cls]
            waterfill(group, self._res, weight, residual, rates)
        return rates


def simulate(graph: MXDAG, cluster: Optional[Cluster] = None, *,
             policy: str = "fair",
             priorities: Optional[dict[str, float]] = None,
             releases: Optional[dict[str, float]] = None,
             coflows: Optional[list[set[str]]] = None) -> SimResult:
    return Simulator(graph, cluster, policy=policy, priorities=priorities,
                     releases=releases, coflows=coflows).run()
