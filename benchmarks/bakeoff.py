"""Baseline bake-off: MXDAG vs the abstractions it subsumes.

Runs every scheduler in :data:`repro.core.baselines.BASELINES` — fair
sharing, SEBF/Varys coflow ordering, Shafiee–Ghaderi dependency-graph
coflow scheduling, a Graphene-style "hard stuff first" compute packer,
and Metaflow-style network-DAG scheduling — plus the MXDAG Principle-1
co-scheduler, through the *same* compiled DES on a scenario × topology ×
oversubscription matrix:

- ``mr16x16`` / ``mr16x16_2tier4to1`` — an all-to-all shuffle on a big
  switch and on a 4:1-oversubscribed two-tier core,
- ``ddl128`` — the Fig. 6 layer-wise data-parallel training step
  (MXDAG recovers ByteScheduler's lower-layer-first flow order),
- ``fanin4_4to1`` / ``fanin8_8to1`` / ``fanin8_8to1_hvy`` — the
  oversubscribed cross-rack fan-in; the ``_hvy`` variant makes the
  critical flow *larger* than the rest, the configuration that splits
  DAG-aware from DAG-blind: smallest-bottleneck-first then schedules
  the critical flow last,
- ``ft8_shuffle`` — the sparse cross-pod shuffle on a full-bisection
  fat-tree(8),
- ``layered2k`` — a ~2k-task Graphene-style random layered DAG.

Row families:

- ``bakeoff.<scenario>.<algo>_ms`` — the simulated makespan
  (informational; model time, not wall time, so the perf gate's
  wall-time machinery ignores it),
- ``bakeoff.<scenario>.mxdag_wins`` — 1.0 iff MXDAG's makespan is ≤
  every baseline's on that scenario.  Emitted for the oversubscribed
  rows (and ddl128, where the win is strict); committed in
  ``baseline.json`` and enforced (must equal 1.0) by check_perf.py —
  the headline claim of the reproduction, as a CI gate,
- ``bakeoff.<scenario>.ref_match`` — 1.0 iff every algorithm's Schedule
  produces the same makespan on the flat-array and event-calendar
  engines (the baselines' Schedules round-trip through both engines
  without divergence).

On the symmetric scenarios (``mr16x16``, ``ft8_shuffle``,
``layered2k``) every abstraction reaches the same makespan — fair
sharing is already optimal there, which is the paper's own observation;
the gap opens exactly where asymmetry meets oversubscription.

``--markdown`` prints the README-ready comparison table, ``--figure
PATH`` writes the grouped-bar SVG (see ``benchmarks/figures.py``), and
``--only PREFIX`` / ``--json PATH`` behave as in ``scale.py``.
"""
from __future__ import annotations

import os
import sys

_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)        # so `python benchmarks/bakeoff.py` works

#: column order of the comparison table; mxdag last (the contender)
ALGOS = ("fair", "sebf", "sg_coflow", "graphene", "metaflow", "mxdag")


def scenarios():
    """name → (build thunk, gated): the scenario matrix.

    The thunk returns ``(graph, cluster)``; ``gated`` marks the rows
    whose ``mxdag_wins`` claim is committed to ``baseline.json`` (every
    oversubscribed scenario, plus ddl128 where the win is strict).
    Thunks build lazily so ``--only`` skips construction costs.
    """
    from repro.core import Cluster, Topology, builders

    def mr16():
        return builders.mapreduce("mr", 16, 16), None

    def mr16_2tier():
        g = builders.mapreduce("mr", 16, 16)
        rack0 = sorted({t.host for t in g.compute_tasks()
                        if t.name.startswith("mr.m")})
        rack1 = sorted({t.host for t in g.compute_tasks()
                        if t.name.startswith("mr.r")})
        topo = Topology.two_tier([rack0, rack1], oversubscription=4.0)
        return g, Cluster.from_topology(topo)

    return {
        "mr16x16": (mr16, False),
        "mr16x16_2tier4to1": (mr16_2tier, True),
        "ddl128": (lambda: (builders.ddl(128, push=2.0, pull=2.0), None),
                   True),
        "fanin4_4to1": (lambda: builders.oversubscribed_fanin(
            4, oversubscription=4.0), True),
        "fanin8_8to1": (lambda: builders.oversubscribed_fanin(
            8, oversubscription=8.0), True),
        "fanin8_8to1_hvy": (lambda: builders.oversubscribed_fanin(
            8, oversubscription=8.0, critical_flow_size=2.0), True),
        "ft8_shuffle": (lambda: builders.fat_tree_shuffle(8, stride=2),
                        False),
        "layered2k": (lambda: (builders.random_layered(2000), None),
                      False),
    }


def sweep(only: str | None = None) -> dict[str, dict[str, float]]:
    """scenario → algo → makespan for the (filtered) matrix.

    Every algorithm's Schedule is simulated on **both** DES engines; a
    divergence raises immediately (the property the ``ref_match`` rows
    commit).  ``only`` restricts to scenario names starting with it.
    """
    from repro.core import MXDAGScheduler
    from repro.core.baselines import BASELINES

    out: dict[str, dict[str, float]] = {}
    for name, (make, _) in scenarios().items():
        if only is not None and not name.startswith(only):
            continue
        g, cl = make()
        schedules = {a: f().schedule(g, cl) for a, f in BASELINES.items()}
        schedules["mxdag"] = MXDAGScheduler(
            try_pipelining=False).schedule(g, cl)
        res: dict[str, float] = {}
        for algo in ALGOS:
            s = schedules[algo]
            ms = s.simulate(cl).makespan
            cal = s.simulate(cl, engine="calendar").makespan
            if abs(ms - cal) >= 1e-9:
                raise AssertionError(
                    f"{name}/{algo}: array {ms} != calendar {cal}")
            res[algo] = ms
        out[name] = res
    return out


def bench_rows(only: str | None = None):
    """The ``bakeoff.*`` (name, value, derived) rows for run.py/CI."""
    gated = {n for n, (_, gate) in scenarios().items() if gate}
    rows = []
    for name, res in sweep(only).items():
        best_base = min(v for a, v in res.items() if a != "mxdag")
        for algo in ALGOS:
            rows.append((f"bakeoff.{name}.{algo}_ms", res[algo],
                         f"{algo} makespan (model time)"))
        if name in gated:
            rows.append((f"bakeoff.{name}.mxdag_wins",
                         1.0 if res["mxdag"] <= best_base + 1e-9 else 0.0,
                         f"mxdag {res['mxdag']:g} <= best baseline "
                         f"{best_base:g} (1.0 = validated)"))
        rows.append((f"bakeoff.{name}.ref_match", 1.0,
                     "all schedules: array == calendar makespan "
                     "(sweep() raises on divergence)"))
    return rows


def markdown_table(results: dict[str, dict[str, float]]) -> str:
    """The README-ready comparison table (best non-MXDAG bolded iff it
    beats MXDAG — which the gate forbids on committed rows)."""
    head = "| scenario | " + " | ".join(ALGOS) + " |"
    sep = "|---" * (len(ALGOS) + 1) + "|"
    lines = [head, sep]
    for name, res in results.items():
        best = min(res.values())
        cells = []
        for a in ALGOS:
            v = res[a]
            s = f"{v:g}"
            if v <= best + 1e-9:
                s = f"**{s}**"
            cells.append(s)
        lines.append(f"| {name} | " + " | ".join(cells) + " |")
    return "\n".join(lines)


def main() -> None:
    """CLI driver: CSV rows by default; see module docstring."""
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", metavar="PREFIX", default=None,
                    help="run only scenarios whose name starts with "
                         "PREFIX, e.g. fanin")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the rows as JSON to PATH")
    ap.add_argument("--markdown", action="store_true",
                    help="print the README-ready makespan table instead "
                         "of CSV rows")
    ap.add_argument("--figure", metavar="PATH", default=None,
                    help="write the grouped-bar SVG comparison to PATH")
    args = ap.parse_args()

    if args.markdown or args.figure:
        results = sweep(args.only)
        if args.markdown:
            print(markdown_table(results))
        if args.figure:
            from benchmarks.figures import bakeoff_figure
            bakeoff_figure(results, args.figure)
            print(f"wrote {args.figure}", file=sys.stderr)
        return

    rows = bench_rows(args.only)
    if args.json:        # artifact first: survives a closed stdout pipe
        with open(args.json, "w") as f:
            json.dump([{"name": n, "value": v, "derived": str(d)}
                       for n, v, d in rows], f, indent=2)
    print("name,value,derived")
    for name, value, derived in rows:
        print(f"{name},{value:.6g},{str(derived).replace(',', ';')}")


if __name__ == "__main__":
    main()
