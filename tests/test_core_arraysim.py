"""Flat-array engine tests: golden differentials against the calendar
core (per-task start/finish, makespan, job completion) across policies,
coflows, pipelining, releases, fabrics and Graphene-style random DAGs;
compile caching; and the pure-stdlib fallback with numpy stubbed out.
"""
import importlib
import sys

import pytest

from repro.core import Cluster, MXDAG, Topology, compute, flow
from repro.core import builders
from repro.core import arraysim
from repro.core.simulator import Simulator


def assert_engines_agree(g, cluster=None, **kw):
    a = Simulator(g, cluster, **kw).run()
    c = Simulator(g, cluster, **kw).calendar_run()
    for n in g.tasks:
        assert a.start[n] == pytest.approx(c.start[n], abs=1e-9), n
        assert a.finish[n] == pytest.approx(c.finish[n], abs=1e-9), n
    assert a.makespan == pytest.approx(c.makespan, abs=1e-9)
    assert a.job_completion == pytest.approx(c.job_completion)
    return a


class TestDifferential:
    def test_paper_figures(self):
        assert_engines_agree(builders.fig1_jobs())
        assert_engines_agree(builders.fig1_jobs(), policy="priority",
                             priorities={"f1": 0, "f3": 1})
        assert_engines_agree(builders.fig2a(),
                             coflows=builders.fig2a_coflows())
        for variant in ("b1", "b2", "b3"):
            assert_engines_agree(builders.fig2b(),
                                 coflows=builders.fig2b_coflows(variant))
        for case in range(4):
            assert_engines_agree(builders.fig3_case(case))
            assert_engines_agree(builders.fig3_case(case),
                                 policy="priority", priorities={})

    def test_mapreduce_and_ddl(self):
        assert_engines_agree(builders.mapreduce("mr", 8, 8))
        assert_engines_agree(builders.ddl(32, push=2.0, pull=2.0))

    def test_pipelined_with_priorities(self):
        g = builders.mapreduce("mr", 8, 8, unit_frac=0.125)
        for (s, d) in list(g.edges):
            g.set_pipelined(s, d, True)
        assert_engines_agree(g)
        assert_engines_agree(g, policy="priority",
                             priorities={n: i % 4
                                         for i, n in enumerate(g.tasks)})

    def test_releases_zero_size_and_slots(self):
        g = MXDAG()
        g.add(compute("a", 1.0, "A"))
        g.add(compute("z", 0.0, "A"))
        g.add(compute("b", 1.0, "A"))
        g.add_edge("z", "b")
        assert_engines_agree(g, releases={"a": 3.0, "b": 0.5})
        g = MXDAG()
        for i in range(5):
            g.add(compute(f"c{i}", 1.0 + 0.25 * i, "H"))
        assert_engines_agree(g, policy="priority",
                             priorities={f"c{i}": (i * 7) % 3
                                         for i in range(5)})

    def test_fabrics_and_routes(self):
        g, cl = builders.oversubscribed_fanin(4, oversubscription=4.0)
        assert_engines_agree(g, cl)
        assert_engines_agree(g, cl, policy="priority",
                             priorities={"f0": 0.0})
        g, cl = builders.fat_tree_shuffle(8, stride=2)
        assert_engines_agree(g, cl)
        t = g.tasks["s0_1"]
        alt = cl.candidate_routes(t)[-1]
        assert_engines_agree(g, cl, routes={"s0_1": alt})

    def test_random_layered(self):
        g = builders.random_layered(1200, n_hosts=32, min_width=8,
                                    max_width=32, seed=7)
        res = assert_engines_agree(g)
        ref = Simulator(g)._reference_run()
        assert res.makespan == pytest.approx(ref.makespan, abs=1e-6)

    def test_disjoint_components_with_priorities(self):
        """Component-level reallocation: flow families sharing no links
        refill independently — results must stay per-task identical to
        the calendar core's global refill, across priority classes,
        releases and staggered starts."""
        g = MXDAG("comps")
        for k in range(4):                       # 4 disjoint NIC pairs
            a = g.add(compute(f"a{k}", 0.5 * (k + 1), f"S{k}"))
            for j in range(3):
                f = g.add(flow(f"f{k}_{j}", 1.0 + 0.25 * j,
                               f"S{k}", f"D{k}"))
                c = g.add(compute(f"c{k}_{j}", 0.5, f"D{k}"))
                g.add_edge(a, f)
                g.add_edge(f, c)
        assert_engines_agree(g)
        assert_engines_agree(g, policy="priority",
                             priorities={f"f{k}_{j}": (k + j) % 3
                                         for k in range(4)
                                         for j in range(3)})
        assert_engines_agree(g, releases={"f1_0": 2.5, "a3": 1.0})
        # compile exposes the component structure
        import repro.core.arraysim as asim
        comp = asim.compile_sim(Simulator(g))
        assert comp.n_comps == 4
        ids = {comp.comp_of_net[comp.net_pos[comp.idx[f"f{k}_{j}"]]]
               for k in range(4) for j in range(3)}
        assert len(ids) == 4

    def test_serial_chain_trickle(self):
        """The ddl-style event trickle (coalesced completion events):
        pushes and pulls form two disjoint contention components."""
        g = builders.ddl(48, push=2.0, pull=2.0)
        assert_engines_agree(g)
        pr = {f"push{i}": float(i) for i in range(48)}
        assert_engines_agree(g, policy="priority", priorities=pr)
        import repro.core.arraysim as asim
        comp = asim.compile_sim(Simulator(g))
        assert comp.n_comps == 2
        # plain barrier flows coalesce; compute tasks never do
        push0 = comp.idx["push0"]
        bp0 = comp.idx["BP0"]
        assert comp.simple[push0] and not comp.simple[bp0]

    def test_unit_bearing_flows_not_coalesced(self):
        """A flow with unit boundaries keeps per-task events (its unit
        events pause integration, which coalescing must not skip)."""
        g = builders.ddl(12, push=2.0, pull=2.0, unit_frac=0.25)
        import repro.core.arraysim as asim
        comp = asim.compile_sim(Simulator(g))
        assert not any(comp.simple[i] for i in comp.net_ids)
        assert_engines_agree(g)

    def test_multi_job_completion_map(self):
        j1, j2 = builders.mapreduce_pair()
        merged = MXDAG("both")
        for j in (j1, j2):
            for t in j:
                merged.add(t)
            for e in j.edges.values():
                merged.add_edge(e.src, e.dst, pipelined=e.pipelined)
        res = assert_engines_agree(merged)
        assert set(res.job_completion) == {"job1", "job2"}

    def test_horizon_and_deadlock_semantics(self):
        g = MXDAG()
        g.add(compute("a", 1.0, "A", unit=0.25))
        with pytest.raises(RuntimeError, match="did not converge"):
            Simulator(g).run(horizon=0.5)
        g = MXDAG()
        g.add(compute("a", 1.0, "A", proc="gpu"))
        cl = Cluster.homogeneous(["A"])          # no gpu pool anywhere
        with pytest.raises(RuntimeError, match="deadlock"):
            Simulator(g, cl).run()


class TestEngineSelection:
    def test_engine_argument(self):
        g = builders.fig1_jobs()
        for engine in ("array", "calendar", "reference"):
            assert Simulator(g, engine=engine).run().makespan == 6.0
        with pytest.raises(ValueError, match="unknown engine"):
            Simulator(g, engine="quantum")

    def test_compile_cached_per_graph_version(self):
        g = builders.mapreduce("mr", 4, 4)
        s1 = Simulator(g)
        c1 = arraysim.compile_sim(s1)
        assert arraysim.compile_sim(Simulator(g)) is c1   # same version
        g.set_pipelined(*next(iter(g.edges)), True)
        assert arraysim.compile_sim(Simulator(g)) is not c1

    def test_compile_keyed_by_coflows_and_routes(self):
        g = builders.fig2a()
        base = arraysim.compile_sim(Simulator(g))
        cofl = arraysim.compile_sim(
            Simulator(g, coflows=builders.fig2a_coflows()))
        assert cofl is not base
        assert arraysim.compile_sim(Simulator(g)) is base  # still cached


class TestNumpyFallback:
    def test_stubbed_numpy_import_falls_back(self):
        """The array engine must run pure-stdlib when numpy is absent
        (core CI lane) and produce identical results.  With numpy
        installed, the numpy and stubbed runs are compared against each
        other; either way the stubbed run is diffed against the
        calendar oracle."""
        g = builders.mapreduce("mr", 6, 6, unit_frac=0.25)
        for (s, d) in list(g.edges):
            g.set_pipelined(s, d, True)
        g2, cl2 = builders.oversubscribed_fanin(4, oversubscription=2.0)
        g3 = builders.fig2a()
        cases = [
            (g, None, {}),
            (g2, cl2, dict(policy="priority", priorities={"f0": 0.0})),
            (g3, None, dict(coflows=builders.fig2a_coflows())),
        ]
        had_np = arraysim.np is not None
        with_np = [Simulator(gg, cl, **kw).run()
                   for gg, cl, kw in cases] if had_np else None
        saved = sys.modules.get("numpy")
        sys.modules["numpy"] = None      # import numpy raises ImportError
        try:
            importlib.reload(arraysim)
            assert arraysim.np is None
            without_np = [Simulator(gg.copy(), cl, **kw).run()
                          for gg, cl, kw in cases]
            calendar = [Simulator(gg.copy(), cl, **kw).calendar_run()
                        for gg, cl, kw in cases]
        finally:
            if saved is None:
                del sys.modules["numpy"]
            else:
                sys.modules["numpy"] = saved
            importlib.reload(arraysim)
        assert (arraysim.np is not None) == had_np
        for b, c in zip(without_np, calendar):
            assert b.start == pytest.approx(c.start, abs=1e-9)
            assert b.finish == pytest.approx(c.finish, abs=1e-9)
        if with_np is not None:
            for a, b in zip(with_np, without_np):
                assert a.start == pytest.approx(b.start, abs=1e-9)
                assert a.finish == pytest.approx(b.finish, abs=1e-9)
                assert a.makespan == pytest.approx(b.makespan, abs=1e-12)

    def test_vectorized_waterfill_delegates_without_numpy(self):
        from repro.core.simulator import waterfill
        paths = {"f1": ("A.nic_out", "B.nic_in"),
                 "f2": ("A.nic_out", "C.nic_in")}
        saved = sys.modules.get("numpy")
        sys.modules["numpy"] = None
        try:
            importlib.reload(arraysim)
            res1 = {l: 1.0 for ls in paths.values() for l in ls}
            res2 = dict(res1)
            r1, r2 = {}, {}
            seq1 = arraysim.vectorized_waterfill(
                list(paths), paths, None, res1, r1)
            seq2 = waterfill(list(paths), paths, None, res2, r2)
            assert seq1 == seq2 and r1 == r2 and res1 == res2
        finally:
            if saved is None:
                del sys.modules["numpy"]
            else:
                sys.modules["numpy"] = saved
            importlib.reload(arraysim)
