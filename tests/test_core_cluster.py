"""Unit tests: Cluster construction round-trips and resource resolution.

Satellite coverage for ``Cluster.restricted`` / ``Cluster.from_topology``
and for ``resources_for`` under every topology builder, including the
single-switch ≡ endpoint-NIC equivalence claim in the cluster docstring.
"""
import pytest

from repro.core import (
    Cluster, MXDAG, MXDAGScheduler, Topology, compute, flow, simulate,
)
from repro.core import builders


BUILDERS = {
    "single_switch": lambda: Topology.single_switch(
        [f"h{i}" for i in range(6)], nic=2.0),
    "two_tier": lambda: Topology.two_tier((3, 2), oversubscription=4.0),
    "leaf_spine": lambda: Topology.leaf_spine((2, 3), 2,
                                              oversubscription=2.0),
    "fat_tree": lambda: Topology.fat_tree(4),
}


@pytest.fixture(params=sorted(BUILDERS), name="topo")
def _topo(request):
    return BUILDERS[request.param]()


class TestFromTopologyRoundTrip:
    def test_hosts_and_nic_caps_round_trip(self, topo):
        cl = Cluster.from_topology(topo, procs={"cpu": 2, "gpu": 1})
        assert sorted(cl.hosts) == sorted(topo.hosts())
        for h in topo.hosts():
            assert cl.hosts[h].nic_out == topo.capacity(f"{h}.nic_out")
            assert cl.hosts[h].nic_in == topo.capacity(f"{h}.nic_in")
            assert cl.slots(f"{h}.cpu") == 2
            assert cl.slots(f"{h}.gpu") == 1
        # bandwidth() resolves NICs and fabric links through the topology
        for l, cap in topo.links.items():
            assert cl.bandwidth(l) == cap

    def test_restricted_keeps_topology_and_links(self, topo):
        cl = Cluster.from_topology(topo)
        keep = set(topo.hosts()[:2])
        sub = cl.restricted(keep)
        assert set(sub.hosts) == keep
        assert sub.topology is cl.topology
        # full link set still resolvable (other hosts' flows just never
        # appear); routed resources of kept hosts are unchanged
        for l, cap in topo.links.items():
            assert sub.bandwidth(l) == cap
        h0, h1 = sorted(keep)
        f = flow("f", 1.0, h0, h1)
        assert sub.resources_for(f) == cl.resources_for(f)

    def test_for_graph_restricts_from_topology(self, topo):
        hs = topo.hosts()
        g = MXDAG("pair")
        g.add(compute("a", 1.0, hs[0]))
        g.add(flow("f", 1.0, hs[0], hs[-1]))
        g.add(compute("b", 1.0, hs[-1]))
        g.add_edge("a", "f")
        g.add_edge("f", "b")
        cl = Cluster.for_graph(g, topology=topo)
        assert set(cl.hosts) == {hs[0], hs[-1]}
        assert cl.resources_for(g.tasks["f"]) == topo.path(hs[0], hs[-1])


class TestResourcesFor:
    def test_compute_resources_ignore_topology(self, topo):
        h = topo.hosts()[0]
        cl = Cluster.from_topology(topo, procs={"gpu": 1})
        t = compute("c", 1.0, h, proc="gpu")
        assert cl.resources_for(t) == (f"{h}.gpu",)

    def test_flow_resources_follow_the_static_route(self, topo):
        cl = Cluster.from_topology(topo)
        hs = topo.hosts()
        for s, d in [(hs[0], hs[1]), (hs[0], hs[-1]), (hs[-1], hs[0])]:
            f = flow("f", 1.0, s, d)
            res = cl.resources_for(f)
            assert res == topo.path(s, d)
            assert res[0] == f"{s}.nic_out" and res[-1] == f"{d}.nic_in"
            assert res in cl.candidate_routes(f)

    def test_cross_rack_crosses_fabric_links(self):
        cl = Cluster.from_topology(Topology.two_tier((2, 2)))
        f = flow("f", 1.0, "r0h0", "r1h1")
        assert cl.resources_for(f) == (
            "r0h0.nic_out", "rack0.up", "rack1.down", "r1h1.nic_in")

    def test_big_switch_cluster_uses_endpoint_nics(self):
        cl = Cluster.homogeneous(["a", "b"])
        f = flow("f", 1.0, "a", "b")
        assert cl.resources_for(f) == ("a.nic_out", "b.nic_in")
        assert cl.candidate_routes(f) == (("a.nic_out", "b.nic_in"),)


class TestSingleSwitchEquivalence:
    """The cluster docstring's claim: a single-switch topology reproduces
    the endpoint-NIC (big switch) results exactly — same resources, same
    simulation, same scheduling decisions."""

    def test_resources_identical(self):
        g = builders.fig2b()
        hosts = sorted({t.host for t in g.compute_tasks()})
        topo = Topology.single_switch(hosts)
        with_topo = Cluster.for_graph(g, topology=topo)
        without = Cluster.for_graph(g)
        for t in g:
            assert with_topo.resources_for(t) == without.resources_for(t)

    @pytest.mark.parametrize("policy", ["fair", "priority"])
    def test_simulation_bit_exact(self, policy):
        g = builders.fig2b()
        hosts = sorted({t.host for t in g.compute_tasks()})
        prio = (MXDAGScheduler(try_pipelining=False)._priorities(g)
                if policy == "priority" else None)
        seed = simulate(g, policy=policy, priorities=prio)
        topo = Topology.single_switch(hosts)
        fab = simulate(g, Cluster.for_graph(g, topology=topo),
                       policy=policy, priorities=prio)
        assert fab.start == seed.start
        assert fab.finish == seed.finish
        assert fab.makespan == seed.makespan

    def test_schedule_decisions_identical(self):
        g = builders.fig3()
        hosts = sorted({t.host or t.src for t in g} |
                       {t.dst for t in g.network_tasks()})
        hosts = sorted(h for h in hosts if h)
        cl = Cluster.for_graph(g, topology=Topology.single_switch(hosts))
        s0 = MXDAGScheduler().schedule(g)
        s1 = MXDAGScheduler().schedule(g, cl)
        assert s0.priorities == s1.priorities
        assert s0.policy == s1.policy
        assert s0.meta["pipelined"] == s1.meta["pipelined"]
        assert s0.simulate().makespan == s1.simulate(cl).makespan
