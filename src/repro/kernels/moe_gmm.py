"""Grouped (per-expert) matmul kernel for MoE expert FFNs (Pallas TPU).

Computes ``out[e] = x[e] @ w[e]`` for E experts over capacity-gathered
token blocks — the compute core of the EP MoE layer after dispatch.
Grid = (E, C/block_c, f/block_f); each step stages an (block_c, d) token
tile and a (d, block_f) weight tile into VMEM and runs one MXU matmul
with fp32 accumulation, contracting d in ``block_d`` slices to bound the
VMEM working set:

    VMEM ≈ block_c·block_d + block_d·block_f + block_c·block_f  (fp32 acc)

which stays < 2 MiB at the default 128/512/128 tiling even for d=7168.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gmm_kernel(x_ref, w_ref, o_ref, *, block_d: int):
    C, d = x_ref.shape[1], x_ref.shape[2]
    f = w_ref.shape[2]
    nd = d // block_d

    def body(i, acc):
        xb = x_ref[0, :, pl.dslice(i * block_d, block_d)]
        wb = w_ref[0, pl.dslice(i * block_d, block_d), :]
        return acc + jax.lax.dot(xb, wb,
                                 preferred_element_type=jnp.float32)

    acc = jax.lax.fori_loop(
        0, nd, body, jnp.zeros((C, f), jnp.float32))
    o_ref[0] = acc.astype(o_ref.dtype)


def gmm(x: jax.Array, w: jax.Array, *, block_c: int = 128,
        block_f: int = 128, block_d: int = 512,
        interpret: bool = True) -> jax.Array:
    """x: [E, C, d]; w: [E, d, f] → [E, C, f]."""
    E, C, d = x.shape
    f = w.shape[2]
    block_c = min(block_c, C)
    block_f = min(block_f, f)
    block_d = min(block_d, d)
    assert C % block_c == 0 and f % block_f == 0 and d % block_d == 0

    grid = (E, C // block_c, f // block_f)
    kernel = functools.partial(_gmm_kernel, block_d=block_d)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, d), lambda e, i, j: (e, i, 0)),
            pl.BlockSpec((1, d, block_f), lambda e, i, j: (e, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda e, i, j: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, C, f), x.dtype),
        interpret=interpret,
    )(x, w)
