"""Golden differential tests: the incremental event-calendar core must
reproduce the retained reference slow path (the seed simulator loop)
exactly — per-task start/finish, not just makespan — across policies,
coflows, pipelining, releases and fabric topologies, including every
scale-sweep DAG the benchmarks time."""
import pytest

from repro.core import Cluster, MXDAG, Topology, compute, flow
from repro.core import builders
from repro.core.simulator import Simulator


def assert_equivalent(g, cluster=None, **kw):
    new = Simulator(g, cluster, **kw).run()
    ref = Simulator(g, cluster, **kw)._reference_run()
    for n in g.tasks:
        assert new.start[n] == pytest.approx(ref.start[n], abs=1e-6), n
        assert new.finish[n] == pytest.approx(ref.finish[n], abs=1e-6), n
    assert new.makespan == pytest.approx(ref.makespan, abs=1e-6)
    assert new.job_completion == pytest.approx(ref.job_completion)


class TestPaperFigures:
    def test_fig1_policies(self):
        g = builders.fig1_jobs()
        assert_equivalent(g)
        assert_equivalent(g, policy="priority",
                          priorities={"f1": 0, "f3": 1})

    def test_fig2_coflows(self):
        assert_equivalent(builders.fig2a(),
                          coflows=builders.fig2a_coflows())
        g = builders.fig2b()
        for variant in ("b1", "b2", "b3"):
            assert_equivalent(g, coflows=builders.fig2b_coflows(variant))

    @pytest.mark.parametrize("case", [0, 1, 2, 3])
    def test_fig3_pipelining_cases(self, case):
        g = builders.fig3_case(case)
        assert_equivalent(g)
        assert_equivalent(g, policy="priority", priorities={})

    def test_releases_and_zero_size(self):
        g = MXDAG()
        g.add(compute("a", 1.0, "A"))
        g.add(compute("z", 0.0, "A"))
        g.add(compute("b", 1.0, "A"))
        g.add_edge("z", "b")
        assert_equivalent(g, releases={"a": 3.0, "b": 0.5})

    def test_slot_contention_with_priorities(self):
        g = MXDAG()
        for i in range(5):
            g.add(compute(f"c{i}", 1.0 + 0.25 * i, "H"))
        assert_equivalent(g, policy="priority",
                          priorities={f"c{i}": (i * 7) % 3
                                      for i in range(5)})


class TestScaleSweepDAGs:
    """Every DAG the scale benchmark times (identical-makespan contract)."""

    @pytest.mark.parametrize("n", [8, 16, 32])
    def test_mapreduce(self, n):
        assert_equivalent(builders.mapreduce("mr", n, n))

    @pytest.mark.parametrize("layers", [32, 128])
    def test_ddl(self, layers):
        assert_equivalent(builders.ddl(layers, push=2.0, pull=2.0))

    def test_mapreduce_pipelined_units(self):
        g = builders.mapreduce("mr", 8, 8, unit_frac=0.125)
        for (s, d) in list(g.edges):
            g.set_pipelined(s, d, True)
        assert_equivalent(g)
        assert_equivalent(g, policy="priority",
                          priorities={n: i % 4
                                      for i, n in enumerate(g.tasks)})

    def test_ddl_pipelined(self):
        assert_equivalent(
            builders.ddl(16, push=2.0, pull=2.0, unit_frac=0.25))

    def test_fat_tree_shuffle(self):
        topo = Topology.fat_tree(4)
        hosts = topo.hosts()
        g = MXDAG("ft_shuffle")
        for i, s in enumerate(hosts[:8]):
            m = g.add(compute(f"m{i}", 1.0, s))
            for j, d in enumerate(hosts[8:]):
                f = g.add(flow(f"s{i}_{j}", 0.125, s, d))
                g.add_edge(m, f)
        assert_equivalent(g, Cluster.from_topology(topo))

    def test_oversubscribed_fanin(self):
        g, cl = builders.oversubscribed_fanin(4, oversubscription=4.0)
        assert_equivalent(g, cl)
        assert_equivalent(g, cl, policy="priority",
                          priorities={"f0": 0.0, "c0": 0.0})

    def test_random_layered(self):
        """The Graphene-style generator, small enough for the quadratic
        reference oracle (the ≥10k bench instances diff array vs
        calendar instead — see scale.py)."""
        g = builders.random_layered(800, n_hosts=16, min_width=8,
                                    max_width=16, seed=11)
        assert_equivalent(g)
        assert_equivalent(g, policy="priority",
                          priorities={n: i % 3
                                      for i, n in enumerate(g.tasks)})


class TestLivelockGuard:
    def test_event_count_guard_trips_on_horizon_livelock(self):
        """A horizon the work cannot fit inside pins `now` at the horizon
        forever; the event-count guard must abort instead of spinning."""
        g = MXDAG()
        g.chain(compute("a", 1.0, "A", unit=0.25),
                flow("f", 1.0, "A", "B", unit=0.25), pipelined=True)
        with pytest.raises(RuntimeError, match="did not converge"):
            Simulator(g).run(horizon=0.5)
        with pytest.raises(RuntimeError, match="did not converge"):
            Simulator(g)._reference_run(horizon=0.5)

    def test_release_jump_matches_reference(self):
        g = MXDAG()
        g.add(compute("a", 1.0, "A"))
        g.add(compute("b", 1.0, "A"))
        g.add_edge("a", "b")
        assert_equivalent(g, releases={"a": 2.0})

    def test_deadlock_detected(self):
        """A task whose processor pool has no slots can never start; both
        engines must raise the deadlock error instead of hanging."""
        from repro.core import Host
        cl = Cluster([Host("A", procs={"cpu": 1})])
        g = MXDAG()
        g.add(compute("a", 1.0, "A", proc="gpu"))
        with pytest.raises(RuntimeError, match="deadlock"):
            Simulator(g, cl).run()
        with pytest.raises(RuntimeError, match="deadlock"):
            Simulator(g, cl)._reference_run()
