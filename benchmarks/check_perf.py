"""CI perf-regression gate: diff a bench JSON against a committed baseline.

Usage::

    python benchmarks/check_perf.py bench.json benchmarks/baseline.json

Compares every wall-time row (``micro.*`` / ``scale.*`` names ending in
``_us``) present in both files and fails (exit 1) when any row regressed
by more than ``--threshold`` (default 2x).  Rows under ``--floor-us``
(default 50µs) are ignored — at that scale the timer and allocator noise
on shared CI runners dwarfs any real regression.  Rows named
``*.ref_match`` must equal 1.0 (the engine under test diverged from its
oracle — a correctness failure, not a perf one), as must rows named
``*.improves`` (a scheduling decision — e.g. placement on the fat-tree
shuffle — stopped beating its fixed baseline), ``*.mxdag_wins``
(MXDAG's makespan fell behind a baseline scheduler's on a bake-off
scenario — see benchmarks/bakeoff.py; the headline claim of the
reproduction, gated like any other correctness row), ``*.replan_wins``
(live replanning stopped strictly beating the no-replan arm on a
fault-injection scenario — see benchmarks/nemesis.py) and
``*.detected`` (the replan controller missed an injected fault).  ``scale.speedup_array_*``
rows (flat-array engine vs the event-calendar core on the Graphene-scale
scenarios, including the ddl(1024) serial-chain trickle that
component-level reallocation + coalesced completion events lifted from
~1.2x) must stay above ``--speedup-floor`` (default 3x — the committed
numbers are 3.8–7.9x, ddl1024 being the tightest; the floor leaves
room for runner noise while still catching the array engine losing its
edge).  Likewise
``scale.speedup_analytic_*`` (compiled analytic passes vs the dict
implementation, committed ≥10x) is floored at 3x and
``scale.speedup_schedule_mr128x128`` (end-to-end schedule() with
compiled analytics vs the dict pipeline) at 2x;
``scale.speedup_schedule_layered20k`` stays informational — that
workload is DES-bound, so its analytic win is real but small.

Wall-time speed-ups never fail the gate; refresh the baseline with
``--update-baseline`` (regenerates the baseline file in place from the
bench JSON — for intentional optimisations, or when a new runner
generation shifts wall times enough that the committed numbers are
noise) and commit the result.
"""
from __future__ import annotations

import argparse
import json
import sys


def load_rows(path: str) -> dict[str, float]:
    with open(path) as f:
        data = json.load(f)
    return {r["name"]: float(r["value"]) for r in data}


def gated(name: str) -> bool:
    # *_seed_us / *_dict_us rows time frozen "before" implementations
    # (the seed hot paths, the dict analytic passes): informational —
    # their drift tracks runner speed, not a code regression.
    return (name.startswith(("micro.", "scale."))
            and name.endswith("_us")
            and not name.endswith(("_seed_us", "_dict_us")))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("bench", help="freshly produced bench JSON")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("--threshold", type=float, default=2.0,
                    help="fail on wall-time regressions beyond this "
                         "factor (default 2x)")
    ap.add_argument("--floor-us", type=float, default=50.0,
                    help="ignore rows faster than this in the baseline")
    ap.add_argument("--speedup-floor", type=float, default=3.0,
                    help="fail when a scale.speedup_array_* row drops "
                         "below this ratio")
    ap.add_argument("--update-baseline", action="store_true",
                    help="regenerate the baseline file in place from the "
                         "bench JSON instead of gating against it")
    args = ap.parse_args(argv)

    if args.update_baseline:
        with open(args.bench) as f:
            data = json.load(f)
        # a partial bench (scale.py --only, --no-seed, missing deps)
        # must not silently drop gate rows from the committed baseline
        try:
            old = set(load_rows(args.baseline))
        except FileNotFoundError:
            old = set()
        lost = sorted(old - {r["name"] for r in data})
        if lost:
            print(f"refusing to update {args.baseline}: the bench JSON "
                  f"is missing {len(lost)} baseline row(s) (partial "
                  f"run?): {lost}", file=sys.stderr)
            return 1
        with open(args.baseline, "w") as f:
            json.dump(data, f, indent=2)
            f.write("\n")
        print(f"baseline {args.baseline} regenerated from {args.bench} "
              f"({len(data)} rows)")
        return 0

    bench = load_rows(args.bench)
    base = load_rows(args.baseline)

    def speedup_floor(name: str):
        """Gated speedup-claim rows and their floors (None = not a
        gated speedup row)."""
        if name.startswith("scale.speedup_array_"):
            return args.speedup_floor
        if name.startswith("scale.speedup_analytic_"):
            return 3.0
        if name == "scale.speedup_schedule_mr128x128":
            return 2.0
        return None

    failures = []
    for name in sorted(base):
        if name.endswith(".ref_match"):
            if name not in bench:
                failures.append(f"{name}: equivalence row missing from "
                                f"bench output (check never ran)")
            elif bench[name] != 1.0:
                failures.append(f"{name}: engine under test diverged "
                                f"from its oracle")
            continue
        if name.endswith(".improves"):
            if name not in bench:
                failures.append(f"{name}: claim row missing from bench "
                                f"output (check never ran)")
            elif bench[name] != 1.0:
                failures.append(f"{name}: decision no longer beats its "
                                f"fixed baseline")
            continue
        if name.endswith(".mxdag_wins"):
            if name not in bench:
                failures.append(f"{name}: bake-off claim row missing "
                                f"from bench output (check never ran)")
            elif bench[name] != 1.0:
                failures.append(f"{name}: MXDAG no longer matches or "
                                f"beats every baseline scheduler")
            continue
        if name.endswith(".replan_wins"):
            if name not in bench:
                failures.append(f"{name}: recovery claim row missing "
                                f"from bench output (check never ran)")
            elif bench[name] != 1.0:
                failures.append(f"{name}: replanning no longer strictly "
                                f"beats the no-replan arm")
            continue
        if name.endswith(".detected"):
            if name not in bench:
                failures.append(f"{name}: detection row missing from "
                                f"bench output (check never ran)")
            elif bench[name] != 1.0:
                failures.append(f"{name}: the controller missed an "
                                f"injected fault")
            continue
        floor = speedup_floor(name)
        if floor is not None:
            if name not in bench:
                failures.append(f"{name}: speedup row missing from bench "
                                f"output (check never ran)")
            elif bench[name] < floor:
                failures.append(
                    f"{name}: speedup {bench[name]:.2f}x below the "
                    f"{floor:g}x floor")
            continue
        if not gated(name) or name not in bench:
            continue
        old, new = base[name], bench[name]
        if old < args.floor_us:
            continue
        ratio = new / old if old > 0 else float("inf")
        marker = ""
        if ratio > args.threshold:
            marker = "  <-- REGRESSION"
            failures.append(f"{name}: {old:.0f}us -> {new:.0f}us "
                            f"({ratio:.2f}x > {args.threshold:g}x)")
        print(f"{name}: {old:.0f}us -> {new:.0f}us ({ratio:.2f}x){marker}")

    missing = [n for n in base
               if gated(n) and n not in bench]
    if missing:
        failures.append(f"rows missing from bench output: {missing}")

    if failures:
        print("\nperf gate FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("\nperf gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
