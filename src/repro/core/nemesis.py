"""Nemesis: fault injection + live replanning on the compiled DES.

The paper's case for MXDAG's hybrid abstraction is not only better
offline schedules but *runtime adaptation*: with compute and network
tasks in one DAG, a controller that notices a straggler or a failure can
tell which kind it is (§4.3) and answer recovery what-ifs — move this
task, re-path that flow — that neither a coflow scheduler nor a
compute-only DAG scheduler can express.  This module closes that loop
against a *running* simulation:

- :class:`Fault` / :func:`random_faults` — a seeded fault schedule:
  host loss, link degradation, task stragglers (rate multipliers).
- :class:`ReplanController` — the recovery brain.  It feeds observed
  progress into :class:`~repro.core.monitor.Monitor`, diagnoses what
  went wrong (host vs network straggler; which fabric link), updates a
  *belief* cluster (surviving hosts, degraded capacities), re-runs
  :class:`~repro.core.schedule.MXDAGScheduler` warm on the remaining
  work, and applies the recovery through the live simulation's
  mutators (``move_task`` off dead/slow hosts, ``repath_flow`` around
  degraded links, ``set_priorities`` from the warm replan).
- :class:`RecoveryTracker` — the referee: per fault, did the system
  notice (detection), what did it conclude (diagnosis), what did it do
  (actions), and did the run still finish (recovery).
- :class:`Nemesis` — the harness driving both: it advances a
  :class:`~repro.core.arraysim.ResumableSim` between fault times and
  probe ticks, injects each fault at its exact scheduled time via
  ``advance_to`` + the fault mutators, and lets the controller react.

Everything is deterministic: the fault schedule is a pure function of
its seed, probe ticks are a fixed cadence, and the simulation itself is
the bit-reproducible array engine — so every scenario replays exactly.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Optional, Sequence

from repro.core.arraysim import ResumableSim
from repro.core.cluster import Cluster
from repro.core.fabric import is_nic_link, nic_in, nic_out
from repro.core.monitor import Monitor
from repro.core.schedule import MXDAGScheduler, Schedule
from repro.core.simulator import Simulator
from repro.core.task import TaskKind
from repro.core.whatif import follow_moves

FAULT_KINDS = ("host_loss", "link_degrade", "straggler")


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault event.

    ``kind`` is one of :data:`FAULT_KINDS`; ``target`` names the victim
    (a host, a fabric link, or a compute task); ``factor`` is the rate
    multiplier for ``link_degrade``/``straggler`` (ignored for host
    loss — a lost host's slots and NICs go to zero).
    """

    time: float
    kind: str
    target: str
    factor: float = 1.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")


def random_faults(graph, cluster: Cluster, *, horizon: float,
                  n: int = 2, seed: int = 0,
                  kinds: Sequence[str] = FAULT_KINDS,
                  window: tuple[float, float] = (0.15, 0.6),
                  severity: tuple[float, float] = (0.05, 0.25),
                  ) -> list[Fault]:
    """A seeded random fault schedule for a graph/cluster pair.

    Targets are drawn from *sorted* candidate lists through one
    ``random.Random(seed)`` stream, so the schedule is a pure function
    of its arguments (satellite of the determinism requirement: every
    scenario replays bit-exact).  Fault times land in
    ``[window[0], window[1]] * horizon`` — mid-run, where there is
    progress to lose; degradation/straggler factors land in
    ``severity`` (fraction of nominal speed).  Any host may die;
    whether the scenario is recoverable is exactly what the harness
    measures.
    """
    rng = random.Random(seed)
    hosts = sorted(cluster.hosts)
    links = sorted(l for l in
                   (cluster.topology.links if cluster.topology is not None
                    else ())
                   if not is_nic_link(l))
    computes = sorted(t.name for t in graph
                      if t.kind is TaskKind.COMPUTE)
    out: list[Fault] = []
    for _ in range(n):
        choices = [k for k in kinds
                   if (k != "link_degrade" or links)
                   and (k != "straggler" or computes)
                   and (k != "host_loss" or hosts)]
        if not choices:
            break
        kind = rng.choice(choices)
        t = round(rng.uniform(window[0], window[1]) * horizon, 6)
        f = round(rng.uniform(*severity), 6)
        if kind == "host_loss":
            out.append(Fault(t, kind, rng.choice(hosts)))
        elif kind == "link_degrade":
            out.append(Fault(t, kind, rng.choice(links), f))
        else:
            out.append(Fault(t, kind, rng.choice(computes), f))
    return sorted(out, key=lambda x: (x.time, x.kind, x.target))


@dataclasses.dataclass
class FaultRecord:
    """The tracker's verdict on one injected fault."""

    fault: Fault
    injected_at: float
    detected: bool = False
    detected_at: Optional[float] = None
    diagnosis: str = ""
    actions: list = dataclasses.field(default_factory=list)
    recovered: bool = False


class RecoveryTracker:
    """Referee: per injected fault, detection, diagnosis, and recovery."""

    def __init__(self):
        self.records: list[FaultRecord] = []

    def injected(self, fault: Fault, at: float) -> FaultRecord:
        """Register an injected fault; returns its (mutable) record."""
        rec = FaultRecord(fault=fault, injected_at=at)
        self.records.append(rec)
        return rec

    def detection_rate(self) -> float:
        """Fraction of injected faults the controller noticed (1.0 on
        an empty schedule — nothing to miss)."""
        if not self.records:
            return 1.0
        return sum(r.detected for r in self.records) / len(self.records)

    def recovery_rate(self) -> float:
        """Fraction of injected faults after which the run finished."""
        if not self.records:
            return 1.0
        return sum(r.recovered for r in self.records) / len(self.records)

    def report(self) -> str:
        """Markdown recovery table (one row per fault)."""
        lines = ["| t | fault | target | detected | diagnosis | actions |",
                 "|---|-------|--------|----------|-----------|---------|"]
        for r in self.records:
            det = (f"t={r.detected_at:.3g}" if r.detected else "MISSED")
            acts = "; ".join(str(a) for a in r.actions) or "—"
            lines.append(f"| {r.fault.time:.3g} | {r.fault.kind} "
                         f"| {r.fault.target} | {det} "
                         f"| {r.diagnosis or '—'} | {acts} |")
        return "\n".join(lines)


class ReplanController:
    """Live recovery: Monitor-fed detection, belief update, warm replan.

    The controller never reads the fault schedule.  It sees what a real
    control plane would see: heartbeat loss (host failures are
    *announced* via :meth:`on_host_loss` — the one fault class detected
    out-of-band) and per-task progress probes (everything else is
    *inferred* from the Monitor's straggler analysis in :meth:`check`).
    Its belief about the cluster — which hosts survive, what each link's
    usable capacity is — is updated per diagnosis, and every reaction
    ends with a warm :class:`MXDAGScheduler` pass over the remaining
    work on the believed cluster, whose priorities are swapped into the
    running simulation without recompiling.
    """

    def __init__(self, schedule: Schedule, cluster: Cluster,
                 rs: ResumableSim, *,
                 scheduler: Optional[MXDAGScheduler] = None,
                 threshold: float = 0.2,
                 expected=None):
        self.schedule = schedule
        self.graph = schedule.graph
        self.cluster = cluster
        self.rs = rs
        self.scheduler = scheduler or MXDAGScheduler(try_pipelining=False)
        if expected is None:
            expected = schedule.simulate(cluster)
        self.monitor = Monitor(self.graph, expected, threshold=threshold)
        self.dead_hosts: set[str] = set()
        self.degraded: dict[str, float] = {}    # link -> believed capacity
        self.suspect_hosts: set[str] = set()    # believed slow executors
        self.actions: list[tuple] = []          # full action log

    # -- belief --------------------------------------------------------
    def belief_cluster(self) -> Cluster:
        """The cluster as the controller currently believes it to be."""
        cl = self.cluster
        if self.dead_hosts:
            cl = cl.without_hosts(self.dead_hosts)
        if self.degraded:
            cl = cl.degraded(self.degraded)
        return cl

    def probe(self) -> None:
        """Feed the live run's progress into the Monitor (one runtime
        progress report per started task, stamped with the sim clock)."""
        t = self.rs.now
        for name, frac in self.rs.progress().items():
            if self.rs.started_at(name) is not None:
                self.monitor.observe(name, frac, t)

    # -- recovery actions ----------------------------------------------
    def _route_for(self, src: str, dst: str) -> tuple[str, ...]:
        """A believed-good route src→dst: the first ECMP candidate whose
        fabric links are not believed degraded (falling back to the
        static pick when every candidate is suspect)."""
        topo = self.cluster.topology
        if topo is None:
            return (nic_out(src), nic_in(dst))
        cands = topo.paths(src, dst)
        for p in cands:
            if not any(l in self.degraded for l in p):
                return p
        return topo.path(src, dst)

    def _pick_host(self, proc: str, avoid: set[str]) -> Optional[str]:
        """A believed-healthy host with a free ``proc`` slot (most free
        slots first, then name order, skipping ``avoid``)."""
        free = self.rs.free_slots()
        best = None
        for (host, pool), k in sorted(free.items()):
            if pool != proc or k < 1 or host in avoid \
                    or host in self.dead_hosts \
                    or host in self.suspect_hosts:
                continue
            if best is None or k > free[(best, proc)]:
                best = host
        return best

    def _relocate(self, task: str, host: str, why: str) -> list[tuple]:
        """Move compute ``task`` to ``host`` in the live run and carry
        its DAG-derived flows (producer sources / consumer destinations
        — the same :func:`follow_moves` rule the offline what-if uses)
        with it, restarting the carried transfers on believed-good
        routes."""
        acts: list[tuple] = [("move_task", task, host, why)]
        self.rs.move_task(task, host)
        for fname, side in follow_moves(self.graph, task, host).items():
            src, dst = self.rs.flow_ends(fname)
            if side == "src":
                src = host
            else:
                dst = host
            acts.append(("repath_flow", fname, f"{src}->{dst}", why))
            self.rs.repath_flow(fname, self._route_for(src, dst),
                                reset=True, src=src, dst=dst)
        return acts

    def _replan_priorities(self) -> list[tuple]:
        """Warm MXDAGScheduler pass over the remaining work.

        Builds the remaining graph — unfinished tasks only, at their
        *remaining* sizes (ground-truth progress from the live run),
        with current placements/endpoints, keeping only edges between
        unfinished tasks (a finished predecessor is a satisfied
        dependency) — schedules it on the believed cluster, and swaps
        the resulting priorities/policy into the running simulation.
        """
        from repro.core.graph import MXDAG

        rs = self.rs
        prog = rs.progress()
        g = self.graph
        rem = MXDAG(f"{g.name}:replan@{rs.now:.6g}")
        alive = set()
        for name, t in g.tasks.items():
            frac = prog[name]
            if frac >= 1.0:
                continue
            alive.add(name)
            left = max(t.size * (1.0 - frac), 1e-9)
            unit = t.unit
            if unit is not None and unit > left:
                unit = left
            if t.kind is TaskKind.COMPUTE:
                rem.add(dataclasses.replace(
                    t, size=left, unit=unit, host=rs.task_host(name)))
            else:
                src, dst = rs.flow_ends(name)
                rem.add(dataclasses.replace(
                    t, size=left, unit=unit, src=src, dst=dst))
        for (s, d), e in g.edges.items():
            if s in alive and d in alive:
                rem.add_edge(s, d, pipelined=e.pipelined)
        if not alive:
            return []
        # a task still stranded on a dead host (no relocation target was
        # found) cannot be scheduled on the believed cluster — the
        # scenario is unrecoverable and a priority shuffle won't fix it
        for name in alive:
            t = rem.tasks[name]
            ends = ((t.host,) if t.kind is TaskKind.COMPUTE
                    else (t.src, t.dst))
            if any(h in self.dead_hosts for h in ends):
                return []
        plan = self.scheduler.schedule(rem, self.belief_cluster())
        self.rs.set_priorities(plan.priorities, plan.policy)
        return [("set_priorities", len(plan.priorities), plan.policy,
                 "warm replan")]

    # -- fault handlers ------------------------------------------------
    def on_host_loss(self, host: str, restarted: Sequence[str]
                     ) -> list[tuple]:
        """React to an announced host failure: mark it dead, re-place
        every restarted compute stranded on it, re-path every restarted
        flow touching it, and warm-replan priorities on the survivors.
        ``restarted`` is what the failure actually reset (the live
        run's lineage closure) — the work list a real controller would
        get from its task tracker."""
        self.dead_hosts.add(host)
        acts: list[tuple] = []
        for name in restarted:
            t = self.graph.tasks[name]
            if t.kind is TaskKind.COMPUTE \
                    and self.rs.task_host(name) in self.dead_hosts:
                new = self._pick_host(t.proc, avoid={host})
                if new is not None:
                    acts += self._relocate(name, new,
                                           f"host {host} lost")
        carried = {a[1] for a in acts if a[0] == "repath_flow"}
        for name in restarted:
            if self.graph.tasks[name].kind is TaskKind.COMPUTE \
                    or name in carried:
                continue
            src, dst = self.rs.flow_ends(name)
            if src in self.dead_hosts or dst in self.dead_hosts:
                continue        # endpoint compute found no new home
            acts.append(("repath_flow", name, f"{src}->{dst}",
                         f"host {host} lost"))
            self.rs.repath_flow(name, self._route_for(src, dst))
        acts += self._replan_priorities()
        self.actions += acts
        return acts

    def check(self) -> tuple[list[str], list[tuple]]:
        """One probe-tick reaction: feed the Monitor, diagnose
        stragglers, and act.  Returns ``(diagnoses, actions)``.

        - A *compute* straggler (slow executor) is speculatively
          re-executed: moved to a believed-healthy host, its
          DAG-derived flows carried along (re-fetching inputs).
        - *Network* stragglers are attributed to the fabric link most
          shared among their current routes; the belief capacity drops
          to the observed/expected rate ratio and each affected flow is
          re-pathed onto an ECMP alternate avoiding the suspect link,
          keeping transferred progress.
        """
        self.probe()
        diagnoses: list[str] = []
        acts: list[tuple] = []
        mon = self.monitor
        rs = self.rs
        for s in mon.host_stragglers():
            host = rs.task_host(s.task)
            st = rs.started_at(s.task)
            if host is None or host in self.suspect_hosts \
                    or st is None or rs.finished_at(s.task) is not None:
                continue
            # lateness alone is not a slow executor: a task restarted
            # after an upstream fault is behind schedule yet progressing
            # at full rate, and re-executing it would thrash.  Require
            # the *observed* rate to be well below nominal.
            t = self.graph.tasks[s.task]
            elapsed = rs.now - st
            exp_dur = max(mon.expected.finish[s.task]
                          - mon.expected.start[s.task], 1e-12)
            if elapsed <= 1e-12 or (rs.progress()[s.task] * t.size
                                    / elapsed) > 0.7 * (t.size / exp_dur):
                continue
            self.suspect_hosts.add(host)
            diagnoses.append(f"compute straggler {s.task} on {host}")
            new = self._pick_host(t.proc, avoid={host})
            if new is not None:
                acts += self._relocate(s.task, new,
                                       f"straggler on {host}")
        nets = [s for s in mon.network_stragglers()
                if rs.finished_at(s.task) is None
                and rs.started_at(s.task) is not None]
        if nets:
            counts: dict[str, int] = {}
            for s in nets:
                for l in self.rs.flow_route(s.task):
                    if not is_nic_link(l):
                        counts[l] = counts.get(l, 0) + 1
            if counts:
                link = max(sorted(counts), key=counts.__getitem__)
                if link not in self.degraded:
                    est = self._estimate_link_factor(link, nets)
                    cap = self.cluster.bandwidth(link)
                    self.degraded[link] = cap * est
                    diagnoses.append(
                        f"degraded link {link} (~{est:.0%} of nominal)")
                    for s in nets:
                        if link not in self.rs.flow_route(s.task):
                            continue
                        src, dst = self.rs.flow_ends(s.task)
                        route = self._route_for(src, dst)
                        if link in route:
                            continue    # no alternate avoids it
                        acts.append(("repath_flow", s.task,
                                     f"{src}->{dst}",
                                     f"avoid {link}"))
                        self.rs.repath_flow(s.task, route)
        if acts:
            acts += self._replan_priorities()
        self.actions += acts
        return diagnoses, acts

    def _estimate_link_factor(self, link: str, stragglers) -> float:
        """Believed remaining capacity fraction of a suspect link: the
        median observed/expected progress-rate ratio over the straggling
        flows that traverse it (clamped away from 0 — a belief of zero
        would make the replanner treat the link as down)."""
        ratios = []
        exp = self.monitor.expected
        for s in stragglers:
            if link not in self.rs.flow_route(s.task):
                continue
            o = self.monitor.obs.get(s.task)
            st = self.rs.started_at(s.task)
            if o is None or st is None or o.time <= st:
                continue
            exp_rate = 1.0 / max(exp.finish[s.task] - exp.start[s.task],
                                 1e-12)
            obs_rate = o.fraction / (o.time - st)
            ratios.append(obs_rate / max(exp_rate, 1e-12))
        if not ratios:
            return 0.5
        ratios.sort()
        return min(1.0, max(0.02, ratios[len(ratios) // 2]))


@dataclasses.dataclass
class NemesisReport:
    """Outcome of one Nemesis run."""

    makespan: float             # inf when the run never finished
    completed: bool
    tracker: RecoveryTracker
    result: object = None       # SimResult when completed

    @property
    def detection_rate(self) -> float:
        """Tracker detection rate (see RecoveryTracker)."""
        return self.tracker.detection_rate()


class Nemesis:
    """The fault-injection harness: drive a live run, hurt it on
    schedule, and let (or don't let) the controller fight back.

    ``probe_every`` is the controller's progress-report cadence (the
    detection latency floor for inferred faults).  With
    ``replan=False`` faults are injected but nothing reacts — the
    no-replan arm of the recovery benchmark; an unrecoverable fault
    then stalls the run and the report's makespan is ``inf``.

    Straggler semantics: a task's speed multiplier models its current
    *executor*.  When the controller speculatively moves a slowed
    compute task to another host, the harness restores its speed to
    nominal — the new executor is a different machine.
    """

    def __init__(self, schedule: Schedule, cluster: Cluster, *,
                 faults: Sequence[Fault],
                 replan: bool = True,
                 probe_every: float = 0.5,
                 scheduler: Optional[MXDAGScheduler] = None,
                 threshold: float = 0.2,
                 expected=None):
        self.schedule = schedule
        self.cluster = cluster
        self.faults = sorted(faults, key=lambda f: f.time)
        self.replan = replan
        self.probe_every = probe_every
        self.scheduler = scheduler
        self.threshold = threshold
        self.expected = expected

    def _make_rs(self) -> ResumableSim:
        s = self.schedule
        sim = Simulator(s.graph, self.cluster, policy=s.policy,
                        priorities=s.priorities, releases=s.releases,
                        coflows=s.coflows, routes=s.routes or None)
        return ResumableSim(sim)

    def run(self, horizon: float = 1e9) -> NemesisReport:
        """Execute the scenario; returns the :class:`NemesisReport`.

        The loop advances the live simulation to the next fault time or
        probe tick (whichever is sooner), injects/reacts there, and
        repeats.  Deterministic by construction: the timeline is the
        sorted merge of the fault schedule and the fixed probe cadence.
        """
        rs = self._make_rs()
        tracker = RecoveryTracker()
        ctl = (ReplanController(self.schedule, self.cluster, rs,
                                scheduler=self.scheduler,
                                threshold=self.threshold,
                                expected=self.expected)
               if self.replan else None)
        slowed: dict[str, float] = {}
        faults = list(self.faults)
        open_recs: list[FaultRecord] = []
        next_probe = self.probe_every
        idle_probes = 0
        status = "paused"
        while True:
            t_fault = faults[0].time if faults else math.inf
            t = min(t_fault, next_probe if ctl is not None else math.inf)
            if t > horizon:
                status = rs.run_until(horizon, allow_stall=True)
                break
            status = rs.run_until(t, allow_stall=True)
            if status == "done":
                break
            if status == "stalled" and not faults:
                # nothing left to inject and nothing can move: without a
                # controller this is the no-replan arm's dead end; with
                # one, give it a final look before giving up
                if ctl is None:
                    break
                _, acts = ctl.check()
                self._executor_moves(rs, acts, slowed)
                if not acts:
                    break
                continue
            if status != "stalled":
                rs.advance_to(t)
            acted = False
            while faults and faults[0].time <= t:
                f = faults.pop(0)
                rec = tracker.injected(f, rs.now)
                self._inject(rs, f, rec, ctl, slowed)
                if not (rec.detected or ctl is None):
                    open_recs.append(rec)
                acted = True
            if ctl is not None and t >= next_probe - 1e-12:
                while next_probe <= t + 1e-12:
                    next_probe += self.probe_every
                diagnoses, acts = ctl.check()
                self._executor_moves(rs, acts, slowed)
                if diagnoses or acts:
                    idle_probes = 0
                    for rec in open_recs:
                        if not rec.detected and self._matches(
                                rec.fault, diagnoses, ctl):
                            rec.detected = True
                            rec.detected_at = rs.now
                            rec.diagnosis = "; ".join(diagnoses)
                            rec.actions += acts
                    open_recs = [r for r in open_recs if not r.detected]
                else:
                    idle_probes += 1
                acted = acted or bool(acts)
            if status == "stalled" and not acted:
                break
            if ctl is not None and idle_probes > 1000:
                break       # controller idle for 1000 probes: give up
        completed = status == "done" or rs.unfinished == 0
        if not completed and rs.unfinished:
            # drain whatever can still run (e.g. faults exhausted, no
            # controller, nothing stalled) up to the horizon
            status = rs.run_until(horizon, allow_stall=True)
            completed = status == "done"
        result = rs.result() if completed else None
        makespan = result.makespan if completed else math.inf
        for rec in tracker.records:
            rec.recovered = completed
        return NemesisReport(makespan=makespan, completed=completed,
                             tracker=tracker, result=result)

    # ------------------------------------------------------------------
    def _inject(self, rs: ResumableSim, f: Fault, rec: FaultRecord,
                ctl: Optional[ReplanController],
                slowed: dict[str, float]) -> None:
        """Apply one fault to the live run (and, for announced faults,
        notify the controller)."""
        if f.kind == "host_loss":
            restarted = rs.kill_host(f.target)
            if ctl is not None:
                rec.detected = True     # heartbeat loss is announced
                rec.detected_at = rs.now
                rec.diagnosis = f"host {f.target} lost heartbeat"
                acts = ctl.on_host_loss(f.target, restarted)
                rec.actions += acts
                self._executor_moves(rs, acts, slowed)
        elif f.kind == "link_degrade":
            rs.scale_link(f.target, f.factor)
        else:
            rs.set_speed(f.target, f.factor)
            slowed[f.target] = f.factor

    @staticmethod
    def _executor_moves(rs: ResumableSim, acts: Sequence[tuple],
                        slowed: dict[str, float]) -> None:
        """The executor-follows-host rule: a slowed (straggling) task
        the controller just moved runs on a *new* machine — its speed
        multiplier returns to nominal (speculative re-execution)."""
        for a in acts:
            if a and a[0] == "move_task" and a[1] in slowed:
                rs.set_speed(a[1], 1.0)
                del slowed[a[1]]

    @staticmethod
    def _matches(fault: Fault, diagnoses: list[str],
                 ctl: ReplanController) -> bool:
        """Does a diagnosis batch explain ``fault``?  Straggler faults
        match a compute-straggler diagnosis naming the task or its
        host; link faults match a degraded-link diagnosis naming the
        link."""
        if fault.kind == "straggler":
            host = ctl.rs.task_host(fault.target)
            return any(d.startswith("compute straggler")
                       and (fault.target in d
                            or (host is not None and host in d))
                       for d in diagnoses)
        if fault.kind == "link_degrade":
            return any(d.startswith("degraded link")
                       and fault.target in d for d in diagnoses)
        return True
