"""jamba-v0.1-52b — hybrid Mamba+attention 1:7 interleave with MoE.

[arXiv:2403.19887; hf]  32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2.  Attention every 8th layer (index 4 in each
period-8 block), MoE every other layer.  SSM blocks use the SSD (mamba2)
formulation — the TPU-friendly chunked form (see DESIGN.md §2); Jamba's
original Mamba-1 d_state=16 is kept.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    n_experts=16,
    n_experts_per_tok=2,
    moe_d_ff=14336,
    moe_layer_period=2,
    moe_layer_offset=1,            # MoE at odd layer indices (1,3,5,...)
    layer_pattern=("mamba", "mamba", "mamba", "mamba",
                   "attn", "mamba", "mamba", "mamba"),
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv=4,
    rope_theta=1e4,
    sub_quadratic=True,
)
