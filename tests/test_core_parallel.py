"""Parallel what-if sweeps and scheduler candidates.

Pins the three guarantees of :mod:`repro.core.parallel`:

1. **determinism** — ``trial_map`` returns results in trial order no
   matter which worker finishes first, so ``workers=N`` sweeps and
   ``MXDAGScheduler(workers=N)`` schedules are bit-identical to serial
   (including which candidate wins a makespan tie);
2. **crash containment** — a dying worker breaks the pool, the missing
   trials re-run serially with a :class:`RuntimeWarning`, and nothing
   hangs or is silently dropped;
3. **graceful degradation** — ``workers<=1`` or a fork-less platform is
   the plain serial loop.

Everything here is stdlib-only (runs in the numpy-free core lane).
"""
import multiprocessing
import os
import warnings

import pytest

from repro.core import builders
from repro.core.parallel import cpu_count, effective_workers, trial_map
from repro.core.schedule import MXDAGScheduler
from repro.core.whatif import WhatIf

HAVE_FORK = "fork" in multiprocessing.get_all_start_methods()
needs_fork = pytest.mark.skipif(not HAVE_FORK,
                                reason="platform has no fork start method")


class TestTrialMap:
    def test_serial_path(self):
        assert trial_map(lambda x: x * 2, [3, 1, 2]) == [6, 2, 4]
        assert trial_map(lambda x: x * 2, [3, 1, 2], workers=1) == [6, 2, 4]
        assert trial_map(lambda x: x, []) == []

    @needs_fork
    def test_parallel_order_matches_input(self):
        # later trials finish first (reverse sleep) — results must still
        # come back in input order
        import time

        def trial(i):
            time.sleep(0.02 * (4 - i))
            return i * 10
        assert trial_map(trial, range(5), workers=4) == \
            [0, 10, 20, 30, 40]

    @needs_fork
    def test_closure_over_unpicklable_state(self):
        # the trial fn travels via fork, never pickle: closures over
        # arbitrary objects (graphs, schedulers, lambdas) are fine
        hidden = {"fn": lambda x: x + 1}
        out = trial_map(lambda i: hidden["fn"](i), range(4), workers=2)
        assert out == [1, 2, 3, 4]

    @needs_fork
    def test_worker_crash_falls_back_serially(self):
        parent = os.getpid()

        def trial(i):
            if i == 1 and os.getpid() != parent:
                os._exit(17)        # hard crash, only ever in a worker
            return i * 10
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            out = trial_map(trial, range(4), workers=2)
        assert out == [0, 10, 20, 30]
        assert any("worker pool failed" in str(r.message) for r in rec)

    def test_effective_workers(self):
        assert effective_workers(None) == 1
        assert effective_workers(0) == 1
        assert effective_workers(1) == 1
        if HAVE_FORK:
            assert effective_workers(4) == 4
        assert cpu_count() >= 1


@needs_fork
class TestSweepsBitIdentical:
    def test_sweep_unit(self):
        g = builders.mapreduce("mr", 8, 8)
        task = next(iter(g.tasks))
        units = [0.25, 0.5, 1.0, 2.0, None]
        serial = WhatIf(g).sweep_unit(task, units)
        par = WhatIf(g).sweep_unit(task, units, workers=3)
        assert par == serial

    def test_sweep_moves(self):
        g = builders.mapreduce("mr", 6, 6)
        task = next(n for n, t in g.tasks.items()
                    if t.host is not None)
        hosts = sorted({t.host for t in g.tasks.values()
                        if isinstance(t.host, str)})[:4]
        serial = WhatIf(g).sweep_moves(task, hosts)
        par = WhatIf(g).sweep_moves(task, hosts, workers=2)
        assert par == serial

    def test_sweep_routes(self):
        g, cl = builders.fat_tree_shuffle(4, stride=2)
        wi_s, wi_p = WhatIf(g, cl), WhatIf(g, cl)
        flow = next(n for n, t in g.tasks.items()
                    if t.src is not None)
        serial = wi_s.sweep_routes(flow)
        par = wi_p.sweep_routes(flow, workers=2)
        assert par == serial
        assert len(serial) >= 1

    def test_sweep_backfills_cache(self):
        # after a parallel sweep the parent answers the same queries
        # from cache (children's caches die with them)
        g = builders.mapreduce("mr", 6, 6)
        task = next(iter(g.tasks))
        wi = WhatIf(g)
        swept = dict(wi.sweep_unit(task, [0.5, 1.0], workers=2))
        n_keys = len(wi._cache)
        assert wi.set_unit(task, 0.5).variant == swept[0.5]
        assert len(wi._cache) == n_keys        # no new simulation


class TestBestWorkers:
    def _schedules_equal(self, a, b):
        assert a.policy == b.policy
        assert a.priorities == b.priorities
        assert a.releases == b.releases
        assert a.simulate().makespan == b.simulate().makespan

    @needs_fork
    def test_schedule_identical_on_tie(self):
        # a symmetric shuffle: priority and fair tie on makespan, and
        # the serial argmin prefers "priority" — the parallel candidate
        # evaluation must agree on the winner, not just the value
        g = builders.mapreduce("mr", 8, 8)
        ser = MXDAGScheduler(try_pipelining=False).schedule(g)
        par = MXDAGScheduler(try_pipelining=False,
                             workers=2).schedule(g)
        self._schedules_equal(ser, par)
        assert par.policy == "priority"

    @needs_fork
    def test_schedule_identical_with_promotions(self):
        # layered DAG with real non-critical classes: the promote loop
        # may iterate; only the speculative first round is parallel
        g = builders.random_layered(300, n_hosts=16, min_width=4,
                                    max_width=16, seed=5)
        ser = MXDAGScheduler(try_pipelining=False).schedule(g)
        par = MXDAGScheduler(try_pipelining=False,
                             workers=2).schedule(g)
        self._schedules_equal(ser, par)

    def test_workers_none_is_serial(self):
        g = builders.mapreduce("mr", 6, 6)
        self._schedules_equal(
            MXDAGScheduler(try_pipelining=False).schedule(g),
            MXDAGScheduler(try_pipelining=False,
                           workers=None).schedule(g))
