"""Fault injection + live replanning (nemesis) and the resumable engine.

Three layers under test:

1. :class:`ResumableSim` with **zero mutations** must be bit-exact
   against ``array_run`` — pausing, resuming, checkpointing and
   restoring are pure control-flow and may not perturb a single float.
2. The fault mutators (kill/resurrect, host loss, link degradation,
   speed multipliers, task moves, flow re-paths, priority swaps) must
   keep the simulation consistent: no deadlocks, conservation of
   gating, and the documented fault-model semantics.
3. The :class:`Nemesis` harness + :class:`ReplanController` must detect
   every injected fault and strictly beat the no-replan arm on the
   oversubscribed recovery scenarios.
"""
import math

import pytest

from repro.core import builders
from repro.core.arraysim import ResumableSim, array_run
from repro.core.cluster import Cluster
from repro.core.nemesis import (
    Fault, Nemesis, RecoveryTracker, random_faults,
)
from repro.core.schedule import MXDAGScheduler
from repro.core.simulator import Simulator


def scenarios():
    """(name, Simulator factory) for every builder family: the same
    sweep the golden differential tests pin the plain engines on."""
    def fanin():
        g, cl = builders.oversubscribed_fanin(8, oversubscription=4.0)
        return Simulator(g, cl)

    def fanin_prio():
        g, cl = builders.oversubscribed_fanin(6, oversubscription=6.0)
        s = MXDAGScheduler(try_pipelining=False).schedule(g, cl)
        return Simulator(s.graph, cl, policy=s.policy,
                         priorities=s.priorities, releases=s.releases)

    def shuffle():
        g, cl = builders.fat_tree_shuffle(8, stride=2)
        return Simulator(g, cl)

    def ddl():
        g = builders.ddl(8, push=2.0, pull=2.0, unit_frac=0.25)
        return Simulator(g, Cluster.for_graph(g))

    def layered():
        g = builders.random_layered(300, n_hosts=16, min_width=4,
                                    max_width=16, seed=5)
        return Simulator(g, Cluster.for_graph(g))

    def coflows():
        g = builders.fig2a()
        return Simulator(g, coflows=builders.fig2a_coflows())

    return [("fanin", fanin), ("fanin_prio", fanin_prio),
            ("shuffle", shuffle), ("ddl_pipelined", ddl),
            ("layered", layered), ("coflows", coflows)]


@pytest.mark.parametrize("name,mk", scenarios())
class TestZeroFaultBitExact:
    """ref_match: the fault-capable engine with no faults IS array_run."""

    def test_uninterrupted(self, name, mk):
        sim = mk()
        ref = array_run(mk())
        rs = ResumableSim(sim)
        assert rs.run_until(math.inf) == "done"
        res = rs.result()
        assert res.start == ref.start
        assert res.finish == ref.finish
        assert res.makespan == ref.makespan
        assert res.job_completion == ref.job_completion

    def test_paused_every_half_second(self, name, mk):
        ref = array_run(mk())
        rs = ResumableSim(mk())
        t, status = 0.0, "paused"
        while status == "paused":
            status = rs.run_until(t)
            t += 0.5
        assert status == "done"
        assert rs.result().finish == ref.finish

    def test_advance_to_between_events(self, name, mk):
        """Partial work integration into the event gap lands on the
        same schedule to within EPS.  (Bit-exactness is only promised
        for between-event pauses; advance_to splits one rate*dt product
        into two, which may differ in the last ulp — it exists for
        landing faults at exact times, where the run diverges anyway.)"""
        ref = array_run(mk())
        rs = ResumableSim(mk())
        t = 0.3
        while rs.run_until(t) == "paused":
            rs.advance_to(t)        # integrate into the gap
            t += 0.7
        res = rs.result()
        assert res.makespan == pytest.approx(ref.makespan, abs=1e-9)
        for n2, f in ref.finish.items():
            assert res.finish[n2] == pytest.approx(f, abs=1e-9)

    def test_checkpoint_restore_fork(self, name, mk):
        ref = array_run(mk())
        rs = ResumableSim(mk())
        rs.run_until(ref.makespan * 0.4)
        snap = rs.checkpoint()
        assert rs.run_until(math.inf) == "done"
        first = rs.result()
        rs.restore(snap)
        assert rs.run_until(math.inf) == "done"
        second = rs.result()
        assert first.finish == ref.finish
        assert second.finish == ref.finish
        # the snapshot survives restoration: fork a third time
        rs.restore(snap)
        assert rs.run_until(math.inf) == "done"
        assert rs.result().finish == ref.finish

    def test_nemesis_with_empty_fault_schedule(self, name, mk):
        sim = mk()
        ref = array_run(mk())
        from repro.core.schedule import Schedule
        sched = Schedule(graph=sim.g, policy=sim.policy,
                         priorities=dict(sim.prio),
                         releases=dict(sim.releases),
                         coflows=[set(c) for c in sim.coflows] or None)
        rep = Nemesis(sched, sim.cluster, faults=[], replan=False).run()
        assert rep.completed and rep.makespan == ref.makespan
        assert rep.result.finish == ref.finish


class TestSessionControl:
    def test_pause_is_between_events(self):
        g, cl = builders.oversubscribed_fanin(4, oversubscription=4.0)
        rs = ResumableSim(Simulator(g, cl))
        assert rs.run_until(0.0) == "paused"
        assert rs.now == 0.0
        rs.advance_to(0.25)
        assert rs.now == 0.25
        with pytest.raises(ValueError):
            rs.advance_to(1e6)      # would skip events
        with pytest.raises(RuntimeError):
            rs.result()             # unfinished

    def test_progress_projection(self):
        g, cl = builders.oversubscribed_fanin(4, oversubscription=1.0)
        rs = ResumableSim(Simulator(g, cl))
        rs.run_until(0.0)
        p0 = rs.progress()
        assert all(v == 0.0 for n, v in p0.items())
        half = rs.progress(at=0.5)
        assert half["f0"] == pytest.approx(0.5)
        rs.run_until(math.inf)
        assert all(v == 1.0 for v in rs.progress().values())

    def test_introspection(self):
        g, cl = builders.oversubscribed_fanin(4, oversubscription=4.0)
        rs = ResumableSim(Simulator(g, cl))
        rs.run_until(0.0)
        assert rs.started_at("f0") == 0.0
        assert rs.finished_at("f0") is None
        assert rs.task_host("c0") == "d0"
        assert rs.flow_ends("f0") == ("s0", "d0")
        route = rs.flow_route("f0")
        assert route[0] == "s0.nic_out" and route[-1] == "d0.nic_in"
        for l in route:
            assert rs.link_capacity(l) == pytest.approx(cl.bandwidth(l))
        # an untraversed (but real) cluster link reports its static
        # capacity and degrading it is a no-op; garbage names raise
        assert rs.link_capacity("rack0.down") == cl.bandwidth("rack0.down")
        rs.scale_link("rack0.down", 0.5)
        with pytest.raises(KeyError):
            rs.set_link_bw("no_such.link", 1.0)
        # c0 is gated on f0, so d0's slot is free until f0 lands
        assert rs.free_slots()[("d0", "cpu")] == 1
        assert set(rs.unfinished_tasks()) == set(g.tasks)


class TestFaultMutators:
    def mk(self, over=4.0):
        g, cl = builders.oversubscribed_fanin(4, oversubscription=over)
        return g, cl, ResumableSim(Simulator(g, cl))

    def test_kill_task_loses_progress(self):
        g, cl, rs = self.mk()
        rs.run_until(1.0)
        rs.advance_to(1.0)
        assert rs.progress()["f0"] > 0.0
        rs.kill_task("f0")
        assert rs.progress()["f0"] == 0.0
        assert rs.run_until(math.inf) == "done"
        # the killed flow restarted from zero at t=1.0 and still ran
        # under 4:1 fan-in contention
        assert rs.result().makespan > array_run(
            Simulator(g, cl)).makespan - 1e-9

    def test_kill_finished_task_resurrects_and_regates(self):
        g, cl, rs = self.mk(over=1.0)
        rs.run_until(1.0)            # flows (size 1, rate 1) all done
        rs.advance_to(1.0)
        assert rs.progress()["f1"] == 1.0
        c1_started = rs.started_at("c1")
        assert c1_started is not None
        # c1 is running on f1's data: killing f1 must refuse until the
        # consumer is killed too
        with pytest.raises(RuntimeError):
            rs.kill_task("f1")
        rs.kill_task("c1")
        rs.kill_task("f1")
        assert rs.progress()["f1"] == 0.0
        assert rs.run_until(math.inf) == "done"
        # f1 re-ran (1s) then c1 re-ran: finish beyond the fault time
        assert rs.finished_at("c1") >= 2.0 - 1e-9

    def test_set_speed_straggler_and_recovery(self):
        g, cl, rs = self.mk(over=1.0)
        base = array_run(Simulator(g, cl)).makespan
        rs.run_until(0.0)
        rs.set_speed("c0", 0.25)     # slow executor
        assert rs.run_until(math.inf) == "done"
        slow = rs.result().makespan
        assert slow > base + 1e-9
        # a speed of 1.0 is the exact nominal path
        rs2 = ResumableSim(Simulator(g, cl))
        rs2.run_until(0.0)
        rs2.set_speed("c0", 1.0)
        rs2.run_until(math.inf)
        assert rs2.result().finish == array_run(Simulator(g, cl)).finish

    def test_straggling_flow_wastes_its_allocation(self):
        """A slowed flow still *holds* its waterfilled share — the
        allocation is wasted, not redistributed (real fabric: a slow
        receiver does not release its fair share to competitors)."""
        g, cl, rs = self.mk(over=4.0)
        rs.run_until(0.0)
        rs.set_speed("f0", 0.5)
        rs.run_until(1.0)
        rs.advance_to(1.0)
        p = rs.progress()
        # all four flows share d-side NICs equally; f0 progresses at
        # half the allocated rate, the others at the full rate
        assert p["f0"] == pytest.approx(p["f1"] / 2)

    def test_set_link_bw_degrades_and_recovers(self):
        g, cl, rs = self.mk(over=1.0)
        rs.run_until(0.0)
        rs.set_link_bw("d0.nic_in", 0.5)
        rs.run_until(math.inf)
        assert rs.finished_at("f0") == pytest.approx(2.0)
        # scale_link composes on the current capacity
        g2, cl2, rs2 = self.mk(over=1.0)
        rs2.run_until(0.0)
        rs2.scale_link("d0.nic_in", 0.5)
        rs2.scale_link("d0.nic_in", 0.5)
        assert rs2.link_capacity("d0.nic_in") == pytest.approx(0.25)

    def test_kill_host_lineage_resurrection(self):
        """Finished data resident on the dead host is re-produced iff an
        unfinished consumer still needs it."""
        g, cl, rs = self.mk(over=1.0)
        rs.run_until(1.5)            # flows done at 1.0, computes running
        rs.advance_to(1.5)
        restarted = rs.kill_host("d1")
        # f1 delivered to d1 and c1 (its consumer) was unfinished: both
        # restart; finished flows to other hosts are untouched
        assert set(restarted) == {"c1", "f1"}
        assert rs.progress()["f1"] == 0.0
        assert rs.link_capacity("d1.nic_in") == 0.0
        assert rs.free_slots()[("d1", "cpu")] == 0
        # unrecoverable without replanning: c1 has nowhere to run
        assert rs.run_until(math.inf, allow_stall=True) == "stalled"
        # recovery: move c1 (f1 re-fetches to the new home), finish
        rs.move_task("c1", "s1")
        rs.repath_flow("f1", ("s1.nic_out", "s1.nic_in"), dst="s1")
        assert rs.run_until(math.inf) == "done"
        assert rs.task_host("c1") == "s1"
        assert rs.flow_ends("f1") == ("s1", "s1")

    def test_kill_host_after_all_consumers_done_is_noop(self):
        g, cl, rs = self.mk(over=1.0)
        rs.run_until(math.inf)
        ms = rs.result().makespan
        assert rs.kill_host("d1") == []
        assert rs.result().makespan == ms

    def test_move_task_to_shared_pool_contends(self):
        """A moved task competes for the destination pool's slots —
        slot accounting must use the existing pool, not a fresh one."""
        g, cl, rs = self.mk(over=1.0)
        rs.run_until(0.0)
        rs.move_task("c1", "d0")     # d0 has 1 cpu slot, c0 lives there
        rs.repath_flow("f1", ("s1.nic_out", "d0.nic_in"), dst="d0")
        assert rs.run_until(math.inf) == "done"
        # c0 and c1 serialize on d0's single slot
        f = rs.result()
        assert abs(f.finish["c0"] - f.finish["c1"]) >= 1.0 - 1e-9

    def test_repath_merges_contention_components(self):
        """Re-pathing a flow onto another flow's links must merge their
        components — split components sharing a link would double-book
        bandwidth in the waterfill."""
        g, cl, rs = self.mk(over=1.0)
        rs.run_until(0.0)
        # f0 and f1 are disjoint (s0->d0, s1->d1); route f0 through
        # d1's ingress NIC instead
        rs.repath_flow("f0", ("s0.nic_out", "d1.nic_in"),
                       reset=True, dst="d1")
        rs.run_until(1.0)
        rs.advance_to(1.0)
        p = rs.progress()
        # two flows share d1.nic_in (cap 1.0): each gets 0.5
        assert p["f0"] == pytest.approx(0.5)
        assert p["f1"] == pytest.approx(0.5)

    def test_set_priorities_mid_run(self):
        g, cl = builders.oversubscribed_fanin(4, oversubscription=4.0)
        rs = ResumableSim(Simulator(g, cl))
        rs.run_until(0.0)
        # strict priority to f3: it should now finish first
        rs.set_priorities({"f3": 0.0, "f0": 1.0, "f1": 1.0, "f2": 1.0},
                          policy="priority")
        rs.run_until(math.inf)
        f = rs.result()
        assert f.finish["f3"] < min(f.finish["f0"], f.finish["f1"],
                                    f.finish["f2"]) - 1e-9


class TestRandomFaults:
    def test_seeded_schedule_is_deterministic(self):
        g, cl = builders.fat_tree_shuffle(8, stride=2)
        a = random_faults(g, cl, horizon=10.0, n=5, seed=42)
        b = random_faults(g, cl, horizon=10.0, n=5, seed=42)
        assert a == b
        c = random_faults(g, cl, horizon=10.0, n=5, seed=43)
        assert a != c
        assert all(f.kind in ("host_loss", "link_degrade", "straggler")
                   for f in a)
        assert all(1.5 <= f.time <= 6.0 for f in a)

    def test_no_fabric_means_no_link_faults(self):
        g = builders.fig1_jobs()
        cl = Cluster.for_graph(g)      # homogeneous big switch, no topo
        fs = random_faults(g, cl, horizon=10.0, n=8, seed=1)
        assert fs and all(f.kind != "link_degrade" for f in fs)

    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError):
            Fault(1.0, "meteor", "d0")


class TestNemesisRecovery:
    def sched_fanin(self, n=8, over=8.0):
        g, cl = builders.oversubscribed_fanin(n, oversubscription=over)
        return MXDAGScheduler(try_pipelining=False).schedule(g, cl), cl

    def test_host_loss_replan_recovers_no_replan_stalls(self):
        sched, cl = self.sched_fanin()
        faults = [Fault(2.5, "host_loss", "d0")]
        no = Nemesis(sched, cl, faults=faults, replan=False).run()
        yes = Nemesis(sched, cl, faults=faults, replan=True).run()
        assert not no.completed and no.makespan == math.inf
        assert yes.completed and yes.makespan < math.inf
        assert yes.detection_rate == 1.0
        rec = yes.tracker.records[0]
        assert rec.detected and rec.recovered
        assert any(a[0] == "move_task" for a in rec.actions)

    def test_straggler_replan_beats_no_replan(self):
        sched, cl = self.sched_fanin()
        faults = [Fault(1.5, "straggler", "c0", 0.125)]
        no = Nemesis(sched, cl, faults=faults, replan=False).run()
        yes = Nemesis(sched, cl, faults=faults, replan=True).run()
        assert no.completed and yes.completed
        assert yes.makespan < no.makespan - 1e-9
        assert yes.detection_rate == 1.0

    def test_link_degrade_replan_beats_no_replan(self):
        g, cl = builders.fat_tree_shuffle(8, stride=2)
        sched = MXDAGScheduler(try_pipelining=False).schedule(g, cl)
        base = sched.simulate(cl).makespan
        faults = [Fault(base * 0.3, "link_degrade", "p0.e1a2.up", 0.1)]
        no = Nemesis(sched, cl, faults=faults, replan=False,
                     probe_every=0.25).run()
        yes = Nemesis(sched, cl, faults=faults, replan=True,
                      probe_every=0.25).run()
        assert no.completed and yes.completed
        assert yes.makespan < no.makespan - 1e-9
        assert yes.detection_rate == 1.0
        assert "p0.e1a2.up" in yes.tracker.records[0].diagnosis

    def test_scenario_replays_bit_exact(self):
        """The whole fault scenario — schedule, injection, detection,
        recovery — is a pure function of its seeds."""
        sched, cl = self.sched_fanin()
        faults = random_faults(sched.graph, cl, horizon=9.0, n=2, seed=7)
        a = Nemesis(sched, cl, faults=faults, replan=True).run()
        b = Nemesis(sched, cl, faults=faults, replan=True).run()
        assert a.makespan == b.makespan
        assert [r.detected_at for r in a.tracker.records] \
            == [r.detected_at for r in b.tracker.records]
        assert a.tracker.report() == b.tracker.report()

    def test_tracker_report_lists_every_fault(self):
        sched, cl = self.sched_fanin()
        faults = [Fault(1.5, "straggler", "c0", 0.125),
                  Fault(2.5, "host_loss", "d1")]
        rep = Nemesis(sched, cl, faults=faults, replan=True).run()
        table = rep.tracker.report()
        assert "straggler" in table and "host_loss" in table
        assert "MISSED" not in table
        assert len(rep.tracker.records) == 2

    def test_empty_tracker_rates(self):
        t = RecoveryTracker()
        assert t.detection_rate() == 1.0
        assert t.recovery_rate() == 1.0


class TestSimulatorPlumbing:
    def test_resumable_entry_point(self):
        # resolve the class through the module at call time: the numpy
        # fallback test reloads arraysim, invalidating import-time
        # class identity
        from repro.core import arraysim

        g, cl = builders.oversubscribed_fanin(4, oversubscription=4.0)
        sim = Simulator(g, cl)
        rs = sim.resumable()
        assert isinstance(rs, arraysim.ResumableSim)
        rs.run_until(math.inf)
        assert rs.result().makespan == array_run(
            Simulator(g, cl)).makespan
