"""MXDAG core: the paper's abstraction, calculus, schedulers and simulator."""
from repro.core.task import MXTask, TaskKind, compute, flow
from repro.core.graph import MXDAG, Edge, NodeTiming
from repro.core.cluster import Cluster, Host
from repro.core.simulator import SimResult, Simulator, simulate
from repro.core.schedule import (
    AltruisticMultiScheduler,
    CoflowConfig,
    FairShareScheduler,
    MXDAGScheduler,
    Schedule,
    auto_coflows,
)
from repro.core.whatif import WhatIf, WhatIfResult
from repro.core.monitor import Monitor, Straggler

__all__ = [
    "MXTask", "TaskKind", "compute", "flow",
    "MXDAG", "Edge", "NodeTiming",
    "Cluster", "Host",
    "SimResult", "Simulator", "simulate",
    "FairShareScheduler", "CoflowConfig", "MXDAGScheduler",
    "AltruisticMultiScheduler", "Schedule", "auto_coflows",
    "WhatIf", "WhatIfResult", "Monitor", "Straggler",
]
