"""Unit tests: placement and routing as first-class scheduling decisions.

Covers the late-binding task/graph layer (logical tasks, ``MXDAG.bind``
endpoint inference), the fabric candidate-path sets, per-flow route
overrides through Cluster/Simulator, the ``PlacementScheduler`` stage and
routing stage of ``MXDAGScheduler``, and the ``move_task`` /
``reroute_flow`` what-if queries — including the acceptance claims that
placement-enabled scheduling strictly beats fixed placement on the
oversubscribed-fanin and fat_tree(8) shuffle scenarios.
"""
import pytest

from repro.core import (
    Cluster, FairShareScheduler, Host, MXDAG, MXDAGScheduler,
    PlacementScheduler, Topology, WhatIf, compute, flow, simulate,
)
from repro.core import builders


class TestBind:
    def test_inference_from_adjacent_computes(self):
        g = MXDAG()
        a = g.add(compute("a", 1.0))                 # logical
        f = g.add(flow("f", 1.0))                    # endpoints unbound
        b = g.add(compute("b", 1.0))
        g.add_edge(a, f)
        g.add_edge(f, b)
        assert set(g.unbound()) == {"a", "f", "b"}
        bound = g.bind({"a": "H0", "b": "H1"})
        assert bound.unbound() == []
        assert bound.tasks["f"].src == "H0"
        assert bound.tasks["f"].dst == "H1"
        # the original graph is untouched
        assert set(g.unbound()) == {"a", "f", "b"}

    def test_flow_to_flow_handoff_unifies(self):
        # push -> pull chains through an unplaced relay host
        g = MXDAG()
        a = g.add(compute("a", 1.0, "W"))
        push = g.add(flow("push", 1.0, "W", None))
        pull = g.add(flow("pull", 1.0, None, "W"))
        b = g.add(compute("b", 1.0, "W"))
        g.add_edge(a, push)
        g.add_edge(push, pull)
        g.add_edge(pull, b)
        bound = g.bind({"push": (None, "PS")})
        assert bound.tasks["push"].dst == "PS"
        assert bound.tasks["pull"].src == "PS"       # unified handoff

    def test_bind_reproduces_placed_builder_variants(self):
        cases = [
            (builders.mapreduce("mr", 2, 2, placed=False),
             builders.mapreduce("mr", 2, 2),
             {"mr.m0": "mr.M0", "mr.m1": "mr.M1",
              "mr.r0": "mr.R0", "mr.r1": "mr.R1"}),
            (builders.ddl(2, placed=False), builders.ddl(2),
             {"push0": (None, "PS"), "push1": (None, "PS")}),
            (builders.oversubscribed_fanin(2, placed=False)[0],
             builders.oversubscribed_fanin(2)[0],
             {"c0": "d0", "c1": "d1"}),
        ]
        for logical, placed, assignment in cases:
            assert logical.unbound()
            assert not placed.unbound()
            bound = logical.bind(assignment)
            assert bound.signature() == placed.signature()

    def test_conflicting_anchors_rejected(self):
        g = MXDAG()
        a = g.add(compute("a", 1.0))
        f = g.add(flow("f", 1.0))
        g.add_edge(a, f)
        with pytest.raises(ValueError, match="conflicting"):
            g.bind({"a": "H0", "f": ("H1", "H2")})   # src must equal a's host

    def test_unresolved_placement_rejected(self):
        g = MXDAG()
        g.add(compute("a", 1.0))
        g.add(compute("b", 1.0))
        with pytest.raises(ValueError, match="undecided.*'b'"):
            g.bind({"a": "H0"})

    def test_reassigning_bound_endpoint_of_half_bound_flow_rejected(self):
        # regression: a conflicting value for the already-bound endpoint
        # of a partially-bound flow must fail loudly, not be dropped
        g = MXDAG()
        g.add(flow("f", 1.0, "A", None))
        with pytest.raises(ValueError, match="already bound"):
            g.bind({"f": ("B", "H")})
        assert g.bind({"f": ("A", "H")}).tasks["f"].dst == "H"  # consistent

    def test_rebinding_bound_task_rejected(self):
        g = MXDAG()
        g.add(compute("a", 1.0, "H0"))
        with pytest.raises(ValueError, match="already bound"):
            g.bind({"a": "H1"})

    def test_fully_bound_graph_binds_to_itself(self):
        # even one whose endpoints disagree with the co-location rules
        g = MXDAG()
        a = g.add(compute("a", 1.0, "A"))
        f = g.add(flow("f", 1.0, "B", "C"))          # src != a's host
        g.add_edge(a, f)
        bound = g.bind({})
        assert bound.signature() == g.signature()

    def test_simulator_rejects_unbound_graph(self):
        g, cl = builders.oversubscribed_fanin(2, placed=False)
        with pytest.raises(ValueError, match="unbound"):
            simulate(g, cl)

    def test_for_graph_rejects_unbound_graph(self):
        g = builders.mapreduce("mr", 2, 2, placed=False)
        with pytest.raises(ValueError, match="unbound"):
            Cluster.for_graph(g)


class TestCandidatePaths:
    def test_single_switch_and_two_tier_have_one_candidate(self):
        t = Topology.single_switch(["A", "B"])
        assert t.paths("A", "B") == (("A.nic_out", "B.nic_in"),)
        t2 = Topology.two_tier([["a0", "a1"], ["b0"]])
        assert len(t2.paths("a0", "b0")) == 1
        assert len(t2.paths("a0", "a1")) == 1        # intra-rack direct

    def test_leaf_spine_offers_every_spine(self):
        t = Topology.leaf_spine((2, 2), 3)
        cands = t.paths("l0h0", "l1h1")
        assert len(cands) == 3
        assert {p[1] for p in cands} == {
            "leaf0.up0", "leaf0.up1", "leaf0.up2"}

    def test_fat_tree_offers_aggs_and_cores(self):
        t = Topology.fat_tree(4)
        assert len(t.paths("p0e0h0", "p0e1h0")) == 2     # one per agg
        assert len(t.paths("p0e0h0", "p1e0h0")) == 4     # one per core
        assert len(t.paths("p0e0h0", "p0e0h1")) == 1     # same edge

    @pytest.mark.parametrize("make", [
        lambda: Topology.two_tier((2, 2), oversubscription=2.0),
        lambda: Topology.leaf_spine((2, 2), 2),
        lambda: Topology.fat_tree(4),
    ], ids=["two_tier", "leaf_spine", "fat_tree"])
    def test_default_path_is_a_candidate(self, make):
        t = make()
        for s in t.hosts():
            for d in t.hosts():
                if s == d:
                    continue
                cands = t.paths(s, d)
                assert t.path(s, d) in cands
                for p in cands:
                    assert p[0] == f"{s}.nic_out"
                    assert p[-1] == f"{d}.nic_in"
                    assert all(l in t.links for l in p)

    def test_explicit_route_is_sole_candidate(self):
        t = Topology.leaf_spine((2, 2), 2)
        t.add_route("l0h0", "l1h0", ("leaf0.up1", "leaf1.down1"))
        assert t.paths("l0h0", "l1h0") == (
            ("l0h0.nic_out", "leaf0.up1", "leaf1.down1", "l1h0.nic_in"),)

    def test_resized_keeps_candidates(self):
        t = Topology.fat_tree(4)
        r = t.resized(2.0)
        assert r.paths("p0e0h0", "p1e0h0") == t.paths("p0e0h0", "p1e0h0")


class TestRouteOverrides:
    def test_cluster_resources_for_route(self):
        t = Topology.leaf_spine((2, 2), 2)
        cl = Cluster.from_topology(t)
        f = flow("f", 1.0, "l0h0", "l1h0")
        default = cl.resources_for(f)
        alt = next(p for p in cl.candidate_routes(f) if p != default)
        assert cl.resources_for(f, route=alt) == alt
        with pytest.raises(ValueError):
            cl.resources_for(compute("c", 1.0, "l0h0"), route=alt)

    def test_simulator_route_override_changes_contention(self):
        t = Topology.leaf_spine((2, 4), 2, uplink=1.0)
        cl = Cluster.from_topology(t)
        g = MXDAG()
        g.add(flow("f0", 1.0, "l0h0", "l1h0"))
        g.add(flow("f1", 1.0, "l0h1", "l1h1"))   # both hash to spine 0
        assert simulate(g, cl).makespan == pytest.approx(2.0)
        alt = t.paths("l0h1", "l1h1")[1]
        r = simulate(g, cl, routes={"f1": alt})
        assert r.makespan == pytest.approx(1.0)

    def test_simulator_rejects_bad_overrides(self):
        t = Topology.leaf_spine((2, 2), 2)
        cl = Cluster.from_topology(t)
        g = MXDAG()
        g.add(flow("f", 1.0, "l0h0", "l1h0"))
        g.add(compute("c", 1.0, "l1h0"))
        g.add_edge("f", "c")
        ok = ("l0h0.nic_out", "l1h0.nic_in")
        with pytest.raises(KeyError, match="unknown task"):
            simulate(g, cl, routes={"zzz": ok})
        with pytest.raises(ValueError, match="network"):
            simulate(g, cl, routes={"c": ok})
        with pytest.raises(KeyError, match="unknown fabric links"):
            simulate(g, cl, routes={
                "f": ("l0h0.nic_out", "nope", "l1h0.nic_in")})
        # a route between the wrong hosts would uncharge the real NICs
        with pytest.raises(ValueError, match="must start"):
            simulate(g, cl, routes={
                "f": ("l0h1.nic_out", "l1h1.nic_in")})

    def test_route_override_does_not_poison_cache(self):
        t = Topology.leaf_spine((2, 4), 2, uplink=1.0)
        cl = Cluster.from_topology(t)
        g = MXDAG()
        g.add(flow("f0", 1.0, "l0h0", "l1h0"))
        g.add(flow("f1", 1.0, "l0h1", "l1h1"))
        before = simulate(g, cl).makespan
        simulate(g, cl, routes={"f1": t.paths("l0h1", "l1h1")[1]})
        assert simulate(g, cl).makespan == before


class TestPlacementScheduler:
    def test_fanin_placement_strictly_beats_fixed(self):
        """Acceptance: on the oversubscribed fan-in, letting the scheduler
        place the consumers avoids the oversubscribed core entirely."""
        fixed_g, cl = builders.oversubscribed_fanin(4, oversubscription=8.0)
        fixed = MXDAGScheduler(try_pipelining=False) \
            .schedule(fixed_g, cl).simulate(cl)
        logical_g, cl2 = builders.oversubscribed_fanin(
            4, oversubscription=8.0, placed=False)
        sched = MXDAGScheduler(try_pipelining=False) \
            .schedule(logical_g, cl2)
        res = sched.simulate(cl2)
        assert res.makespan < fixed.makespan - 1e-9
        assert res.makespan == pytest.approx(9.0)   # 1 (flow) + 8 (compute)
        assert fixed.makespan == pytest.approx(10.0)
        # every consumer was pulled into rack 0 (hosts s*)
        assert all(h.startswith("s") for h in sched.placement.values())
        # the schedule records the decision and its graph is bound
        assert sched.graph.unbound() == []

    def test_ft8_shuffle_placement_strictly_beats_fixed(self):
        """Acceptance: sparse cross-pod shuffle on fat_tree(8) — ECMP
        core collisions bind the fixed layout; placement avoids them."""
        fixed_g, cl = builders.fat_tree_shuffle(8, stride=2)
        fixed = MXDAGScheduler(try_pipelining=False) \
            .schedule(fixed_g, cl).simulate(cl)
        logical_g, cl2 = builders.fat_tree_shuffle(8, stride=2,
                                                   placed=False)
        placer = PlacementScheduler(des_refine=False)
        res = MXDAGScheduler(try_pipelining=False, placement=placer) \
            .schedule(logical_g, cl2).simulate(cl2)
        assert fixed.makespan == pytest.approx(4.0)
        assert res.makespan == pytest.approx(3.5)
        assert res.makespan < fixed.makespan - 1e-9

    def test_des_refinement_never_hurts(self):
        logical_g, cl = builders.oversubscribed_fanin(
            3, oversubscription=6.0, placed=False)
        heur = MXDAGScheduler(
            try_pipelining=False,
            placement=PlacementScheduler(des_refine=False)) \
            .schedule(logical_g, cl).simulate(cl).makespan
        refined = MXDAGScheduler(
            try_pipelining=False,
            placement=PlacementScheduler(des_refine=True)) \
            .schedule(logical_g, cl).simulate(cl).makespan
        assert refined <= heur + 1e-9

    def test_placement_needs_cluster(self):
        g = builders.mapreduce("mr", 2, 2, placed=False)
        with pytest.raises(ValueError, match="cluster"):
            MXDAGScheduler(try_pipelining=False).schedule(g)

    def test_slot_pressure_spreads_computes(self):
        # 4 logical computes, no flows: land on 4 distinct 1-slot hosts
        g = MXDAG()
        for i in range(4):
            g.add(compute(f"c{i}", 1.0))
        cl = Cluster.homogeneous(["h0", "h1", "h2", "h3"])
        sched = MXDAGScheduler(try_pipelining=False).schedule(g, cl)
        assert sorted(sched.placement.values()) == ["h0", "h1", "h2", "h3"]
        assert sched.simulate(cl).makespan == pytest.approx(1.0)

    def test_proc_pool_constraint_respected(self):
        g = MXDAG()
        g.add(compute("c", 1.0, proc="gpu"))
        cl = Cluster([Host("cpuonly", procs={"cpu": 1}),
                      Host("gpubox", procs={"cpu": 1, "gpu": 1})])
        sched = MXDAGScheduler(try_pipelining=False).schedule(g, cl)
        assert sched.placement == {"c": "gpubox"}


class TestRoutingStage:
    def _collision_case(self):
        t = Topology.leaf_spine((2, 4), 2, uplink=1.0)
        cl = Cluster.from_topology(t)
        g = MXDAG()
        g.add(flow("f0", 1.0, "l0h0", "l1h0"))
        g.add(flow("f1", 1.0, "l0h1", "l1h1"))   # both hash to spine 0
        return g, cl, t

    def test_reroute_resolves_ecmp_collision(self):
        g, cl, t = self._collision_case()
        base = MXDAGScheduler(try_pipelining=False).schedule(g, cl)
        routed = MXDAGScheduler(try_pipelining=False,
                                try_routing=True).schedule(g, cl)
        assert base.simulate(cl).makespan == pytest.approx(2.0)
        assert routed.simulate(cl).makespan == pytest.approx(1.0)
        assert len(routed.routes) == 1               # one flow moved
        (moved, path), = routed.routes.items()
        assert path in t.paths(g.tasks[moved].src, g.tasks[moved].dst)

    def test_routing_off_by_default_and_empty_when_useless(self):
        g, cl, _ = self._collision_case()
        assert MXDAGScheduler(try_pipelining=False) \
            .schedule(g, cl).routes == {}
        # no topology -> nothing to route
        g2 = builders.fig1_jobs()
        assert MXDAGScheduler(try_pipelining=False, try_routing=True) \
            .schedule(g2).routes == {}


def MXDAG_with_gpu_task() -> MXDAG:
    g = MXDAG()
    g.add(compute("c", 1.0, "g0", proc="gpu"))
    return g


class TestWhatIfPlacementRouting:
    def test_move_task(self):
        g, cl = builders.oversubscribed_fanin(4, oversubscription=8.0)
        w = WhatIf(g, cl, scheduler=MXDAGScheduler(try_pipelining=False))
        r = w.move_task("c0", "s1")     # consumer joins the senders' rack
        assert r.baseline == pytest.approx(10.0)
        assert r.variant == pytest.approx(9.0)
        assert r.helps
        with pytest.raises(ValueError):
            w.move_task("f0", "s1")     # flows are rerouted, not moved
        with pytest.raises(KeyError, match="unknown host"):
            w.move_task("c0", "nowhere")
        with pytest.raises(ValueError, match="pool"):
            # hosts in this cluster only have cpu pools
            WhatIf(MXDAG_with_gpu_task(), Cluster.homogeneous(["h0"]),
                   scheduler=MXDAGScheduler(try_pipelining=False)) \
                .move_task("c", "h0")

    def test_move_task_leaves_shared_flows_alone(self):
        # regression: a flow with other compute consumers keeps its
        # destination — only flows exclusive to the moved task follow it.
        # H2's ingress is kept busy, so the buggy rewrite (f.dst -> H2)
        # would halve f's rate and report 4.0 instead of 3.0.
        g = MXDAG()
        a = g.add(compute("a", 1.0, "H0"))
        f = g.add(flow("f", 1.0, "H0", "H1"))
        c1 = g.add(compute("c1", 1.0, "H1"))
        c2 = g.add(compute("c2", 1.0, "H1"))
        g.add(flow("busy", 2.0, "H3", "H2"))         # occupies H2.nic_in
        g.add_edge(a, f)
        g.add_edge(f, c1)
        g.add_edge(f, c2)                            # f is shared
        w = WhatIf(g, Cluster.homogeneous(["H0", "H1", "H2", "H3"]),
                   scheduler=FairShareScheduler())
        r = w.move_task("c1", "H2")
        assert g.tasks["f"].dst == "H1"              # original untouched
        assert r.variant == pytest.approx(3.0)       # f still lands on H1

    def test_reroute_flow(self):
        t = Topology.leaf_spine((2, 4), 2, uplink=1.0)
        cl = Cluster.from_topology(t)
        g = MXDAG()
        g.add(flow("f0", 1.0, "l0h0", "l1h0"))
        g.add(flow("f1", 1.0, "l0h1", "l1h1"))
        w = WhatIf(g, cl, scheduler=MXDAGScheduler(try_pipelining=False))
        r = w.reroute_flow("f1", t.paths("l0h1", "l1h1")[1])
        assert r.baseline == pytest.approx(2.0)
        assert r.variant == pytest.approx(1.0)
        assert r.helps
        with pytest.raises(KeyError):
            w.reroute_flow("zzz", ())
