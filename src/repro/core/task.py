"""MXTask: the node type of an MXDAG (paper §3.1).

An MXTask is either a *compute* task (bound to a host processor) or a
*network* task (a single sender→receiver flow).  Every MXTask carries the two
quantitative annotations the paper defines:

- ``size``  — completion time (seconds) with the **maximum** resource
  assigned (full processor / full NIC bandwidth).  Equivalent to task
  duration in Decima/Graphene.
- ``unit``  — the smallest pipelineable unit, in the same seconds-at-full-
  resource measure.  ``unit == size`` means the task cannot be pipelined.

Completion time under a partial resource assignment ``r ∈ (0, 1]`` is
``size / r`` (paper: "the size can be used to estimate the completion time
when only partial resources are assigned").

Placement is a *decision*, not an intrinsic property: a compute task may be
constructed with ``host=None`` (a logical task whose executing host is
chosen by the scheduler) and a flow with ``src``/``dst`` ``None`` (endpoints
bound late, usually inferred from the placement of the compute tasks it
connects — see :meth:`~repro.core.graph.MXDAG.bind`).  An unbound task has
no resource identity yet: :meth:`MXTask.resources` raises until every
placement field it needs is bound.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Optional


class TaskKind(enum.Enum):
    """The two first-class task kinds of the abstraction (§3.1)."""

    COMPUTE = "compute"
    NETWORK = "network"


@dataclasses.dataclass(frozen=True)
class MXTask:
    """A single physical process (compute) or flow (network) in an MXDAG."""

    name: str
    kind: TaskKind
    size: float                      # seconds at full resource
    unit: Optional[float] = None     # pipeline unit; None => not pipelineable
    # Placement (None = logical / unbound; see MXDAG.bind) -------------
    host: Optional[str] = None       # compute tasks: executing host
    src: Optional[str] = None        # network tasks: sender host
    dst: Optional[str] = None        # network tasks: receiver host
    proc: str = "cpu"                # compute tasks: processor pool on host
    # Bookkeeping ------------------------------------------------------
    job: str = "job0"                # owning MXDAG/job id (multi-job sched)

    def __post_init__(self) -> None:
        if self.size < 0:
            raise ValueError(f"{self.name}: size must be >= 0")
        if self.unit is not None and not (0 < self.unit <= self.size or self.size == 0):
            raise ValueError(f"{self.name}: unit must be in (0, size]")
        if self.kind is TaskKind.COMPUTE and (self.src is not None
                                              or self.dst is not None):
            raise ValueError(f"{self.name}: compute task takes host, "
                             f"not src/dst")
        if self.kind is TaskKind.NETWORK and self.host is not None:
            raise ValueError(f"{self.name}: network task takes src/dst, "
                             f"not host")

    # -- derived -------------------------------------------------------
    @property
    def bound(self) -> bool:
        """True iff every placement field this task needs is set."""
        if self.kind is TaskKind.COMPUTE:
            return self.host is not None
        return self.src is not None and self.dst is not None

    @property
    def pipelineable(self) -> bool:
        """Whether the task has unit structure finer than its size."""
        return self.unit is not None and self.unit < self.size

    @property
    def effective_unit(self) -> float:
        """Unit size; for unpipelineable tasks the paper sets unit = size."""
        return self.unit if self.unit is not None else self.size

    @property
    def n_units(self) -> int:
        """Number of units (``ceil(size / effective_unit)``, min 1)."""
        if self.size == 0:
            return 1
        return max(1, int(math.ceil(self.size / self.effective_unit - 1e-12)))

    def time(self, rsrc: float = 1.0) -> float:
        """Completion time under resource fraction ``rsrc``."""
        if not (0 < rsrc <= 1.0 + 1e-12):
            raise ValueError(f"rsrc must be in (0,1], got {rsrc}")
        return self.size / rsrc

    def unit_time(self, rsrc: float = 1.0) -> float:
        """One unit's completion time under resource fraction ``rsrc``."""
        if not (0 < rsrc <= 1.0 + 1e-12):
            raise ValueError(f"rsrc must be in (0,1], got {rsrc}")
        return self.effective_unit / rsrc

    # -- resource identity --------------------------------------------
    def resources(self, topology=None) -> tuple[str, ...]:
        """Names of the resources this task occupies while running.

        Compute tasks occupy one processor pool.  Network tasks occupy the
        sender's egress NIC and the receiver's ingress NIC — plus, when a
        :class:`~repro.core.fabric.Topology` is given, every fabric link on
        the flow's static route (the flow's rate is capped by the tightest
        link at any instant).
        """
        if not self.bound:
            raise ValueError(
                f"{self.name}: unbound task has no resources yet — apply a "
                f"placement with MXDAG.bind() before simulating")
        if self.kind is TaskKind.COMPUTE:
            return (f"{self.host}.{self.proc}",)
        if topology is not None:
            return tuple(topology.path(self.src, self.dst))
        return (f"{self.src}.nic_out", f"{self.dst}.nic_in")


def compute(name: str, size: float, host: Optional[str] = None, *,
            unit: float | None = None, proc: str = "cpu",
            job: str = "job0") -> MXTask:
    """Convenience constructor for compute MXTasks (``host=None``: logical,
    placed later by the scheduler via :meth:`MXDAG.bind`)."""
    return MXTask(name=name, kind=TaskKind.COMPUTE, size=size, unit=unit,
                  host=host, proc=proc, job=job)


def flow(name: str, size: float, src: Optional[str] = None,
         dst: Optional[str] = None, *,
         unit: float | None = None, job: str = "job0") -> MXTask:
    """Convenience constructor for network MXTasks (``None`` endpoints are
    bound late, usually inferred from adjacent compute placements)."""
    return MXTask(name=name, kind=TaskKind.NETWORK, size=size, unit=unit,
                  src=src, dst=dst, job=job)
