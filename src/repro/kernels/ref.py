"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, scale=None):
    """q: [B,H,S,hd]; k,v: [B,K,T,hd].  Plain softmax attention in fp32."""
    B, H, S, hd = q.shape
    K, T = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    k = jnp.repeat(k, G, axis=1)
    v = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.arange(S)[:, None] >= jnp.arange(T)[None, :]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def ssd_intra_chunk_ref(x, dt, A, Bm, Cm):
    """Oracle for the SSD intra-chunk kernel.

    x: [BH,nc,Q,P], dt: [BH,nc,Q], A: [BH], Bm/Cm: [BG,nc,Q,N].
    Returns (y [BH,nc,Q,P] f32, states [BH,nc,N,P] f32, cum [BH,nc,Q] f32).
    """
    BH, nc, Q, P = x.shape
    BG, N = Bm.shape[0], Bm.shape[3]
    hpg = BH // BG
    f32 = jnp.float32
    x = x.astype(f32)
    dt = dt.astype(f32)
    Bh = jnp.repeat(Bm.astype(f32), hpg, axis=0)
    Ch = jnp.repeat(Cm.astype(f32), hpg, axis=0)

    dA = dt * A[:, None, None]
    cum = jnp.cumsum(dA, axis=-1)
    seg = cum[..., :, None] - cum[..., None, :]
    tril = jnp.tril(jnp.ones((Q, Q), bool))
    Lmat = jnp.where(tril, jnp.exp(jnp.where(tril, seg, 0.0)), 0.0)
    CB = jnp.einsum("hcqn,hckn->hcqk", Ch, Bh)
    xdt = x * dt[..., None]
    y = jnp.einsum("hcqk,hckp->hcqp", CB * Lmat, xdt)
    decay_end = jnp.exp(cum[..., -1:] - cum)
    states = jnp.einsum("hcqn,hcqp->hcnp", Bh * decay_end[..., None], xdt)
    return y, states, cum


def ssd_sequential_ref(x, dt, A, Bm, Cm, init_state=None):
    """Fully sequential SSM recurrence — oracle for the *whole* SSD layer
    (chunked == sequential is the state-space-duality claim itself).

    x: [B,L,H,P], dt: [B,L,H], A: [H], Bm/Cm: [B,L,G,N].
    Returns (y [B,L,H,P], final_state [B,H,P,N]).
    """
    Bsz, L, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hpg = H // G
    f32 = jnp.float32
    Bh = jnp.repeat(Bm.astype(f32), hpg, axis=2)
    Ch = jnp.repeat(Cm.astype(f32), hpg, axis=2)
    xf = x.astype(f32)
    dtf = dt.astype(f32)
    s = (jnp.zeros((Bsz, H, P, N), f32) if init_state is None
         else init_state.astype(f32))

    def step(s, t):
        dec = jnp.exp(dtf[:, t] * A)                       # [B,H]
        s = s * dec[..., None, None] + jnp.einsum(
            "bhn,bhp,bh->bhpn", Bh[:, t], xf[:, t], dtf[:, t])
        y = jnp.einsum("bhn,bhpn->bhp", Ch[:, t], s)
        return s, y

    s, ys = jax.lax.scan(step, s, jnp.arange(L))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), s


def gmm_ref(x, w):
    """x: [E,C,d]; w: [E,d,f] → [E,C,f] (fp32 accumulate)."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)
