"""Nemesis: fault injection + live replanning on the compiled DES.

The paper's case for MXDAG's hybrid abstraction is not only better
offline schedules but *runtime adaptation*: with compute and network
tasks in one DAG, a controller that notices a straggler or a failure can
tell which kind it is (§4.3) and answer recovery what-ifs — move this
task, re-path that flow — that neither a coflow scheduler nor a
compute-only DAG scheduler can express.  This module closes that loop
against a *running* simulation:

- :class:`Fault` / :func:`random_faults` — a seeded fault schedule:
  host loss, link degradation, task stragglers (rate multipliers),
  plus the correlated kinds: ``rack_loss`` (a ToR/edge-switch loss
  whose blast radius — :func:`rack_blast` — takes its fabric links and
  every resident host in one stroke) and ``link_recover`` (the healing
  half of a flapping link; :func:`flapping_link` emits
  degrade→recover→degrade cycles, :func:`fault_storm` packs several
  distinct faults into one overlapping window).
- :class:`ReplanController` — the recovery brain.  It feeds observed
  progress into :class:`~repro.core.monitor.Monitor`, diagnoses what
  went wrong (host vs network straggler; which fabric link), updates a
  *belief* cluster (surviving hosts, degraded capacities), re-runs
  :class:`~repro.core.schedule.MXDAGScheduler` warm on the remaining
  work, and applies the recovery through the live simulation's
  mutators (``move_task`` off dead/slow hosts, ``repath_flow`` around
  degraded links, ``set_priorities`` from the warm replan).  With
  ``cost_aware=True`` it prices every *speculative* move first: the
  compiled analytic critical path (:mod:`repro.core.arrayanalytic`)
  of the remaining work with the straggler at its observed rate vs
  restarting it from zero elsewhere and re-fetching its inputs —
  committing only past a hysteresis margin, under a bounded
  speculation budget with a cooldown that backs off exponentially
  after a losing speculation (so flapping faults cannot thrash it).
- :class:`RecoveryTracker` — the referee: per fault, did the system
  notice (detection), what did it conclude (diagnosis), what did it do
  (actions), and did the run still finish (recovery).
- :class:`Nemesis` — the harness driving both: it advances a
  :class:`~repro.core.arraysim.ResumableSim` between fault times and
  probe ticks, injects each fault at its exact scheduled time via
  ``advance_to`` + the fault mutators, and lets the controller react.

Everything is deterministic: the fault schedule is a pure function of
its seed, probe ticks are a fixed cadence, and the simulation itself is
the bit-reproducible array engine — so every scenario replays exactly.
"""
from __future__ import annotations

import dataclasses
import math
import random
import re
from typing import Optional, Sequence

from repro.core.arraysim import ResumableSim
from repro.core.cluster import Cluster
from repro.core.fabric import is_nic_link, nic_in, nic_out
from repro.core.monitor import Monitor
from repro.core.schedule import MXDAGScheduler, Schedule
from repro.core.simulator import Simulator
from repro.core.task import TaskKind
from repro.core.whatif import follow_moves

#: the independent single-victim fault classes random_faults samples
BASE_FAULT_KINDS = ("host_loss", "link_degrade", "straggler")

#: every injectable kind, including the correlated/cascade ones:
#: ``rack_loss`` (ToR blast radius) and ``link_recover`` (the healing
#: half of a flap — never sampled on its own; it is not a fault)
FAULT_KINDS = BASE_FAULT_KINDS + ("rack_loss", "link_recover")


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault event.

    ``kind`` is one of :data:`FAULT_KINDS`; ``target`` names the victim
    (a host, a fabric link, a compute task, or — for ``rack_loss`` — a
    ToR/edge switch group as named by :func:`tor_groups`); ``factor``
    is the rate multiplier for ``link_degrade``/``straggler`` and the
    restored capacity fraction for ``link_recover`` (ignored for
    host/rack loss — lost slots, NICs and switch links go to zero).
    """

    time: float
    kind: str
    target: str
    factor: float = 1.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")


def random_faults(graph, cluster: Cluster, *, horizon: float,
                  n: int = 2, seed: int = 0,
                  kinds: Sequence[str] = BASE_FAULT_KINDS,
                  window: tuple[float, float] = (0.15, 0.6),
                  severity: tuple[float, float] = (0.05, 0.25),
                  ) -> list[Fault]:
    """A seeded random fault schedule for a graph/cluster pair.

    Targets are drawn from *sorted* candidate lists through one
    ``random.Random(seed)`` stream, so the schedule is a pure function
    of its arguments (satellite of the determinism requirement: every
    scenario replays bit-exact).  Fault times land in
    ``[window[0], window[1]] * horizon`` — mid-run, where there is
    progress to lose; degradation/straggler factors land in
    ``severity`` (fraction of nominal speed).  Any host may die;
    whether the scenario is recoverable is exactly what the harness
    measures.
    """
    rng = random.Random(seed)
    hosts = sorted(cluster.hosts)
    links = sorted(l for l in
                   (cluster.topology.links if cluster.topology is not None
                    else ())
                   if not is_nic_link(l))
    computes = sorted(t.name for t in graph
                      if t.kind is TaskKind.COMPUTE)
    racks = sorted(tor_groups(cluster)) if "rack_loss" in kinds else []
    out: list[Fault] = []
    for _ in range(n):
        choices = [k for k in kinds
                   if (k != "link_degrade" or links)
                   and (k != "straggler" or computes)
                   and (k != "host_loss" or hosts)
                   and (k != "rack_loss" or racks)
                   and k != "link_recover"]
        if not choices:
            break
        kind = rng.choice(choices)
        t = round(rng.uniform(window[0], window[1]) * horizon, 6)
        f = round(rng.uniform(*severity), 6)
        if kind == "host_loss":
            out.append(Fault(t, kind, rng.choice(hosts)))
        elif kind == "link_degrade":
            out.append(Fault(t, kind, rng.choice(links), f))
        elif kind == "rack_loss":
            out.append(Fault(t, kind, rng.choice(racks)))
        else:
            out.append(Fault(t, kind, rng.choice(computes), f))
    return sorted(out, key=lambda x: (x.time, x.kind, x.target))


# ----------------------------------------------------------------------
# correlated fault campaigns (cascades, flaps, storms)
# ----------------------------------------------------------------------
def _switch_group(link: str) -> str:
    """The switch-group name of a fabric link: the link name without
    its ``.up``/``.down`` leaf and any trailing aggregation suffix —
    ``rack0.up → rack0``, ``leaf1.up2 → leaf1``,
    ``p0.e1a2.up → p0.e1`` (the fat-tree edge switch)."""
    stem = link.rsplit(".", 1)[0]
    return re.sub(r"a\d+$", "", stem)


def tor_groups(cluster: Cluster) -> dict[str, tuple[list, list]]:
    """ToR/edge switch groups with resident hosts:
    ``name -> (hosts, links)``.

    A fabric link belongs to group :func:`_switch_group` of its name; a
    host is *resident* in the group that owns every first fabric hop of
    its egress paths — the host's only way into the fabric.  Groups
    without residents (aggregation/core link bundles) are dropped: a
    core-switch loss degrades paths but strands no host, which ECMP
    already models as individual ``link_degrade`` faults.
    """
    topo = cluster.topology
    if topo is None:
        return {}
    groups: dict[str, set] = {}
    for l in topo.links:
        if not is_nic_link(l):
            groups.setdefault(_switch_group(l), set()).add(l)
    if not groups:
        return {}
    hosts = sorted(cluster.hosts)
    first: dict[str, set] = {}
    for h in hosts:
        fl: set = set()
        for d in hosts:
            if d == h:
                continue
            for p in topo.paths(h, d):
                for l in p:
                    if not is_nic_link(l):
                        fl.add(l)
                        break
        first[h] = fl
    out: dict[str, tuple[list, list]] = {}
    for name in sorted(groups):
        resident = [h for h in hosts
                    if first[h] and first[h] <= groups[name]]
        if resident:
            out[name] = (resident, sorted(groups[name]))
    return out


def rack_blast(cluster: Cluster, tor: str) -> tuple[list, list]:
    """Blast radius of losing ToR/edge switch ``tor``:
    ``(resident hosts, switch links)`` — what one ``rack_loss`` fault
    takes down in a single stroke."""
    groups = tor_groups(cluster)
    if tor not in groups:
        raise ValueError(
            f"unknown ToR group {tor!r}; known: {sorted(groups) or '—'}")
    return groups[tor]


def flapping_link(link: str, *, start: float, period: float,
                  cycles: int = 2, factor: float = 0.1) -> list[Fault]:
    """A flapping fabric link: degrade → recover → degrade …

    Cycle ``c`` degrades ``link`` to ``factor`` of nominal at
    ``start + c*period`` and restores full capacity half a period
    later.  Degradation is *grey* (the controller must infer it);
    recovery is announced (``link_recover`` — fabrics advertise
    port-up, it is grey failure that hides).
    """
    if period <= 0 or cycles < 1:
        raise ValueError("need period > 0 and cycles >= 1")
    out: list[Fault] = []
    for c in range(cycles):
        t = start + c * period
        out.append(Fault(round(t, 9), "link_degrade", link, factor))
        out.append(Fault(round(t + period / 2.0, 9),
                         "link_recover", link, 1.0))
    return out


def fault_storm(graph, cluster: Cluster, *, horizon: float,
                n: int = 3, seed: int = 0,
                window: tuple[float, float] = (0.2, 0.4),
                severity: tuple[float, float] = (0.05, 0.25),
                kinds: Sequence[str] = BASE_FAULT_KINDS) -> list[Fault]:
    """A seeded burst of *distinct* overlapping faults.

    Like :func:`random_faults` but with the times packed into a tight
    window (every fault lands while the previous ones are still being
    detected/recovered — simultaneously *active* faults, the storm the
    per-fault attribution machinery exists for) and targets drawn
    without replacement, so no victim is hit twice and the fault mix
    cycles through the available kinds.
    """
    rng = random.Random(seed)
    pools = {
        "host_loss": sorted(cluster.hosts),
        "link_degrade": sorted(
            l for l in (cluster.topology.links
                        if cluster.topology is not None else ())
            if not is_nic_link(l)),
        "straggler": sorted(t.name for t in graph
                            if t.kind is TaskKind.COMPUTE),
        "rack_loss": sorted(tor_groups(cluster))
        if "rack_loss" in kinds else [],
    }
    out: list[Fault] = []
    order = [k for k in kinds if k != "link_recover"]
    i = 0
    while len(out) < n and any(pools.get(k) for k in order):
        kind = order[i % len(order)]
        i += 1
        pool = pools.get(kind) or []
        if not pool:
            continue
        target = pool.pop(rng.randrange(len(pool)))
        t = round(rng.uniform(window[0], window[1]) * horizon, 6)
        f = round(rng.uniform(*severity), 6)
        out.append(Fault(t, kind, target,
                         f if kind in ("link_degrade", "straggler")
                         else 1.0))
    return sorted(out, key=lambda x: (x.time, x.kind, x.target))


@dataclasses.dataclass
class FaultRecord:
    """The tracker's verdict on one injected fault."""

    fault: Fault
    injected_at: float
    detected: bool = False
    detected_at: Optional[float] = None
    diagnosis: str = ""
    actions: list = dataclasses.field(default_factory=list)
    recovered: bool = False


class RecoveryTracker:
    """Referee: per injected fault, detection, diagnosis, and recovery."""

    def __init__(self):
        self.records: list[FaultRecord] = []

    def injected(self, fault: Fault, at: float) -> FaultRecord:
        """Register an injected fault; returns its (mutable) record."""
        rec = FaultRecord(fault=fault, injected_at=at)
        self.records.append(rec)
        return rec

    def detection_rate(self) -> float:
        """Fraction of injected faults the controller noticed (1.0 on
        an empty schedule — nothing to miss)."""
        if not self.records:
            return 1.0
        return sum(r.detected for r in self.records) / len(self.records)

    def recovery_rate(self) -> float:
        """Fraction of injected faults after which the run finished."""
        if not self.records:
            return 1.0
        return sum(r.recovered for r in self.records) / len(self.records)

    def report(self) -> str:
        """Markdown recovery table (one row per fault)."""
        lines = ["| t | fault | target | detected | diagnosis | actions |",
                 "|---|-------|--------|----------|-----------|---------|"]
        for r in self.records:
            det = (f"t={r.detected_at:.3g}" if r.detected else "MISSED")
            acts = "; ".join(str(a) for a in r.actions) or "—"
            lines.append(f"| {r.fault.time:.3g} | {r.fault.kind} "
                         f"| {r.fault.target} | {det} "
                         f"| {r.diagnosis or '—'} | {acts} |")
        return "\n".join(lines)


class ReplanController:
    """Live recovery: Monitor-fed detection, belief update, warm replan.

    The controller never reads the fault schedule.  It sees what a real
    control plane would see: heartbeat loss (host failures are
    *announced* via :meth:`on_host_loss` — the one fault class detected
    out-of-band) and per-task progress probes (everything else is
    *inferred* from the Monitor's straggler analysis in :meth:`check`).
    Its belief about the cluster — which hosts survive, what each link's
    usable capacity is — is updated per diagnosis, and every reaction
    ends with a warm :class:`MXDAGScheduler` pass over the remaining
    work on the believed cluster, whose priorities are swapped into the
    running simulation without recompiling.
    """

    def __init__(self, schedule: Schedule, cluster: Cluster,
                 rs: ResumableSim, *,
                 scheduler: Optional[MXDAGScheduler] = None,
                 threshold: float = 0.2,
                 expected=None,
                 cost_aware: bool = False,
                 hysteresis: float = 0.05,
                 spec_budget: int = 8,
                 spec_cooldown: float = 1.0,
                 link_budget: int = 4):
        self.schedule = schedule
        self.graph = schedule.graph
        self.cluster = cluster
        self.rs = rs
        self.scheduler = scheduler or MXDAGScheduler(try_pipelining=False)
        if expected is None:
            expected = schedule.simulate(cluster)
        self.monitor = Monitor(self.graph, expected, threshold=threshold)
        self.dead_hosts: set[str] = set()
        self.degraded: dict[str, float] = {}    # link -> believed capacity
        self.suspect_hosts: set[str] = set()    # believed slow executors
        self.actions: list[tuple] = []          # full action log
        # -- cost model (inactive unless cost_aware) --
        self.cost_aware = cost_aware
        self.hysteresis = hysteresis
        self.link_budget = link_budget
        self.declined: list[tuple] = []         # (time, what, reason)
        self._spec_left = spec_budget
        self._spec_ok_at = 0.0
        self._base_cooldown = spec_cooldown
        self._cooldown = spec_cooldown
        self._pending: list[tuple] = []         # (task, t0, projected dur)
        self._link_events: dict[str, int] = {}
        self._rebase: dict[str, tuple] = {}     # flow -> (t, frac) at repath

    # -- belief --------------------------------------------------------
    def belief_cluster(self) -> Cluster:
        """The cluster as the controller currently believes it to be."""
        cl = self.cluster
        if self.dead_hosts:
            cl = cl.without_hosts(self.dead_hosts)
        if self.degraded:
            cl = cl.degraded(self.degraded)
        return cl

    def probe(self) -> None:
        """Feed the live run's progress into the Monitor (one runtime
        progress report per started task, stamped with the sim clock)."""
        t = self.rs.now
        for name, frac in self.rs.progress().items():
            if self.rs.started_at(name) is not None:
                self.monitor.observe(name, frac, t)

    # -- recovery actions ----------------------------------------------
    def _route_for(self, src: str, dst: str) -> tuple[str, ...]:
        """A believed-good route src→dst: the first ECMP candidate whose
        fabric links are not believed degraded (falling back to the
        static pick when every candidate is suspect)."""
        topo = self.cluster.topology
        if topo is None:
            return (nic_out(src), nic_in(dst))
        cands = topo.paths(src, dst)
        for p in cands:
            if not any(l in self.degraded for l in p):
                return p
        return topo.path(src, dst)

    def _pick_host(self, proc: str, avoid: set[str]) -> Optional[str]:
        """A believed-healthy host with a free ``proc`` slot (most free
        slots first, then name order, skipping ``avoid``)."""
        free = self.rs.free_slots()
        best = None
        for (host, pool), k in sorted(free.items()):
            if pool != proc or k < 1 or host in avoid \
                    or host in self.dead_hosts \
                    or host in self.suspect_hosts:
                continue
            if best is None or k > free[(best, proc)]:
                best = host
        return best

    def _repath(self, fname: str, route, **kw) -> None:
        """Repath a flow in the live run and *rebase* its progress
        clock: rate judgements after this point start from the flow's
        progress now, so the lifetime average depressed by the old
        route cannot keep implicating the new one."""
        self.rs.repath_flow(fname, route, **kw)
        self._rebase[fname] = (self.rs.now, self.rs.progress()[fname])

    def _recent_rate(self, task: str) -> tuple[float, float]:
        """``(observed, nominal)`` progress rate (fraction per time) of
        a running flow, measured since its last repath (or its start)
        — the window in which its *current* route is the suspect."""
        rs = self.rs
        st = rs.started_at(task)
        frac = rs.progress()[task]
        t0, f0 = self._rebase.get(task, (st, 0.0))
        if t0 < st or f0 > frac:    # restarted since the repath
            self._rebase.pop(task, None)
            t0, f0 = st, 0.0
        exp = self.monitor.expected
        nominal = 1.0 / max(exp.finish[task] - exp.start[task], 1e-12)
        dt = rs.now - t0
        if dt <= 1e-12:
            return nominal, nominal     # no evidence yet: assume fine
        return (frac - f0) / dt, nominal

    def _relocate(self, task: str, host: str, why: str) -> list[tuple]:
        """Move compute ``task`` to ``host`` in the live run and carry
        its DAG-derived flows (producer sources / consumer destinations
        — the same :func:`follow_moves` rule the offline what-if uses)
        with it, restarting the carried transfers on believed-good
        routes."""
        acts: list[tuple] = [("move_task", task, host, why)]
        self.rs.move_task(task, host)
        for fname, side in follow_moves(self.graph, task, host).items():
            src, dst = self.rs.flow_ends(fname)
            if side == "src":
                src = host
            else:
                dst = host
            acts.append(("repath_flow", fname, f"{src}->{dst}", why))
            self._repath(fname, self._route_for(src, dst),
                         reset=True, src=src, dst=dst)
        return acts

    def _remaining_graph(self) -> tuple:
        """The remaining work as an MXDAG: unfinished tasks only, at
        their *remaining* sizes (ground-truth progress from the live
        run), with current placements/endpoints, keeping only edges
        between unfinished tasks (a finished predecessor is a satisfied
        dependency).  Returns ``(rem, alive)``."""
        from repro.core.graph import MXDAG

        rs = self.rs
        prog = rs.progress()
        g = self.graph
        rem = MXDAG(f"{g.name}:replan@{rs.now:.6g}")
        alive = set()
        for name, t in g.tasks.items():
            frac = prog[name]
            if frac >= 1.0:
                continue
            alive.add(name)
            left = max(t.size * (1.0 - frac), 1e-9)
            unit = t.unit
            if unit is not None and unit > left:
                unit = left
            if t.kind is TaskKind.COMPUTE:
                rem.add(dataclasses.replace(
                    t, size=left, unit=unit, host=rs.task_host(name)))
            else:
                src, dst = rs.flow_ends(name)
                rem.add(dataclasses.replace(
                    t, size=left, unit=unit, src=src, dst=dst))
        for (s, d), e in g.edges.items():
            if s in alive and d in alive:
                rem.add_edge(s, d, pipelined=e.pipelined)
        return rem, alive

    def _replan_priorities(self) -> list[tuple]:
        """Warm MXDAGScheduler pass over the remaining work
        (:meth:`_remaining_graph`): schedules it on the believed
        cluster, and swaps the resulting priorities/policy into the
        running simulation.
        """
        rem, alive = self._remaining_graph()
        if not alive:
            return []
        # a task still stranded on a dead host (no relocation target was
        # found) cannot be scheduled on the believed cluster — the
        # scenario is unrecoverable and a priority shuffle won't fix it
        for name in alive:
            t = rem.tasks[name]
            ends = ((t.host,) if t.kind is TaskKind.COMPUTE
                    else (t.src, t.dst))
            if any(h in self.dead_hosts for h in ends):
                return []
        plan = self.scheduler.schedule(rem, self.belief_cluster())
        self.rs.set_priorities(plan.priorities, plan.policy)
        return [("set_priorities", len(plan.priorities), plan.policy,
                 "warm replan")]

    # -- fault handlers ------------------------------------------------
    def on_host_loss(self, host: str, restarted: Sequence[str]
                     ) -> list[tuple]:
        """React to an announced host failure: mark it dead, re-place
        every restarted compute stranded on it, re-path every restarted
        flow touching it, and warm-replan priorities on the survivors.
        ``restarted`` is what the failure actually reset (the live
        run's lineage closure) — the work list a real controller would
        get from its task tracker."""
        self.dead_hosts.add(host)
        acts: list[tuple] = []
        for name in restarted:
            t = self.graph.tasks[name]
            if t.kind is TaskKind.COMPUTE \
                    and self.rs.task_host(name) in self.dead_hosts:
                new = self._pick_host(t.proc, avoid={host})
                if new is not None:
                    acts += self._relocate(name, new,
                                           f"host {host} lost")
        carried = {a[1] for a in acts if a[0] == "repath_flow"}
        for name in restarted:
            if self.graph.tasks[name].kind is TaskKind.COMPUTE \
                    or name in carried:
                continue
            src, dst = self.rs.flow_ends(name)
            if src in self.dead_hosts or dst in self.dead_hosts:
                continue        # endpoint compute found no new home
            acts.append(("repath_flow", name, f"{src}->{dst}",
                         f"host {host} lost"))
            self._repath(name, self._route_for(src, dst))
        acts += self._replan_priorities()
        self.actions += acts
        return acts

    def on_link_recover(self, link: str, capacity: float) -> list[tuple]:
        """React to an announced port-up: restore the link's believed
        capacity (dropping the degraded mark entirely when it is back
        at nominal) and warm-replan so routes may reclaim it."""
        nominal = self.cluster.bandwidth(link)
        if capacity >= nominal - 1e-12:
            self.degraded.pop(link, None)
        else:
            self.degraded[link] = capacity
        acts = self._replan_priorities()
        self.actions += acts
        return acts

    # -- cost model -----------------------------------------------------
    def _move_arm(self, rem, task: str, new_host: str):
        """The what-if graph for speculatively re-executing ``task`` on
        ``new_host``: the remaining graph with the task restarted at
        FULL size (speculation pays the restart) and its carried flows
        (:func:`follow_moves`) restarted at full size on the moved
        endpoint — re-added even when already finished, because the
        live ``_relocate`` restarts them too."""
        from repro.core.graph import MXDAG

        g = self.graph
        carried = follow_moves(g, task, new_host)
        present = set(rem.tasks) | {task} | set(carried)
        arm = MXDAG(f"{rem.name}:move:{task}")
        for name in sorted(present):
            if name == task:
                arm.add(dataclasses.replace(g.tasks[name], host=new_host))
            elif name in carried:
                src, dst = self.rs.flow_ends(name)
                if carried[name] == "src":
                    src = new_host
                else:
                    dst = new_host
                arm.add(dataclasses.replace(g.tasks[name],
                                            src=src, dst=dst))
            else:
                arm.add(rem.tasks[name])
        for (s, d), e in g.edges.items():
            if s in present and d in present:
                arm.add_edge(s, d, pipelined=e.pipelined)
        return arm

    def _speculation_veto(self, task: str, new_host: str,
                          est: float) -> Optional[str]:
        """Is speculatively re-executing ``task`` on ``new_host`` worth
        it?  Returns ``None`` to commit (charging the speculation
        budget and arming the cooldown) or the veto reason.

        Prices both arms with the compiled analytic critical path on
        the remaining graph: *stay* keeps the straggler at its observed
        rate fraction ``est``; *move* restarts it (and its carried
        flows) at full size on the new host.  The move must beat stay
        by the hysteresis margin — near-ties are not worth the restart
        risk.  Committed speculations are tracked; one that finishes
        later than projected doubles the cooldown (exponential backoff
        against flap-driven thrash), an on-time one resets it."""
        from repro.core.arrayanalytic import analyze

        now = self.rs.now
        if self._spec_left <= 0:
            return "speculation budget exhausted"
        if now < self._spec_ok_at - 1e-12:
            return f"speculation cooldown until t={self._spec_ok_at:.4g}"
        rem, alive = self._remaining_graph()
        if task not in alive:
            return None         # raced with completion: nothing to price
        est = min(1.0, max(0.02, est))
        stay = analyze(rem, rsrc={task: est}).makespan
        timing = analyze(self._move_arm(rem, task, new_host))
        if timing.makespan >= stay * (1.0 - self.hysteresis):
            return (f"not worth it: move~{timing.makespan:.4g} vs "
                    f"stay~{stay:.4g}")
        self._spec_left -= 1
        self._spec_ok_at = now + self._cooldown
        self._pending.append(
            (task, now, timing.completion[timing.idx[task]]))
        return None

    def _speculation_feedback(self) -> None:
        """Score finished speculations: losing ones (actual duration
        beyond projection by more than the hysteresis margin) double
        the cooldown; winners reset it."""
        if not self._pending:
            return
        rs = self.rs
        still = []
        for task, t0, proj in self._pending:
            ft = rs.finished_at(task)
            if ft is None:
                still.append((task, t0, proj))
                continue
            if ft - t0 > proj * (1.0 + self.hysteresis) + 1e-9:
                self._cooldown *= 2.0
                self._spec_ok_at = max(self._spec_ok_at,
                                       rs.now + self._cooldown)
                self.declined.append(
                    (rs.now, task,
                     f"losing speculation ({ft - t0:.4g} vs projected "
                     f"{proj:.4g}); cooldown -> {self._cooldown:.4g}"))
            else:
                self._cooldown = self._base_cooldown
        self._pending = still

    def check(self) -> tuple[list[str], list[tuple]]:
        """One probe-tick reaction: feed the Monitor, diagnose
        stragglers, and act.  Returns ``(diagnoses, actions)``.

        - A *compute* straggler (slow executor) is speculatively
          re-executed: moved to a believed-healthy host, its
          DAG-derived flows carried along (re-fetching inputs).
        - *Network* stragglers are attributed to the fabric link most
          shared among their current routes; the belief capacity drops
          to the observed/expected rate ratio and each affected flow is
          re-pathed onto an ECMP alternate avoiding the suspect link,
          keeping transferred progress.
        """
        self.probe()
        if self.cost_aware:
            self._speculation_feedback()
        diagnoses: list[str] = []
        acts: list[tuple] = []
        mon = self.monitor
        rs = self.rs
        for s in mon.host_stragglers():
            host = rs.task_host(s.task)
            st = rs.started_at(s.task)
            if host is None or host in self.suspect_hosts \
                    or st is None or rs.finished_at(s.task) is not None:
                continue
            # lateness alone is not a slow executor: a task restarted
            # after an upstream fault is behind schedule yet progressing
            # at full rate, and re-executing it would thrash.  Require
            # the *observed* rate to be well below nominal.
            t = self.graph.tasks[s.task]
            elapsed = rs.now - st
            exp_dur = max(mon.expected.finish[s.task]
                          - mon.expected.start[s.task], 1e-12)
            if elapsed <= 1e-12 or (rs.progress()[s.task] * t.size
                                    / elapsed) > 0.7 * (t.size / exp_dur):
                continue
            self.suspect_hosts.add(host)
            diagnoses.append(f"compute straggler {s.task} on {host}")
            new = self._pick_host(t.proc, avoid={host})
            if new is None:
                continue
            if self.cost_aware:
                # observed / nominal rate fraction = frac * exp_dur / t
                est = rs.progress()[s.task] * exp_dur / elapsed
                veto = self._speculation_veto(s.task, new, est)
                if veto is not None:
                    self.declined.append((rs.now, s.task, veto))
                    continue
            acts += self._relocate(s.task, new,
                                   f"straggler on {host}")
        nets = []
        for s in mon.network_stragglers():
            if rs.finished_at(s.task) is not None \
                    or rs.started_at(s.task) is None:
                continue
            # lateness alone is not a bad route: a flow repathed off a
            # degraded link is behind schedule yet moving at full rate
            # on its new route, and blaming that route would cascade
            # false positives across the fabric.  Judge the *recent*
            # rate — since the last repath — against nominal.
            obs, nominal = self._recent_rate(s.task)
            if obs > 0.7 * nominal:
                continue
            nets.append(s)
        if nets:
            counts: dict[str, int] = {}
            for s in nets:
                for l in self.rs.flow_route(s.task):
                    if not is_nic_link(l):
                        counts[l] = counts.get(l, 0) + 1
            if counts:
                link = max(sorted(counts), key=counts.__getitem__)
                if link not in self.degraded:
                    est = self._estimate_link_factor(link, nets)
                    if est >= 0.7:
                        # mildly slow is ambient contention, not a
                        # fault — acting on it would thrash
                        link = None
                if link is not None and link not in self.degraded:
                    cap = self.cluster.bandwidth(link)
                    self.degraded[link] = cap * est
                    diagnoses.append(
                        f"degraded link {link} (~{est:.0%} of nominal)")
                    self._link_events[link] = \
                        self._link_events.get(link, 0) + 1
                    if self.cost_aware \
                            and self._link_events[link] > self.link_budget:
                        # a link diagnosed degraded this many times is
                        # flapping: stop paying the repath churn, keep
                        # the belief (routes avoid it where possible)
                        self.declined.append(
                            (rs.now, link,
                             f"link {link} flapped "
                             f"{self._link_events[link]}x; repath "
                             f"budget ({self.link_budget}) exhausted"))
                    else:
                        for s in nets:
                            if link not in self.rs.flow_route(s.task):
                                continue
                            src, dst = self.rs.flow_ends(s.task)
                            route = self._route_for(src, dst)
                            if link in route:
                                continue    # no alternate avoids it
                            acts.append(("repath_flow", s.task,
                                         f"{src}->{dst}",
                                         f"avoid {link}"))
                            self._repath(s.task, route)
        if acts:
            acts += self._replan_priorities()
        self.actions += acts
        return diagnoses, acts

    def _estimate_link_factor(self, link: str, stragglers) -> float:
        """Believed remaining capacity fraction of a suspect link: the
        median observed/expected progress-rate ratio over the straggling
        flows that traverse it (clamped away from 0 — a belief of zero
        would make the replanner treat the link as down)."""
        ratios = []
        for s in stragglers:
            if link not in self.rs.flow_route(s.task):
                continue
            obs_rate, exp_rate = self._recent_rate(s.task)
            ratios.append(obs_rate / max(exp_rate, 1e-12))
        if not ratios:
            return 0.5
        ratios.sort()
        return min(1.0, max(0.02, ratios[len(ratios) // 2]))


@dataclasses.dataclass
class NemesisReport:
    """Outcome of one Nemesis run."""

    makespan: float             # inf when the run never finished
    completed: bool
    tracker: RecoveryTracker
    result: object = None       # SimResult when completed

    @property
    def detection_rate(self) -> float:
        """Tracker detection rate (see RecoveryTracker)."""
        return self.tracker.detection_rate()


class Nemesis:
    """The fault-injection harness: drive a live run, hurt it on
    schedule, and let (or don't let) the controller fight back.

    ``probe_every`` is the controller's progress-report cadence (the
    detection latency floor for inferred faults).  With
    ``replan=False`` faults are injected but nothing reacts — the
    no-replan arm of the recovery benchmark; an unrecoverable fault
    then stalls the run and the report's makespan is ``inf``.

    Straggler semantics: a task's speed multiplier models its current
    *executor*.  When the controller speculatively moves a slowed
    compute task to another host, the harness restores its speed to
    nominal — the new executor is a different machine.
    """

    def __init__(self, schedule: Schedule, cluster: Cluster, *,
                 faults: Sequence[Fault],
                 replan: bool = True,
                 probe_every: float = 0.5,
                 scheduler: Optional[MXDAGScheduler] = None,
                 threshold: float = 0.2,
                 expected=None,
                 cost_aware: bool = False):
        self.schedule = schedule
        self.cluster = cluster
        self.faults = sorted(faults, key=lambda f: f.time)
        self.replan = replan
        self.probe_every = probe_every
        self.scheduler = scheduler
        self.threshold = threshold
        self.expected = expected
        self.cost_aware = cost_aware

    def _make_rs(self) -> ResumableSim:
        s = self.schedule
        sim = Simulator(s.graph, self.cluster, policy=s.policy,
                        priorities=s.priorities, releases=s.releases,
                        coflows=s.coflows, routes=s.routes or None)
        return ResumableSim(sim)

    def run(self, horizon: float = 1e9) -> NemesisReport:
        """Execute the scenario; returns the :class:`NemesisReport`.

        The loop advances the live simulation to the next fault time or
        probe tick (whichever is sooner), injects/reacts there, and
        repeats.  Deterministic by construction: the timeline is the
        sorted merge of the fault schedule and the fixed probe cadence.
        """
        rs = self._make_rs()
        tracker = RecoveryTracker()
        ctl = (ReplanController(self.schedule, self.cluster, rs,
                                scheduler=self.scheduler,
                                threshold=self.threshold,
                                expected=self.expected,
                                cost_aware=self.cost_aware,
                                spec_cooldown=2 * self.probe_every)
               if self.replan else None)
        self.controller = ctl       # exposed for post-run introspection
        slowed: dict[str, float] = {}
        faults = list(self.faults)
        open_recs: list[FaultRecord] = []
        next_probe = self.probe_every
        idle_probes = 0
        status = "paused"
        while True:
            t_fault = faults[0].time if faults else math.inf
            t = min(t_fault, next_probe if ctl is not None else math.inf)
            if t > horizon:
                status = rs.run_until(horizon, allow_stall=True)
                break
            status = rs.run_until(t, allow_stall=True)
            if status == "done":
                break
            if status == "stalled" and not faults:
                # nothing left to inject and nothing can move: without a
                # controller this is the no-replan arm's dead end; with
                # one, give it a final look before giving up
                if ctl is None:
                    break
                _, acts = ctl.check()
                self._executor_moves(rs, acts, slowed)
                if not acts:
                    break
                continue
            if status != "stalled":
                rs.advance_to(t)
            acted = False
            while faults and faults[0].time <= t:
                f = faults.pop(0)
                rec = tracker.injected(f, rs.now)
                self._inject(rs, f, rec, ctl, slowed)
                if not (rec.detected or ctl is None):
                    open_recs.append(rec)
                acted = True
            if ctl is not None and t >= next_probe - 1e-12:
                while next_probe <= t + 1e-12:
                    next_probe += self.probe_every
                diagnoses, acts = ctl.check()
                self._executor_moves(rs, acts, slowed)
                if diagnoses or acts:
                    idle_probes = 0
                    for rec in open_recs:
                        if rec.detected:
                            continue
                        # per-fault attribution: in a storm one probe
                        # tick may diagnose several faults at once —
                        # give each record only the diagnoses (and
                        # actions) naming its own victim
                        mine = [d for d in diagnoses
                                if self._matches(rec.fault, [d], ctl)]
                        if mine:
                            rec.detected = True
                            rec.detected_at = rs.now
                            rec.diagnosis = "; ".join(mine)
                            rec.actions += self._attributed(
                                rec.fault, acts, ctl)
                    open_recs = [r for r in open_recs if not r.detected]
                else:
                    idle_probes += 1
                acted = acted or bool(acts)
            if status == "stalled" and not acted:
                break
            if ctl is not None and idle_probes > 1000:
                break       # controller idle for 1000 probes: give up
        completed = status == "done" or rs.unfinished == 0
        if not completed and rs.unfinished:
            # drain whatever can still run (e.g. faults exhausted, no
            # controller, nothing stalled) up to the horizon
            status = rs.run_until(horizon, allow_stall=True)
            completed = status == "done"
        result = rs.result() if completed else None
        makespan = result.makespan if completed else math.inf
        for rec in tracker.records:
            rec.recovered = completed
        return NemesisReport(makespan=makespan, completed=completed,
                             tracker=tracker, result=result)

    # ------------------------------------------------------------------
    def _inject(self, rs: ResumableSim, f: Fault, rec: FaultRecord,
                ctl: Optional[ReplanController],
                slowed: dict[str, float]) -> None:
        """Apply one fault to the live run (and, for announced faults,
        notify the controller)."""
        if f.kind == "host_loss":
            restarted = rs.kill_host(f.target)
            if ctl is not None:
                rec.detected = True     # heartbeat loss is announced
                rec.detected_at = rs.now
                rec.diagnosis = f"host {f.target} lost heartbeat"
                acts = ctl.on_host_loss(f.target, restarted)
                rec.actions += acts
                self._executor_moves(rs, acts, slowed)
        elif f.kind == "rack_loss":
            # correlated blast radius: the ToR's links go dark and every
            # resident host dies with it, one atomic stroke
            hosts_r, links_r = rack_blast(self.cluster, f.target)
            for l in links_r:
                rs.set_link_bw(l, 0.0)
            per_host = [(h, rs.kill_host(h)) for h in hosts_r]
            if ctl is not None:
                rec.detected = True     # heartbeat loss is announced
                rec.detected_at = rs.now
                rec.diagnosis = (
                    f"rack {f.target} lost: {len(hosts_r)} hosts "
                    f"({', '.join(hosts_r)}), {len(links_r)} links dark")
                # mark the whole radius dead up front so relocation for
                # the first host never lands on a sibling about to die
                ctl.dead_hosts.update(hosts_r)
                for h, restarted in per_host:
                    acts = ctl.on_host_loss(h, restarted)
                    rec.actions += acts
                    self._executor_moves(rs, acts, slowed)
        elif f.kind == "link_recover":
            cap = self.cluster.bandwidth(f.target) * f.factor
            rs.set_link_bw(f.target, cap)
            if ctl is not None:
                rec.detected = True     # port-up is announced
                rec.detected_at = rs.now
                rec.diagnosis = (f"link {f.target} up at "
                                 f"{f.factor:g}x nominal")
                rec.actions += ctl.on_link_recover(f.target, cap)
        elif f.kind == "link_degrade":
            rs.scale_link(f.target, f.factor)
        else:
            rs.set_speed(f.target, f.factor)
            slowed[f.target] = f.factor

    @staticmethod
    def _executor_moves(rs: ResumableSim, acts: Sequence[tuple],
                        slowed: dict[str, float]) -> None:
        """The executor-follows-host rule: a slowed (straggling) task
        the controller just moved runs on a *new* machine — its speed
        multiplier returns to nominal (speculative re-execution)."""
        for a in acts:
            if a and a[0] == "move_task" and a[1] in slowed:
                rs.set_speed(a[1], 1.0)
                del slowed[a[1]]

    @staticmethod
    def _matches(fault: Fault, diagnoses: list[str],
                 ctl: ReplanController) -> bool:
        """Does a diagnosis batch explain ``fault``?  Straggler faults
        match a compute-straggler diagnosis naming the task or its
        host; link faults match a degraded-link diagnosis naming the
        link."""
        if fault.kind == "straggler":
            host = ctl.rs.task_host(fault.target)
            return any(d.startswith("compute straggler")
                       and (fault.target in d
                            or (host is not None and host in d))
                       for d in diagnoses)
        if fault.kind == "link_degrade":
            return any(d.startswith("degraded link")
                       and fault.target in d for d in diagnoses)
        return True

    @staticmethod
    def _attributed(fault: Fault, acts: Sequence[tuple],
                    ctl: ReplanController) -> list[tuple]:
        """The subset of a probe tick's actions that name the fault's
        victim (its target, or for stragglers the task's current host)
        — per-fault credit when a storm makes one tick react to several
        faults at once.  Falls back to the whole batch when nothing
        names the victim (e.g. a pure priority replan)."""
        keys = {fault.target}
        if fault.kind == "straggler":
            h = ctl.rs.task_host(fault.target)
            if h is not None:
                keys.add(h)
        mine = [a for a in acts
                if any(isinstance(x, str) and k in x
                       for x in a for k in keys)]
        return mine if mine else list(acts)
