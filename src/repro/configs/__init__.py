"""Config registry: ``get(name)`` / ``get_smoke(name)`` / ``ARCHS``."""
from repro.configs.base import (
    ArchConfig, RunConfig, ShapeConfig, SHAPES, applicable_shapes,
)

from repro.configs.jamba_v0_1_52b import CONFIG as _jamba
from repro.configs.olmoe_1b_7b import CONFIG as _olmoe
from repro.configs.deepseek_v3_671b import CONFIG as _dsv3
from repro.configs.deepseek_7b import CONFIG as _ds7b
from repro.configs.nemotron_4_15b import CONFIG as _nemotron
from repro.configs.chatglm3_6b import CONFIG as _chatglm3
from repro.configs.deepseek_coder_33b import CONFIG as _dscoder
from repro.configs.whisper_large_v3 import CONFIG as _whisper
from repro.configs.mamba2_130m import CONFIG as _mamba2
from repro.configs.internvl2_2b import CONFIG as _internvl2

ARCHS: dict[str, ArchConfig] = {c.name: c for c in [
    _jamba, _olmoe, _dsv3, _ds7b, _nemotron,
    _chatglm3, _dscoder, _whisper, _mamba2, _internvl2,
]}


def get(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def get_smoke(name: str) -> ArchConfig:
    return get(name).reduced()


__all__ = ["ArchConfig", "RunConfig", "ShapeConfig", "SHAPES",
           "applicable_shapes", "ARCHS", "get", "get_smoke"]
