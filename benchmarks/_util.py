"""Shared benchmark helpers."""
from __future__ import annotations

import gc
import statistics
import time


class Timing(float):
    """A best-of wall time (µs) that also carries the rep spread.

    Behaves exactly like the float it always was — CSV printing, JSON
    dumping and the baseline diff all see the min — while ``median_us``
    / ``stdev_us`` / ``reps`` let row builders report the spread in the
    derived text and the trend report reason about noise.
    """

    median_us: float
    stdev_us: float
    reps: int

    def __new__(cls, samples_us):
        samples_us = list(samples_us)
        self = super().__new__(cls, min(samples_us))
        self.median_us = statistics.median(samples_us)
        self.stdev_us = (statistics.stdev(samples_us)
                         if len(samples_us) > 1 else 0.0)
        self.reps = len(samples_us)
        return self

    @property
    def note(self) -> str:
        """Spread summary for a row's derived text."""
        return (f"min of {self.reps}; median {self.median_us:.0f}us; "
                f"stdev {self.stdev_us:.0f}us")


def timeit_us(fn, *args, repeat: int = 3) -> Timing:
    """Best-of-``repeat`` wall time of ``fn(*args)`` in microseconds.

    Returns a :class:`Timing` — a float (the min) that also records the
    median/stdev across reps.  The collector is paused during the timed
    region: large compiled DAGs hold millions of objects, and a
    collection landing inside one rep is pure inter-run noise for a
    best-of measurement.
    """
    samples = []
    was_enabled = gc.isenabled()
    gc.disable()
    try:
        for _ in range(repeat):
            t0 = time.perf_counter()
            fn(*args)
            samples.append((time.perf_counter() - t0) * 1e6)
    finally:
        if was_enabled:
            gc.enable()
    return Timing(samples)


def timeit_pair_us(fn_a, fn_b, repeat: int = 3) -> tuple[Timing, Timing]:
    """Interleaved best-of timing of two thunks (A, B, A, B, ...).

    For speedup-claim rows the two arms must see the same machine: a
    frequency step or noisy neighbour landing entirely inside one arm
    of a back-to-back measurement fabricates (or hides) a ratio.
    Interleaving spreads such drift across both.
    """
    sa, sb = [], []
    was_enabled = gc.isenabled()
    gc.disable()
    try:
        for _ in range(repeat):
            t0 = time.perf_counter()
            fn_a()
            sa.append((time.perf_counter() - t0) * 1e6)
            t0 = time.perf_counter()
            fn_b()
            sb.append((time.perf_counter() - t0) * 1e6)
    finally:
        if was_enabled:
            gc.enable()
    return Timing(sa), Timing(sb)
