import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede any jax import: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this:
  1. builds the production mesh (16×16 single-pod or 2×16×16 multi-pod),
  2. constructs the model + ShapeDtypeStruct inputs (zero allocation),
  3. jits the right step (train_step / forward / serve_step) with the
     sharding rules of launch/sharding.py, ``.lower()``s and
     ``.compile()``s it,
  4. prints memory_analysis() (proves it fits) and cost_analysis(),
  5. extracts the three roofline terms (launch/hlo_analysis.py) and
     appends the record to benchmarks/results/dryrun.json (incremental —
     reruns skip completed cells unless --force).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-7b \
      --shape train_4k --multi-pod both
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs.base import ArchConfig, RunConfig, SHAPES, \
    applicable_shapes
from repro.launch import hlo_analysis, sharding as shard_lib
from repro.launch.mesh import dp_axes, make_production_mesh, n_chips
from repro.launch.specs import decode_specs, input_specs
from repro.launch.train import (init_train_state, make_train_step,
                                model_flops, state_shardings)
from repro.launch.serve import make_serve_step
from repro.models import Model
from repro.optim import AdamW, AdamWConfig

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "results")


def default_run(cfg: ArchConfig, overrides: Optional[dict] = None
                ) -> RunConfig:
    n = cfg.param_counts()["total"]
    small = n < 1e9
    fsdp = n > 5e9
    # §Perf dsv3 iter 2: with FSDP every microbatch re-gathers params, so
    # fewer/larger microbatches win (AG traffic halves; stash still fits)
    base = RunConfig(fsdp=fsdp, opt_8bit=n > 2.5e10, remat=True,
                     batch_axes="all" if small else "dp",
                     microbatches=1 if small else (2 if fsdp else 4))
    if overrides:
        import dataclasses
        base = dataclasses.replace(base, **overrides)
    return base


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               run_overrides: Optional[dict] = None,
               verbose: bool = True) -> dict:
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    run = default_run(cfg, run_overrides)
    if (run_overrides is None or "seq_shard" not in run_overrides) \
            and run.batch_axes == "all" \
            and shape.global_batch % mesh.devices.size != 0:
        # §Perf mamba2 iter 4: when the batch cannot fill the mesh, shard
        # the sequence over the otherwise-idle "model" axis (57x on
        # mamba2 prefill); when it can, plain batch sharding wins.
        import dataclasses
        run = dataclasses.replace(run, seq_shard=True)
    model_dp = (tuple(mesh.axis_names) if run.batch_axes == "all"
                else dp_axes(mesh))
    model = Model(cfg, run, mesh=mesh, dp_axes=model_dp)
    chips = n_chips(mesh)
    mf = model_flops(cfg, shape)

    t0 = time.monotonic()
    with mesh:
        if shape.kind == "train":
            opt = AdamW(AdamWConfig(state_8bit=run.opt_8bit))
            state_shapes = jax.eval_shape(
                lambda: init_train_state(model, opt, run,
                                         jax.random.PRNGKey(0)))
            st_sh = state_shardings(state_shapes, cfg, run, mesh)
            batch = input_specs(cfg, shape)
            b_sh = shard_lib.batch_shardings(batch, mesh, run)
            step = make_train_step(model, opt, run)
            lowered = jax.jit(step, in_shardings=(st_sh, b_sh),
                              donate_argnums=0).lower(state_shapes, batch)
        elif shape.kind == "prefill":
            params_shapes = jax.eval_shape(model.init,
                                           jax.random.PRNGKey(0))
            p_sh = shard_lib.param_shardings(params_shapes, cfg, run, mesh)
            batch = input_specs(cfg, shape)
            b_sh = shard_lib.batch_shardings(batch, mesh, run)
            lowered = jax.jit(model.forward,
                              in_shardings=(p_sh, b_sh)
                              ).lower(params_shapes, batch)
        else:                                    # decode
            params_shapes = jax.eval_shape(model.init,
                                           jax.random.PRNGKey(0))
            p_sh = shard_lib.param_shardings(params_shapes, cfg, run, mesh)
            tokens, cache, index = decode_specs(model, cfg, shape)
            c_sh = shard_lib.cache_shardings(cache, cfg, mesh)
            t_sh = shard_lib.batch_shardings(tokens, mesh, run)
            i_sh = NamedSharding(mesh, P())
            step = make_serve_step(model)
            lowered = jax.jit(step,
                              in_shardings=(p_sh, c_sh, t_sh, i_sh),
                              donate_argnums=1
                              ).lower(params_shapes, cache, tokens, index)
        t_lower = time.monotonic() - t0
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0 - t_lower

    mem = hlo_analysis.memory_summary(compiled)
    hlo_text = compiled.as_text()
    roof = hlo_analysis.analyze(compiled, chips, model_flops=mf,
                                hlo_text=hlo_text)
    if verbose:
        print(compiled.memory_analysis())
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, list) else cost
        print({k: v for k, v in cost.items()
               if k in ("flops", "bytes accessed")})

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind,
        "run": {"fsdp": run.fsdp, "opt_8bit": run.opt_8bit,
                "remat": run.remat, "sync_mode": run.sync_mode,
                "moe_combine": run.moe_combine,
                "batch_axes": run.batch_axes,
                **(run_overrides or {})},
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem,
        "roofline": roof.to_dict(),
        "ok": True,
    }
    return rec


# ----------------------------------------------------------------------
def _results_path(tag: str) -> str:
    os.makedirs(RESULTS, exist_ok=True)
    return os.path.join(RESULTS, f"dryrun_{tag}.json")


def load_results(tag: str = "baseline") -> dict:
    path = _results_path(tag)
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def save_result(tag: str, key: str, rec: dict) -> None:
    data = load_results(tag)
    data[key] = rec
    with open(_results_path(tag), "w") as f:
        json.dump(data, f, indent=1)


def run_cells(archs, shapes, meshes, *, tag="baseline", force=False,
              run_overrides=None) -> None:
    done = load_results(tag)
    for arch in archs:
        cfg = configs.get(arch)
        app = applicable_shapes(cfg)
        for shape_name in shapes:
            if shape_name not in app:
                key = f"{arch}|{shape_name}|skip"
                if key not in done:
                    save_result(tag, key, {
                        "arch": arch, "shape": shape_name, "ok": False,
                        "skipped": "long_500k needs sub-quadratic attention"
                                   " (DESIGN.md §4)"})
                continue
            for mp in meshes:
                mesh_tag = "2x16x16" if mp else "16x16"
                key = f"{arch}|{shape_name}|{mesh_tag}"
                if key in done and done[key].get("ok") and not force:
                    print(f"[skip done] {key}")
                    continue
                print(f"[lower] {key} ...", flush=True)
                try:
                    rec = lower_cell(arch, shape_name, multi_pod=mp,
                                     run_overrides=run_overrides)
                    print(f"[ok] {key}: compile={rec['compile_s']}s "
                          f"dominant={rec['roofline']['dominant']} "
                          f"frac={rec['roofline']['roofline_fraction']:.3f}",
                          flush=True)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_tag, "ok": False,
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                    print(f"[FAIL] {key}: {type(e).__name__}: "
                          f"{str(e)[:200]}", flush=True)
                save_result(tag, key, rec)


def main(argv=None) -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--multi-pod", default="both",
                   choices=["no", "yes", "both"])
    p.add_argument("--all", action="store_true")
    p.add_argument("--force", action="store_true")
    p.add_argument("--tag", default="baseline")
    p.add_argument("--set", action="append", default=[],
                   help="RunConfig override, e.g. --set fsdp=False")
    args = p.parse_args(argv)

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = {"True": True, "False": False}.get(v, v) \
            if not v.lstrip("-").isdigit() else int(v)

    archs = [args.arch] if args.arch else sorted(configs.ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"no": [False], "yes": [True], "both": [False, True]}[
        args.multi_pod]
    run_cells(archs, shapes, meshes, tag=args.tag, force=args.force,
              run_overrides=overrides or None)


if __name__ == "__main__":
    main()
