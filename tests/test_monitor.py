"""Monitoring & straggler identification (§4.3) and what-if analysis."""
import pytest

from repro.core import (
    FairShareScheduler, Monitor, MXDAGScheduler, TaskKind, WhatIf,
)
from repro.core import builders


class TestMonitor:
    @pytest.fixture
    def setup(self):
        g = builders.fig1_jobs()
        sched = MXDAGScheduler().schedule(g)
        expected = sched.simulate()
        return g, expected

    def test_host_straggler_identified(self, setup):
        g, expected = setup
        mon = Monitor(g, expected)
        # task b expected to run 2.0 -> 3.0; at t=2.9 only 20% done
        mon.observe("b", 0.2, 2.9)
        stragglers = mon.stragglers()
        assert [s.task for s in stragglers] == ["b"]
        assert stragglers[0].kind is TaskKind.COMPUTE
        assert mon.host_stragglers() and not mon.network_stragglers()

    def test_network_straggler_distinguished(self, setup):
        """The paper: traditional DAG cannot distinguish host vs network
        stragglers; MXDAG can."""
        g, expected = setup
        mon = Monitor(g, expected)
        mon.observe("f1", 0.1, 1.9)   # flow f1 expected 1.0 -> 2.0
        assert mon.network_stragglers() and not mon.host_stragglers()

    def test_on_track_task_not_flagged(self, setup):
        g, expected = setup
        mon = Monitor(g, expected)
        mon.observe("b", 0.5, 2.5)    # exactly on schedule
        assert mon.stragglers() == []

    def test_replan_updates_critical_path(self, setup):
        g, expected = setup
        mon = Monitor(g, expected)
        # f3 is off-critical (slack 2); make it 10x slower than expected:
        # at t=4.5 it should be done (finish 2.0 in mx schedule) but is 10%
        mon.observe("f3", 0.1, 1.9)
        new_cp = mon.replan_critical_path()
        assert "f3" in new_cp  # straggling flow becomes critical

    def test_replan_threads_observed_starts_as_releases(self, setup):
        """replan_critical_path passes observed starts into the analytic
        pass — a branch that merely *started late* (on schedule since)
        replans as critical without any size re-estimation."""
        g, expected = setup
        mon = Monitor(g, expected)
        mon.observe("b", 0.5, 2.5)     # on-schedule: no straggler
        assert mon.stragglers() == []
        # explicit observed starts: f3 actually began at t=6 (planned 1)
        cp = mon.replan_critical_path(release={"f3": 6.0})
        assert "f3" in cp
        # default: planned starts — replan equals the undisturbed path
        assert mon.replan_critical_path() == g.critical_path(
            release={n: expected.start[n] for n in mon.obs})

    def test_observation_requires_known_task(self, setup):
        g, expected = setup
        mon = Monitor(g, expected)
        with pytest.raises(KeyError):
            mon.observe("nope", 0.5, 1.0)

    def test_observe_clamps_fraction_to_unit_interval(self, setup):
        """Regression: a negative fraction (noisy progress counter)
        produced a negative rate and a projected finish in the past;
        fractions now clamp to [0, 1] on observation."""
        g, expected = setup
        mon = Monitor(g, expected)
        mon.observe("b", -0.3, 2.9)
        assert mon.obs["b"].fraction == 0.0
        proj = mon.projected_finish("b")
        assert proj is not None and proj >= 2.9
        mon.observe("b", 1.7, 2.9)
        assert mon.obs["b"].fraction == 1.0
        assert mon.projected_finish("b") == 2.9

    def test_projected_finish_zero_fraction_no_division(self, setup):
        """fraction == 0 must not divide by zero: the projection shifts
        the expected duration to start at the observation time."""
        g, expected = setup
        mon = Monitor(g, expected)
        mon.observe("b", 0.0, 5.0)     # b expected 2.0 -> 3.0
        dur = expected.finish["b"] - expected.start["b"]
        assert mon.projected_finish("b") == pytest.approx(5.0 + dur)
        # observation exactly at the expected start: rate denominator
        # is clamped, not zero-divided
        mon.observe("b", 0.5, expected.start["b"])
        assert mon.projected_finish("b") is not None

    def test_clamped_observations_still_flag_stragglers(self, setup):
        g, expected = setup
        mon = Monitor(g, expected)
        mon.observe("b", -1.0, 4.0)    # hopeless (and noisy) progress
        assert "b" in [s.task for s in mon.stragglers()]


class TestWhatIf:
    def test_pipeline_whatif_matches_fig3(self):
        g = builders.fig3()
        w = WhatIf(g)
        helpful = w.pipeline_edges([("a", "f1")])
        harmful = w.pipeline_edges([("a", "f1"), ("a", "f3")])
        assert helpful.helps
        assert harmful.variant > helpful.variant

    def test_unit_sweep_smaller_units_help_on_critical_path(self):
        g = builders.fig3()
        g.set_pipelined("a", "f1", True)
        w = WhatIf(g)
        res = w.sweep_unit("f1", [0.5, 0.25, 0.125])
        times = [t for _, t in res]
        assert times == sorted(times, reverse=True) or \
            max(times) - min(times) < 1e-9

    def test_sweep_unit_crossing_task_size_clamps(self):
        """Regression: set_unit/sweep_unit crashed mid-sweep with
        'unit must be in (0, size]' when a candidate exceeded the task
        size; now it clamps exactly like repartition."""
        g = builders.fig3()
        g.set_pipelined("a", "f1", True)
        w = WhatIf(g)
        # f1 has size 1.0 — the sweep crosses it
        res = w.sweep_unit("f1", [0.5, 1.0, 2.0, 5.0])
        assert [u for u, _ in res] == [0.5, 1.0, 2.0, 5.0]
        # clamped candidates are equivalent to unit == size
        at_size = w.set_unit("f1", 1.0).variant
        assert res[2][1] == pytest.approx(at_size)
        assert res[3][1] == pytest.approx(at_size)

    def test_speedup_zero_over_zero_is_one(self):
        """Regression: 0/0 (zero-size baseline and variant) returned
        inf; equal makespans are a 1.0 speedup."""
        from repro.core import WhatIfResult
        assert WhatIfResult(0.0, 0.0).speedup == 1.0
        assert WhatIfResult(5.0, 0.0).speedup == float("inf")
        assert WhatIfResult(4.0, 2.0).speedup == 2.0
        assert not WhatIfResult(0.0, 0.0).helps
        # end to end: a graph of zero-size tasks
        from repro.core import MXDAG, compute
        g = MXDAG("zero")
        g.add(compute("a", 0.0, "A"))
        w = WhatIf(g)
        r = w.repartition({"a": 0.0})
        assert r.speedup == 1.0

    def test_repartition(self):
        g = builders.fig1_jobs()
        w = WhatIf(g)
        # shrinking c (the sink, on every path) always helps ...
        r = w.repartition({"c": 0.25})
        assert r.helps
        # ... but shrinking b does NOT: the what-if reveals that C's ingress
        # NIC (serializing f2 and f3) becomes the bottleneck — exactly the
        # kind of insight the paper claims MXDAG enables (§4.3)
        r2 = w.repartition({"b": 0.25})
        assert not r2.helps
        # growing a critical compute task hurts
        r3 = w.repartition({"b": 3.0})
        assert r3.variant > r3.baseline
