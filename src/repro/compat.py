"""Version-compatibility shims for the jax side (single source of truth;
the model code and the subprocess test probes both import from here)."""
import jax

try:                                    # jax >= 0.4.38 exports it top-level
    shard_map = jax.shard_map
except AttributeError:                  # older jax: experimental namespace
    from jax.experimental.shard_map import shard_map

__all__ = ["shard_map"]
