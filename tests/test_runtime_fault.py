"""Fault-tolerant training runtime: straggler attribution, failure
injection, and the nemesis recovery drill glue.

JAX-dependent (the training loop runs real jitted steps), so these run
in the full CI lane only.
"""
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import builders  # noqa: E402
from repro.core.schedule import MXDAGScheduler  # noqa: E402
from repro.runtime.fault import (  # noqa: E402
    LoopConfig, SimulatedFailure, StepMonitor, recovery_drill,
    run_training,
)

pytestmark = [pytest.mark.jax]


def step_graph_and_expected():
    g = builders.fig1_jobs()
    sched = MXDAGScheduler().schedule(g)
    return g, sched.simulate()


class TestStepMonitor:
    def test_first_step_seeds_ewma(self):
        mon = StepMonitor()
        assert mon.record(0, 1.0) is None
        assert mon.ewma == 1.0

    def test_step_time_anomaly_without_graph(self):
        mon = StepMonitor(threshold=1.5)
        mon.record(0, 1.0)
        assert mon.record(1, 1.01) is None
        rep = mon.record(2, 5.0)
        assert rep is not None and rep.kind == "step-time"
        assert rep.detail == ""
        assert mon.reports == [rep]

    def test_compute_straggler_attribution(self):
        """A slow step plus task progress showing a lagging *compute*
        task attributes the anomaly to the host (paper §4.3)."""
        g, expected = step_graph_and_expected()
        mon = StepMonitor(step_graph=g, expected=expected)
        mon.record(0, 3.0)
        # task b expected 2.0 -> 3.0; at step time 2.9 only 20% done
        rep = mon.record(1, 9.0, task_progress={"b": 0.2})
        assert rep is not None
        assert rep.kind == "compute" and rep.detail == "b"

    def test_network_straggler_attribution(self):
        g, expected = step_graph_and_expected()
        mon = StepMonitor(step_graph=g, expected=expected)
        mon.record(0, 1.9)
        rep = mon.record(1, 6.0, task_progress={"f1": 0.1})
        assert rep is not None
        assert rep.kind == "network" and rep.detail == "f1"

    def test_worst_kind_wins_attribution(self):
        """With both kinds lagging, the larger lag wins the diagnosis."""
        g, expected = step_graph_and_expected()
        mon = StepMonitor(step_graph=g, expected=expected)
        mon.record(0, 2.9)
        rep = mon.record(1, 9.0, task_progress={"b": 0.01, "f1": 0.9})
        assert rep is not None and rep.kind == "compute"


class TestFailureInjection:
    def _loop(self, tmp_path, **kw):
        return LoopConfig(total_steps=6, ckpt_dir=str(tmp_path / "ckpt"),
                          ckpt_every=2, **kw)

    @staticmethod
    def _parts():
        @jax.jit
        def train_step(state, batch):
            new = state + batch
            return new, {"loss": jnp.sum(batch)}

        return {
            "train_step": train_step,
            "init_state": lambda: jnp.zeros((4,)),
            "batch_at": lambda step: jnp.full((4,), float(step)),
        }

    def test_fail_at_step_restarts_from_checkpoint(self, tmp_path):
        steps = []
        out = run_training(
            self._loop(tmp_path, fail_at_step=3),
            on_step=lambda step, metrics: steps.append(step),
            **self._parts())
        assert out["completed"] and out["restarts"] == 1
        assert out["final_step"] == 5
        # steps 0..2 ran, the crash hit before 3, and the restart
        # resumed after the latest checkpoint (step 1) — not from zero
        assert steps[:3] == [0, 1, 2]
        assert steps[3] == 2  # ckpt at step 1 -> resume at 2
        # the injection disarms after firing once
        assert steps.count(3) == 1

    def test_fail_at_step_zero_restarts_from_scratch(self, tmp_path):
        out = run_training(self._loop(tmp_path, fail_at_step=0),
                           **self._parts())
        assert out["completed"] and out["restarts"] == 1

    def test_exhausted_restarts_reraise(self, tmp_path):
        calls = {"n": 0}

        def bad_batch(step):
            if step == 3:
                calls["n"] += 1
                raise SimulatedFailure("flaky data source")
            return jnp.full((4,), float(step))

        parts = self._parts()
        parts["batch_at"] = bad_batch
        with pytest.raises(SimulatedFailure):
            run_training(self._loop(tmp_path, max_restarts=2), **parts)
        assert calls["n"] == 3  # initial try + 2 restarts


class TestRecoveryDrill:
    def test_drill_reports_recovery(self):
        from repro.core.nemesis import Fault

        g, cl = builders.oversubscribed_fanin(8, oversubscription=8.0)
        sched = MXDAGScheduler(try_pipelining=False).schedule(g, cl)
        out = recovery_drill(sched, cl,
                             faults=[Fault(2.5, "host_loss", "d0")])
        assert out["no_replan"] == float("inf")
        assert out["replan"] < float("inf")
        assert out["detection_rate"] == 1.0
        assert out["recovered"]
        assert "host_loss" in out["report"]
        assert out["faults"][0]["target"] == "d0"

    def test_drill_seeded_schedule_is_deterministic(self):
        g, cl = builders.oversubscribed_fanin(6, oversubscription=6.0)
        sched = MXDAGScheduler(try_pipelining=False).schedule(g, cl)
        a = recovery_drill(sched, cl, n_faults=2, seed=11)
        b = recovery_drill(sched, cl, n_faults=2, seed=11)
        assert a["faults"] == b["faults"]
        assert a["replan"] == b["replan"]
        assert a["report"] == b["report"]
