"""Fabric-aware scheduler baselines: the abstractions MXDAG subsumes (§2).

The paper's headline claim is not "MXDAG beats fair sharing" — it is that
*neither of the two dominant abstractions* can reach the co-scheduled
optimum: the Coflow abstraction sees flows but not the compute DAG behind
them (§2.2), and compute-only DAG scheduling sees the DAG but leaves the
network to fair sharing (§2.1).  This module implements competitive,
fabric-aware schedulers from both families so the comparison can be run
numerically (``benchmarks/bakeoff.py``):

- :class:`SEBFScheduler` — Varys-style Smallest-Effective-Bottleneck-First
  coflow ordering.  Coflows are ordered by their effective bottleneck
  Γ(C) = max over links of (bytes C places on the link / link capacity),
  computed over each flow's *full fabric path* (oversubscribed uplinks
  count), and strict-priority classes serialize the coflows on shared
  links.  DAG-blind: a tiny coflow feeding the job's longest compute chain
  gets no special treatment.

- :class:`DependencyCoflowScheduler` — the dependency-graph coflow
  scheduling of Shafiee & Ghaderi ("Scheduling Coflows with Dependency
  Graph"): the coflow groups are contracted into a coflow-level precedence
  DAG (A → B iff data flows from a member of A to a member of B through
  compute-only intermediaries) and ordered by a precedence-respecting
  greedy — among coflows whose predecessors are all ordered, smallest
  effective bottleneck first.  Sees coflow *dependencies*, still not
  compute durations.

- :class:`GrapheneScheduler` — a Graphene/DAGPS-style "do the hard stuff
  first" packer over the *compute* tasks: each compute task's priority is
  its analytic bottom level (longest remaining work to a sink, flows
  counted at nominal NIC rate), longest first, driving the non-preemptive
  slot dispatch.  Network-oblivious: flows carry no priorities, so every
  link fair-shares — exactly the compute-only-DAG half of Fig. 1(b).

- :class:`MetaflowScheduler` — Metaflow-style network-DAG scheduling
  (Fei et al.): flows are priority-ordered by their depth in the
  flow-level DAG (stage-0 flows first — upstream flows unblock the most
  downstream work), compute unmanaged.  Network-DAG-aware but blind to
  compute durations: it cannot tell which stage-0 flow feeds the long
  reduce.

Every baseline expresses its *entire* decision through the existing
:class:`~repro.core.schedule.Schedule` abstraction — per-task priority
classes plus coflow groupings; placement and routes stay default.  That
was the point of building them: the bake-off stress-tests whether the
Schedule decision catalogue spans the published competitors.  It does,
with one refactor the exercise forced (documented as it happened):
coflow-*ordering* baselines need every flow covered by the ordering, so
:func:`~repro.core.schedule.auto_coflows` grew a ``singletons=`` switch —
a flow outside every group would otherwise default to priority class 0.0
and silently preempt the entire ordering.  Ordering itself (SEBF ranks,
precedence-respecting list order, bottom-level ranks, depth ranks) maps
onto priority classes, and group coupling onto ``Schedule.coflows``
(synchronized start + MADD rates + all-or-nothing gating, the §2.2
semantics), so no new decision kind was needed.

All baselines are deterministic: ties break on sorted member names, so a
baseline's Schedule — like the co-scheduler's — is a pure function of
(graph, cluster).
"""
from __future__ import annotations

from typing import Optional

from repro.core.cluster import Cluster
from repro.core.graph import MXDAG
from repro.core.schedule import FairShareScheduler, Schedule, auto_coflows
from repro.core.task import TaskKind


def _cluster_for(graph: MXDAG, cluster: Optional[Cluster]) -> Cluster:
    """``cluster`` or the graph's cached default, exactly as the
    Simulator resolves it — so a baseline's bottleneck analysis and the
    subsequent :meth:`Schedule.simulate` see the same capacities."""
    if cluster is not None:
        return cluster
    cached = graph.__dict__.get("_default_cluster")
    if cached is not None and cached[0] == graph._version:
        return cached[1]
    cluster = Cluster.for_graph(graph)
    graph._default_cluster = (graph._version, cluster)
    return cluster


def effective_bottleneck(group, graph: MXDAG, cluster: Cluster) -> float:
    """Varys' Γ: the time ``group`` needs on its most contended link.

    ``max`` over every resource any member flow occupies of (total bytes
    the group places on it) / capacity.  Fabric-aware: with a Topology,
    a flow charges every link on its static route, so an oversubscribed
    rack uplink carrying the whole group dominates the endpoint NICs.

    :param group: iterable of flow names forming one coflow.
    :param graph: the MXDAG owning the flows.
    :param cluster: capacities + (optional) fabric the flows run on.
    :returns: Γ in seconds; ``0.0`` for an empty group.
    """
    load: dict[str, float] = {}
    for n in group:
        t = graph.tasks[n]
        for link in cluster.resources_for(t):
            load[link] = load.get(link, 0.0) + t.size
    return max((v / cluster.bandwidth(link) for link, v in load.items()),
               default=0.0)


def coflow_dag(graph: MXDAG, groups: list[set[str]]) -> list[set[int]]:
    """Contract the task DAG into coflow-level precedence.

    Group A precedes group B iff a directed path runs from a member of A
    to a member of B passing through no other group's member — the
    "dependency graph" of Shafiee & Ghaderi, where each stage's coflow
    must finish before the next stage's can start.

    :param graph: the task-level MXDAG.
    :param groups: disjoint flow groups (every flow in at most one).
    :returns: per-group predecessor index sets, aligned with ``groups``.
    """
    gid: dict[str, int] = {}
    for i, grp in enumerate(groups):
        for n in grp:
            gid[n] = i
    preds: list[set[int]] = [set() for _ in groups]
    # nearest upstream groups per task, propagated in topo order
    up: dict[str, frozenset[int]] = {}
    for n in graph.topo_order():
        acc: set[int] = set()
        for p in graph.preds(n):
            acc |= up[p]
        i = gid.get(n)
        if i is None:
            up[n] = frozenset(acc)
        else:
            preds[i] |= acc - {i}
            up[n] = frozenset((i,))
    return preds


def flow_depth(graph: MXDAG) -> dict[str, int]:
    """Per-flow depth in the flow-level DAG (Metaflow's network DAG).

    A flow's depth is the largest number of flows on any path from a DAG
    source up to and including itself, minus one — stage-0 flows are
    depth 0, the flows they (transitively) feed are depth 1, and so on.
    Compute tasks are transparent: they relay depth without adding to it.

    :param graph: the task-level MXDAG.
    :returns: name → depth for every network task.
    """
    depth: dict[str, int] = {}
    out: dict[str, int] = {}
    for n in graph.topo_order():
        d = max((depth[p] for p in graph.preds(n)), default=0)
        if graph.tasks[n].kind is TaskKind.NETWORK:
            out[n] = d
            d += 1
        depth[n] = d
    return out


def _group_key(group: set[str]) -> tuple[str, ...]:
    """Deterministic identity of a flow group (sorted member names)."""
    return tuple(sorted(group))


def _coflow_priorities(groups: list[set[str]], order: list[int],
                       ) -> dict[str, float]:
    """Priority classes from a coflow ordering: the i-th scheduled
    group's members all land in class ``float(i)``."""
    prio: dict[str, float] = {}
    for rank, gi in enumerate(order):
        for n in groups[gi]:
            prio[n] = float(rank)
    return prio


class SEBFScheduler:
    """Varys-style Smallest-Effective-Bottleneck-First coflow ordering.

    Flows are grouped into coflows (caller-supplied, or the conventional
    stage grouping of :func:`~repro.core.schedule.auto_coflows` with
    singleton coverage), each group's effective bottleneck Γ is computed
    over full fabric paths, and groups are ordered ascending Γ (ties:
    lexicographic member names).  The ordering becomes strict priority
    classes; groups of ≥2 flows additionally run under the §2.2 coflow
    semantics (synchronized start, MADD rates, all-or-nothing gating).
    DAG precedence between coflows is deliberately ignored — that is the
    abstraction's blind spot the bake-off measures.
    """

    def __init__(self, *, coflows: Optional[list[set[str]]] = None):
        """:param coflows: explicit flow grouping; default derives the
        conventional stage grouping (plus singletons) from the DAG."""
        self.coflows = coflows

    def _groups(self, graph: MXDAG) -> list[set[str]]:
        """The flow grouping this scheduler orders (see ``__init__``)."""
        if self.coflows is not None:
            return [set(c) for c in self.coflows]
        return auto_coflows(graph, singletons=True)

    def _order(self, graph: MXDAG,
               cluster: Cluster) -> tuple[list[set[str]], list[int]]:
        """(groups, scheduling order): ascending Γ, name tie-break."""
        groups = self._groups(graph)
        gamma = [effective_bottleneck(grp, graph, cluster)
                 for grp in groups]
        order = sorted(range(len(groups)),
                       key=lambda i: (gamma[i], _group_key(groups[i])))
        return groups, order

    def schedule(self, graph: MXDAG,
                 cluster: Optional[Cluster] = None) -> Schedule:
        """Order the graph's coflows by Γ and emit the Schedule.

        :param graph: a fully-bound MXDAG (baselines do not place tasks).
        :param cluster: capacities/fabric; default derived from the graph.
        :returns: a ``policy="priority"`` Schedule whose classes encode
            the SEBF order and whose ``coflows`` carry the ≥2 groups.
        """
        cl = _cluster_for(graph, cluster)
        groups, order = self._order(graph, cl)
        prio = _coflow_priorities(groups, order)
        multi = [groups[i] for i in order if len(groups[i]) >= 2]
        return Schedule(graph=graph, policy="priority", priorities=prio,
                        coflows=multi or None,
                        meta={"algorithm": "sebf",
                              "order": [_group_key(groups[i])
                                        for i in order]})


class DependencyCoflowScheduler(SEBFScheduler):
    """Shafiee & Ghaderi dependency-graph coflow scheduling.

    Same grouping and bottleneck metric as :class:`SEBFScheduler`, but
    the order respects the coflow-level precedence DAG: a group becomes
    eligible only once every predecessor group is ordered, and among
    eligible groups the smallest Γ goes next — the natural greedy member
    of the ordering-based algorithm family their paper analyses.  Still
    blind to compute durations: precedence says *which* coflows wait,
    not which feed the long compute chain.
    """

    def schedule(self, graph: MXDAG,
                 cluster: Optional[Cluster] = None) -> Schedule:
        """Order coflows by precedence-respecting smallest-Γ-first.

        :param graph: a fully-bound MXDAG.
        :param cluster: capacities/fabric; default derived from the graph.
        :returns: a ``policy="priority"`` Schedule (see
            :meth:`SEBFScheduler.schedule`); ``meta["coflow_dag"]`` maps
            each group to its predecessor groups.
        """
        cl = _cluster_for(graph, cluster)
        groups = self._groups(graph)
        gamma = [effective_bottleneck(grp, graph, cl) for grp in groups]
        preds = coflow_dag(graph, groups)
        remaining = set(range(len(groups)))
        done: set[int] = set()
        order: list[int] = []
        while remaining:
            ready = [i for i in remaining if preds[i] <= done]
            # a cycle is impossible (the task DAG is acyclic and the
            # contraction preserves reachability), so ready is never empty
            nxt = min(ready, key=lambda i: (gamma[i],
                                            _group_key(groups[i])))
            order.append(nxt)
            remaining.discard(nxt)
            done.add(nxt)
        prio = _coflow_priorities(groups, order)
        multi = [groups[i] for i in order if len(groups[i]) >= 2]
        return Schedule(graph=graph, policy="priority", priorities=prio,
                        coflows=multi or None,
                        meta={"algorithm": "sg_coflow",
                              "order": [_group_key(groups[i])
                                        for i in order],
                              "coflow_dag": {
                                  _group_key(groups[i]): sorted(
                                      _group_key(groups[p])
                                      for p in preds[i])
                                  for i in range(len(groups))}})


class GrapheneScheduler:
    """Graphene/DAGPS-style "do the hard stuff first" compute packer.

    Each compute task is scored by its bottom level — the longest
    remaining-work path from the task to a sink under the analytic
    (contention-free) calculus, flows counted at nominal rate 1.0 — and
    compute priority classes rank descending bottom level, so the tasks
    heading the longest chains claim contended processor slots first.
    Flows carry **no** priorities: the network fair-shares, which is the
    compute-only-DAG abstraction's defining blind spot (Fig. 1(b)) —
    on an oversubscribed core this baseline collapses to fair sharing
    no matter how well it packs the computes.
    """

    def schedule(self, graph: MXDAG,
                 cluster: Optional[Cluster] = None) -> Schedule:
        """Rank compute tasks by descending bottom level.

        :param graph: a fully-bound MXDAG.
        :param cluster: accepted for interface symmetry; the packer is
            network-oblivious, so only slot pools would matter and those
            are per-host either way.
        :returns: a ``policy="priority"`` Schedule with classes on
            compute tasks only (flows fair-share in the implicit class).
        """
        del cluster          # network-oblivious by construction
        down: dict[str, float] = {}
        for n in reversed(graph.topo_order()):
            t = graph.tasks[n]
            down[n] = t.time(1.0) + max((down[s] for s in graph.succs(n)),
                                        default=0.0)
        levels = sorted({round(down[t.name], 12)
                         for t in graph.compute_tasks()}, reverse=True)
        rank = {v: i for i, v in enumerate(levels)}
        prio = {t.name: float(rank[round(down[t.name], 12)])
                for t in graph.compute_tasks()}
        return Schedule(graph=graph, policy="priority", priorities=prio,
                        meta={"algorithm": "graphene",
                              "bottom_level": down})


class MetaflowScheduler:
    """Metaflow-style network-DAG scheduling: depth-ordered flows.

    The network abstraction is the DAG *of flows*: each flow's priority
    class is its depth in that DAG (stage-0 flows first — an upstream
    flow gates strictly more downstream work than the flows it feeds).
    Compute is unmanaged — and because the flow DAG carries no compute
    durations, two same-depth flows are indistinguishable even when one
    feeds an 8-second reduce and the other a 1-second one.  That gap is
    exactly what MXDAG's slack-driven classes close.
    """

    def schedule(self, graph: MXDAG,
                 cluster: Optional[Cluster] = None) -> Schedule:
        """Assign each flow its network-DAG depth as its class.

        :param graph: a fully-bound MXDAG.
        :param cluster: accepted for interface symmetry; depth is a pure
            graph property.
        :returns: a ``policy="priority"`` Schedule with classes on
            network tasks only (compute dispatch stays name-ordered).
        """
        del cluster          # depth is topology-independent
        prio = {n: float(d) for n, d in flow_depth(graph).items()}
        return Schedule(graph=graph, policy="priority", priorities=prio,
                        meta={"algorithm": "metaflow"})


#: name → zero-arg factory for every baseline the bake-off sweeps;
#: "fair" is the Fig. 1(b) dependency-driven fair-sharing floor.
BASELINES = {
    "fair": FairShareScheduler,
    "sebf": SEBFScheduler,
    "sg_coflow": DependencyCoflowScheduler,
    "graphene": GrapheneScheduler,
    "metaflow": MetaflowScheduler,
}
