"""What-if analysis on MXDAGs (paper §4.3).

MXDAG's explicit network tasks make questions answerable that a traditional
DAG cannot express: *would pipelining these two tasks help?*, *what unit
(chunk) size is best?*, *what if we re-partition work between compute and
network?* — and, with placement and routing as first-class decisions,
*what if this task ran on another host?* (:meth:`WhatIf.move_task`) and
*what if this flow took another path through the fabric?*
(:meth:`WhatIf.reroute_flow`).  Each query re-evaluates the scheduled DAG
in the DES.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence

from repro.core.cluster import Cluster
from repro.core.graph import MXDAG
from repro.core.parallel import trial_map
from repro.core.schedule import MXDAGScheduler
from repro.core.task import MXTask, TaskKind


def follow_moves(g: MXDAG, task: str, host: str) -> dict[str, str]:
    """Which flow endpoints follow when compute ``task`` moves to ``host``.

    Placement is DAG-derived: a flow the task *produces* moves its source
    with it, a flow it *consumes* moves its destination — unless the flow
    is shared with other compute producers/consumers that stay behind, in
    which case its endpoint stays where their data is.  Returns
    ``{flow_name: "src" | "dst"}`` for every flow whose named endpoint
    should become ``host``.  Shared by :meth:`WhatIf.move_task` (offline
    what-if) and the nemesis replan controller (live recovery), so the
    two layers cannot disagree about what a move means.
    """
    moves: dict[str, str] = {}
    for s in g.succs(task):
        ts = g.tasks[s]
        if ts.kind is TaskKind.NETWORK and all(
                g.tasks[p].kind is not TaskKind.COMPUTE or p == task
                for p in g.preds(s)):
            moves[s] = "src"
    for p in g.preds(task):
        tp = g.tasks[p]
        if tp.kind is TaskKind.NETWORK and all(
                g.tasks[s].kind is not TaskKind.COMPUTE or s == task
                for s in g.succs(p)):
            moves[p] = "dst"
    return moves


@dataclasses.dataclass
class WhatIfResult:
    """Baseline vs variant makespan of one what-if query."""

    baseline: float
    variant: float

    @property
    def speedup(self) -> float:
        """baseline/variant; a zero-makespan variant is "infinitely
        faster" only if the baseline was actually slower — two equal
        (including both-zero) makespans are a 1.0, not an inf."""
        if self.variant > 0:
            return self.baseline / self.variant
        return 1.0 if self.variant == self.baseline else float("inf")

    @property
    def helps(self) -> bool:
        """Whether the variant is strictly faster (beyond EPS)."""
        return self.variant < self.baseline - 1e-9


class WhatIf:
    """What-if query engine with a shared result cache.

    The baseline is scheduled+simulated once per WhatIf instance, not once
    per query — a sweep of k variants costs k evaluations instead of 2k.
    Variant results are also memoized by (graph signature, cluster
    signature), so repeated or overlapping sweeps re-use earlier answers.
    """

    def __init__(self, graph: MXDAG, cluster: Optional[Cluster] = None,
                 scheduler: Optional[MXDAGScheduler] = None):
        self.graph = graph
        self.cluster = cluster
        self.scheduler = scheduler or MXDAGScheduler(try_pipelining=False)
        self._cache: dict = {}

    @staticmethod
    def _cluster_key(cl: Optional[Cluster]):
        return None if cl is None else cl.signature()

    def _makespan(self, g: MXDAG, cluster: Optional[Cluster] = None,
                  routes: Optional[Mapping[str, tuple[str, ...]]] = None,
                  ) -> float:
        cl = cluster if cluster is not None else self.cluster
        base_key = (g.signature(), self._cluster_key(cl))
        key = (base_key,
               tuple(sorted(routes.items())) if routes else None)
        ms = self._cache.get(key)
        if ms is None:
            # the Schedule is independent of the routes argument: cache
            # it on its own key so a route sweep pays one schedule() and
            # one DES run per candidate, not one full pipeline each
            sched = self._cache.get(("sched", base_key))
            if sched is None:
                sched = self.scheduler.schedule(g, cl)
                self._cache[("sched", base_key)] = sched
            ms = sched.simulate(cl, routes=dict(routes or {})).makespan
            self._cache[key] = ms
        return ms

    def baseline(self) -> float:
        """The unmodified graph's makespan (cached)."""
        return self._makespan(self.graph)

    # ------------------------------------------------------------------
    def pipeline_edges(self, edges: Sequence[tuple[str, str]]) -> WhatIfResult:
        """Would streaming these edges shrink the makespan? (Fig. 3)"""
        g = self.graph.copy()
        for s, d in edges:
            g.set_pipelined(s, d, True)
        return WhatIfResult(self.baseline(), self._makespan(g))

    def _sweep(self, graphs: Sequence[MXDAG], workers: Optional[int],
               label: str) -> list[float]:
        """Evaluate variant graphs, optionally across worker processes.

        The baseline is evaluated first so forked workers inherit the
        warm schedule/compile caches copy-on-write.  Trials are
        dispatched by index and collected in index order, so the result
        list is bit-identical to the serial sweep no matter which worker
        finishes first; the parent cache is backfilled afterwards so
        later queries reuse the sweep even though each child's own cache
        dies with it.
        """
        self.baseline()
        vals = trial_map(lambda i: self._makespan(graphs[i]),
                         range(len(graphs)), workers, label=label)
        ck = self._cluster_key(self.cluster)
        for g, ms in zip(graphs, vals):
            self._cache[((g.signature(), ck), None)] = ms
        return vals

    def _unit_graph(self, task: str, unit: Optional[float]) -> MXDAG:
        g = self.graph.copy()
        t = g.tasks[task]
        if unit is not None and t.size > 0:
            unit = min(unit, t.size)
        g.replace_task(dataclasses.replace(t, unit=unit))
        return g

    def set_unit(self, task: str, unit: Optional[float]) -> WhatIfResult:
        """Change a task's pipeline unit (chunk) size.

        A candidate unit above the task's size is clamped to the size
        (``unit == size`` ⇒ not pipelineable), exactly as
        :meth:`repartition` clamps a surviving unit when shrinking a
        task — a sweep crossing the task size answers "what if the
        chunking were coarser" instead of crashing mid-sweep on
        MXTask's ``unit <= size`` validation.
        """
        g = self._unit_graph(task, unit)    # validate before simulating
        return WhatIfResult(self.baseline(), self._makespan(g))

    def sweep_unit(self, task: str, units: Sequence[float],
                   workers: Optional[int] = None,
                   ) -> list[tuple[float, float]]:
        """Makespan as a function of the unit size — pick the knee.

        ``workers`` > 1 fans the trials across forked processes (one
        schedule+DES per unit); the returned list is bit-identical to
        the serial sweep.
        """
        units = list(units)
        vals = self._sweep([self._unit_graph(task, u) for u in units],
                           workers, f"sweep_unit({task})")
        return list(zip(units, vals))

    def resize_fabric(self, scale: Optional[float] = None, *,
                      links: Optional[Mapping[str, float]] = None,
                      ) -> WhatIfResult:
        """Would changing fabric link capacities change the makespan?

        ``scale`` multiplies every fabric (non-NIC) link — e.g. ``scale=4``
        undoes a 4:1 oversubscribed core; ``links`` sets individual link
        capacities (NICs included) by name.  The answerable question a
        big-switch model cannot even pose: *is this job actually
        core-bound, and how much fabric would it take to stop being so?*
        """
        if self.cluster is None or self.cluster.topology is None:
            raise ValueError("resize_fabric needs a cluster with a "
                             "fabric Topology")
        topo = self.cluster.topology.resized(scale, links=links)
        return WhatIfResult(self.baseline(),
                            self._makespan(self.graph,
                                           self.cluster.with_topology(topo)))

    def move_task(self, task: str, host: str) -> WhatIfResult:
        """Would running ``task`` on ``host`` change the makespan?

        Placement is DAG-derived: moving a compute task moves the flows
        it produces (their source) and the flows it consumes (their
        destination) with it — the answerable question of a scheduler
        where placement is a decision, not a frozen input.  A flow shared
        with *other* compute producers/consumers keeps its endpoint (its
        data still lands where the tasks that stay behind are).
        """
        g = self._move_graph(task, host)    # validate before simulating
        return WhatIfResult(self.baseline(), self._makespan(g))

    def _move_graph(self, task: str, host: str) -> MXDAG:
        g = self.graph.copy()
        t = g.tasks[task]
        if t.kind is not TaskKind.COMPUTE:
            raise ValueError(f"{task}: move_task re-places compute tasks "
                             f"(use reroute_flow for network tasks)")
        if self.cluster is not None:
            h = self.cluster.hosts.get(host)
            if h is None:
                raise KeyError(f"unknown host {host!r}")
            if h.procs.get(t.proc, 0) < 1:
                raise ValueError(f"host {host!r} has no {t.proc!r} pool "
                                 f"for {task}")
        g.replace_task(dataclasses.replace(t, host=host))
        for fname, side in follow_moves(g, task, host).items():
            g.replace_task(dataclasses.replace(g.tasks[fname],
                                               **{side: host}))
        return g

    def sweep_moves(self, task: str, hosts: Sequence[str],
                    workers: Optional[int] = None,
                    ) -> list[tuple[str, float]]:
        """Makespan of running ``task`` on each candidate host.

        Validation (unknown host, missing proc pool) happens up front in
        the parent, so a bad candidate raises before any worker forks.
        """
        hosts = list(hosts)
        vals = self._sweep([self._move_graph(task, h) for h in hosts],
                           workers, f"sweep_moves({task})")
        return list(zip(hosts, vals))

    def sweep_routes(self, flow: str,
                     routes: Optional[Sequence[Sequence[str]]] = None,
                     workers: Optional[int] = None,
                     ) -> list[tuple[tuple[str, ...], float]]:
        """Makespan of sending ``flow`` over each candidate route.

        ``routes`` defaults to the fabric's candidate paths for the
        flow's endpoints.  The Schedule is shared across the sweep (a
        route override changes only the DES), so each trial is one
        simulation; ``workers`` fans those across processes.
        """
        t = self.graph.tasks[flow]
        if t.kind is not TaskKind.NETWORK:
            raise ValueError(f"{flow}: only network tasks are routed")
        if routes is None:
            if self.cluster is None:
                raise ValueError("sweep_routes needs explicit routes or a "
                                 "cluster with a fabric Topology")
            routes = self.cluster.candidate_routes(t)
        cands = [tuple(r) for r in routes]
        self.baseline()
        vals = trial_map(
            lambda i: self._makespan(self.graph, routes={flow: cands[i]}),
            range(len(cands)), workers, label=f"sweep_routes({flow})")
        base_key = (self.graph.signature(),
                    self._cluster_key(self.cluster))
        for r, ms in zip(cands, vals):
            self._cache[(base_key, ((flow, r),))] = ms
        return list(zip(cands, vals))

    def reroute_flow(self, flow: str,
                     route: Sequence[str]) -> WhatIfResult:
        """Would sending ``flow`` over ``route`` (one of the fabric's
        candidate paths — see :meth:`Cluster.candidate_routes`) change
        the makespan?"""
        t = self.graph.tasks[flow]
        if t.kind is not TaskKind.NETWORK:
            raise ValueError(f"{flow}: only network tasks are routed")
        return WhatIfResult(
            self.baseline(),
            self._makespan(self.graph, routes={flow: tuple(route)}))

    def repartition(self, changes: dict[str, float]) -> WhatIfResult:
        """Re-size tasks (e.g. move work between compute and network)."""
        g = self.graph.copy()
        for name, size in changes.items():
            t = g.tasks[name]
            unit = t.unit if (t.unit is None or t.unit <= size) else size
            g.replace_task(dataclasses.replace(t, size=size, unit=unit))
        return WhatIfResult(self.baseline(), self._makespan(g))
