"""Fault injection + live replanning (nemesis) and the resumable engine.

Three layers under test:

1. :class:`ResumableSim` with **zero mutations** must be bit-exact
   against ``array_run`` — pausing, resuming, checkpointing and
   restoring are pure control-flow and may not perturb a single float.
2. The fault mutators (kill/resurrect, host loss, link degradation,
   speed multipliers, task moves, flow re-paths, priority swaps) must
   keep the simulation consistent: no deadlocks, conservation of
   gating, and the documented fault-model semantics.
3. The :class:`Nemesis` harness + :class:`ReplanController` must detect
   every injected fault and strictly beat the no-replan arm on the
   oversubscribed recovery scenarios.
4. Coflow-coupled resurrection: killing a finished coflow member
   rewinds the MADD group bookkeeping bit-exactly (differential against
   a fresh sim built in the post-fault state), and refusals name the
   offending consumers.
5. Cascade campaigns (rack blast radius, flapping links, fault storms)
   and the cost-aware replanner (worth-it vetoes, budgets).

The property tests run under hypothesis when the environment ships it
and fall back to a seeded parametrize sweep when it does not.
"""
import dataclasses
import math
import random

import pytest

from repro.core import builders
from repro.core.arraysim import ResumableSim, array_run
from repro.core.cluster import Cluster
from repro.core.fabric import is_nic_link
from repro.core.graph import MXDAG
from repro.core.nemesis import (
    BASE_FAULT_KINDS, Fault, Nemesis, RecoveryTracker, fault_storm,
    flapping_link, rack_blast, random_faults, tor_groups,
)
from repro.core.schedule import MXDAGScheduler, auto_coflows
from repro.core.simulator import Simulator
from repro.core.task import TaskKind

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                               # container may not ship it
    HAVE_HYPOTHESIS = False


def seeded_property(n_examples):
    """``@given`` a random seed under hypothesis; otherwise a seeded
    ``parametrize`` sweep — same driver, deterministic fallback."""
    def deco(fn):
        if HAVE_HYPOTHESIS:
            return settings(max_examples=n_examples, deadline=None)(
                given(seed=st.integers(min_value=0, max_value=2**32 - 1))(fn))
        return pytest.mark.parametrize("seed", range(n_examples))(fn)
    return deco


def scenarios():
    """(name, Simulator factory) for every builder family: the same
    sweep the golden differential tests pin the plain engines on."""
    def fanin():
        g, cl = builders.oversubscribed_fanin(8, oversubscription=4.0)
        return Simulator(g, cl)

    def fanin_prio():
        g, cl = builders.oversubscribed_fanin(6, oversubscription=6.0)
        s = MXDAGScheduler(try_pipelining=False).schedule(g, cl)
        return Simulator(s.graph, cl, policy=s.policy,
                         priorities=s.priorities, releases=s.releases)

    def shuffle():
        g, cl = builders.fat_tree_shuffle(8, stride=2)
        return Simulator(g, cl)

    def ddl():
        g = builders.ddl(8, push=2.0, pull=2.0, unit_frac=0.25)
        return Simulator(g, Cluster.for_graph(g))

    def layered():
        g = builders.random_layered(300, n_hosts=16, min_width=4,
                                    max_width=16, seed=5)
        return Simulator(g, Cluster.for_graph(g))

    def coflows():
        g = builders.fig2a()
        return Simulator(g, coflows=builders.fig2a_coflows())

    return [("fanin", fanin), ("fanin_prio", fanin_prio),
            ("shuffle", shuffle), ("ddl_pipelined", ddl),
            ("layered", layered), ("coflows", coflows)]


@pytest.mark.parametrize("name,mk", scenarios())
class TestZeroFaultBitExact:
    """ref_match: the fault-capable engine with no faults IS array_run."""

    def test_uninterrupted(self, name, mk):
        sim = mk()
        ref = array_run(mk())
        rs = ResumableSim(sim)
        assert rs.run_until(math.inf) == "done"
        res = rs.result()
        assert res.start == ref.start
        assert res.finish == ref.finish
        assert res.makespan == ref.makespan
        assert res.job_completion == ref.job_completion

    def test_paused_every_half_second(self, name, mk):
        ref = array_run(mk())
        rs = ResumableSim(mk())
        t, status = 0.0, "paused"
        while status == "paused":
            status = rs.run_until(t)
            t += 0.5
        assert status == "done"
        assert rs.result().finish == ref.finish

    def test_advance_to_between_events(self, name, mk):
        """Partial work integration into the event gap lands on the
        same schedule to within EPS.  (Bit-exactness is only promised
        for between-event pauses; advance_to splits one rate*dt product
        into two, which may differ in the last ulp — it exists for
        landing faults at exact times, where the run diverges anyway.)"""
        ref = array_run(mk())
        rs = ResumableSim(mk())
        t = 0.3
        while rs.run_until(t) == "paused":
            rs.advance_to(t)        # integrate into the gap
            t += 0.7
        res = rs.result()
        assert res.makespan == pytest.approx(ref.makespan, abs=1e-9)
        for n2, f in ref.finish.items():
            assert res.finish[n2] == pytest.approx(f, abs=1e-9)

    def test_checkpoint_restore_fork(self, name, mk):
        ref = array_run(mk())
        rs = ResumableSim(mk())
        rs.run_until(ref.makespan * 0.4)
        snap = rs.checkpoint()
        assert rs.run_until(math.inf) == "done"
        first = rs.result()
        rs.restore(snap)
        assert rs.run_until(math.inf) == "done"
        second = rs.result()
        assert first.finish == ref.finish
        assert second.finish == ref.finish
        # the snapshot survives restoration: fork a third time
        rs.restore(snap)
        assert rs.run_until(math.inf) == "done"
        assert rs.result().finish == ref.finish

    def test_nemesis_with_empty_fault_schedule(self, name, mk):
        sim = mk()
        ref = array_run(mk())
        from repro.core.schedule import Schedule
        sched = Schedule(graph=sim.g, policy=sim.policy,
                         priorities=dict(sim.prio),
                         releases=dict(sim.releases),
                         coflows=[set(c) for c in sim.coflows] or None)
        rep = Nemesis(sched, sim.cluster, faults=[], replan=False).run()
        assert rep.completed and rep.makespan == ref.makespan
        assert rep.result.finish == ref.finish


class TestSessionControl:
    def test_pause_is_between_events(self):
        g, cl = builders.oversubscribed_fanin(4, oversubscription=4.0)
        rs = ResumableSim(Simulator(g, cl))
        assert rs.run_until(0.0) == "paused"
        assert rs.now == 0.0
        rs.advance_to(0.25)
        assert rs.now == 0.25
        with pytest.raises(ValueError):
            rs.advance_to(1e6)      # would skip events
        with pytest.raises(RuntimeError):
            rs.result()             # unfinished

    def test_progress_projection(self):
        g, cl = builders.oversubscribed_fanin(4, oversubscription=1.0)
        rs = ResumableSim(Simulator(g, cl))
        rs.run_until(0.0)
        p0 = rs.progress()
        assert all(v == 0.0 for n, v in p0.items())
        half = rs.progress(at=0.5)
        assert half["f0"] == pytest.approx(0.5)
        rs.run_until(math.inf)
        assert all(v == 1.0 for v in rs.progress().values())

    def test_introspection(self):
        g, cl = builders.oversubscribed_fanin(4, oversubscription=4.0)
        rs = ResumableSim(Simulator(g, cl))
        rs.run_until(0.0)
        assert rs.started_at("f0") == 0.0
        assert rs.finished_at("f0") is None
        assert rs.task_host("c0") == "d0"
        assert rs.flow_ends("f0") == ("s0", "d0")
        route = rs.flow_route("f0")
        assert route[0] == "s0.nic_out" and route[-1] == "d0.nic_in"
        for l in route:
            assert rs.link_capacity(l) == pytest.approx(cl.bandwidth(l))
        # an untraversed (but real) cluster link reports its static
        # capacity and degrading it is a no-op; garbage names raise
        assert rs.link_capacity("rack0.down") == cl.bandwidth("rack0.down")
        rs.scale_link("rack0.down", 0.5)
        with pytest.raises(KeyError):
            rs.set_link_bw("no_such.link", 1.0)
        # c0 is gated on f0, so d0's slot is free until f0 lands
        assert rs.free_slots()[("d0", "cpu")] == 1
        assert set(rs.unfinished_tasks()) == set(g.tasks)


class TestFaultMutators:
    def mk(self, over=4.0):
        g, cl = builders.oversubscribed_fanin(4, oversubscription=over)
        return g, cl, ResumableSim(Simulator(g, cl))

    def test_kill_task_loses_progress(self):
        g, cl, rs = self.mk()
        rs.run_until(1.0)
        rs.advance_to(1.0)
        assert rs.progress()["f0"] > 0.0
        rs.kill_task("f0")
        assert rs.progress()["f0"] == 0.0
        assert rs.run_until(math.inf) == "done"
        # the killed flow restarted from zero at t=1.0 and still ran
        # under 4:1 fan-in contention
        assert rs.result().makespan > array_run(
            Simulator(g, cl)).makespan - 1e-9

    def test_kill_finished_task_resurrects_and_regates(self):
        g, cl, rs = self.mk(over=1.0)
        rs.run_until(1.0)            # flows (size 1, rate 1) all done
        rs.advance_to(1.0)
        assert rs.progress()["f1"] == 1.0
        c1_started = rs.started_at("c1")
        assert c1_started is not None
        # c1 is running on f1's data: killing f1 must refuse until the
        # consumer is killed too
        with pytest.raises(RuntimeError):
            rs.kill_task("f1")
        rs.kill_task("c1")
        rs.kill_task("f1")
        assert rs.progress()["f1"] == 0.0
        assert rs.run_until(math.inf) == "done"
        # f1 re-ran (1s) then c1 re-ran: finish beyond the fault time
        assert rs.finished_at("c1") >= 2.0 - 1e-9

    def test_set_speed_straggler_and_recovery(self):
        g, cl, rs = self.mk(over=1.0)
        base = array_run(Simulator(g, cl)).makespan
        rs.run_until(0.0)
        rs.set_speed("c0", 0.25)     # slow executor
        assert rs.run_until(math.inf) == "done"
        slow = rs.result().makespan
        assert slow > base + 1e-9
        # a speed of 1.0 is the exact nominal path
        rs2 = ResumableSim(Simulator(g, cl))
        rs2.run_until(0.0)
        rs2.set_speed("c0", 1.0)
        rs2.run_until(math.inf)
        assert rs2.result().finish == array_run(Simulator(g, cl)).finish

    def test_straggling_flow_wastes_its_allocation(self):
        """A slowed flow still *holds* its waterfilled share — the
        allocation is wasted, not redistributed (real fabric: a slow
        receiver does not release its fair share to competitors)."""
        g, cl, rs = self.mk(over=4.0)
        rs.run_until(0.0)
        rs.set_speed("f0", 0.5)
        rs.run_until(1.0)
        rs.advance_to(1.0)
        p = rs.progress()
        # all four flows share d-side NICs equally; f0 progresses at
        # half the allocated rate, the others at the full rate
        assert p["f0"] == pytest.approx(p["f1"] / 2)

    def test_set_link_bw_degrades_and_recovers(self):
        g, cl, rs = self.mk(over=1.0)
        rs.run_until(0.0)
        rs.set_link_bw("d0.nic_in", 0.5)
        rs.run_until(math.inf)
        assert rs.finished_at("f0") == pytest.approx(2.0)
        # scale_link composes on the current capacity
        g2, cl2, rs2 = self.mk(over=1.0)
        rs2.run_until(0.0)
        rs2.scale_link("d0.nic_in", 0.5)
        rs2.scale_link("d0.nic_in", 0.5)
        assert rs2.link_capacity("d0.nic_in") == pytest.approx(0.25)

    def test_kill_host_lineage_resurrection(self):
        """Finished data resident on the dead host is re-produced iff an
        unfinished consumer still needs it."""
        g, cl, rs = self.mk(over=1.0)
        rs.run_until(1.5)            # flows done at 1.0, computes running
        rs.advance_to(1.5)
        restarted = rs.kill_host("d1")
        # f1 delivered to d1 and c1 (its consumer) was unfinished: both
        # restart; finished flows to other hosts are untouched
        assert set(restarted) == {"c1", "f1"}
        assert rs.progress()["f1"] == 0.0
        assert rs.link_capacity("d1.nic_in") == 0.0
        assert rs.free_slots()[("d1", "cpu")] == 0
        # unrecoverable without replanning: c1 has nowhere to run
        assert rs.run_until(math.inf, allow_stall=True) == "stalled"
        # recovery: move c1 (f1 re-fetches to the new home), finish
        rs.move_task("c1", "s1")
        rs.repath_flow("f1", ("s1.nic_out", "s1.nic_in"), dst="s1")
        assert rs.run_until(math.inf) == "done"
        assert rs.task_host("c1") == "s1"
        assert rs.flow_ends("f1") == ("s1", "s1")

    def test_kill_host_after_all_consumers_done_is_noop(self):
        g, cl, rs = self.mk(over=1.0)
        rs.run_until(math.inf)
        ms = rs.result().makespan
        assert rs.kill_host("d1") == []
        assert rs.result().makespan == ms

    def test_move_task_to_shared_pool_contends(self):
        """A moved task competes for the destination pool's slots —
        slot accounting must use the existing pool, not a fresh one."""
        g, cl, rs = self.mk(over=1.0)
        rs.run_until(0.0)
        rs.move_task("c1", "d0")     # d0 has 1 cpu slot, c0 lives there
        rs.repath_flow("f1", ("s1.nic_out", "d0.nic_in"), dst="d0")
        assert rs.run_until(math.inf) == "done"
        # c0 and c1 serialize on d0's single slot
        f = rs.result()
        assert abs(f.finish["c0"] - f.finish["c1"]) >= 1.0 - 1e-9

    def test_repath_merges_contention_components(self):
        """Re-pathing a flow onto another flow's links must merge their
        components — split components sharing a link would double-book
        bandwidth in the waterfill."""
        g, cl, rs = self.mk(over=1.0)
        rs.run_until(0.0)
        # f0 and f1 are disjoint (s0->d0, s1->d1); route f0 through
        # d1's ingress NIC instead
        rs.repath_flow("f0", ("s0.nic_out", "d1.nic_in"),
                       reset=True, dst="d1")
        rs.run_until(1.0)
        rs.advance_to(1.0)
        p = rs.progress()
        # two flows share d1.nic_in (cap 1.0): each gets 0.5
        assert p["f0"] == pytest.approx(0.5)
        assert p["f1"] == pytest.approx(0.5)

    def test_set_priorities_mid_run(self):
        g, cl = builders.oversubscribed_fanin(4, oversubscription=4.0)
        rs = ResumableSim(Simulator(g, cl))
        rs.run_until(0.0)
        # strict priority to f3: it should now finish first
        rs.set_priorities({"f3": 0.0, "f0": 1.0, "f1": 1.0, "f2": 1.0},
                          policy="priority")
        rs.run_until(math.inf)
        f = rs.result()
        assert f.finish["f3"] < min(f.finish["f0"], f.finish["f1"],
                                    f.finish["f2"]) - 1e-9


class TestRandomFaults:
    def test_seeded_schedule_is_deterministic(self):
        g, cl = builders.fat_tree_shuffle(8, stride=2)
        a = random_faults(g, cl, horizon=10.0, n=5, seed=42)
        b = random_faults(g, cl, horizon=10.0, n=5, seed=42)
        assert a == b
        c = random_faults(g, cl, horizon=10.0, n=5, seed=43)
        assert a != c
        assert all(f.kind in ("host_loss", "link_degrade", "straggler")
                   for f in a)
        assert all(1.5 <= f.time <= 6.0 for f in a)

    def test_no_fabric_means_no_link_faults(self):
        g = builders.fig1_jobs()
        cl = Cluster.for_graph(g)      # homogeneous big switch, no topo
        fs = random_faults(g, cl, horizon=10.0, n=8, seed=1)
        assert fs and all(f.kind != "link_degrade" for f in fs)

    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError):
            Fault(1.0, "meteor", "d0")


class TestNemesisRecovery:
    def sched_fanin(self, n=8, over=8.0):
        g, cl = builders.oversubscribed_fanin(n, oversubscription=over)
        return MXDAGScheduler(try_pipelining=False).schedule(g, cl), cl

    def test_host_loss_replan_recovers_no_replan_stalls(self):
        sched, cl = self.sched_fanin()
        faults = [Fault(2.5, "host_loss", "d0")]
        no = Nemesis(sched, cl, faults=faults, replan=False).run()
        yes = Nemesis(sched, cl, faults=faults, replan=True).run()
        assert not no.completed and no.makespan == math.inf
        assert yes.completed and yes.makespan < math.inf
        assert yes.detection_rate == 1.0
        rec = yes.tracker.records[0]
        assert rec.detected and rec.recovered
        assert any(a[0] == "move_task" for a in rec.actions)

    def test_straggler_replan_beats_no_replan(self):
        sched, cl = self.sched_fanin()
        faults = [Fault(1.5, "straggler", "c0", 0.125)]
        no = Nemesis(sched, cl, faults=faults, replan=False).run()
        yes = Nemesis(sched, cl, faults=faults, replan=True).run()
        assert no.completed and yes.completed
        assert yes.makespan < no.makespan - 1e-9
        assert yes.detection_rate == 1.0

    def test_link_degrade_replan_beats_no_replan(self):
        g, cl = builders.fat_tree_shuffle(8, stride=2)
        sched = MXDAGScheduler(try_pipelining=False).schedule(g, cl)
        base = sched.simulate(cl).makespan
        faults = [Fault(base * 0.3, "link_degrade", "p0.e1a2.up", 0.1)]
        no = Nemesis(sched, cl, faults=faults, replan=False,
                     probe_every=0.25).run()
        yes = Nemesis(sched, cl, faults=faults, replan=True,
                      probe_every=0.25).run()
        assert no.completed and yes.completed
        assert yes.makespan < no.makespan - 1e-9
        assert yes.detection_rate == 1.0
        assert "p0.e1a2.up" in yes.tracker.records[0].diagnosis

    def test_scenario_replays_bit_exact(self):
        """The whole fault scenario — schedule, injection, detection,
        recovery — is a pure function of its seeds."""
        sched, cl = self.sched_fanin()
        faults = random_faults(sched.graph, cl, horizon=9.0, n=2, seed=7)
        a = Nemesis(sched, cl, faults=faults, replan=True).run()
        b = Nemesis(sched, cl, faults=faults, replan=True).run()
        assert a.makespan == b.makespan
        assert [r.detected_at for r in a.tracker.records] \
            == [r.detected_at for r in b.tracker.records]
        assert a.tracker.report() == b.tracker.report()

    def test_tracker_report_lists_every_fault(self):
        sched, cl = self.sched_fanin()
        faults = [Fault(1.5, "straggler", "c0", 0.125),
                  Fault(2.5, "host_loss", "d1")]
        rep = Nemesis(sched, cl, faults=faults, replan=True).run()
        table = rep.tracker.report()
        assert "straggler" in table and "host_loss" in table
        assert "MISSED" not in table
        assert len(rep.tracker.records) == 2

    def test_empty_tracker_rates(self):
        t = RecoveryTracker()
        assert t.detection_rate() == 1.0
        assert t.recovery_rate() == 1.0


class TestCoflowResurrect:
    """Coflow-coupled resurrection: killing finished members of a MADD
    group rewinds gate counts and group membership exactly."""

    T = 2.5        # reducers are half done (they run 2.0 -> 3.0)

    def mk(self):
        g = builders.mapreduce("mr", 2, 2)
        cl = Cluster.for_graph(g)
        return g, cl, ResumableSim(Simulator(g, cl,
                                             coflows=auto_coflows(g)))

    def test_reducer_host_loss_differential_vs_fresh(self):
        """The acceptance oracle: after rewinding a finished shuffle
        group and replaying recovery, the mutated sim must agree
        *bit-exactly* with a fresh sim constructed in the post-fault
        state (all sizes dyadic, so float equality is meaningful)."""
        g, cl, rs = self.mk()
        rs.run_until(self.T)
        rs.advance_to(self.T)
        restarted = rs.kill_host("mr.R1")
        # r1's inputs were delivered to the dead host: the finished
        # coflow group {s0_1, s1_1} is resurrected alongside r1
        assert set(restarted) == {"mr.r1", "mr.s0_1", "mr.s1_1"}
        # recovery: rerun r1 on the idle mapper host M0, re-fetch there
        rs.move_task("mr.r1", "mr.M0")
        for f in ("mr.s0_1", "mr.s1_1"):
            src, _ = rs.flow_ends(f)
            rs.repath_flow(f, (f"{src}.nic_out", "mr.M0.nic_in"),
                           reset=True, dst="mr.M0")
        assert rs.run_until(math.inf) == "done"
        res = rs.result()

        # fresh-sim oracle built from the post-fault state: r0 at its
        # remaining size, r1 + its shuffle group from scratch on M0
        g2 = MXDAG("post")
        g2.add(dataclasses.replace(g.tasks["mr.r0"], size=0.5))
        g2.add(dataclasses.replace(g.tasks["mr.r1"], host="mr.M0"))
        for f in ("mr.s0_1", "mr.s1_1"):
            g2.add(dataclasses.replace(g.tasks[f], dst="mr.M0"))
            g2.add_edge(f, "mr.r1")
        fresh = array_run(Simulator(g2, cl,
                                    coflows=[{"mr.s0_1", "mr.s1_1"}]))
        for n in g2.tasks:
            assert res.finish[n] == self.T + fresh.finish[n]

    def test_resurrect_conflict_names_started_consumers(self):
        # resolve the class through the module at call time: the numpy
        # fallback test reloads arraysim, invalidating import-time
        # class identity
        from repro.core.arraysim import ResurrectConflict

        g, cl, rs = self.mk()
        rs.run_until(1.5)
        rs.advance_to(1.5)           # shuffle flows mid-flight
        with pytest.raises(ResurrectConflict) as ei:
            rs.kill_task("mr.m1")
        e = ei.value
        assert e.task == "mr.m1"
        # member-synchronized gating: every started shuffle flow runs
        # on m1's barrier, so every one of them is named
        assert set(e.consumers) == {"mr.s0_0", "mr.s0_1",
                                    "mr.s1_0", "mr.s1_1"}
        for c in e.consumers:
            assert c in str(e)
        assert isinstance(e, RuntimeError)
        # the refusal left the sim untouched: it completes clean
        assert rs.run_until(math.inf) == "done"
        assert rs.result().makespan == 3.0

    def test_kill_host_autokills_consumers_and_resyncs(self):
        g, cl, rs = self.mk()
        rs.run_until(1.5)
        rs.advance_to(1.5)
        restarted = rs.kill_host("mr.M1")
        # lineage closure caught the ResurrectConflict, killed exactly
        # the started consumers, and retried
        assert set(restarted) == {"mr.m1", "mr.s0_0", "mr.s0_1",
                                  "mr.s1_0", "mr.s1_1"}
        # recovery: rerun m1 on a reducer host (idle until shuffles land)
        rs.move_task("mr.m1", "mr.R0")
        for f in ("mr.s1_0", "mr.s1_1"):
            _, dst = rs.flow_ends(f)
            rs.repath_flow(f, ("mr.R0.nic_out", f"{dst}.nic_in"),
                           src="mr.R0")
        assert rs.run_until(math.inf) == "done"
        # group membership survived the rewind: all four flows restart
        # member-synchronized once m1's barrier re-opens at t=2.5
        starts = {rs.started_at(f) for f in
                  ("mr.s0_0", "mr.s0_1", "mr.s1_0", "mr.s1_1")}
        assert len(starts) == 1
        assert starts.pop() == pytest.approx(2.5)


def _storm_mutate(rs, rng, hosts, links, tasks):
    """Apply one random mutator; preconditions may legitimately refuse
    (finished consumers, dead hosts, missing pools) — refusals are part
    of the surface under test and must not corrupt state."""
    op = rng.randrange(6)
    try:
        if op == 0:
            rs.kill_task(rng.choice(tasks))
        elif op == 1:
            rs.kill_host(rng.choice(hosts))
        elif op == 2:
            rs.scale_link(rng.choice(links), rng.choice([0.25, 0.5]))
        elif op == 3:
            link = rng.choice(links)
            rs.set_link_bw(link, rs.link_capacity(link) or 1.0)
        elif op == 4:
            rs.set_speed(rng.choice(tasks), rng.choice([0.25, 0.5, 1.0]))
        else:
            task, host = rng.choice(tasks), rng.choice(hosts)
            rs.move_task(task, host)
    except (ValueError, KeyError, RuntimeError):
        pass


class TestMutatorStorms:
    """Property tests: checkpoint isolation under arbitrary mutator
    storms, and mutator/spec equivalence against fresh sims."""

    def _fork_scenarios(self):
        def fanin():
            g, cl = builders.oversubscribed_fanin(4, oversubscription=2.0)
            return g, cl, Simulator(g, cl)

        def mr_coflows():
            g = builders.mapreduce("mr", 2, 2)
            cl = Cluster.for_graph(g)
            return g, cl, Simulator(g, cl, coflows=auto_coflows(g))

        return [fanin, mr_coflows]

    @seeded_property(12)
    def test_parent_replays_bit_exact_after_fork_storm(self, seed):
        """A forked checkpoint absorbs an arbitrary mutator storm; the
        restored parent must replay the unmutated run bit-exactly."""
        for mk in self._fork_scenarios():
            g, cl, sim = mk()
            ref = array_run(mk()[2])
            rs = ResumableSim(sim)
            rs.run_until(0.5)
            snap = rs.checkpoint()

            rng = random.Random(seed)
            hosts = sorted(cl.hosts)
            links = sorted(
                l for h in hosts for l in (f"{h}.nic_in", f"{h}.nic_out")
            ) + sorted(cl.topology.links if cl.topology else ())
            tasks = sorted(g.tasks)
            t = 0.5
            for _ in range(6):
                t += rng.uniform(0.2, 0.6)
                status = rs.run_until(t, allow_stall=True)
                if status == "done":
                    break
                if status != "stalled":
                    rs.advance_to(t)
                _storm_mutate(rs, rng, hosts, links, tasks)
            rs.run_until(1e6, allow_stall=True)     # fork may stall: fine

            rs.restore(snap)
            assert rs.run_until(math.inf) == "done"
            res = rs.result()
            assert res.finish == ref.finish
            assert res.start == ref.start

    @seeded_property(12)
    def test_mutators_at_t0_match_fresh_sim_from_mutated_spec(self, seed):
        """Moves, degradations and slowdowns applied at t=0 must land on
        the same schedule as a fresh sim built from the mutated spec."""
        rng = random.Random(seed)
        g, cl = builders.oversubscribed_fanin(4, oversubscription=2.0)
        topo = cl.topology
        hosts = sorted(cl.hosts)

        moves = {f"c{i}": rng.choice(hosts)
                 for i in range(4) if rng.random() < 0.5}
        speeds = {f"c{i}": rng.choice([0.25, 0.5])
                  for i in range(4) if rng.random() < 0.4}
        degr = {l: rng.choice([0.25, 0.5])
                for l in rng.sample(sorted(topo.links),
                                    k=rng.randrange(0, 3))}

        rs = ResumableSim(Simulator(g, cl))
        rs.run_until(0.0)
        for task, h in moves.items():
            rs.move_task(task, h)
            fl = f"f{task[1:]}"              # fanin: f_i feeds c_i
            src, _ = rs.flow_ends(fl)
            rs.repath_flow(fl, topo.path(src, h), dst=h)
        for task, f in speeds.items():
            rs.set_speed(task, f)
        for l, f in degr.items():
            rs.set_link_bw(l, cl.bandwidth(l) * f)
        assert rs.run_until(math.inf) == "done"
        live = rs.result()

        g2 = MXDAG("mutated")
        for t in g.tasks.values():
            if t.kind is TaskKind.COMPUTE:
                t = dataclasses.replace(
                    t, host=moves.get(t.name, t.host),
                    size=t.size / speeds.get(t.name, 1.0))
            else:
                consumer = f"c{t.name[1:]}"
                if consumer in moves:
                    t = dataclasses.replace(t, dst=moves[consumer])
            g2.add(t)
        for e in g.edges.values():
            g2.add_edge(e.src, e.dst, pipelined=e.pipelined)
        cl2 = cl.degraded({l: cl.bandwidth(l) * f for l, f in degr.items()})
        fresh = array_run(Simulator(g2, cl2))
        # rerouted flows can leave non-dyadic waterfill shares (e.g. a
        # 3-way split of 2.0), where the live path and the fresh path
        # associate the same products differently — last-ulp only
        assert live.finish.keys() == fresh.finish.keys()
        for n in fresh.finish:
            assert live.finish[n] == pytest.approx(fresh.finish[n],
                                                   abs=1e-9)
            assert live.start[n] == pytest.approx(fresh.start[n],
                                                  abs=1e-9)


def _loaded_fabric_link(g, cl):
    """Most-traversed non-NIC link under static routing (bench's pick)."""
    from collections import Counter
    cnt = Counter()
    for t in g.tasks.values():
        if t.kind is TaskKind.NETWORK:
            for l in cl.resources_for(t):
                if not is_nic_link(l):
                    cnt[l] += 1
    return max(sorted(cnt), key=cnt.__getitem__)


class TestCascadeCampaigns:
    def coflow_shuffle(self):
        g, cl = builders.fat_tree_shuffle(8, stride=2)
        sched = MXDAGScheduler(try_pipelining=False).schedule(g, cl)
        return g, cl, dataclasses.replace(sched, coflows=auto_coflows(g))

    def test_tor_groups_and_rack_blast(self):
        g, cl = builders.fat_tree_shuffle(8, stride=2)
        groups = tor_groups(cl)
        assert "p0.e0" in groups
        hosts, links = rack_blast(cl, "p0.e0")
        assert hosts and links
        assert all(h.startswith("p0e0") for h in hosts)
        assert all(l.startswith("p0.e0") for l in links)
        with pytest.raises(ValueError):
            rack_blast(cl, "nonexistent.switch")
        # a big-switch cluster has no ToR structure to blast
        assert tor_groups(Cluster.for_graph(builders.fig1_jobs())) == {}

    def test_flapping_link_schedule(self):
        fs = flapping_link("p0.e0a0.up", start=1.0, period=0.5,
                           cycles=2, factor=0.25)
        assert [f.kind for f in fs] == ["link_degrade", "link_recover",
                                        "link_degrade", "link_recover"]
        assert [f.time for f in fs] == [1.0, 1.25, 1.5, 1.75]
        assert all(f.target == "p0.e0a0.up" for f in fs)
        assert fs[0].factor == 0.25 and fs[1].factor == 1.0
        with pytest.raises(ValueError):
            flapping_link("l", start=0.0, period=0.0)
        with pytest.raises(ValueError):
            flapping_link("l", start=0.0, period=1.0, cycles=0)

    def test_fault_storm_distinct_targets_in_window(self):
        g, cl = builders.fat_tree_shuffle(8, stride=2)
        fs = fault_storm(g, cl, horizon=4.0, n=4, seed=3)
        assert len(fs) == 4
        assert len({(f.kind, f.target) for f in fs}) == 4
        assert all(0.2 * 4.0 <= f.time <= 0.4 * 4.0 + 1e-9 for f in fs)
        assert all(f.kind in BASE_FAULT_KINDS for f in fs)
        assert fs == fault_storm(g, cl, horizon=4.0, n=4, seed=3)
        # opting into rack_loss draws from the ToR groups
        fs2 = fault_storm(g, cl, horizon=4.0, n=4, seed=3,
                          kinds=BASE_FAULT_KINDS + ("rack_loss",))
        assert any(f.kind == "rack_loss" for f in fs2)

    def test_rack_loss_recovery(self):
        g, cl, sched = self.coflow_shuffle()
        base = sched.simulate(cl).makespan
        faults = [Fault(0.4 * base, "rack_loss", "p0.e0")]
        no = Nemesis(sched, cl, faults=faults, replan=False,
                     probe_every=0.25).run()
        yes = Nemesis(sched, cl, faults=faults, replan=True,
                      probe_every=0.25).run()
        assert not no.completed            # stranded mappers: stalls
        assert yes.completed and yes.makespan < math.inf
        assert yes.detection_rate == 1.0
        rec = yes.tracker.records[0]
        assert "rack p0.e0" in rec.diagnosis
        assert any(a[0] == "move_task" for a in rec.actions)

    def test_storm_per_fault_attribution(self):
        """Three simultaneously active faults: every record must carry
        its *own* diagnosis, not the probe batch's union."""
        g, cl, sched = self.coflow_shuffle()
        base = sched.simulate(cl).makespan
        link = _loaded_fabric_link(g, cl)
        faults = [Fault(0.3 * base, "link_degrade", link, 0.05),
                  Fault(0.45 * base, "host_loss", "p1e0h0"),
                  Fault(0.5 * base, "straggler", "r5", 0.1)]
        rep = Nemesis(sched, cl, faults=faults, replan=True,
                      probe_every=0.25).run()
        assert rep.completed and rep.detection_rate == 1.0
        by_kind = {r.fault.kind: r for r in rep.tracker.records}
        assert link in by_kind["link_degrade"].diagnosis
        assert "r5" not in by_kind["link_degrade"].diagnosis
        assert "p1e0h0" in by_kind["host_loss"].diagnosis
        assert "r5" in by_kind["straggler"].diagnosis
        assert link not in by_kind["straggler"].diagnosis


class TestCostAwareReplan:
    def sched_fanin(self, n=8, over=8.0):
        g, cl = builders.oversubscribed_fanin(n, oversubscription=over)
        return MXDAGScheduler(try_pipelining=False).schedule(g, cl), cl

    def test_mild_straggler_move_is_vetoed(self):
        """c0 at 0.6x with most of its work behind it: staying rides out
        the mild slowdown; moving pays the full 8s restart. Always-act
        loses to doing nothing; the cost model prices both arms on the
        analytic critical path and declines the move."""
        sched, cl = self.sched_fanin()
        faults = [Fault(3.0, "straggler", "c0", 0.6)]
        no = Nemesis(sched, cl, faults=faults, replan=False).run()
        plain = Nemesis(sched, cl, faults=faults, replan=True).run()
        nem = Nemesis(sched, cl, faults=faults, replan=True,
                      cost_aware=True)
        cost = nem.run()
        assert plain.makespan > no.makespan + 1e-9
        assert cost.makespan <= no.makespan + 1e-9
        assert cost.detection_rate == 1.0          # seen, priced, declined
        assert any("not worth it" in reason
                   for _, _, reason in nem.controller.declined)

    def test_severe_straggler_still_acted_on(self):
        sched, cl = self.sched_fanin()
        faults = [Fault(1.5, "straggler", "c0", 0.125)]
        no = Nemesis(sched, cl, faults=faults, replan=False).run()
        cost = Nemesis(sched, cl, faults=faults, replan=True,
                       cost_aware=True).run()
        assert cost.completed
        assert cost.makespan < no.makespan - 1e-9
        assert cost.detection_rate == 1.0

    def test_host_loss_relocation_is_never_cost_gated(self):
        """Losing a host leaves no stay arm — relocation is survival,
        not speculation, so the cost model must not veto it."""
        sched, cl = self.sched_fanin()
        faults = [Fault(2.5, "host_loss", "d0")]
        cost = Nemesis(sched, cl, faults=faults, replan=True,
                       cost_aware=True).run()
        assert cost.completed and cost.makespan < math.inf
        assert cost.detection_rate == 1.0


class TestSimulatorPlumbing:
    def test_resumable_entry_point(self):
        # resolve the class through the module at call time: the numpy
        # fallback test reloads arraysim, invalidating import-time
        # class identity
        from repro.core import arraysim

        g, cl = builders.oversubscribed_fanin(4, oversubscription=4.0)
        sim = Simulator(g, cl)
        rs = sim.resumable()
        assert isinstance(rs, arraysim.ResumableSim)
        rs.run_until(math.inf)
        assert rs.result().makespan == array_run(
            Simulator(g, cl)).makespan
