"""Compiled analytic layer tests: golden *bit-exact* equivalence of
arrayanalytic.analyze/critical_path and the scheduler's compiled
priorities against the dict implementations (MXDAG.with_slack /
critical_path / MXDAGScheduler._priorities) on every builder scenario,
compile caching, the numpy-stubbed stdlib fallback, and a hypothesis
property over random layered DAGs.
"""
import importlib
import sys

import pytest

from repro.core import MXDAG, MXDAGScheduler, compute, flow
from repro.core import arrayanalytic, builders


def scenario_graphs():
    """Every builder scenario the dict/array equivalence must cover,
    including pipelined and released graphs."""
    gs = [builders.fig1_jobs(), builders.fig2a(), builders.fig2b()]
    gs += [builders.fig3_case(c) for c in range(4)]
    gs.append(builders.ddl(8, push=2.0, pull=2.0))
    gs.append(builders.ddl(8, push=2.0, pull=2.0, unit_frac=0.25))
    gs.append(builders.mapreduce("mr", 8, 8))
    piped = builders.mapreduce("mrp", 6, 6, unit_frac=0.25)
    for e in list(piped.edges):
        piped.set_pipelined(*e, True)
    gs.append(piped)
    g, _ = builders.oversubscribed_fanin(4, oversubscription=4.0)
    gs.append(g)
    g, _ = builders.fat_tree_shuffle(8, stride=2)
    gs.append(g)
    gs.append(builders.serial_chain(64, pipelined=True, unit=0.25))
    gs.append(builders.random_layered(800, n_hosts=32, min_width=8,
                                      max_width=32, seed=11))
    for j in builders.mapreduce_pair():
        gs.append(j)
    return gs


def assert_bit_equal(g, rsrc=None, release=None):
    """analyze()/critical_path() == the dict passes, with ``==`` — the
    compiled layer's contract is bit-exactness, not approximation."""
    at = arrayanalytic.analyze(g, rsrc, release)
    d = g.with_slack(rsrc, release)
    assert set(d) == set(at.names)
    for i, nm in enumerate(at.names):
        tm = d[nm]
        assert tm.ready == at.ready[i], nm
        assert tm.first_out == at.first_out[i], nm
        assert tm.completion == at.completion[i], nm
        assert tm.latest_completion == at.latest[i], nm
        assert tm.slack == at.slack[i], nm
    assert at.makespan == g.makespan(rsrc, release)
    assert arrayanalytic.critical_path(g, rsrc, release) \
        == g.critical_path(rsrc, release)
    # to_dict() round-trips into the exact with_slack() mapping
    assert at.to_dict() == d


class TestGoldenEquivalence:
    def test_every_builder_scenario(self):
        for g in scenario_graphs():
            assert_bit_equal(g)

    def test_with_resources(self):
        g = builders.fig1_jobs()
        assert_bit_equal(g, rsrc={"f1": 0.5, "b": 0.25, "f3": 1.0})
        g2 = builders.ddl(8, push=2.0, pull=2.0, unit_frac=0.25)
        assert_bit_equal(g2, rsrc={f"push{i}": 0.5 for i in range(8)})

    def test_with_releases(self):
        g = builders.fig1_jobs()
        assert_bit_equal(g, release={"f3": 7.0, "a": 1.5})
        g2 = builders.mapreduce("mr", 6, 6)
        assert_bit_equal(g2, release={"mr.m0": 3.0, "mr.r5": 10.0})

    def test_rsrc_validation_matches_task_time(self):
        g = builders.fig1_jobs()
        with pytest.raises(ValueError, match="rsrc must be in"):
            arrayanalytic.analyze(g, rsrc={"f1": 0.0})
        with pytest.raises(ValueError, match="rsrc must be in"):
            arrayanalytic.analyze(g, rsrc={"f1": 1.5})

    def test_priorities_equal_dict_path(self):
        for g in scenario_graphs():
            sa = MXDAGScheduler(analytic="array")
            sd = MXDAGScheduler(analytic="dict")
            assert sa._priorities(g) == sd._priorities(g), g.name

    def test_release_shrinks_overstated_slack(self):
        """with_slack() used to drop releases: a late-released branch
        looked slack-rich even when its release makes it critical."""
        g = MXDAG("rel")
        a = g.add(compute("a", 4.0, "A"))
        b = g.add(compute("b", 1.0, "B"))
        without = g.with_slack()
        with_rel = g.with_slack(release={"b": 6.0})
        assert without["b"].slack == pytest.approx(3.0)
        # released at 6, b finishes at 7 and becomes the critical sink
        assert with_rel["b"].slack == pytest.approx(0.0)
        assert with_rel["a"].slack == pytest.approx(3.0)
        assert g.critical_path(release={"b": 6.0}) == ["b"]
        assert_bit_equal(g, release={"b": 6.0})


class TestCompileCache:
    def test_cached_per_graph_version(self):
        g = builders.mapreduce("mr", 4, 4)
        c1 = arrayanalytic.compile_analytic(g)
        assert arrayanalytic.compile_analytic(g) is c1
        g.set_pipelined(*next(iter(g.edges)), True)
        assert arrayanalytic.compile_analytic(g) is not c1

    def test_shared_with_arraysim_compile(self):
        from repro.core import arraysim
        from repro.core.simulator import Simulator
        g = builders.mapreduce("mr", 4, 4)
        an = arrayanalytic.compile_analytic(g)
        sim = arraysim.compile_sim(Simulator(g))
        assert sim.names is an.names
        assert sim.name_rank is an.name_rank
        assert sim.size is an.size


class TestSchedulerEquivalence:
    def test_schedule_outputs_identical(self):
        """analytic="array" and analytic="dict" produce bit-identical
        Schedules (priorities, policy, critical path, prediction)."""
        cases = [
            (builders.fig1_jobs(), dict()),
            (builders.fig3(), dict()),
            (builders.ddl(8, push=2.0, pull=2.0),
             dict(try_pipelining=False)),
            (builders.ddl(6, push=2.0, pull=2.0, unit_frac=0.25), dict()),
            (builders.mapreduce("mr", 6, 6), dict(try_pipelining=False)),
        ]
        for g, kw in cases:
            sa = MXDAGScheduler(analytic="array", **kw).schedule(g.copy())
            sd = MXDAGScheduler(analytic="dict", **kw).schedule(g.copy())
            assert sa.policy == sd.policy, g.name
            assert sa.priorities == sd.priorities, g.name
            assert sa.meta["critical_path"] == sd.meta["critical_path"]
            assert sa.meta["predicted_makespan"] \
                == sd.meta["predicted_makespan"]
            assert sa.meta["pipelined"] == sd.meta["pipelined"]

    def test_unknown_analytic_rejected(self):
        with pytest.raises(ValueError, match="unknown analytic"):
            MXDAGScheduler(analytic="quantum")


class TestNumpyFallback:
    def test_stubbed_numpy_import_falls_back(self):
        """The compiled layer must run pure-stdlib when numpy is absent
        (core CI lane) and produce bit-identical results."""
        cases = [builders.fig2b(),
                 builders.ddl(6, push=2.0, pull=2.0, unit_frac=0.25),
                 builders.random_layered(400, n_hosts=16, min_width=4,
                                         max_width=16, seed=3)]
        had_np = arrayanalytic.np is not None
        with_np = None
        if had_np:
            with_np = [(arrayanalytic.analyze(g),
                        arrayanalytic.critical_path(g)) for g in cases]
        saved = sys.modules.get("numpy")
        sys.modules["numpy"] = None      # import numpy raises ImportError
        try:
            importlib.reload(arrayanalytic)
            assert arrayanalytic.np is None
            for k, g in enumerate(cases):
                g2 = g.copy()            # fresh cache: stdlib compile
                at = arrayanalytic.analyze(g2)
                d = g2.with_slack()
                for i, nm in enumerate(at.names):
                    assert d[nm].completion == at.completion[i]
                    assert d[nm].latest_completion == at.latest[i]
                cp = arrayanalytic.critical_path(g2)
                assert cp == g2.critical_path()
                if with_np is not None:
                    a_np, cp_np = with_np[k]
                    assert at.completion == a_np.completion
                    assert at.latest == a_np.latest
                    assert cp == cp_np
        finally:
            if saved is None:
                del sys.modules["numpy"]
            else:
                sys.modules["numpy"] = saved
            importlib.reload(arrayanalytic)
        assert (arrayanalytic.np is not None) == had_np

    def test_np_compiled_graph_survives_numpy_removal(self):
        """A graph compiled with numpy mirrors still analyzes correctly
        through the stdlib path when numpy later vanishes (the analyze
        guard is on the module's np, not just the compile flag)."""
        g = builders.fig2a()
        arrayanalytic.compile_analytic(g)      # maybe-with-np compile
        saved = sys.modules.get("numpy")
        sys.modules["numpy"] = None
        try:
            importlib.reload(arrayanalytic)
            at = arrayanalytic.analyze(g)      # cached comp, stdlib walk
            d = g.with_slack()
            for i, nm in enumerate(at.names):
                assert d[nm].completion == at.completion[i]
        finally:
            if saved is None:
                del sys.modules["numpy"]
            else:
                sys.modules["numpy"] = saved
            importlib.reload(arrayanalytic)


hypothesis = None
try:
    import hypothesis
    from hypothesis import given, settings, strategies as st
except ImportError:
    pass


if hypothesis is not None:
    class TestAnalyticProperty:
        @given(n=st.integers(min_value=2, max_value=120),
               seed=st.integers(min_value=0, max_value=2**16),
               frac=st.sampled_from([None, 0.25, 0.5]))
        @settings(max_examples=30, deadline=None)
        def test_random_layered_bit_equal(self, n, seed, frac):
            g = builders.random_layered(
                max(n, 2), n_hosts=16, min_width=2, max_width=16,
                seed=seed)
            if frac is not None:
                import dataclasses
                # deterministically pipeline some edges to exercise the
                # streaming branches of both passes
                for i, e in enumerate(list(g.edges)):
                    if (i * 2654435761 + seed) % 3 == 0:
                        g.set_pipelined(*e, True)
                for j, (nm, t) in enumerate(list(g.tasks.items())):
                    if (j + seed) % 2 and t.size > 0:
                        g.replace_task(dataclasses.replace(
                            t, unit=t.size * frac))
            assert_bit_equal(g)
