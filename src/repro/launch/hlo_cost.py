"""Trip-count-aware cost analysis over compiled SPMD HLO text.

``compiled.cost_analysis()`` visits every while body ONCE — with
scan-over-layers (and microbatch scans) that under-counts flops, bytes
and collective traffic by the trip count.  This module parses the HLO
module into its computations, recovers each while loop's trip count from
its condition (`compare(iter, constant), direction=LT`), and accumulates:

- flops: 2·|out|·K for every ``dot`` (including dots inside fusions) —
  matmuls dominate every assigned arch;
- hbm bytes: Σ (operand + output bytes) per top-level op, fusions counted
  as single ops (their internals stay in registers/VMEM — XLA's own
  fusion model);
- collective bytes per kind, with physically-meaningful conventions:
  all-reduce 2×in, all-gather out, reduce-scatter in, all-to-all in,
  collective-permute in (ring-equivalent wire bytes per device);

all scaled by the product of enclosing loop trip counts.  The result is
the per-device roofline numerator set for §Roofline.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\](?:\{[^}]*\})?")


def _shape_info(type_str: str) -> tuple[int, list[tuple[str, list[int]]]]:
    """Total bytes + [(dtype, dims), ...] for a (possibly tuple) type."""
    total = 0
    shapes = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        ds = [int(x) for x in dims.split(",")] if dims else []
        n = 1
        for d in ds:
            n *= d
        total += n * _DTYPE_BYTES[dt]
        shapes.append((dt, ds))
    return total, shapes


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    out_type: str
    operands: list[str]
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    params: dict[str, str]          # param name -> type str
    ops: list[Op]
    types: dict[str, str]           # %name -> type str (params + defs)


_COMP_HEADER = re.compile(
    r"^(ENTRY\s+)?%?([\w.\-]+)(?:\.clone)?\s*\((.*)\)\s*->\s*(.+?)\s*\{\s*$")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(.*?\)|\S+)\s+([\w\-]+)\((.*)$")


def parse_module(text: str) -> tuple[dict[str, Computation], Optional[str]]:
    comps: dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HEADER.match(line.strip())
            if m:
                is_entry, name, params_str, _ = m.groups()
                params = {}
                # params: "a: f32[2], b: (f32[], s32[])"
                depth = 0
                cur_name, buf = None, ""
                tokens = params_str
                parts = []
                for ch in tokens:
                    if ch == "(" :
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                    if ch == "," and depth == 0:
                        parts.append(buf)
                        buf = ""
                    else:
                        buf += ch
                if buf.strip():
                    parts.append(buf)
                for part in parts:
                    if ":" in part:
                        pname, ptype = part.split(":", 1)
                        params[pname.strip()] = ptype.strip()
                cur = Computation(name=name, params=params, ops=[],
                                  types=dict(params))
                if is_entry:
                    entry = name
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_LINE.match(line)
        if m:
            name, out_type, kind, rest = m.groups()
            # split rest at the matching close paren of the call
            depth = 1
            i = 0
            for i, ch in enumerate(rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
            args = rest[:i]
            attrs = rest[i + 1:]
            operands = re.findall(r"%([\w.\-]+)", args)
            op = Op(name=name, kind=kind, out_type=out_type,
                    operands=operands, attrs=attrs + " ||| " + args)
            cur.ops.append(op)
            cur.types[name] = out_type
    return comps, entry


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", scale: float = 1.0) -> None:
        self.flops += other.flops * scale
        self.bytes += other.bytes * scale
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * scale

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())


class HloCostModel:
    def __init__(self, text: str):
        self.comps, self.entry = parse_module(text)
        self.constants: dict[str, int] = {}
        for comp in self.comps.values():
            for op in comp.ops:
                if op.kind == "constant":
                    m = re.search(r"\|\|\|\s*(-?\d+)\s*$", op.attrs)
                    if m and op.out_type.startswith(("s32[]", "u32[]",
                                                     "s64[]", "u64[]")):
                        self.constants[op.name] = int(m.group(1))
        self._memo: dict[str, Cost] = {}

    # ------------------------------------------------------------------
    def _dot_flops(self, comp: Computation, op: Op) -> float:
        out_bytes, out_shapes = _shape_info(op.out_type)
        if not out_shapes:
            return 0.0
        out_numel = 1
        for d in out_shapes[0][1]:
            out_numel *= d
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
        lhs_type = comp.types.get(op.operands[0], "") if op.operands else ""
        _, lhs_shapes = _shape_info(lhs_type)
        k = 1
        if m and m.group(1) and lhs_shapes:
            dims = lhs_shapes[0][1]
            for idx in m.group(1).split(","):
                i = int(idx)
                if i < len(dims):
                    k *= dims[i]
        return 2.0 * out_numel * k

    def _trip_count(self, cond_name: str) -> int:
        cond = self.comps.get(cond_name)
        if cond is None:
            return 1
        # direct compare or fusion wrapping a compare
        for op in cond.ops:
            if op.kind == "compare" and "direction=LT" in op.attrs:
                for o in op.operands:
                    if o in self.constants:
                        return max(1, self.constants[o])
            if op.kind == "fusion":
                called = re.search(r"calls=%([\w.\-]+)", op.attrs)
                if called and called.group(1) in self.comps:
                    inner = self.comps[called.group(1)]
                    has_lt = any(i.kind == "compare" and
                                 "direction=LT" in i.attrs
                                 for i in inner.ops)
                    if has_lt:
                        for o in op.operands:
                            if o in self.constants:
                                return max(1, self.constants[o])
        return 1

    # ops whose operand reads cannot be fused away on TPU (matmuls read
    # full panels; gathers/scatters/collectives stream their inputs)
    _READ_OPS = {"dot", "gather", "scatter", "dynamic-slice",
                 "dynamic-update-slice", "sort",
                 *COLLECTIVES, *(c + "-start" for c in COLLECTIVES)}

    def _op_bytes(self, comp: Computation, op: Op) -> float:
        """HBM traffic model approximating TPU fusion: every op pays its
        OUTPUT bytes (write traffic ≈ read traffic of its consumer chain);
        operand reads are added only for ops that stream large inputs
        irrespective of fusion (dot/gather/scatter/collectives).  Counting
        operands for every op would double-count fused elementwise chains
        (validated: ~5× overcount on the dense-7B cell)."""
        skip = {"parameter", "constant", "tuple", "get-tuple-element",
                "bitcast", "reshape", "copy", "after-all", "token",
                "partition-id", "replica-id", "iota"}
        if op.kind in skip:
            return 0.0
        # dynamic-update-slice updates IN PLACE (buffer aliased): traffic
        # is the update slice, not the whole buffer.  Without this, every
        # scan stash / decode-cache write counts the full stacked buffer
        # per iteration (measured: 6.4 TB phantom traffic on dsv3 train).
        if op.kind == "dynamic-update-slice" or (
                op.kind == "fusion" and self._fusion_has_dus(op)):
            opb = []
            for o in op.operands:
                t = comp.types.get(o)
                if t:
                    b, _ = _shape_info(t)
                    if b > 0:
                        opb.append(b)
            return 2.0 * min(opb) if opb else 0.0
        total, _ = _shape_info(op.out_type)
        if op.kind in self._READ_OPS or op.kind == "fusion":
            for o in op.operands:
                t = comp.types.get(o)
                if t:
                    b, _ = _shape_info(t)
                    total += b
        return float(total)

    def _fusion_root_kind(self, op: Op) -> str:
        m = re.search(r"calls=%([\w.\-]+)", op.attrs)
        if not m:
            return ""
        called = self.comps.get(m.group(1))
        if not called or not called.ops:
            return ""
        return called.ops[-1].kind

    def _fusion_has_dus(self, op: Op) -> bool:
        """Fusions containing a dynamic-update-slice alias their buffer
        operand (the root may be a convert wrapping the DUS)."""
        m = re.search(r"calls=%([\w.\-]+)", op.attrs)
        if not m:
            return False
        called = self.comps.get(m.group(1))
        if not called:
            return False
        return any(o.kind == "dynamic-update-slice" for o in called.ops)

    def _collective(self, comp: Computation, op: Op) -> dict:
        base = op.kind[:-6] if op.kind.endswith("-start") else op.kind
        if base not in COLLECTIVES or op.kind.endswith("-done"):
            return {}
        in_bytes = 0.0
        for o in op.operands:
            t = comp.types.get(o)
            if t:
                b, _ = _shape_info(t)
                in_bytes += b
        out_bytes, _ = _shape_info(op.out_type)
        if base == "all-reduce":
            wire = 2.0 * in_bytes
        elif base == "all-gather":
            wire = float(out_bytes)
        else:                       # RS / A2A / permute
            wire = in_bytes
        return {base: wire}

    # ------------------------------------------------------------------
    def cost_of(self, comp_name: str, *, inside_fusion: bool = False
                ) -> Cost:
        key = f"{comp_name}|{inside_fusion}"
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(comp_name)
        c = Cost()
        if comp is None:
            return c
        for op in comp.ops:
            if op.kind == "dot":
                c.flops += self._dot_flops(comp, op)
                if not inside_fusion:
                    c.bytes += self._op_bytes(comp, op)
                continue
            coll = self._collective(comp, op)
            if coll:
                for k, v in coll.items():
                    c.coll[k] = c.coll.get(k, 0.0) + v
                if not inside_fusion:
                    c.bytes += self._op_bytes(comp, op)
                continue
            if op.kind == "while":
                body = re.search(r"body=%([\w.\-]+)", op.attrs)
                cond = re.search(r"condition=%([\w.\-]+)", op.attrs)
                trips = self._trip_count(cond.group(1)) if cond else 1
                if body:
                    c.add(self.cost_of(body.group(1)), scale=trips)
                if cond:
                    c.add(self.cost_of(cond.group(1)), scale=trips)
                continue
            if op.kind in ("fusion",):
                called = re.search(r"calls=%([\w.\-]+)", op.attrs)
                if called:
                    c.add(self.cost_of(called.group(1),
                                       inside_fusion=True))
                if not inside_fusion:
                    c.bytes += self._op_bytes(comp, op)
                continue
            if op.kind in ("call", "conditional", "async-start"):
                for m in re.finditer(
                        r"(?:to_apply|calls|branch_computations=\{|"
                        r"true_computation|false_computation)=?\{?%([\w.\-]+)",
                        op.attrs):
                    c.add(self.cost_of(m.group(1)))
                continue
            if op.kind in ("custom-call",):
                if not inside_fusion:
                    c.bytes += self._op_bytes(comp, op)
                continue
            if not inside_fusion:
                c.bytes += self._op_bytes(comp, op)
        self._memo[key] = c
        return c

    def total(self) -> Cost:
        if self.entry is None:
            return Cost()
        return self.cost_of(self.entry)


def analyze_text(text: str) -> Cost:
    return HloCostModel(text).total()


class _Reporter(HloCostModel):
    """Debug: attribute cost to individual ops with trip multipliers."""

    def top_ops(self, n: int = 25):
        rows = []

        def walk(comp_name: str, scale: float, inside_fusion: bool):
            comp = self.comps.get(comp_name)
            if comp is None:
                return
            for op in comp.ops:
                if op.kind == "while":
                    body = re.search(r"body=%([\w.\-]+)", op.attrs)
                    cond = re.search(r"condition=%([\w.\-]+)", op.attrs)
                    trips = self._trip_count(cond.group(1)) if cond else 1
                    if body:
                        walk(body.group(1), scale * trips, inside_fusion)
                    continue
                if op.kind == "fusion":
                    called = re.search(r"calls=%([\w.\-]+)", op.attrs)
                    if called:
                        walk(called.group(1), scale, True)
                    if not inside_fusion:
                        b = self._op_bytes(comp, op)
                        if b:
                            rows.append((b * scale, "bytes", op.kind,
                                         op.name, op.out_type[:60], scale))
                    continue
                coll = self._collective(comp, op)
                if coll:
                    for k, v in coll.items():
                        rows.append((v * scale, "coll:" + k, op.kind,
                                     op.name, op.out_type[:60], scale))
                    continue
                if op.kind == "dot":
                    rows.append((self._dot_flops(comp, op) * scale,
                                 "flops", op.kind, op.name,
                                 op.out_type[:60], scale))
                if not inside_fusion:
                    b = self._op_bytes(comp, op)
                    if b:
                        rows.append((b * scale, "bytes", op.kind, op.name,
                                     op.out_type[:60], scale))

        walk(self.entry, 1.0, False)
        rows.sort(reverse=True)
        return rows[:n]
