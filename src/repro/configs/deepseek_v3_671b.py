"""deepseek-v3-671b — MLA + 256-expert top-8 MoE + MTP.

[arXiv:2412.19437; hf]  61L d_model=7168 128H d_ff(expert)=2048
vocab=129280, 1 shared + 256 routed experts top-8, first 3 layers dense
(d_ff=18432 per the HF config), MLA with q_lora=1536 kv_lora=512
nope=128 rope=64 v=128, multi-token-prediction head.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,                    # dense layers (first 3)
    vocab_size=129280,
    head_dim=192,                  # qk_nope + qk_rope
    n_experts=256,
    n_experts_per_tok=8,
    n_shared_experts=1,
    moe_d_ff=2048,                 # per-expert FFN width (assigned d_ff)
    moe_layer_period=1,
    first_dense_layers=3,
    attn_type="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    rope_theta=1e4,
    mtp=True,
)
