"""Benchmark driver.  Prints ``name,value,derived`` CSV rows:

- one section per paper figure (figures.py — the paper's only
  quantitative claims are its worked examples),
- scheduler micro-benchmarks (wall-time of the Principle-1 scheduler and
  the DES on generated DAGs),
- the roofline summary per dry-run cell (roofline.py; populated by
  ``python -m repro.launch.dryrun --all``).
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _timeit(fn, *args, repeat=3):
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn(*args)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def scheduler_micro():
    from repro.core import MXDAGScheduler, simulate
    from repro.core import builders
    rows = []
    g = builders.mapreduce("mr", 8, 8)
    rows.append(("micro.schedule_mr8x8_us",
                 _timeit(lambda: MXDAGScheduler(
                     try_pipelining=False).schedule(g)),
                 "Principle-1 scheduling of an 8x8 shuffle (80 tasks)"))
    rows.append(("micro.simulate_mr8x8_us",
                 _timeit(lambda: simulate(g)),
                 "DES of the same DAG"))
    g2 = builders.ddl(32, push=2.0, pull=2.0)
    rows.append(("micro.schedule_ddl32_us",
                 _timeit(lambda: MXDAGScheduler(
                     try_pipelining=False).schedule(g2)),
                 "Principle-1 scheduling of a 32-layer DDL step"))
    return rows


def main() -> None:
    from benchmarks import figures, roofline

    rows = []
    for fig in figures.ALL:
        rows += fig()
    rows += scheduler_micro()
    rows += roofline.bench_rows()

    print("name,value,derived")
    for name, value, derived in rows:
        d = str(derived).replace(",", ";")
        print(f"{name},{value:.6g},{d}")


if __name__ == "__main__":
    main()
