"""Shared benchmark helpers."""
from __future__ import annotations

import gc
import time


def timeit_us(fn, *args, repeat: int = 3) -> float:
    """Best-of-``repeat`` wall time of ``fn(*args)`` in microseconds.

    The collector is paused during the timed region: large compiled DAGs
    hold millions of objects, and a collection landing inside one rep is
    pure inter-run noise for a best-of measurement.
    """
    best = float("inf")
    was_enabled = gc.isenabled()
    gc.disable()
    try:
        for _ in range(repeat):
            t0 = time.perf_counter()
            fn(*args)
            best = min(best, time.perf_counter() - t0)
    finally:
        if was_enabled:
            gc.enable()
    return best * 1e6
