"""Scale benchmark: DES + Principle-1 scheduler wall time on large DAGs.

Sweeps ``mapreduce(N, N)`` for N ∈ {8, 16, 32}, ``ddl(L)`` for
L ∈ {32, 128}, and a ``fat_tree(8)`` cross-pod shuffle, timing both
``simulate`` (the flat-array engine) and ``MXDAGScheduler.schedule``
(with and without pipelining) — plus a Graphene-scale section:
``mapreduce(128, 128)`` (16640 tasks), ``ddl(1024)`` and
``random_layered(20000)``, where ``scale.speedup_array_*`` rows compare
the flat-array engine against the event-calendar core on the same DAG
(ddl(1024) is the serial-chain trickle whose row is the
component-level-reallocation claim — ~1.2x before components +
coalesced completion events), ``scale.analytic_*`` rows time the
compiled analytic passes (arrayanalytic.analyze / critical_path /
argsort-rank priorities) against the dict implementations with a
bit-exactness ``ref_match``, and ``scale.schedule_*`` rows time the
end-to-end Principle-1 pipeline on both analytic substrates with a
Schedule-identity ``ref_match``.  ``scale.speedup_batch_*`` rows
compare the mega-batch event loop against the per-event loop on the
same compiled engine (interleaved best-of so a frequency step can't
fabricate the ratio; exact-makespan ``ref_match``), and
``scale.speedup_parallel_*`` rows time a ``workers=4`` what-if unit
sweep against the serial loop (bit-identical results;
``scale.parallel_cores`` records the runner's usable cores, which
conditions the CI floor).  Graphs are built outside the timed
region — construction and simulation are separate costs (and were
separate bottlenecks).

The placement rows time the placement-enabled scheduler on the sparse
``fat_tree(8)`` shuffle with *logical* reducers (128 candidate hosts,
16 co-location classes); ``scale.placement_ft8_shuffle.improves`` is the
acceptance claim — placement-enabled scheduling strictly beats the fixed
layout, whose static ECMP picks collide on core links — and is enforced
(must equal 1.0) by check_perf.py.

Two kinds of extra rows:

- ``*_seed_us`` — the same workload on the *seed implementation*: the
  original O(links·flows) waterfill scan, the per-event full-rescan
  simulator loop (retained as ``Simulator._reference_run``), and the
  scheduler without memoization or the incremental pipelining worklist.
  ``scale.speedup_*`` rows report seed/new ratios.
- ``*.ref_match`` — 1.0 iff the engine under test reproduces its oracle's
  makespan on that DAG: the reference slow path for the classic sweep,
  the event-calendar core for the ≥10k-task scenarios (where the
  quadratic reference is unusable).  Enforced by check_perf.py and the
  differential tests.

``--only PREFIX`` restricts the sweep to matching row stems and
``--profile`` wraps it in cProfile — see ``--help``.
"""
from __future__ import annotations

import contextlib
import os
import sys
import time

_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)        # so `python benchmarks/scale.py` works

from benchmarks._util import timeit_pair_us, timeit_us  # noqa: E402

EPS = 1e-9


def _seed_waterfill(group, paths, weight, residual, rates, prep=None):
    """The seed's waterfill, verbatim: O(links · flows) bottleneck scan
    and O(n²) frozen-membership test.  Used only to measure the "before"
    rows; ``weight=None`` (the new unit-weight convention) is adapted to
    the seed's always-call-the-closure behaviour, and the ``prep``
    hoisting hook is accepted and ignored (the seed rebuilt everything
    per call — that is part of what the rows measure)."""
    del prep
    if weight is None:
        def weight(n):  # noqa: ARG001 - seed called a closure per flow
            return 1.0
    unfrozen = sorted(group)
    seq = []
    while unfrozen:
        best_r, best_ratio = None, float("inf")
        for r in residual:
            w = sum(weight(n) for n in unfrozen if r in paths[n])
            if w > EPS:
                ratio = residual[r] / w
                if ratio < best_ratio - EPS:
                    best_r, best_ratio = r, ratio
        if best_r is None:
            for n in unfrozen:
                rates[n] = 0.0
                seq.append((n, 0.0))
            return seq
        frozen_now = [n for n in unfrozen if best_r in paths[n]]
        for n in frozen_now:
            alloc = weight(n) * best_ratio
            rates[n] = alloc
            seq.append((n, alloc))
            for r in paths[n]:
                residual[r] = max(0.0, residual[r] - alloc)
        unfrozen = [n for n in unfrozen if n not in frozen_now]
    return seq


@contextlib.contextmanager
def seed_implementation():
    """Swap in the seed hot paths: original waterfill + the reference
    per-event rescan loop for every simulate() the scheduler issues."""
    import repro.core.simulator as simmod
    import repro.core.schedule as schedmod

    def seed_simulate(graph, cluster=None, **kw):
        return simmod.Simulator(graph, cluster, **kw)._reference_run()

    saved = (simmod.waterfill, schedmod.simulate)
    simmod.waterfill = _seed_waterfill
    schedmod.simulate = seed_simulate
    try:
        yield seed_simulate
    finally:
        simmod.waterfill, schedmod.simulate = saved


def _workloads():
    from repro.core import Cluster, MXDAG, Topology, builders, compute, flow

    out = {}
    for n in (8, 16, 32):
        out[f"mr{n}x{n}"] = (builders.mapreduce("mr", n, n), None)
    out["ddl32"] = (builders.ddl(32, push=2.0, pull=2.0), None)
    out["ddl128"] = (builders.ddl(128, push=2.0, pull=2.0), None)

    topo = Topology.fat_tree(8)
    hosts = topo.hosts()
    g = MXDAG("ft8_shuffle")
    senders, receivers = hosts[:16], hosts[16:32]
    for i, s in enumerate(senders):
        m = g.add(compute(f"m{i}", 1.0, s))
        for j, d in enumerate(receivers):
            f = g.add(flow(f"s{i}_{j}", 1.0 / 16, s, d))
            g.add_edge(m, f)
    out["ft8_shuffle"] = (g, Cluster.from_topology(topo))
    return out


def _pipelined_workloads():
    from repro.core import builders
    return {
        "mr8x8": builders.mapreduce("mr", 8, 8, unit_frac=0.125),
        "mr16x16": builders.mapreduce("mr", 16, 16, unit_frac=0.125),
        "ddl32": builders.ddl(32, push=2.0, pull=2.0, unit_frac=0.25),
    }


def _big_workloads():
    """≥4k-task scenarios exercising the flat-array engine at scale
    (name → builder thunk; built lazily so ``--only`` skips the cost)."""
    from repro.core import builders

    return {
        "mr128x128": lambda: (builders.mapreduce("mr", 128, 128), None),
        "ddl1024": lambda: (builders.ddl(1024, push=2.0, pull=2.0), None),
        "layered20k": lambda: (builders.random_layered(20000), None),
    }


def bench_rows(seed_rows: bool = True, only: str | None = None):
    """All ``scale.*`` rows; ``only`` restricts to row names (minus the
    ``scale.`` prefix) starting with that string — perf iteration on one
    scenario shouldn't pay for the full sweep."""
    from repro.core import MXDAGScheduler, simulate
    from repro.core.simulator import Simulator

    def want(stem: str) -> bool:
        # a block's stem is a prefix of every row name it produces, so
        # match in both directions: --only may name a whole block
        # ("simulate_mr128") or one full row ("simulate_mr8x8_us")
        return (only is None or stem.startswith(only)
                or only.startswith(stem))

    rows = []
    work = _workloads()
    piped = _pipelined_workloads()
    big = _big_workloads()
    big_cache: dict = {}

    def big_graph(name):
        if name not in big_cache:
            big_cache[name] = big[name]()
        return big_cache[name]

    # -- simulate (flat-array engine vs the reference oracle) ----------
    new_us = {}
    for name, (g, cl) in work.items():
        if not want(f"simulate_{name}"):
            continue
        us = timeit_us(lambda g=g, cl=cl: simulate(g, cl), repeat=3)
        new_us[f"simulate_{name}"] = us
        rows.append((f"scale.simulate_{name}_us", us,
                     f"flat-array DES, {len(g.tasks)} tasks"))
        ref = Simulator(g, cl)._reference_run()
        new = simulate(g, cl)
        rows.append((f"scale.simulate_{name}.ref_match",
                     1.0 if abs(ref.makespan - new.makespan) < 1e-9
                     else 0.0,
                     f"makespan {new.makespan:g} == reference slow path"))

    # -- simulate at Graphene scale (array vs event-calendar core) -----
    # the reference oracle is quadratic and unusable at this size, so
    # the equivalence row diffs the two fast engines against each other.
    # ddl1024 (a serial-chain event trickle) is included: its
    # speedup_array row is the component-level-reallocation claim —
    # before components+coalesced events it sat at ~1.2x.
    for name, make in big.items():
        if not want(f"simulate_{name}"):
            continue
        g, cl = big_graph(name)
        sim = Simulator(g, cl)
        us = timeit_us(sim.run, repeat=3 if len(g.tasks) >= 10000 else 2)
        rows.append((f"scale.simulate_{name}_us", us,
                     f"flat-array DES, {len(g.tasks)} tasks"))
        if len(g.tasks) >= 4096:
            # best-of-2 so the gated speedup ratio compares two warm
            # bests (the first calendar rep pays the cold _statics
            # build, as the first array rep pays the compile)
            cal_us = timeit_us(sim.calendar_run, repeat=2)
            rows.append((f"scale.simulate_{name}_cal_us", cal_us,
                         "event-calendar core, same DAG"))
            rows.append((f"scale.speedup_array_{name}", cal_us / us,
                         "flat-array speedup over the event calendar"))
            rows.append((f"scale.simulate_{name}.ref_match",
                         1.0 if abs(sim.run().makespan
                                    - sim.calendar_run().makespan) < 1e-9
                         else 0.0,
                         "array engine == event-calendar core makespan"))

    # -- mega-batch event loop (batch=True vs the per-event oracle) ----
    # both arms run the same compiled flat-array engine; batch=False is
    # the pre-mega-batch loop kept verbatim as the differential oracle.
    # Interleaved best-of so a frequency step can't fabricate the ratio;
    # ref_match is exact makespan equality between the two loops.
    # mr128x128 is deliberately absent: its 16k-flow uniform shuffle is
    # routed to the vectorized waterfill rounds by the batch fill's
    # group-size gate, so batch≈nobatch there (~1.0x) by design.
    for name, floor_note in (("layered20k", "gated >= 1.2x"),
                             ("ddl1024", "gated >= 1.5x")):
        if not want(f"simulate_{name}_batch"):
            continue
        g, cl = big_graph(name)

        def run_batch(g=g, cl=cl):
            return Simulator(g, cl).run(batch=True)

        def run_nobatch(g=g, cl=cl):
            return Simulator(g, cl).run(batch=False)

        run_batch()                     # warm the compile for both arms
        b_us, n_us = timeit_pair_us(run_batch, run_nobatch, repeat=3)
        rows.append((f"scale.simulate_{name}_batch_us", b_us,
                     f"mega-batch event loop ({b_us.note})"))
        rows.append((f"scale.simulate_{name}_nobatch_us", n_us,
                     f"per-event oracle loop ({n_us.note})"))
        rows.append((f"scale.speedup_batch_{name}", n_us / b_us,
                     f"mega-batch speedup over the per-event loop "
                     f"({floor_note})"))
        rows.append((f"scale.simulate_{name}_batch.ref_match",
                     1.0 if run_batch().makespan == run_nobatch().makespan
                     else 0.0,
                     "batched loop == per-event loop makespan (exact)"))

    # -- parallel what-if sweeps (workers=4 vs serial) -----------------
    # one schedule()+DES per trial, fanned across forked workers that
    # inherit the parent's warm compile caches copy-on-write.  The
    # ratio is gated (>=2x) only when the recorded parallel_cores row
    # shows >=4 usable cores — on a 1-core runner the fan-out is
    # correctness-only and the row is informational.
    if want("sweep_unit_mr128x128") or want("parallel_cores"):
        from repro.core.parallel import cpu_count
        from repro.core.whatif import WhatIf
        rows.append(("scale.parallel_cores", float(cpu_count()),
                     "usable cores on this runner (conditions the "
                     "speedup_parallel gate)"))
        g, cl = big_graph("mr128x128")
        units = [2.0 ** k for k in range(-3, 5)]        # 8 trials
        task = next(iter(g.tasks))

        def sweep(workers=None, g=g, cl=cl):
            # fresh WhatIf per arm: its memo cache would otherwise make
            # every trial after the first free
            return WhatIf(g, cl).sweep_unit(task, units, workers=workers)

        t0 = time.perf_counter()
        serial = sweep()
        s_us = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        par = sweep(workers=4)
        p_us = (time.perf_counter() - t0) * 1e6
        rows.append(("scale.sweep_unit_mr128x128_us", p_us,
                     f"what-if unit sweep ({len(units)} trials, "
                     f"workers=4)"))
        rows.append(("scale.sweep_unit_mr128x128_serial_us", s_us,
                     "same sweep, serial"))
        rows.append(("scale.speedup_parallel_mr128x128", s_us / p_us,
                     "workers=4 sweep speedup over serial (gated >=2x "
                     "when parallel_cores >= 4)"))
        rows.append(("scale.sweep_unit_mr128x128.ref_match",
                     1.0 if par == serial else 0.0,
                     "parallel sweep bit-identical to serial"))

    # -- analytic passes at Graphene scale (compiled vs dict) ----------
    # with_slack + priorities + critical_path: the per-DAG overhead the
    # Principle-1 scheduler pays before any DES run.  ref_match is a
    # *bit-exactness* claim (==, not approx) on slacks, latest
    # completions, the critical path and the priority map.
    from repro.core import arrayanalytic
    for name in ("mr128x128", "layered20k"):
        if not want(f"analytic_{name}"):
            continue
        g, cl = big_graph(name)
        sched = MXDAGScheduler(try_pipelining=False)
        arrayanalytic.compile_analytic(g)     # warm: per-schedule passes

        def compiled_passes(g=g, sched=sched):
            at = arrayanalytic.analyze(g)
            sched._priorities_from(at.names, at.slack)
            arrayanalytic.critical_path(g)

        def dict_passes(g=g, sched=sched):
            sched._priorities(g, g.with_slack())
            g.critical_path()

        us = timeit_us(compiled_passes, repeat=3)
        dus = timeit_us(dict_passes, repeat=2)
        rows.append((f"scale.analytic_{name}_us", us,
                     f"compiled analytic passes, {len(g.tasks)} tasks"))
        rows.append((f"scale.analytic_{name}_dict_us", dus,
                     "dict analytic passes (with_slack/critical_path)"))
        rows.append((f"scale.speedup_analytic_{name}", dus / us,
                     "compiled analytic speedup over the dict passes"))
        at = arrayanalytic.analyze(g)
        d = g.with_slack()
        ok = all(d[nm].slack == at.slack[i]
                 and d[nm].latest_completion == at.latest[i]
                 for i, nm in enumerate(at.names))
        ok = ok and arrayanalytic.critical_path(g) == g.critical_path()
        ok = ok and (MXDAGScheduler(analytic="array")._priorities(g)
                     == MXDAGScheduler(analytic="dict")._priorities(g))
        rows.append((f"scale.analytic_{name}.ref_match",
                     1.0 if ok else 0.0,
                     "compiled analytics bit-equal to the dict passes"))

    # -- schedule at Graphene scale (end-to-end Principle-1 pipeline) --
    for name in ("mr128x128", "layered20k"):
        if not want(f"schedule_{name}"):
            continue
        g, cl = big_graph(name)
        us = timeit_us(
            lambda g=g, cl=cl: MXDAGScheduler(
                try_pipelining=False).schedule(g, cl), repeat=3)
        dus = timeit_us(
            lambda g=g, cl=cl: MXDAGScheduler(
                try_pipelining=False,
                analytic="dict").schedule(g, cl), repeat=2)
        rows.append((f"scale.schedule_{name}_us", us,
                     f"Principle-1 scheduling, {len(g.tasks)} tasks "
                     f"(compiled analytics)"))
        rows.append((f"scale.schedule_{name}_dict_us", dus,
                     "same pipeline on the dict analytic passes"))
        rows.append((f"scale.speedup_schedule_{name}", dus / us,
                     "schedule() speedup from the compiled analytics"))
        sa = MXDAGScheduler(try_pipelining=False).schedule(g, cl)
        sd = MXDAGScheduler(try_pipelining=False,
                            analytic="dict").schedule(g, cl)
        rows.append((f"scale.schedule_{name}.ref_match",
                     1.0 if (sa.policy == sd.policy
                             and sa.priorities == sd.priorities
                             and sa.meta["critical_path"]
                             == sd.meta["critical_path"]
                             and sa.meta["predicted_makespan"]
                             == sd.meta["predicted_makespan"])
                     else 0.0,
                     "compiled-analytic Schedule bit-identical to dict"))

    # -- schedule (no pipelining) --------------------------------------
    for name in ("mr8x8", "mr16x16", "ddl32", "ddl128", "ft8_shuffle"):
        if not want(f"schedule_{name}"):
            continue
        g, cl = work[name]
        us = timeit_us(
            lambda g=g, cl=cl: MXDAGScheduler(
                try_pipelining=False).schedule(g, cl),
            repeat=1 if len(g.tasks) > 300 else 3)
        new_us[f"schedule_{name}"] = us
        rows.append((f"scale.schedule_{name}_us", us,
                     "Principle-1 scheduling (memoized _best)"))

    # -- placement-enabled scheduling (fat_tree(8) sparse shuffle) -----
    from repro.core import PlacementScheduler, builders
    if want("schedule_ft8_shuffle_placed") or want("placement_ft8_shuffle"):
        fixed_g, fixed_cl = builders.fat_tree_shuffle(8, stride=2)
        fixed_ms = MXDAGScheduler(try_pipelining=False) \
            .schedule(fixed_g, fixed_cl).simulate(fixed_cl).makespan
        logical_g, logical_cl = builders.fat_tree_shuffle(8, stride=2,
                                                          placed=False)

        def _place():
            sched = MXDAGScheduler(
                try_pipelining=False,
                placement=PlacementScheduler(des_refine=False),
            ).schedule(logical_g, logical_cl)
            return sched.simulate(logical_cl).makespan

        us = timeit_us(_place, repeat=3)
        placed_ms = _place()
        rows.append(("scale.schedule_ft8_shuffle_placed_us", us,
                     f"placement-enabled scheduling, "
                     f"{len(logical_g.tasks)} tasks / 128 hosts"))
        rows.append(("scale.placement_ft8_shuffle.improves",
                     1.0 if placed_ms < fixed_ms - 1e-9 else 0.0,
                     f"placed makespan {placed_ms:g} < fixed {fixed_ms:g} "
                     f"(1.0 = validated)"))

    # -- schedule (greedy pipelining on) -------------------------------
    for name, g in piped.items():
        if not want(f"schedule_{name}_pipelined"):
            continue
        us = timeit_us(
            lambda g=g: MXDAGScheduler(try_pipelining=True).schedule(g),
            repeat=1)
        new_us[f"schedule_{name}_pipelined"] = us
        rows.append((f"scale.schedule_{name}_pipelined_us", us,
                     "greedy pipelining via the incremental worklist"))

    # -- seed-implementation rows (before/after evidence) --------------
    if seed_rows:
        with seed_implementation() as seed_simulate:
            for name in ("mr32x32", "ddl128"):
                if f"simulate_{name}" not in new_us:
                    continue
                g, cl = work[name]
                us = timeit_us(lambda g=g, cl=cl: seed_simulate(g, cl),
                               repeat=3)
                rows.append((f"scale.simulate_{name}_seed_us", us,
                             "seed implementation of the same DES"))
                rows.append((f"scale.speedup_simulate_{name}",
                             us / new_us[f"simulate_{name}"],
                             "flat-array speedup over the seed"))
            if "schedule_mr16x16_pipelined" in new_us:
                g = piped["mr16x16"]
                us = timeit_us(
                    lambda: MXDAGScheduler(
                        try_pipelining=True, memoize=False,
                        incremental_pipelining=False).schedule(g),
                    repeat=1)
                rows.append(("scale.schedule_mr16x16_pipelined_seed_us",
                             us,
                             "seed scheduler (full re-scan, no memo) on "
                             "the seed DES"))
                rows.append(("scale.speedup_schedule_mr16x16_pipelined",
                             us / new_us["schedule_mr16x16_pipelined"],
                             "scheduling speedup over the seed"))
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--no-seed", action="store_true",
                    help="skip the (slow) seed-implementation rows")
    ap.add_argument("--only", metavar="PREFIX", default=None,
                    help="run only rows whose name (minus 'scale.') "
                         "starts with PREFIX, e.g. simulate_mr128")
    ap.add_argument("--profile", action="store_true",
                    help="cProfile the sweep; print top 20 by cumtime")
    args = ap.parse_args()

    def run():
        return bench_rows(seed_rows=not args.no_seed, only=args.only)

    if args.profile:
        import cProfile
        import pstats

        pr = cProfile.Profile()
        pr.enable()
        rows = run()
        pr.disable()
        pstats.Stats(pr).sort_stats("cumtime").print_stats(20)
    else:
        rows = run()
    print("name,value,derived")
    for name, value, derived in rows:
        print(f"{name},{value:.6g},{str(derived).replace(',', ';')}")


if __name__ == "__main__":
    main()
