"""Model zoo: composable JAX definitions for the assigned architectures."""
from repro.models.model import BlockSpec, Model, Segment, derive_segments

__all__ = ["Model", "BlockSpec", "Segment", "derive_segments"]
