"""Fault injection and live replanning, narrated: a host dies mid-run
and the controller recovers on the compiled DES.

The scenario (``builders.oversubscribed_fanin(8, 8:1)``): eight rack-0
senders each push one flow across an 8:1-oversubscribed core to a
consumer on rack 1; flow ``f0`` feeds the 8-second critical compute
``c0`` on host ``d0``.  Fault-free makespan: 9.0.

At t=2.5 — while ``c0`` is running — host ``d0`` dies.  Three worlds:

- **no replan** — the fault lands and nothing reacts.  ``c0``'s slot
  pool is gone, its progress with it, and the run *stalls forever*
  (makespan ∞).  The kind-aware lineage rule also resurrects ``f0``:
  its delivered bytes lived on the dead host, so the finished flow
  must re-run — a compute→compute edge, by contrast, is control-only
  and would survive.
- **replan** — the ``ReplanController`` hears the heartbeat loss
  (host loss is an *announced* fault; stragglers and link degradation
  must be inferred from Monitor observations), moves ``c0`` to a
  believed-healthy host, repaths the resurrected ``f0`` to the new
  destination, and re-prioritises the remaining graph with a warm
  ``MXDAGScheduler`` run on the surviving cluster.
- **oracle** — knew before t=0 that ``d0`` was doomed and never placed
  ``c0`` there.  The replan/oracle gap is the price of *detecting* at
  runtime instead of knowing.

All of it runs on one live ``ResumableSim`` session: the harness
pauses the compiled array state at the fault time, mutates it
(``kill_host`` → slots zeroed, residents killed, lineage restarted),
and resumes — no recompile, and only the contention components the
fault touched re-waterfill.  The full scenario matrix (plus an
executor straggler and a degraded fat-tree core link) is
``benchmarks/nemesis.py``; CI pins ``replan_wins``/``detected``/
``ref_match`` at 1.0 via ``benchmarks/baseline.json``.

Run:  PYTHONPATH=src python examples/fault_recovery.py
"""
import sys
sys.path.insert(0, "src")

from repro.core import MXDAGScheduler
from repro.core.builders import oversubscribed_fanin
from repro.core.nemesis import Fault, Nemesis

g, cluster = oversubscribed_fanin(8, oversubscription=8.0)
sched = MXDAGScheduler(try_pipelining=False).schedule(g, cluster)
expected = sched.simulate(cluster)
print(f"{g.name}: fault-free makespan {expected.makespan:g} "
      f"(f0 -> 8s compute c0 on d0 is the critical path)\n")

faults = [Fault(2.5, "host_loss", "d0")]

print("arm 1: fault at t=2.5, nothing reacts")
no = Nemesis(sched, cluster, faults=faults, replan=False,
             expected=expected).run()
print(f"  makespan: {no.makespan:g}  (c0's slot pool is gone -> "
      f"the run stalls)\n")

print("arm 2: fault at t=2.5, controller replans")
yes = Nemesis(sched, cluster, faults=faults, replan=True,
              expected=expected).run()
print(f"  makespan: {yes.makespan:g}")
print(f"  detection rate: {yes.detection_rate:g}")
print("\n" + yes.tracker.report() + "\n")

# the oracle: a plan that never used d0 — move c0 before anything runs
from repro.core import WhatIf

oracle = WhatIf(g, cluster).move_task("c0", "d1").variant
print(f"oracle (knew d0 was doomed, planned around it): {oracle:g}")
print(f"price of runtime detection: replan {yes.makespan:g} / "
      f"oracle {oracle:g} = {yes.makespan / oracle:.2f}x")
