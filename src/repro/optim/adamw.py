"""AdamW with cosine schedule, global-norm clipping, and optional 8-bit
moment state (built from scratch — no optax in this environment).

8-bit state: each moment tensor is stored as int8 with one fp32 absmax
scale per trailing-axis row (block quantization).  For the ≥33B assigned
archs this is what makes optimizer state fit 16 GiB/chip HBM at the
assigned mesh (DESIGN.md §6); the quantization error is re-absorbed each
step because m/v are re-quantized from the freshly updated fp32 values.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

Params = dict


# ----------------------------------------------------------------------
# schedules
# ----------------------------------------------------------------------
def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)
    return lr


# ----------------------------------------------------------------------
# int8 block quantization for moments
# ----------------------------------------------------------------------
def _quant8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequant8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    state_8bit: bool = False


class AdamW:
    def __init__(self, cfg: AdamWConfig = AdamWConfig()):
        self.cfg = cfg

    # -- state ----------------------------------------------------------
    def init(self, params: Params) -> Params:
        def zeros_like_moment(p):
            if self.cfg.state_8bit:
                return {"q": jnp.zeros(p.shape, jnp.int8),
                        "s": jnp.zeros(p.shape[:-1] + (1,), jnp.float32)}
            return jnp.zeros(p.shape, jnp.float32)

        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(zeros_like_moment, params),
            "v": jax.tree.map(zeros_like_moment, params),
        }

    def _read(self, moment):
        if self.cfg.state_8bit:
            return _dequant8(moment["q"], moment["s"])
        return moment

    def _write(self, value):
        if self.cfg.state_8bit:
            q, s = _quant8(value)
            return {"q": q, "s": s}
        return value

    # -- update ----------------------------------------------------------
    def update(self, grads: Params, state: Params, params: Params
               ) -> tuple[Params, Params]:
        cfg = self.cfg
        step = state["step"] + 1
        lr = cfg.lr(step) if callable(cfg.lr) else cfg.lr

        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if cfg.clip_norm is not None:
            gnorm = jnp.sqrt(sum(
                jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-12))
            grads = jax.tree.map(lambda g: g * scale, grads)

        bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
        bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

        def upd(p, g, m_st, v_st):
            m = cfg.b1 * self._read(m_st) + (1 - cfg.b1) * g
            v = cfg.b2 * self._read(v_st) + (1 - cfg.b2) * jnp.square(g)
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
            decay = cfg.weight_decay if p.ndim >= 2 else 0.0
            newp = (p.astype(jnp.float32)
                    - lr * (delta + decay * p.astype(jnp.float32)))
            return newp.astype(p.dtype), self._write(m), self._write(v)

        out = jax.tree.map(upd, params, grads, state["m"], state["v"],
                           is_leaf=lambda x: isinstance(x, jax.Array))
        # unzip the 3-tuples
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"step": step, "m": new_m, "v": new_v}
