"""MXDAG-planned gradient sync: numerical equivalence + HLO structure.

- bucketed (custom-vjp synced scan) must produce the SAME gradients as
  barrier (plain scan + XLA-placed reduction);
- on a multi-device mesh the bucketed backward must contain per-layer
  reduce-scatter/all-reduce INSIDE a while body, while barrier reduces
  after the loop (checked in a subprocess with 8 host devices so the main
  test process keeps 1 device);
- plan_sync recovers ByteScheduler's lower-layer-first order and predicts
  a win exactly when the step is comm-bound (Fig. 6 / §4.1.1).
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import RunConfig, SHAPES
from repro.models import Model


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


class TestNumericalEquivalence:
    @pytest.mark.parametrize("arch", ["deepseek-7b", "olmoe-1b-7b",
                                      "mamba2-130m"])
    def test_bucketed_grads_match_barrier(self, arch, mesh):
        cfg = configs.get_smoke(arch)
        rng = jax.random.PRNGKey(0)
        batch = {"tokens": jax.random.randint(rng, (2, 16), 0,
                                              cfg.vocab_size)}

        grads = {}
        for mode in ("barrier", "bucketed"):
            m = Model(cfg, RunConfig(sync_mode=mode, remat=False),
                      mesh=mesh, dtype=jnp.float32)
            params = m.init(jax.random.PRNGKey(1))
            loss, g = jax.jit(jax.value_and_grad(
                lambda p: m.loss(p, batch)[0]))(params)
            grads[mode] = (float(loss), g)

        assert grads["barrier"][0] == pytest.approx(
            grads["bucketed"][0], rel=1e-5)
        for (pa, a), (pb, b) in zip(
                jax.tree_util.tree_flatten_with_path(grads["barrier"][1])[0],
                jax.tree_util.tree_flatten_with_path(grads["bucketed"][1])[0]):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4,
                err_msg=str(pa))


_HLO_PROBE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, re, sys
import jax, jax.numpy as jnp
from repro import configs
from repro.configs.base import RunConfig
from repro.models import Model
from repro.launch import sharding as shard_lib
from repro.launch.train import init_train_state, make_train_step, state_shardings
from repro.launch.specs import input_specs
from repro.optim import AdamW, AdamWConfig
from repro.launch.hlo_cost import parse_module, COLLECTIVES

cfg = configs.get_smoke("deepseek-7b")
import dataclasses
cfg = dataclasses.replace(cfg, n_layers=4, vocab_size=512)
mesh = jax.make_mesh((4, 2), ("data", "model"))
out = {}
for mode in ("barrier", "bucketed"):
    run = RunConfig(sync_mode=mode, remat=True, attn_impl="xla")
    model = Model(cfg, run, mesh=mesh, dp_axes=("data",))
    opt = AdamW(AdamWConfig())
    with mesh:
        ss = jax.eval_shape(lambda: init_train_state(
            model, opt, run, jax.random.PRNGKey(0)))
        batch = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
        comp = jax.jit(make_train_step(model, opt, run),
                       in_shardings=(state_shardings(ss, cfg, run, mesh),
                                     shard_lib.batch_shardings(batch, mesh)),
                       ).lower(ss, batch).compile()
    comps, entry = parse_module(comp.as_text())
    # collectives inside while bodies vs at top level
    body_names = set()
    for c in comps.values():
        for op in c.ops:
            if op.kind == "while":
                m = re.search(r"body=%([\w.\-]+)", op.attrs)
                if m:
                    body_names.add(m.group(1))
    inside = inside_bf16 = outside = 0
    for cname, c in comps.items():
        for op in c.ops:
            base = op.kind[:-6] if op.kind.endswith("-start") else op.kind
            if base in ("all-reduce", "reduce-scatter"):
                if cname in body_names:
                    inside += 1
                    if "bf16[" in op.out_type:
                        inside_bf16 += 1
                else:
                    outside += 1
    out[mode] = {"inside": inside, "inside_bf16": inside_bf16,
                 "outside": outside}
print(json.dumps(out))
"""


class TestHLOStructure:
    def test_bucketed_emits_collectives_inside_loop(self):
        env = dict(os.environ)
        env["PYTHONPATH"] = "src"
        res = subprocess.run([sys.executable, "-c", _HLO_PROBE],
                             capture_output=True, text=True, env=env,
                             cwd=os.path.dirname(os.path.dirname(
                                 os.path.abspath(__file__))))
        assert res.returncode == 0, res.stderr[-2000:]
        data = json.loads(res.stdout.strip().splitlines()[-1])
        # Both modes reduce per layer inside the loop: GSPMD places the
        # grad AR at its production point inside the reverse scan, i.e.
        # the Fig. 6 layer-wise structure is XLA's natural lowering for
        # scan-over-layers (a *refuted* hypothesis that barrier mode
        # would reduce once after the loop — recorded in EXPERIMENTS.md
        # §Perf).  The invariants that hold: in-loop reductions exist,
        # and the bucketed hook never adds collective traffic.
        assert data["bucketed"]["inside"] > 0, data
        assert data["bucketed"]["inside"] <= data["barrier"]["inside"] + 2, data


class TestPlan:
    def test_order_is_lower_layer_first(self):
        from repro.sync.plan import plan_sync
        cfg = configs.get("deepseek-7b")
        plan = plan_sync(cfg, SHAPES["train_4k"])
        idx = [int(name[4:]) for name in plan.order]
        assert idx == sorted(idx), plan.order

    def test_bucketed_predicted_when_comm_bound(self):
        from repro.sync.plan import plan_sync
        # deepseek-coder-33b dense on 256 chips: sync per layer is
        # comparable to compute -> overlap should win
        cfg = configs.get("deepseek-coder-33b")
        plan = plan_sync(cfg, SHAPES["train_4k"])
        assert plan.mode == "bucketed"
        assert plan.predicted_speedup > 1.0

    def test_plan_reports_both_predictions(self):
        from repro.sync.plan import plan_sync
        for arch in ("deepseek-7b", "olmoe-1b-7b"):
            plan = plan_sync(configs.get(arch), SHAPES["train_4k"])
            assert plan.predicted_bucketed > 0
            assert plan.predicted_barrier > 0
            assert plan.predicted_bucketed <= plan.predicted_barrier + 1e-9
