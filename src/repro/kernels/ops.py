"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to "not on TPU": in this CPU container the kernel
bodies execute in Python interpret mode for correctness validation; on a
real TPU the same call sites compile to Mosaic.  ``flash_attention`` is
differentiable: the forward runs the kernel, the backward recomputes via
the jnp oracle (standard recompute-flash; a fused bwd kernel is a listed
follow-up in DESIGN.md).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.moe_gmm import gmm as _gmm
from repro.kernels.ssd import ssd_intra_chunk as _ssd_intra


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


# ----------------------------------------------------------------------
# flash attention: [B,S,H,hd] layout (model-side convention)
# ----------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _flash(q_bhsd, k_bhsd, v_bhsd, causal, scale):
    return flash_attention_bhsd(q_bhsd, k_bhsd, v_bhsd, causal=causal,
                                scale=scale, interpret=_interpret_default())


def _flash_fwd(q, k, v, causal, scale):
    return _flash(q, k, v, causal, scale), (q, k, v)


def _flash_bwd(causal, scale, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: _ref.flash_attention_ref(
            q_, k_, v_, causal=causal, scale=scale), q, k, v)
    return vjp(g)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    scale: Optional[float] = None) -> jax.Array:
    """q: [B,S,H,hd]; k,v: [B,T,K,hd] → [B,S,H,hd]  (GQA-aware)."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    o = _flash(qt, kt, vt, causal, scale)
    return jnp.swapaxes(o, 1, 2)


# ----------------------------------------------------------------------
# SSD: full chunked layer built on the intra-chunk kernel
# ----------------------------------------------------------------------
def ssd_chunked_pallas(xh: jax.Array, dt: jax.Array, A: jax.Array,
                       Bm: jax.Array, Cm: jax.Array, chunk: int,
                       init_state: Optional[jax.Array] = None):
    """Same contract as models.ssm.ssd_chunked, intra-chunk via Pallas.

    xh: [B,L,H,P], dt: [B,L,H], A: [H], Bm/Cm: [B,L,G,N]."""
    Bsz, L, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert L % chunk == 0
    nc = L // chunk

    # flatten (batch, head) and (batch, group) for the kernel grid
    x_k = xh.reshape(Bsz, nc, chunk, H, P).transpose(0, 3, 1, 2, 4) \
        .reshape(Bsz * H, nc, chunk, P)
    dt_k = dt.reshape(Bsz, nc, chunk, H).transpose(0, 3, 1, 2) \
        .reshape(Bsz * H, nc, chunk)
    A_k = jnp.tile(A, Bsz)
    B_k = Bm.reshape(Bsz, nc, chunk, G, N).transpose(0, 3, 1, 2, 4) \
        .reshape(Bsz * G, nc, chunk, N)
    C_k = Cm.reshape(Bsz, nc, chunk, G, N).transpose(0, 3, 1, 2, 4) \
        .reshape(Bsz * G, nc, chunk, N)

    y_intra, states, cum = _ssd_intra(
        x_k, dt_k, A_k, B_k, C_k, interpret=_interpret_default())

    # inter-chunk recurrence + correction (linear, outside the kernel)
    states = states.reshape(Bsz, H, nc, N, P)
    cum_b = cum.reshape(Bsz, H, nc, chunk)
    chunk_decay = jnp.exp(cum_b[..., -1])                  # [B,H,nc]
    s0 = (jnp.zeros((Bsz, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(s, inp):
        dec, st = inp                                      # [B,H], [B,H,N,P]
        s_new = s * dec[..., None, None] + jnp.swapaxes(st, -1, -2)
        return s_new, s

    final, prev = jax.lax.scan(
        step, s0, (jnp.moveaxis(chunk_decay, 2, 0),
                   jnp.moveaxis(states, 2, 0)))
    prev = jnp.moveaxis(prev, 0, 2)                        # [B,H,nc,P,N]

    hpg = H // G
    Ch = jnp.repeat(
        Cm.reshape(Bsz, nc, chunk, G, N)[:, :, :, :, None, :], hpg, axis=4
    ).reshape(Bsz, nc, chunk, H, N)
    decay_from_start = jnp.exp(cum_b).transpose(0, 2, 3, 1)  # [B,nc,Q,H]
    y_inter = jnp.einsum(
        "bcqhn,bhcpn->bcqhp",
        Ch.astype(jnp.float32) * decay_from_start[..., None], prev)

    y_intra = y_intra.reshape(Bsz, H, nc, chunk, P) \
        .transpose(0, 2, 3, 1, 4)                          # [B,nc,Q,H,P]
    y = (y_intra + y_inter).reshape(Bsz, L, H, P)
    return y.astype(xh.dtype), final


# ----------------------------------------------------------------------
# grouped matmul
# ----------------------------------------------------------------------
def grouped_matmul(x: jax.Array, w: jax.Array, **kw) -> jax.Array:
    """x: [E,C,d]; w: [E,d,f] → [E,C,f]."""
    return _gmm(x, w, interpret=_interpret_default(), **kw)
