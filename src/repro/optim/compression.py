"""Gradient compression with error feedback (beyond-paper distributed trick).

Gradients are cast to fp8 (e4m3) before crossing the network; the
quantization residual stays in a local error-feedback accumulator and is
re-added next step, so the compression is unbiased over time (1-bit-Adam
style analysis).  On the wire this halves every gradient collective's
bytes vs bf16 — directly visible in the dry-run's collective-bytes term
(§Roofline), which is how we measure it without hardware.

The compress/decompress pair brackets the gradient sync:

    err, g8 = compress(g + err)        # local
    g8_synced = <reduce-scatter / all-reduce on fp8>
    g = decompress(g8_synced)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

Params = dict

F8 = jnp.float8_e4m3fn
F8_MAX = 448.0


def init_error_state(params: Params) -> Params:
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_leaf(g: jax.Array, err: jax.Array
                  ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (g8, scale, new_err).  Per-tensor absmax scaling into the
    fp8 dynamic range; residual goes to the error accumulator."""
    g32 = g.astype(jnp.float32) + err
    absmax = jnp.max(jnp.abs(g32))
    scale = jnp.maximum(absmax, 1e-12) / F8_MAX
    g8 = (g32 / scale).astype(F8)
    new_err = g32 - g8.astype(jnp.float32) * scale
    return g8, scale, new_err


def decompress_leaf(g8: jax.Array, scale: jax.Array) -> jax.Array:
    return g8.astype(jnp.float32) * scale


def compress_tree(grads: Params, err: Params):
    out = jax.tree.map(compress_leaf, grads, err)
    g8 = jax.tree.map(lambda t: t[0], out,
                      is_leaf=lambda x: isinstance(x, tuple))
    scale = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda t: t[2], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    return g8, scale, new_err


def decompress_tree(g8: Params, scale: Params) -> Params:
    return jax.tree.map(decompress_leaf, g8, scale)
