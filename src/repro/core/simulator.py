"""Discrete-event simulator for MXDAG execution on a cluster.

Models exactly the behaviours the paper reasons about:

- compute tasks occupy processor slots exclusively and non-preemptively
  (compute "can be easily isolated"),
- network flows share bandwidth on every link of their path — just the two
  endpoint NICs on a big-switch cluster, or the full ToR/spine route when
  the cluster carries a fabric Topology — under a pluggable allocation
  policy ("fair" max-min sharing — the network-aware-DAG baseline of
  Fig. 1(b) — or "priority" — the co-scheduler of Fig. 1(c)); flow rates
  are preemptible and recomputed at every event,
- pipelined edges stream units: the consumer may process its j-th unit only
  once every streaming predecessor has *delivered* input fraction
  ≥ (j+1)/n_units (unit-granular, as in Fig. 5),
- coflows (for the §2.2 baseline): synchronized start, MADD-style coupled
  rates (members' rates proportional to remaining work so they finish
  together), and all-or-nothing downstream gating.

The simulator advances by exact rate integration between events; events are
unit boundaries, task completions, and release times, so no behaviour change
can occur between events and the result is exact for piecewise-constant
rates.

Event-calendar invariants (the fast :meth:`Simulator.run` core)
---------------------------------------------------------------
Between two consecutive events every rate is constant, so the engine keeps
a heap of upcoming event times instead of rescanning all tasks:

- A task's rate can change **only** when the set of runnable, unstarved
  flows in some priority class changes (a start, a completion, or a
  starvation flip when work catches up with the pipelined input cap), or —
  for coflow members — when remaining sizes shift the MADD weights.  A
  unit-boundary event that changes none of those leaves every rate intact,
  so the waterfill is skipped entirely and the previous rates are reused.
- Within the "priority" policy, classes are waterfilled in ascending order
  on residual capacity; class c's allocation depends only on classes < c.
  When only class c's runnable set changed, classes below c *replay* their
  logged freeze sequence (bit-identical residual subtraction) and only
  classes ≥ c are waterfilled afresh.
- ``work_cap``/``delivered_fraction`` are maintained incrementally from a
  precomputed streaming-predecessor adjacency: a consumer's cap is
  recomputed only when a streaming producer crosses one of its own unit
  boundaries (its event) or completes.
- Start gating is monotone (done, delivered fraction, coflow completion
  and release only ever progress), so gating is re-evaluated only for
  tasks *triggered* by a completion, a first-unit delivery, a release, or
  a freed compute slot — never by a global rescan.

The retained :meth:`Simulator._reference_run` slow path is the seed
implementation; the golden differential tests assert the event-calendar
core reproduces its start/finish/makespan to within EPS on every scenario.

Engines
-------
:meth:`Simulator.run` dispatches on the ``engine`` argument:

- ``"array"`` (default) — the flat-array engine in
  :mod:`repro.core.arraysim`.  The (MXDAG, Cluster, coflows, routes)
  quadruple is compiled once into integer-interned arrays, cached on the
  graph keyed by (graph version, cluster identity, coflow grouping,
  route overrides) — so scheduler ``_best`` loops and what-if sweeps
  that vary only priorities/releases/policy compile once per graph
  version.  Compiled layout: insertion-order task ids with a
  lexicographic ``name_rank`` (reproducing every name-ordered tie-break
  on ints); per-task ``size``/``unit``/``n_units``/kind/job scalars;
  flow→link incidence as interned link-id tuples plus a CSR
  (``fl_ptr``/``fl_flat``) mirror for the vectorized waterfill;
  start-gating compiled to *counters* (unmet barrier / coflow /
  member-sync preconditions, with per-completion decrement lists —
  equivalent to the calendar's gate re-scan because gating is
  monotone); streaming-predecessor adjacency; coflow membership and
  slot-pool interning; *contention components* (union-find over the
  link incidence) so a completion re-waterfills only the flows
  sharing its component, with per-component coalesced next-completion
  heap entries for streaming-free unit-free flows (see the arraysim
  module docstring).  Run state is flat float64 work/rate vectors and
  int heap entries.  NumPy is optional and import-guarded: with it, the
  waterfill's bottleneck search and batch freezing run as array
  reductions over the incidence CSR; without it (the pure-stdlib core
  CI lane) the same compiled engine runs list-backed kernels with a
  scalar progressive fill, producing identical results.
- ``"calendar"`` — :meth:`Simulator.calendar_run`, the dict-based
  event-calendar core above (pure stdlib; the differential oracle for
  the array engine, and the "before" timing in the scale benchmarks).
- ``"reference"`` — :meth:`Simulator._reference_run`, the seed loop.

:meth:`Simulator.resumable` opens the array engine as a *session*
(:class:`~repro.core.arraysim.ResumableSim`): pause between events,
checkpoint/restore the flat run state, apply fault mutations (host
loss, link degradation, stragglers, task moves, flow re-paths), and
resume without recompiling — the substrate of the fault-injection and
live-replanning layer in :mod:`repro.core.nemesis`.  ``array_run``
itself is one uninterrupted session, so the fault-capable engine and
the plain one cannot drift.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Mapping, Optional, Sequence

from repro.core.cluster import Cluster
from repro.core.fabric import link_flow_index, nic_in, nic_out
from repro.core.graph import MXDAG
from repro.core.task import MXTask, TaskKind

EPS = 1e-9


def waterfill_prep(group, paths) -> tuple[list[str], dict[str, list[str]]]:
    """The (sorted group, link→flows index) pair :func:`waterfill` scans.

    Both are pure functions of ``(group, paths)`` and are never mutated by
    the fill, so a caller replaying the same flow group per event (every
    priority-class pass of :meth:`Simulator._allocate_rates`, most events
    of the calendar core) computes them once and passes ``prep=`` instead
    of re-sorting and re-inverting the paths on every call.
    """
    unfrozen = sorted(group)
    return unfrozen, link_flow_index(unfrozen, paths)


def waterfill(group: list[str], paths, weight, residual: dict[str, float],
              rates: dict[str, float],
              prep: Optional[tuple] = None) -> list[tuple[str, float]]:
    """Weighted max-min fair allocation of ``group`` over ``residual``.

    ``paths[n]`` is the tuple of links flow n occupies; ``weight(n)`` its
    share weight, or ``None`` for unit weights.  Progressive filling:
    repeatedly find the bottleneck link (minimum residual capacity per unit
    weight), freeze every flow crossing it at its weighted share, subtract
    along those flows' paths, recurse on the rest.  Mutates ``residual``
    and ``rates``; returns the freeze sequence ``[(flow, rate), ...]`` in
    allocation order so a caller can replay the identical subtraction.
    ``prep`` is an optional cached :func:`waterfill_prep` result for this
    exact ``(group, paths)`` pair.
    """
    if prep is None:
        prep = waterfill_prep(group, paths)
    unfrozen, by_link = prep
    unfrozen = list(unfrozen)
    seq: list[tuple[str, float]] = []
    if not unfrozen:
        return seq
    unfrozen_set = set(unfrozen)
    if weight is None:
        counts = {r: float(len(fl)) for r, fl in by_link.items()}
    while unfrozen:
        best_r, best_ratio = None, float("inf")
        for r in residual:
            fl = by_link.get(r)
            if not fl:
                continue
            if weight is None:
                w = counts[r]
            else:
                w = sum(weight(n) for n in fl if n in unfrozen_set)
            if w > EPS:
                ratio = residual[r] / w
                if ratio < best_ratio - EPS:
                    best_r, best_ratio = r, ratio
        if best_r is None:
            for n in unfrozen:
                rates[n] = 0.0
                seq.append((n, 0.0))
            return seq
        frozen_now = [n for n in by_link[best_r] if n in unfrozen_set]
        for n in frozen_now:
            alloc = best_ratio if weight is None else weight(n) * best_ratio
            rates[n] = alloc
            seq.append((n, alloc))
            for r in paths[n]:
                residual[r] = max(0.0, residual[r] - alloc)
                if weight is None:
                    counts[r] -= 1.0
        unfrozen_set.difference_update(frozen_now)
        unfrozen = [n for n in unfrozen if n in unfrozen_set]
    return seq


def max_min_rates(paths, capacity,
                  weights: Optional[dict[str, float]] = None,
                  ) -> dict[str, float]:
    """Weighted max-min fair rates for flows over shared links.

    ``paths``: flow → iterable of links; ``capacity``: link → bandwidth.
    A pure function of its inputs — the Simulator's per-event allocation
    reduces to it within each priority class, and the fabric property
    tests check its invariants directly on random topologies.
    """
    p = {n: tuple(ls) for n, ls in paths.items()}
    residual = {r: float(capacity[r]) for ls in p.values() for r in ls}
    w = weights or {}
    rates: dict[str, float] = {}
    weight = (lambda n: w.get(n, 1.0)) if w else None
    waterfill(sorted(p), p, weight, residual, rates)
    return rates


@dataclasses.dataclass
class SimResult:
    """Per-task start/finish times plus makespan and per-job JCTs."""

    start: dict[str, float]
    finish: dict[str, float]
    makespan: float
    job_completion: dict[str, float]

    def jct(self, job: str) -> float:
        """Job completion time of ``job``."""
        return self.job_completion[job]


class _State:
    __slots__ = ("task", "work", "started", "finished", "has_slot")

    def __init__(self, task: MXTask) -> None:
        self.task = task
        self.work = 0.0
        self.started: Optional[float] = None
        self.finished: Optional[float] = None
        self.has_slot = False

    @property
    def done(self) -> bool:
        """Whether the task has finished."""
        return self.finished is not None

    def delivered_fraction(self) -> float:
        """Fraction of output delivered downstream (unit granularity)."""
        t = self.task
        if self.done:
            return 1.0
        if t.size <= 0:
            return 1.0
        u = t.effective_unit
        return min(1.0, math.floor(self.work / u + EPS) * u / t.size)


class Simulator:
    """The DES: executes one MXDAG on a Cluster under a Schedule's
    decisions (see the module docstring for semantics and engines)."""

    def __init__(self, graph: MXDAG, cluster: Optional[Cluster] = None, *,
                 policy: str = "fair",
                 priorities: Optional[dict[str, float]] = None,
                 releases: Optional[dict[str, float]] = None,
                 coflows: Optional[list[set[str]]] = None,
                 routes: Optional[Mapping[str, Sequence[str]]] = None,
                 engine: str = "array",
                 ) -> None:
        if policy not in ("fair", "priority"):
            raise ValueError(f"unknown policy {policy}")
        if engine not in ("array", "calendar", "reference"):
            raise ValueError(f"unknown engine {engine}")
        self.engine = engine
        unbound = graph.unbound()
        if unbound:
            raise ValueError(
                f"cannot simulate {graph.name}: unbound tasks {unbound} "
                f"(apply a placement with MXDAG.bind, or schedule with "
                f"MXDAGScheduler on an explicit cluster)")
        self.g = graph
        if cluster is None:
            # the default cluster is a pure function of the graph; cache
            # it so scheduler loops don't rebuild it per simulation
            cached = graph.__dict__.get("_default_cluster")
            if cached is not None and cached[0] == graph._version:
                cluster = cached[1]
            else:
                cluster = Cluster.for_graph(graph)
                graph._default_cluster = (graph._version, cluster)
        self.cluster = cluster
        self.policy = policy
        self.prio = dict(priorities or {})
        self.releases = dict(releases or {})
        self.coflows = [set(c) for c in (coflows or [])]
        # per-flow route overrides (routing as a scheduling decision): an
        # overlay on a fresh dict, so the version-keyed base cache is
        # never poisoned by one run's route choices
        self.routes = {n: tuple(p) for n, p in (routes or {}).items()}
        if self.routes:
            topo = cluster.topology
            for n, p in self.routes.items():
                t = graph.tasks.get(n)
                if t is None:
                    raise KeyError(f"route override for unknown task {n}")
                if t.kind is not TaskKind.NETWORK:
                    raise ValueError(f"route override for {n}: only "
                                     f"network tasks are routed")
                # a route must connect the flow's actual endpoints — a
                # path between other hosts would silently uncharge the
                # real sender/receiver NICs
                first, last = nic_out(t.src), nic_in(t.dst)
                if len(p) < 2 or p[0] != first or p[-1] != last:
                    raise ValueError(
                        f"route override for {n} must start at {first} "
                        f"and end at {last}, got {p}")
                bad = [l for l in p[1:-1]
                       if topo is None or l not in topo.links]
                if bad:
                    raise KeyError(f"route override for {n} uses "
                                   f"unknown fabric links {bad}")
        self._coflow_of: dict[str, int] = {}
        for i, c in enumerate(self.coflows):
            for n in c:
                if n in self._coflow_of:
                    raise ValueError(f"{n} in two coflows")
                if self.g.tasks[n].kind is not TaskKind.NETWORK:
                    raise ValueError(f"coflow member {n} must be a flow")
                self._coflow_of[n] = i

    @property
    def _res(self) -> dict:
        """Resource paths, resolved lazily and cached: a compute task's
        processor pool, a flow's full link path (endpoint NICs only on
        big-switch clusters), with this run's route overrides overlaid.
        The base map is cached on the graph per (version, cluster); it
        is only materialized for the calendar/reference engines and for
        fabric/route compiles — the big-switch array compile interns
        links straight from the task endpoints and never builds the
        string map.
        """
        res = self.__dict__.get("_res_map")
        if res is None:
            graph, cluster = self.g, self.cluster
            cached = graph.__dict__.get("_res_cache")
            if cached is not None and cached[0] == graph._version \
                    and cached[1] is cluster:
                base_res = cached[2]
            else:
                base_res = {n: cluster.resources_for(t)
                            for n, t in graph.tasks.items()}
                graph._res_cache = (graph._version, cluster, base_res)
            res = {**base_res, **self.routes} if self.routes else base_res
            self.__dict__["_res_map"] = res
        return res

    def run(self, horizon: float = 1e15, *,
            batch: bool = True) -> SimResult:
        """Simulate to completion with the configured engine.

        ``batch=False`` makes the array engine process events strictly
        one at a time (the pre-mega-batch loop, kept as the batched
        loop's differential oracle); calendar/reference engines ignore
        it.  Results are bit-identical either way.
        """
        if self.engine == "calendar":
            return self.calendar_run(horizon)
        if self.engine == "reference":
            return self._reference_run(horizon)
        from repro.core.arraysim import array_run
        return array_run(self, horizon, batch=batch)

    def resumable(self, horizon: float = 1e15, *, batch: bool = True):
        """A pausable array-engine session over this simulation.

        Returns a :class:`~repro.core.arraysim.ResumableSim`: the same
        compiled flat-array run as ``engine="array"``, but exposing
        pause/mutate/resume, checkpoint/restore, and the fault-model
        mutators (kill_host, scale_link, set_speed, move_task,
        repath_flow, set_priorities) used by :mod:`repro.core.nemesis`.
        With no mutations applied it is bit-exact against :meth:`run`;
        ``batch=False`` selects the per-event oracle loop as in
        :meth:`run`.
        """
        from repro.core.arraysim import ResumableSim
        return ResumableSim(self, horizon, batch=batch)

    # ------------------------------------------------------------------
    # incremental event-calendar core (see module docstring invariants)
    # ------------------------------------------------------------------
    def _statics(self) -> dict:
        """Graph/coflow-derived constants of a run, cached on the graph.

        Everything here is a pure function of (graph version, coflows) —
        the scheduler simulates the same graph under several priority
        maps, and what-if sweeps re-simulate scheduled graphs, so the
        precompute is shared across runs instead of rebuilt per sim.
        """
        g = self.g
        tasks = g.tasks
        coflows = self.coflows
        coflow_of = self._coflow_of
        key = (g._version,
               tuple(tuple(sorted(c)) for c in coflows))
        cached = g.__dict__.get("_sim_statics")
        if cached is not None and cached[0] == key:
            return cached[1]

        # per-task scalars (size/effective_unit/n_units are properties;
        # the event loop reads them millions of times)
        size_of = {n: t.size for n, t in tasks.items()}
        unit_of = {n: t.effective_unit for n, t in tasks.items()}
        nu_of = {n: t.n_units for n, t in tasks.items()}
        is_compute = {n: t.kind is TaskKind.COMPUTE
                      for n, t in tasks.items()}

        # streaming adjacency for work_cap maintenance (coflow producers
        # gate at start instead, exactly as the reference's work_cap skip)
        stream_in: dict[str, list[str]] = {n: [] for n in tasks}
        stream_out: dict[str, list[str]] = {n: [] for n in tasks}
        # flows fed by any effectively-pipelined edge (coflow or not):
        # they contend in the top priority class (paper §4.1)
        stream_fed: set[str] = set()
        for (p, n), e in g.edges.items():
            if g.effective_pipelined(e):
                stream_fed.add(n)
                if coflow_of.get(p) is None:
                    stream_in[n].append(p)
                    stream_out[p].append(n)

        # start-gating lists, compiled once: barrier preds (must be done),
        # streaming preds (first-unit fraction), coflow preds (coflow must
        # be done), plus the member-sync preds of the task's own coflow
        _empty: tuple = ()
        gate_barrier: dict[str, tuple] = {}
        gate_stream: dict[str, tuple] = {}
        gate_cof: dict[str, tuple] = {}
        gate_sync: dict[str, tuple] = {}
        for n in tasks:
            barrier, stream, cofs = [], [], []
            for p in g.preds(n):
                ci = coflow_of.get(p)
                if ci is not None:
                    cofs.append(ci)
                elif g.effective_pipelined(g.edges[(p, n)]):
                    stream.append(p)
                else:
                    barrier.append(p)
            gate_barrier[n] = tuple(barrier) if barrier else _empty
            gate_stream[n] = tuple(stream) if stream else _empty
            gate_cof[n] = tuple(cofs) if cofs else _empty
            ci = coflow_of.get(n)
            gate_sync[n] = (tuple(p for m in coflows[ci]
                                  for p in g.preds(m))
                            if ci is not None else _empty)

        net_order = [n for n, t in tasks.items()
                     if t.kind is TaskKind.NETWORK]
        net_idx = {n: i for i, n in enumerate(net_order)}

        # tasks whose coflow-sync start gate cares about a completion of n
        coflow_fed_by: dict[str, list[int]] = {}
        for i, c in enumerate(coflows):
            for m in c:
                for p in g.preds(m):
                    coflow_fed_by.setdefault(p, []).append(i)

        data = dict(size_of=size_of, unit_of=unit_of, nu_of=nu_of,
                    is_compute=is_compute, stream_in=stream_in,
                    stream_out=stream_out, stream_fed=stream_fed,
                    has_streaming=any(stream_out.values()),
                    gate_barrier=gate_barrier, gate_stream=gate_stream,
                    gate_cof=gate_cof, gate_sync=gate_sync,
                    net_order=net_order, net_idx=net_idx,
                    coflow_fed_by=coflow_fed_by)
        g._sim_statics = (key, data)
        return data

    def calendar_run(self, horizon: float = 1e15) -> SimResult:
        """The incremental event-calendar engine (dict-keyed oracle)."""
        g = self.g
        tasks = g.tasks
        st = {n: _State(t) for n, t in tasks.items()}
        now = 0.0
        slots_free = {f"{h}.{p}": k
                      for h, host in self.cluster.hosts.items()
                      for p, k in host.procs.items()}
        coflow_of = self._coflow_of
        coflows = self.coflows
        inf = float("inf")
        prio_get = self.prio.get

        sd = self._statics()
        size_of = sd["size_of"]
        unit_of = sd["unit_of"]
        nu_of = sd["nu_of"]
        is_compute = sd["is_compute"]
        stream_in = sd["stream_in"]
        stream_out = sd["stream_out"]
        has_streaming = sd["has_streaming"]
        gate_barrier = sd["gate_barrier"]
        gate_stream = sd["gate_stream"]
        gate_cof = sd["gate_cof"]
        gate_sync = sd["gate_sync"]
        net_order = sd["net_order"]
        net_idx = sd["net_idx"]
        coflow_fed_by = sd["coflow_fed_by"]
        stream_fed = sd["stream_fed"]

        # flow priority classes are static for a run: the streaming flag
        # and the priority map never change mid-simulation
        cls_of = ({n: None for n in net_order} if self.policy == "fair"
                  else {n: 0.0 if n in stream_fed else prio_get(n, 0.0)
                        for n in net_order})
        # dispatch order of the start pass (static: priority, then name)
        sort_key = {n: (prio_get(n, 0.0), n) for n in tasks}

        bw = self.cluster.bandwidths(
            r for n in net_order for r in self._res[n])

        # -- dynamic state ---------------------------------------------
        cap: dict[str, float] = {}       # work_cap, tasks with stream_in
        d_units: dict[str, int] = {}     # delivered units, stream_out keys
        starved = {n: False for n in tasks}
        rates = {n: 0.0 for n in tasks}
        active: set[str] = set()         # started, unfinished, rate > EPS
        runnable_net: set[str] = set()   # started, unfinished flows
        waiting_slot: dict[str, set[str]] = {}
        dirty_classes: set = set()
        alloc_log: dict = {}             # class -> freeze sequence
        heap: list[tuple[float, int, str, int]] = []
        stamp = {n: 0 for n in tasks}
        unfinished = len(tasks)
        heappush = heapq.heappush
        heappop = heapq.heappop
        succs_of = g._succ

        def coflow_done(i: int) -> bool:
            """All-or-nothing: has every member of coflow ``i`` finished?"""
            return all(st[m].finished is not None for m in coflows[i])

        def delivered_fraction(p: str) -> float:
            """Fraction of ``p``'s output delivered (unit granularity)."""
            ps = st[p]
            if ps.finished is not None:
                return 1.0
            size = size_of[p]
            if size <= 0:
                return 1.0
            u = unit_of[p]
            return min(1.0, math.floor(ps.work / u + EPS) * u / size)

        def pred_satisfied_for_start(n: str) -> bool:
            """Can task n begin its first unit now?  (Seed semantics.)"""
            for p in gate_barrier[n]:
                if st[p].finished is None:
                    return False
            for ci in gate_cof[n]:
                if not coflow_done(ci):            # all-or-nothing gating
                    return False
            for p in gate_stream[n]:
                if delivered_fraction(p) + EPS < 1.0 / nu_of[n]:
                    return False
            # coflow synchronized start: every member's preds must be done
            for p in gate_sync[n]:
                if st[p].finished is None:
                    return False
            return True

        def recompute_cap(n: str) -> float:
            """Work cap from streaming predecessors' delivered units."""
            c = size_of[n]
            nu = nu_of[n]
            eu = unit_of[n]
            for p in stream_in[n]:
                if st[p].finished is None:
                    enabled = math.floor(delivered_fraction(p) * nu + EPS)
                    c = min(c, enabled * eu)
            return c

        def cap_of(n: str) -> float:
            """Current work cap of ``n`` (size when unconstrained)."""
            return cap.get(n, size_of[n])

        def dirty(n: str) -> None:
            """Mark ``n``'s priority class for re-waterfill."""
            dirty_classes.add(cls_of[n])

        def schedule_event(n: str) -> None:
            """(Re)compute task n's next unit-boundary/cap/completion."""
            ver = stamp[n] + 1
            stamp[n] = ver
            s = st[n]
            r = rates[n]
            if s.finished is not None or s.started is None or r <= EPS:
                active.discard(n)
                return
            active.add(n)
            size = size_of[n]
            w = s.work
            u = unit_of[n]
            if u < size:
                tgt = (math.floor(w / u + EPS) + 1) * u
                if tgt > size:
                    tgt = size
            else:
                tgt = size
            best = inf
            if tgt > w + EPS:
                best = (tgt - w) / r
            if size > w + EPS:
                d = (size - w) / r
                if d < best:
                    best = d
            c = cap.get(n)
            if c is not None and c > w + EPS:
                d = (c - w) / r
                if d < best:
                    best = d
            if best < inf:
                heappush(heap, (now + best, 1, n, ver))

        def weight_for(group_has_coflow: bool):
            """MADD weight function for a class, or None when uniform."""
            if not group_has_coflow:
                return None

            def weight(n: str) -> float:
                """Member weight ∝ remaining work (MADD coupling)."""
                ci = coflow_of.get(n)
                if ci is None:
                    return 1.0
                rem = {m: size_of[m] - st[m].work
                       for m in coflows[ci] if st[m].finished is None}
                mx = max(rem.values(), default=1.0)
                return max(rem.get(n, 0.0) / mx, 1e-6) if mx > 0 else 1.0
            return weight

        wf_prep: dict = {}           # (cls, group) -> waterfill_prep

        def allocate() -> set[str]:
            """Waterfill classes from the lowest dirty one up; replay the
            untouched classes below it (their runnable sets are unchanged,
            so their rates — and the residual they leave behind — are the
            ones already logged).  Returns the freshly waterfilled flows."""
            # task-insertion order, as the seed's full scan produced it
            flows = sorted((n for n in runnable_net if not starved[n]),
                           key=net_idx.__getitem__)
            changed: set[str] = set()
            residual: dict[str, float] = {}
            for n in flows:
                for r in self._res[n]:
                    if r not in residual:
                        residual[r] = bw[r]
            if self.policy == "fair":
                classes: list = [None]
                lowest = None            # single class: always waterfill
            else:
                classes = sorted({cls_of[n] for n in flows})
                lowest = min(dirty_classes) if dirty_classes else None
            new_log: dict = {}
            for cls in classes:
                if lowest is None or cls >= lowest or cls not in alloc_log:
                    group = [n for n in flows if cls_of[n] == cls]
                    old = [rates[n] for n in group]
                    # an unchanged class group re-fills with the identical
                    # sorted order and link index: cache the prep per
                    # (class, group) instead of rebuilding it every event
                    pkey = (cls, tuple(group))
                    prep = wf_prep.get(pkey)
                    if prep is None:
                        if len(wf_prep) > 512:
                            wf_prep.clear()
                        prep = wf_prep[pkey] = waterfill_prep(
                            group, self._res)
                    seq = waterfill(
                        group, self._res,
                        weight_for(any(n in coflow_of for n in group)),
                        residual, rates, prep=prep)
                    # an unchanged rate means unchanged absolute event
                    # times — the existing heap entry stays valid
                    changed.update(n for n, o in zip(group, old)
                                   if rates[n] != o)
                    new_log[cls] = seq
                else:
                    # unchanged class: replay the logged freeze sequence —
                    # identical subtraction order, bit-identical residual
                    for n, alloc in alloc_log[cls]:
                        rates[n] = alloc
                        for r in self._res[n]:
                            residual[r] = max(0.0, residual[r] - alloc)
                    new_log[cls] = alloc_log[cls]
            alloc_log.clear()
            alloc_log.update(new_log)
            dirty_classes.clear()
            return changed

        candidates: set[str] = set()
        freed: set[str] = set()
        touched: set[str] = set()        # need schedule_event refresh

        def complete(n: str) -> None:
            """Finish ``n``: free its slot, trigger gated candidates."""
            nonlocal unfinished
            s = st[n]
            s.finished = now
            unfinished -= 1
            active.discard(n)
            if s.has_slot:
                r = tasks[n].resources()[0]
                slots_free[r] += 1
                s.has_slot = False
                freed.add(r)
            if is_compute[n]:
                rates[n] = 0.0
            else:
                runnable_net.discard(n)
                if rates[n]:
                    rates[n] = 0.0
                    dirty_classes.add(cls_of[n])
            candidates.update(succs_of[n])
            for c in stream_out[n]:
                cs = st[c]
                if cs.started is not None and cs.finished is None:
                    nc = recompute_cap(c)
                    if nc != cap.get(c):
                        cap[c] = nc
                        touched.add(c)
            if coflows:
                ci = coflow_of.get(n)
                if ci is not None and coflow_done(ci):
                    for m in coflows[ci]:
                        candidates.update(succs_of[m])
                for ci2 in coflow_fed_by.get(n, ()):
                    candidates.update(coflows[ci2])

        def on_start(n: str) -> None:
            """Initialize ``n``'s streaming caps/counters at start."""
            s = st[n]
            c = size_of[n]
            if stream_in[n]:
                c = cap[n] = recompute_cap(n)
            if stream_out[n]:
                d_units[n] = 0
                for c2 in stream_out[n]:
                    candidates.add(c2)   # first-unit gate may already pass
            is_starved = c <= s.work + EPS
            starved[n] = is_starved
            if is_compute[n]:
                rates[n] = 0.0 if is_starved else 1.0
            else:
                runnable_net.add(n)
                dirty_classes.add(cls_of[n])
            touched.add(n)

        def process_starts() -> None:
            """Start every gated candidate; cascade zero-size completions
            (the seed's same-timestamp `continue` loop)."""
            while True:
                startable = [n for n in candidates
                             if st[n].started is None
                             and self.releases.get(n, 0.0) <= now + EPS
                             and pred_satisfied_for_start(n)]
                candidates.clear()
                if not startable:
                    return
                zero_done = False
                for n in sorted(startable, key=sort_key.__getitem__):
                    s = st[n]
                    if is_compute[n]:
                        r = tasks[n].resources()[0]
                        if slots_free.get(r, 0) >= 1:
                            slots_free[r] -= 1
                            s.has_slot = True
                            s.started = now
                            waiting_slot.get(r, set()).discard(n)
                        else:
                            waiting_slot.setdefault(r, set()).add(n)
                            continue
                    else:
                        s.started = now
                    on_start(n)
                    if size_of[n] <= EPS:
                        complete(n)
                        zero_done = True
                # newly freed slots may admit earlier waiters immediately
                for r in freed:
                    candidates.update(waiting_slot.get(r, ()))
                freed.clear()
                if not zero_done and not candidates:
                    return

        # -- initialisation --------------------------------------------
        for n, rel in self.releases.items():
            if rel > EPS:
                heapq.heappush(heap, (rel, 0, n, 0))
        candidates.update(st)
        process_starts()
        if dirty_classes:
            touched.update(allocate())
        for n in touched:
            schedule_event(n)
        touched.clear()

        # -- main loop -------------------------------------------------
        guard = 0
        max_iters = 10000 * (len(tasks) + 1) + sum(nu_of.values())
        while unfinished:
            guard += 1
            if guard > max_iters:
                raise RuntimeError("simulator did not converge (livelock?)")

            # next event time (skip stale heap entries lazily)
            t_next = None
            while heap:
                tm, kind, n, stp = heap[0]
                if kind == 1 and (stamp[n] != stp
                                  or st[n].finished is not None):
                    heappop(heap)
                    continue
                if kind == 0 and st[n].started is not None:
                    heappop(heap)
                    continue
                t_next = tm
                break
            if t_next is None:
                pend = [n for n, s in st.items() if not s.done]
                raise RuntimeError(f"deadlock at t={now:.6g}: {pend}")
            if t_next > horizon:
                t_next = horizon     # seed semantics: never pass horizon;
                #                      no progress past it trips the guard
            dt = t_next - now
            if dt > 0.0:
                for n in active:
                    s = st[n]
                    w = s.work + rates[n] * dt
                    size = size_of[n]
                    s.work = size if w > size else w
            now = t_next

            batch: list[str] = []
            while heap and heap[0][0] <= t_next:
                tm, kind, n, stp = heappop(heap)
                if kind == 1 and stamp[n] == stp \
                        and st[n].finished is None:
                    batch.append(n)
                elif kind == 0 and st[n].started is None:
                    candidates.add(n)

            # completions (scan active: a task reaching its cap or size is
            # still rate>0 until this very event)
            finished_now = [n for n in active
                            if st[n].work >= size_of[n] - EPS]
            for n in finished_now:
                complete(n)

            # unit-boundary crossings feed streaming consumers
            if has_streaming:
                for n in batch:
                    if not stream_out[n] or st[n].finished is not None:
                        continue
                    du = math.floor(st[n].work / unit_of[n] + EPS)
                    if du != d_units[n]:
                        d_units[n] = du
                        for c in stream_out[n]:
                            cs = st[c]
                            if cs.started is None:
                                candidates.add(c)
                            elif cs.finished is None:
                                nc = recompute_cap(c)
                                if nc != cap.get(c):
                                    cap[c] = nc
                                    touched.add(c)

            for r in freed:
                candidates.update(waiting_slot.get(r, ()))
            freed.clear()
            if candidates:
                process_starts()

            # starvation flips (cap moved, or work caught up with cap)
            for n in touched.union(x for x in batch
                                   if st[x].finished is None):
                s = st[n]
                if s.started is None or s.finished is not None:
                    continue
                is_starved = cap_of(n) <= s.work + EPS
                if is_starved != starved[n]:
                    starved[n] = is_starved
                    if is_compute[n]:
                        rates[n] = 0.0 if is_starved else 1.0
                    else:
                        if is_starved:
                            rates[n] = 0.0   # excluded from the waterfill
                        dirty(n)
                touched.add(n)

            # MADD weights drift with remaining work: any class holding a
            # running coflow member reallocates every event
            if coflows:
                for i, c in enumerate(coflows):
                    if any(st[m].started is not None
                           and st[m].finished is None for m in c):
                        for m in c:
                            dirty_classes.add(cls_of[m])

            if dirty_classes:
                touched.update(allocate())

            for n in touched:
                schedule_event(n)
            for n in batch:
                if n not in touched:
                    schedule_event(n)
            touched.clear()

        start = {n: s.started for n, s in st.items()}         # type: ignore
        finish = {n: s.finished for n, s in st.items()}       # type: ignore
        jobs: dict[str, float] = {}
        for n, s in st.items():
            j = tasks[n].job
            jobs[j] = max(jobs.get(j, 0.0), s.finished)       # type: ignore
        return SimResult(start=start, finish=finish,
                         makespan=max(finish.values(), default=0.0),
                         job_completion=jobs)

    # ------------------------------------------------------------------
    # golden slow path: the seed implementation, kept as the differential-
    # test oracle for the event-calendar core.  Verbatim except for two
    # crash fixes the fuzzer surfaced (the results on every non-crashing
    # input are untouched): (1) the zero-size start cascade re-looped on
    # *any historical* zero-size completion, livelocking whenever one
    # coexisted with a startable compute task blocked on a busy slot;
    # (2) a DAG whose final tasks complete inside that cascade fell
    # through to the deadlock check with nothing pending.
    # ------------------------------------------------------------------
    def _reference_run(self, horizon: float = 1e15) -> SimResult:
        g = self.g
        st = {n: _State(t) for n, t in g.tasks.items()}
        now = 0.0
        slots_free = {f"{h}.{p}": k
                      for h, host in self.cluster.hosts.items()
                      for p, k in host.procs.items()}

        def coflow_done(i: int) -> bool:
            """All-or-nothing: has every member of coflow ``i`` finished?"""
            return all(st[m].done for m in self.coflows[i])

        def pred_satisfied_for_start(n: str) -> bool:
            """Can task n begin its first unit now?"""
            for p in g.preds(n):
                e = g.edges[(p, n)]
                ps = st[p]
                ci = self._coflow_of.get(p)
                if ci is not None:
                    if not coflow_done(ci):        # all-or-nothing gating
                        return False
                    continue
                if g.effective_pipelined(e):
                    nu = g.tasks[n].n_units
                    if ps.delivered_fraction() + EPS < 1.0 / nu:
                        return False
                elif not ps.done:
                    return False
            # coflow synchronized start: every member's preds must be done
            ci = self._coflow_of.get(n)
            if ci is not None:
                for m in self.coflows[ci]:
                    for p in g.preds(m):
                        if not st[p].done:
                            return False
            return True

        def work_cap(n: str) -> float:
            """Max work task n may perform given currently delivered inputs.

            Quantized to the *consumer's* unit granularity: unit j may be
            processed only once its full input (fraction (j+1)/n_units) has
            been delivered by every streaming predecessor (Fig. 5).
            """
            t = g.tasks[n]
            cap = t.size
            nu = t.n_units
            for p in g.preds(n):
                e = g.edges[(p, n)]
                if self._coflow_of.get(p) is not None:
                    continue  # gated at start; coflow edges are barriers
                if g.effective_pipelined(e) and not st[p].done:
                    frac = st[p].delivered_fraction()
                    enabled = math.floor(frac * nu + EPS)
                    cap = min(cap, enabled * t.effective_unit)
            return cap

        def release(n: str) -> float:
            """Earliest allowed start of ``n`` (0.0 when unconstrained)."""
            return self.releases.get(n, 0.0)

        # main loop ----------------------------------------------------
        guard = 0
        max_iters = 10000 * (len(g.tasks) + 1) + sum(
            t.n_units for t in g.tasks.values())
        while any(not s.done for s in st.values()):
            guard += 1
            if guard > max_iters:
                raise RuntimeError("simulator did not converge (livelock?)")

            # 1) start tasks whose gating allows it
            startable = [n for n, s in st.items()
                         if s.started is None and release(n) <= now + EPS
                         and pred_satisfied_for_start(n)]
            # compute tasks need a free slot; dispatch by (priority, name)
            zero_completed = False
            for n in sorted(startable,
                            key=lambda n: (self.prio.get(n, 0.0), n)):
                t = g.tasks[n]
                if t.kind is TaskKind.COMPUTE:
                    r = t.resources()[0]
                    if slots_free.get(r, 0) >= 1:
                        slots_free[r] -= 1
                        st[n].has_slot = True
                        st[n].started = now
                else:
                    st[n].started = now
                if t.size <= EPS and st[n].started is not None:
                    st[n].finished = now
                    zero_completed = True
                    if st[n].has_slot:
                        slots_free[t.resources()[0]] += 1
                        st[n].has_slot = False

            # zero-size completions may unlock more starts immediately.
            # Only a completion from *this* pass warrants the re-loop —
            # the seed keyed this on any historical zero-size completion,
            # which livelocked whenever one existed alongside a startable
            # compute task blocked on a busy slot (nothing changes between
            # passes, so the same-timestamp loop never exits).
            if zero_completed:
                # cheap: loop again to re-evaluate gating at same timestamp
                if any(st[n].started is None and release(n) <= now + EPS
                       and pred_satisfied_for_start(n)
                       for n in st):
                    continue

            # 2) rates
            rates = self._allocate_rates(st, work_cap)

            # 3) dt to next boundary
            dt = horizon - now
            progressing = False
            for n, s in st.items():
                if s.done or s.started is None:
                    continue
                r = rates.get(n, 0.0)
                if r <= EPS:
                    continue
                progressing = True
                t = g.tasks[n]
                u = t.effective_unit
                # next unit boundary strictly above current work
                k = math.floor(s.work / u + EPS) + 1
                targets = [min(k * u, t.size), t.size, work_cap(n)]
                for tgt in targets:
                    if tgt > s.work + EPS:
                        dt = min(dt, (tgt - s.work) / r)
            future_rel = [rel for n, rel in self.releases.items()
                          if st[n].started is None and rel > now + EPS]
            if future_rel:
                dt = min(dt, min(future_rel) - now)
            if not progressing:
                if future_rel:
                    now = min(future_rel)
                    continue
                # could be waiting on a compute slot that frees only at a
                # completion — but nothing progresses ⇒ deadlock
                pend = [n for n, s in st.items() if not s.done]
                if not pend:
                    break   # a zero-size start cascade finished the DAG
                    # mid-iteration (seed bug fix: it raised "deadlock"
                    # with nothing pending)
                raise RuntimeError(f"deadlock at t={now:.6g}: {pend}")
            dt = max(dt, 0.0)

            # 4) integrate
            now += dt
            for n, s in st.items():
                if s.done or s.started is None:
                    continue
                r = rates.get(n, 0.0)
                if r > EPS:
                    s.work = min(g.tasks[n].size, s.work + r * dt)

            # 5) completions
            for n, s in st.items():
                t = g.tasks[n]
                if not s.done and s.started is not None \
                        and s.work >= t.size - EPS:
                    s.finished = now
                    if s.has_slot:
                        slots_free[t.resources()[0]] += 1
                        s.has_slot = False

        start = {n: s.started for n, s in st.items()}         # type: ignore
        finish = {n: s.finished for n, s in st.items()}       # type: ignore
        jobs: dict[str, float] = {}
        for n, s in st.items():
            j = g.tasks[n].job
            jobs[j] = max(jobs.get(j, 0.0), s.finished)       # type: ignore
        return SimResult(start=start, finish=finish,
                         makespan=max(finish.values(), default=0.0),
                         job_completion=jobs)

    # ------------------------------------------------------------------
    def _allocate_rates(self, st: dict[str, _State],
                        work_cap) -> dict[str, float]:
        """Instantaneous rates for all runnable tasks (reference path).

        Compute tasks: rate 1 while holding a slot and not input-starved.
        Flows: weighted max-min fair within a priority class over every
        link on their path, classes served in strict priority order on
        residual link capacity.  Coflow members get weights ∝ remaining
        work (MADD: finish together).

        Paper semantic (§4.1): a *pipelined* task "enforces the resources to
        be occupied right after the precedent task begins processing, which
        may contend with the tasks on the critical path" — so a flow fed by
        a streaming edge contends in the top priority class once started.
        This is precisely why Principle 1 applies pipelining only when it
        shrinks the makespan (Fig. 3 case 3).
        """
        g = self.g
        rates: dict[str, float] = {}
        flows: list[str] = []
        for n, s in st.items():
            if s.done or s.started is None:
                continue
            if work_cap(n) <= s.work + EPS:
                rates[n] = 0.0           # starved on pipelined input
                continue
            t = g.tasks[n]
            if t.kind is TaskKind.COMPUTE:
                rates[n] = 1.0 if s.has_slot else 0.0
            else:
                flows.append(n)

        if not flows:
            return rates

        residual = {}
        for n in flows:
            for r in self._res[n]:
                residual.setdefault(r, self.cluster.bandwidth(r))

        def weight(n: str) -> float:
            """MADD weight: member rate ∝ remaining work."""
            ci = self._coflow_of.get(n)
            if ci is None:
                return 1.0
            rem = {m: g.tasks[m].size - st[m].work for m in self.coflows[ci]
                   if not st[m].done}
            mx = max(rem.values(), default=1.0)
            return max(rem.get(n, 0.0) / mx, 1e-6) if mx > 0 else 1.0

        def flow_class(n: str) -> float:
            """Priority class of flow ``n`` under the current policy."""
            # streaming flows occupy bandwidth eagerly (paper §4.1)
            if any(g.effective_pipelined(g.edges[(p, n)])
                   for p in g.preds(n)):
                return 0.0
            return self.prio.get(n, 0.0)

        has_coflow = bool(self._coflow_of)
        if self.policy == "priority":
            classes = sorted({flow_class(n) for n in flows})
        else:
            classes = [None]

        # hoisted waterfill prep: the reference loop reallocates every
        # event, but a class whose runnable group did not change replays
        # the same (sorted group, link index) — cache it per (cls, group)
        # instead of re-sorting and re-inverting paths per event
        prep_cache = self.__dict__.setdefault("_wf_prep_cache", {})
        for cls in classes:
            group = [n for n in flows
                     if cls is None or flow_class(n) == cls]
            pkey = (cls, tuple(group))
            prep = prep_cache.get(pkey)
            if prep is None:
                if len(prep_cache) > 512:
                    prep_cache.clear()
                prep = prep_cache[pkey] = waterfill_prep(group, self._res)
            waterfill(group, self._res, weight if has_coflow else None,
                      residual, rates, prep=prep)
        return rates


def simulate(graph: MXDAG, cluster: Optional[Cluster] = None, *,
             policy: str = "fair",
             priorities: Optional[dict[str, float]] = None,
             releases: Optional[dict[str, float]] = None,
             coflows: Optional[list[set[str]]] = None,
             routes: Optional[Mapping[str, Sequence[str]]] = None,
             engine: str = "array",
             ) -> SimResult:
    """One-shot convenience wrapper: build a Simulator and run it."""
    return Simulator(graph, cluster, policy=policy, priorities=priorities,
                     releases=releases, coflows=coflows, routes=routes,
                     engine=engine).run()
