"""Substrate tests: checkpointing (atomic/elastic), data determinism,
optimizer (incl. 8-bit state), fp8 error-feedback compression, and the
fault-tolerant runtime loop."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import all_steps, latest_step, restore, save, \
    save_async
from repro.data import DataConfig, SyntheticLM
from repro.optim import AdamW, AdamWConfig, compression, cosine_schedule

pytestmark = [pytest.mark.slow, pytest.mark.jax]


class TestCheckpoint:
    def tree(self):
        return {"w": jnp.full((4, 8), 1.5, jnp.bfloat16),
                "b": jnp.arange(3, dtype=jnp.float32),
                "opt": {"q": jnp.ones((2, 2), jnp.int8),
                        "step": jnp.int32(7)}}

    def test_roundtrip_preserves_dtypes_and_values(self, tmp_path):
        t = self.tree()
        save(str(tmp_path), 5, t)
        out = restore(str(tmp_path), 5, t)
        for (pa, a), (pb, b) in zip(
                jax.tree_util.tree_flatten_with_path(t)[0],
                jax.tree_util.tree_flatten_with_path(out)[0]):
            assert a.dtype == b.dtype, pa
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))

    def test_atomic_no_tmp_left_and_prune(self, tmp_path):
        t = self.tree()
        for s in (1, 2, 3, 4, 5):
            save(str(tmp_path), s, t, keep=3)
        assert all_steps(str(tmp_path)) == [3, 4, 5]
        assert latest_step(str(tmp_path)) == 5
        assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))

    def test_async_save(self, tmp_path):
        t = self.tree()
        th = save_async(str(tmp_path), 9, t)
        th.join()
        out = restore(str(tmp_path), 9, t)
        np.testing.assert_array_equal(np.asarray(out["b"]),
                                      np.asarray(t["b"]))

    def test_shape_mismatch_rejected(self, tmp_path):
        t = self.tree()
        save(str(tmp_path), 0, t)
        bad = dict(t)
        bad["w"] = jnp.zeros((5, 8), jnp.bfloat16)
        with pytest.raises(ValueError):
            restore(str(tmp_path), 0, bad)

    def test_elastic_restore_onto_sharding(self, tmp_path):
        """Mesh-shape independence: restore device_puts per a sharding."""
        t = self.tree()
        save(str(tmp_path), 0, t)
        mesh = jax.make_mesh((1,), ("data",))
        from jax.sharding import NamedSharding, PartitionSpec as P
        sh = jax.tree.map(lambda x: NamedSharding(
            mesh, P(*([None] * x.ndim))), t)
        out = restore(str(tmp_path), 0, t, shardings=sh)
        assert out["w"].sharding == sh["w"]


class TestData:
    def test_deterministic_replay(self):
        cfg = DataConfig(vocab_size=512, seq_len=32, global_batch=4, seed=3)
        d1, d2 = SyntheticLM(cfg), SyntheticLM(cfg)
        for step in (0, 7, 123):
            np.testing.assert_array_equal(
                np.asarray(d1.batch_at(step)["tokens"]),
                np.asarray(d2.batch_at(step)["tokens"]))

    def test_steps_differ(self):
        cfg = DataConfig(vocab_size=512, seq_len=32, global_batch=4)
        d = SyntheticLM(cfg)
        a = np.asarray(d.batch_at(0)["tokens"])
        b = np.asarray(d.batch_at(1)["tokens"])
        assert not np.array_equal(a, b)

    def test_learnable_structure(self):
        """Consecutive tokens mostly follow an affine progression."""
        cfg = DataConfig(vocab_size=512, seq_len=64, global_batch=8,
                         noise_prob=0.0)
        toks = np.asarray(SyntheticLM(cfg).batch_at(0)["tokens"])
        diffs = np.diff(toks, axis=1) % cfg.vocab_size
        # stride constant within each row
        assert (diffs == diffs[:, :1]).mean() > 0.99


class TestOptim:
    def params(self):
        k = jax.random.PRNGKey(0)
        return {"w": jax.random.normal(k, (8, 8), jnp.float32),
                "b": jnp.zeros((8,), jnp.float32)}

    def quad_grads(self, p):
        return jax.grad(lambda p: jnp.sum(p["w"] ** 2) +
                        jnp.sum((p["b"] - 1.0) ** 2))(p)

    def test_adamw_descends(self):
        opt = AdamW(AdamWConfig(lr=0.05, weight_decay=0.0))
        p = self.params()
        st = opt.init(p)
        loss0 = float(jnp.sum(p["w"] ** 2) + jnp.sum((p["b"] - 1) ** 2))
        for _ in range(50):
            p, st = opt.update(self.quad_grads(p), st, p)
        loss1 = float(jnp.sum(p["w"] ** 2) + jnp.sum((p["b"] - 1) ** 2))
        assert loss1 < 0.1 * loss0

    def test_8bit_state_descends_like_fp32(self):
        """Per-row int8 moments perturb the trajectory (expected) but the
        optimizer must still reach comparably low loss."""
        def loss(p):
            return float(jnp.sum(p["w"] ** 2) + jnp.sum((p["b"] - 1) ** 2))

        p0 = self.params()
        loss0 = loss(p0)
        finals = {}
        for tag, o in (("f32", AdamW(AdamWConfig(lr=0.05,
                                                 weight_decay=0.0))),
                       ("i8", AdamW(AdamWConfig(lr=0.05, weight_decay=0.0,
                                                state_8bit=True)))):
            p, st = p0, o.init(p0)
            for _ in range(50):
                p, st = o.update(self.quad_grads(p), st, p)
            finals[tag] = loss(p)
            if tag == "i8":
                assert st["m"]["w"]["q"].dtype == jnp.int8
        assert finals["i8"] < 0.2 * loss0
        assert finals["i8"] < 10 * max(finals["f32"], 1e-3)

    def test_clip_norm(self):
        opt = AdamW(AdamWConfig(lr=1e-3, clip_norm=1e-6))
        p = self.params()
        st = opt.init(p)
        p2, _ = opt.update(self.quad_grads(p), st, p)
        # with a tiny clip, the update is bounded by ~lr regardless of grad
        assert float(jnp.max(jnp.abs(p2["w"] - p["w"]))) < 2e-3

    def test_cosine_schedule(self):
        lr = cosine_schedule(1.0, warmup=10, total=100, floor=0.1)
        assert float(lr(0)) == 0.0
        assert float(lr(10)) == pytest.approx(1.0)
        assert float(lr(100)) == pytest.approx(0.1, abs=1e-6)


class TestCompression:
    def test_error_feedback_unbiased_over_steps(self):
        """Repeated compression of a constant gradient converges to the
        true value on average (error feedback re-injects the residual)."""
        g = {"w": jnp.linspace(-1.0, 1.0, 64).reshape(8, 8)}
        err = compression.init_error_state(g)
        acc = jnp.zeros((8, 8))
        n = 50
        for _ in range(n):
            g8, scale, err = compression.compress_tree(g, err)
            acc = acc + compression.decompress_tree(g8, scale)["w"]
        np.testing.assert_allclose(np.asarray(acc / n),
                                   np.asarray(g["w"]),
                                   rtol=1e-2, atol=1e-3)

    def test_wire_dtype_is_fp8(self):
        g = {"w": jnp.ones((4, 4))}
        err = compression.init_error_state(g)
        g8, scale, _ = compression.compress_tree(g, err)
        assert g8["w"].dtype == compression.F8


class TestRuntimeLoop:
    def test_failure_injection_and_resume(self, tmp_path):
        from repro.runtime import LoopConfig, run_training

        calls = []

        def train_step(state, batch):
            calls.append(int(state["step"]))
            return {"step": state["step"] + 1}, {"loss": 1.0}

        summary = run_training(
            LoopConfig(total_steps=10, ckpt_dir=str(tmp_path),
                       ckpt_every=3, fail_at_step=7),
            train_step=train_step,
            init_state=lambda: {"step": jnp.int32(0)},
            batch_at=lambda step: {"x": jnp.zeros(())})
        assert summary["completed"] and summary["restarts"] == 1
        # steps 6.. re-run after the restart from the step-5 checkpoint
        assert calls.count(6) == 2

    def test_step_monitor_flags_slow_step(self):
        from repro.runtime import StepMonitor
        mon = StepMonitor(threshold=1.5)
        for s in range(5):
            mon.record(s, 1.0)
        rep = mon.record(5, 5.0)
        assert rep is not None and rep.kind == "step-time"
