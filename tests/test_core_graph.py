"""Unit tests: MXDAG graph structure and the §3.2 path calculus."""
import pytest

from repro.core import MXDAG, MXTask, TaskKind, compute, flow
from repro.core import builders


def chain_graph(tasks, pipelined=False):
    g = MXDAG("chain")
    g.chain(*tasks, pipelined=pipelined)
    return g


class TestConstruction:
    def test_duplicate_task_rejected(self):
        g = MXDAG()
        g.add(compute("a", 1.0, "A"))
        with pytest.raises(ValueError):
            g.add(compute("a", 1.0, "A"))

    def test_cycle_rejected(self):
        g = MXDAG()
        g.add(compute("a", 1.0, "A"))
        g.add(compute("b", 1.0, "B"))
        g.add_edge("a", "b")
        with pytest.raises(ValueError):
            g.add_edge("b", "a")

    def test_task_validation(self):
        with pytest.raises(ValueError):
            compute("x", -1.0, "A")
        with pytest.raises(ValueError):
            compute("x", 1.0, "A", unit=2.0)   # unit > size
        # placement fields must match the task kind
        with pytest.raises(ValueError):
            MXTask(name="x", kind=TaskKind.COMPUTE, size=1.0, src="A")
        with pytest.raises(ValueError):
            MXTask(name="f", kind=TaskKind.NETWORK, size=1.0, host="A")

    def test_logical_tasks_are_unbound(self):
        # None placements are legal (bound late); resources() refuses
        # until the task is fully bound
        c = compute("x", 1.0)
        assert not c.bound
        f = flow("f", 1.0, "A", None)          # dst bound late
        assert not f.bound
        with pytest.raises(ValueError, match="unbound"):
            f.resources()
        assert flow("g", 1.0, "A", "B").bound

    def test_topo_order(self):
        g = builders.fig1_jobs()
        order = g.topo_order()
        pos = {n: i for i, n in enumerate(order)}
        for (s, d) in g.edges:
            assert pos[s] < pos[d]

    def test_units(self):
        t = compute("a", 1.0, "A", unit=0.25)
        assert t.pipelineable and t.n_units == 4
        t2 = compute("b", 1.0, "A")
        assert not t2.pipelineable and t2.n_units == 1


class TestCalculus:
    def test_eq1_sequential(self):
        ts = [compute("a", 2.0, "A"), compute("b", 3.0, "B")]
        assert MXDAG.len_sequential(ts) == 5.0
        assert MXDAG.len_sequential(ts, {"a": 0.5}) == 7.0

    def test_eq2_pipelined(self):
        # Fig. 5 style: units u_i, sizes N*u_i (equal unit counts)
        ts = [compute("a", 4.0, "A", unit=1.0),
              compute("b", 8.0, "B", unit=2.0)]
        # sum(units) + max(sizes) - max(units) = 3 + 8 - 2 = 9
        assert MXDAG.len_pipelined(ts) == 9.0

    def test_eq2_throughput_capped_by_slowest_stage(self):
        # paper: "maximum throughput of the flow can be restricted by the
        # CPU processing speed when pipeline is used"
        cpu = compute("c", 10.0, "A", unit=1.0)   # slow producer
        f = flow("f", 2.0, "A", "B", unit=0.2)    # fast flow
        ln = MXDAG.len_pipelined([cpu, f])
        assert ln == pytest.approx(1.0 + 0.2 + 10.0 - 1.0)

    def test_evaluate_matches_eq1_on_sequential_chain(self):
        ts = [compute(f"t{i}", 1.0 + i, "H") for i in range(4)]
        g = chain_graph(ts)
        timing = g.evaluate()
        assert timing["t3"].completion == pytest.approx(
            MXDAG.len_sequential(ts))

    def test_evaluate_matches_eq2_on_pipelined_chain(self):
        n = 5
        ts = [compute(f"t{i}", (i + 1) * n * 0.5, "H", unit=(i + 1) * 0.5)
              for i in range(3)]
        g = chain_graph(ts, pipelined=True)
        timing = g.evaluate()
        assert timing["t2"].completion == pytest.approx(
            MXDAG.len_pipelined(ts))

    def test_pipelined_edge_into_unpipelineable_consumer_is_barrier(self):
        a = compute("a", 2.0, "A", unit=0.5)
        b = compute("b", 1.0, "B")           # not pipelineable
        g = MXDAG()
        g.chain(a, b, pipelined=True)
        assert g.evaluate()["b"].completion == pytest.approx(3.0)

    def test_partial_resource_scaling(self):
        ts = [compute("a", 2.0, "A")]
        g = chain_graph(ts)
        assert g.evaluate({"a": 0.5})["a"].completion == pytest.approx(4.0)


class TestCriticalPath:
    def test_fig1_critical_path(self):
        g = builders.fig1_jobs()
        assert g.critical_path() == ["a", "f1", "b", "f2", "c"]

    def test_slack_zero_on_critical_path(self):
        g = builders.fig1_jobs()
        timing = g.with_slack()
        for n in g.critical_path():
            assert timing[n].slack == pytest.approx(0.0, abs=1e-9)
        assert timing["f3"].slack > 0

    def test_makespan(self):
        g = builders.fig1_jobs()
        assert g.makespan() == pytest.approx(5.0)


class TestCopaths:
    def test_fig4a_copath(self):
        g = builders.fig1_jobs()
        cps = g.copaths()
        assert ("a", "c") in cps
        paths = cps[("a", "c")]
        assert sorted(map(tuple, paths)) == [
            ("a", "f1", "b", "f2", "c"), ("a", "f3", "c")]

    def test_copath_members_share_head_and_tail(self):
        g = builders.fig2b()
        for (h, t), paths in g.copaths().items():
            for p in paths:
                assert p[0] == h and p[-1] == t
