"""Fault-injection recovery benchmark: replan vs no-replan vs oracle.

Each scenario injects one concrete fault (host loss, executor
straggler, link degradation) into a *live* flat-array DES run via the
Nemesis harness (``repro.core.nemesis``) and measures the recovery
makespan three ways:

- **no-replan** — the fault lands and nothing reacts.  An
  unrecoverable fault (a dead host holding unfinished work) stalls the
  run: makespan ``inf``.
- **replan** — the ReplanController probes progress, feeds it into the
  Monitor, diagnoses the fault (announced for host loss; *inferred*
  from straggler observations for slow executors and degraded links),
  and recovers with ``move_task`` / ``repath_flow`` / a warm
  ``MXDAGScheduler`` re-prioritisation.
- **oracle** — a clairvoyant plan that knew the fault before t=0:
  schedule around the doomed host / slow executor (best ``move_task``
  what-if over every healthy host) or route around the degraded link
  (ECMP candidates avoiding it).  The gap ``replan / oracle`` is the
  price of *detecting* at runtime instead of knowing.

Row families (gated rows committed in ``baseline.json`` and enforced
by check_perf.py):

- ``nemesis.<scenario>.base_ms`` / ``no_replan_ms`` / ``replan_ms`` /
  ``cost_ms`` / ``oracle_ms`` — model-time makespans (informational;
  ``cost_ms`` is the cost-aware controller arm),
- ``nemesis.<scenario>.replan_wins`` — 1.0 iff replanning *strictly*
  beats the no-replan arm (gated: the robustness headline),
- ``nemesis.<scenario>.detected`` — 1.0 iff the tracker confirmed the
  controller noticed every injected fault (gated; scenarios whose
  re-faults are symptomless — a flap's second dip on an evacuated
  link — report an informational ``detect_rate`` instead),
- ``nemesis.<scenario>.no_worse`` — 1.0 iff the *cost-aware*
  controller's makespan is <= the no-replan arm (gated for every
  scenario including ``layered_rand``: pricing speculation via the
  analytic critical path means replanning never loses to doing
  nothing),
- ``nemesis.<scenario>.ref_match`` — 1.0 iff a Nemesis run with an
  *empty* fault schedule reproduces the plain ``array_run`` makespan
  bit-exactly (gated: the pause/mutate/resume machinery is free when
  unused),
- ``nemesis.<scenario>.vs_oracle`` — replan/oracle ratio
  (informational),
- ``nemesis.layered_rand.*`` — a seeded ``random_faults`` schedule on
  a random layered DAG (wins/detection informational — the fault mix
  depends on ``--seed`` — but ``no_worse`` is gated),
- ``nemesis.cascade_*`` — correlated fault campaigns (rack
  blast-radius under a coflow-coupled shuffle, flapping core link,
  3-fault storm with overlapping windows); recovery rewinds MADD
  coflow groups through ``ResumableSim.resurrect``.

``--smoke`` restricts to the two CI-lane scenarios (one host loss, one
link degradation); ``--report PATH`` writes the markdown recovery
report the CI uploads as an artifact; ``--only PREFIX`` / ``--json
PATH`` behave as in scale.py; ``--seed`` reseeds the random scenario.
"""
from __future__ import annotations

import os
import sys

_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)        # so `python benchmarks/nemesis.py` works

#: scenarios the CI bench-smoke lane runs (one announced fault, one
#: inferred fault) — seeded, deterministic, < 1s together
SMOKE = ("fanin8_hostloss", "ft8_linkdeg")


def _best_move(g, cl, task: str, avoid: set[str]) -> float:
    """Oracle makespan: the best ``move_task`` what-if over every
    healthy host with a matching slot pool (the plan of a scheduler
    that knew ``task``'s resources were doomed)."""
    from repro.core import WhatIf

    w = WhatIf(g, cl)
    proc = g.tasks[task].proc
    best = float("inf")
    for hname, h in sorted(cl.hosts.items()):
        if hname in avoid or h.procs.get(proc, 0) < 1:
            continue
        best = min(best, w.move_task(task, hname).variant)
    return best


def _loaded_fabric_link(g, cl) -> str:
    """The most-traversed non-NIC link under the static ECMP routing —
    the deterministic pick for the link-degradation scenario."""
    from collections import Counter

    from repro.core import TaskKind
    from repro.core.fabric import is_nic_link

    cnt: Counter = Counter()
    for t in g.tasks.values():
        if t.kind is TaskKind.NETWORK:
            for l in cl.resources_for(t):
                if not is_nic_link(l):
                    cnt[l] += 1
    return max(sorted(cnt), key=cnt.__getitem__)


def _reroute_oracle(sched, cl, link: str) -> float:
    """Oracle makespan for a degraded link: every flow whose static
    route traverses it takes the first ECMP candidate that avoids it
    (from t=0, on undegraded capacities — the oracle never touches the
    bad link)."""
    from repro.core import TaskKind

    routes = {}
    for t in sched.graph.tasks.values():
        if t.kind is not TaskKind.NETWORK:
            continue
        if link in cl.resources_for(t):
            for p in cl.candidate_routes(t):
                if link not in p:
                    routes[t.name] = p
                    break
    return sched.simulate(cl, routes=routes).makespan


def scenarios(seed: int = 0):
    """name → build thunk for the fault matrix.

    Each thunk returns a dict with the scheduled run (``sched``,
    ``cl``), the fault list, the oracle makespan, the controller's
    probe cadence, and ``gated`` (whether the win/detection claims are
    committed to baseline.json — False only for the random-sampled
    scenario, whose fault mix depends on ``seed``).
    """
    from repro.core import Cluster, MXDAGScheduler, builders
    from repro.core.nemesis import Fault, random_faults

    def _plan(g, cl):
        return MXDAGScheduler(try_pipelining=False).schedule(g, cl)

    def fanin8_hostloss():
        g, cl = builders.oversubscribed_fanin(8, oversubscription=8.0)
        sched = _plan(g, cl)
        return dict(
            sched=sched, cl=cl,
            faults=[Fault(2.5, "host_loss", "d0")],
            oracle=_best_move(g, cl, "c0", avoid={"d0"}),
            probe_every=0.5, gated=True)

    def fanin8_straggler():
        g, cl = builders.oversubscribed_fanin(8, oversubscription=8.0)
        sched = _plan(g, cl)
        return dict(
            sched=sched, cl=cl,
            faults=[Fault(1.5, "straggler", "c0", factor=0.125)],
            oracle=_best_move(g, cl, "c0", avoid={"d0"}),
            probe_every=0.5, gated=True)

    def ft8_linkdeg():
        g, cl = builders.fat_tree_shuffle(8, stride=2)
        sched = _plan(g, cl)
        base = sched.simulate(cl).makespan
        link = _loaded_fabric_link(g, cl)
        return dict(
            sched=sched, cl=cl,
            faults=[Fault(0.3 * base, "link_degrade", link, factor=0.1)],
            oracle=_reroute_oracle(sched, cl, link),
            probe_every=0.25, gated=True)

    def layered_rand():
        g = builders.random_layered(400, n_hosts=16, min_width=4,
                                    max_width=16, seed=7)
        cl = Cluster.for_graph(g)
        sched = _plan(g, cl)
        base = sched.simulate(cl).makespan
        return dict(
            sched=sched, cl=cl,
            faults=random_faults(g, cl, horizon=base, n=2, seed=seed),
            oracle=base,     # no closed-form clairvoyant; base = bound
            probe_every=0.5, gated=False)

    def _coflow_shuffle():
        """ft8 shuffle with its shuffle flows grouped into coflows —
        the cascade scenarios run with MADD coupling on, so recovery
        exercises the coflow-rewind path in ``ResumableSim``."""
        import dataclasses

        from repro.core.schedule import auto_coflows

        g, cl = builders.fat_tree_shuffle(8, stride=2)
        sched = _plan(g, cl)
        sched = dataclasses.replace(sched, coflows=auto_coflows(g))
        return g, cl, sched

    def cascade_rack():
        # correlated blast radius: one ToR loss takes out 4 mapper
        # hosts and their 8 edge-agg links in a single stroke, mid
        # shuffle — lineage closure rewinds the coupled coflow groups
        g, cl, sched = _coflow_shuffle()
        base = sched.simulate(cl).makespan
        return dict(
            sched=sched, cl=cl,
            faults=[Fault(0.4 * base, "rack_loss", "p0.e0")],
            oracle=base,     # losing a rack can't beat the full fabric
            probe_every=0.25, gated=True)

    def cascade_flap():
        # the most-loaded core link flaps: degrade -> recover ->
        # degrade -> recover.  The win is evacuating the link during
        # the dips without false-positive cascades; the second dip hits
        # an already-evacuated link (symptomless, so detection of it is
        # not gated — there is nothing for inference to see)
        from repro.core.nemesis import flapping_link

        g, cl, sched = _coflow_shuffle()
        base = sched.simulate(cl).makespan
        link = _loaded_fabric_link(g, cl)
        return dict(
            sched=sched, cl=cl,
            faults=flapping_link(link, start=0.2 * base,
                                 period=0.3 * base, cycles=2,
                                 factor=0.05),
            oracle=_reroute_oracle(sched, cl, link),
            probe_every=0.25, gated=True, detect_gated=False)

    def cascade_storm():
        # three distinct faults with overlapping active windows: a
        # degraded core link during the shuffle, a reducer host dying
        # after its coflow completed (the canonical MapReduce recovery
        # — rewinds the finished shuffle group), and a slowed reducer
        # executor.  Exercises per-fault attribution in the tracker.
        g, cl, sched = _coflow_shuffle()
        base = sched.simulate(cl).makespan
        link = _loaded_fabric_link(g, cl)
        return dict(
            sched=sched, cl=cl,
            faults=[Fault(0.3 * base, "link_degrade", link, 0.05),
                    Fault(0.45 * base, "host_loss", "p1e0h0"),
                    Fault(0.5 * base, "straggler", "r5", 0.1)],
            oracle=base,     # no closed-form clairvoyant; base = bound
            probe_every=0.25, gated=True)

    return {
        "fanin8_hostloss": fanin8_hostloss,
        "fanin8_straggler": fanin8_straggler,
        "ft8_linkdeg": ft8_linkdeg,
        "layered_rand": layered_rand,
        "cascade_rack": cascade_rack,
        "cascade_flap": cascade_flap,
        "cascade_storm": cascade_storm,
    }


def run_scenario(spec: dict) -> dict:
    """Run all four arms plus the zero-fault equivalence check."""
    from repro.core.nemesis import Nemesis

    sched, cl = spec["sched"], spec["cl"]
    expected = sched.simulate(cl)
    kw = dict(probe_every=spec["probe_every"], expected=expected)
    no = Nemesis(sched, cl, faults=spec["faults"], replan=False,
                 **kw).run()
    yes = Nemesis(sched, cl, faults=spec["faults"], replan=True,
                  **kw).run()
    cost = Nemesis(sched, cl, faults=spec["faults"], replan=True,
                   cost_aware=True, **kw).run()
    zero = Nemesis(sched, cl, faults=[], replan=True, **kw).run()
    return {
        "base": expected.makespan,
        "no_replan": no.makespan,
        "replan": yes.makespan,
        "cost": cost.makespan,
        "oracle": spec["oracle"],
        "detection_rate": yes.detection_rate,
        "ref_match": 1.0 if zero.makespan == expected.makespan else 0.0,
        "report": yes.tracker.report(),
    }


def bench_rows(only: str | None = None, *, seed: int = 0,
               smoke: bool = False, reports: dict | None = None):
    """The ``nemesis.*`` (name, value, derived) rows for run.py/CI.

    ``reports``, when given, collects each scenario's markdown recovery
    report (for ``--report``/the CI artifact).
    """
    rows = []
    for name, make in scenarios(seed).items():
        if smoke and name not in SMOKE:
            continue
        if only is not None and not name.startswith(only):
            continue
        spec = make()
        res = run_scenario(spec)
        if reports is not None:
            reports[name] = res["report"]
        f = spec["faults"][0] if spec["faults"] else None
        what = (f"{f.kind} {f.target} @t={f.time:g}" if f else "no faults")
        rows.append((f"nemesis.{name}.base_ms", res["base"],
                     "fault-free makespan (model time)"))
        rows.append((f"nemesis.{name}.no_replan_ms", res["no_replan"],
                     f"{what}; nothing reacts (inf = stalled)"))
        rows.append((f"nemesis.{name}.replan_ms", res["replan"],
                     f"{what}; controller detects and replans"))
        rows.append((f"nemesis.{name}.cost_ms", res["cost"],
                     f"{what}; cost-aware controller (analytic "
                     "worth-it model, hysteresis, bounded budget)"))
        rows.append((f"nemesis.{name}.oracle_ms", res["oracle"],
                     "clairvoyant plan that knew the fault before t=0"))
        if spec["gated"]:
            rows.append((
                f"nemesis.{name}.replan_wins",
                1.0 if res["replan"] < res["no_replan"] - 1e-9 else 0.0,
                f"replan {res['replan']:g} < no-replan "
                f"{res['no_replan']:g} (1.0 = validated)"))
            if spec.get("detect_gated", True):
                rows.append((
                    f"nemesis.{name}.detected",
                    1.0 if res["detection_rate"] == 1.0 else 0.0,
                    "controller noticed every injected fault"))
            else:
                rows.append((f"nemesis.{name}.detect_rate",
                             res["detection_rate"],
                             "symptomless re-faults are undetectable "
                             "by inference; informational"))
        else:
            rows.append((f"nemesis.{name}.detect_rate",
                         res["detection_rate"],
                         f"seeded random_faults (seed={seed}); "
                         "informational"))
        rows.append((
            f"nemesis.{name}.no_worse",
            1.0 if res["cost"] <= res["no_replan"] + 1e-9 else 0.0,
            f"cost-aware replan {res['cost']:g} <= no-replan "
            f"{res['no_replan']:g} (1.0 = never loses to doing "
            "nothing)"))
        rows.append((f"nemesis.{name}.ref_match", res["ref_match"],
                     "zero-fault Nemesis == plain array_run makespan "
                     "(bit-exact)"))
        if res["oracle"] > 0 and res["replan"] < float("inf"):
            rows.append((f"nemesis.{name}.vs_oracle",
                         res["replan"] / res["oracle"],
                         "recovery makespan / clairvoyant makespan "
                         "(the price of runtime detection)"))
    return rows


def recovery_report(reports: dict[str, str]) -> str:
    """One markdown document with every scenario's tracker table."""
    parts = ["# Nemesis recovery report", ""]
    for name, rep in reports.items():
        parts += [f"## {name}", "", rep, ""]
    return "\n".join(parts)


def main() -> None:
    """CLI driver: CSV rows by default; see module docstring."""
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", metavar="PREFIX", default=None,
                    help="run only scenarios whose name starts with "
                         "PREFIX, e.g. fanin")
    ap.add_argument("--smoke", action="store_true",
                    help="run only the CI smoke pair (one host loss, "
                         "one link degradation)")
    ap.add_argument("--seed", type=int, default=0,
                    help="seed for the random_faults scenario")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the rows as JSON to PATH")
    ap.add_argument("--report", metavar="PATH", default=None,
                    help="write the markdown recovery report to PATH")
    args = ap.parse_args()

    reports: dict[str, str] = {}
    rows = bench_rows(args.only, seed=args.seed, smoke=args.smoke,
                      reports=reports)
    if args.json:        # artifact first: survives a closed stdout pipe
        with open(args.json, "w") as f:
            json.dump([{"name": n, "value": v, "derived": str(d)}
                       for n, v, d in rows], f, indent=2)
    if args.report:
        with open(args.report, "w") as f:
            f.write(recovery_report(reports) + "\n")
    print("name,value,derived")
    for name, value, derived in rows:
        print(f"{name},{value:.6g},{str(derived).replace(',', ';')}")


if __name__ == "__main__":
    main()
