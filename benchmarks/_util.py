"""Shared benchmark helpers."""
from __future__ import annotations

import time


def timeit_us(fn, *args, repeat: int = 3) -> float:
    """Best-of-``repeat`` wall time of ``fn(*args)`` in microseconds."""
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn(*args)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6
