"""CI perf-regression gate: diff a bench JSON against a committed baseline.

Usage::

    python benchmarks/check_perf.py bench.json benchmarks/baseline.json

Compares every wall-time row (``micro.*`` / ``scale.*`` names ending in
``_us``) present in both files and fails (exit 1) when any row regressed
by more than ``--threshold`` (default 2x).  Rows under ``--floor-us``
(default 50µs) are ignored — at that scale the timer and allocator noise
on shared CI runners dwarfs any real regression.  Rows named
``*.ref_match`` must equal 1.0 (the engine under test diverged from its
oracle — a correctness failure, not a perf one), as must rows named
``*.improves`` (a scheduling decision — e.g. placement on the fat-tree
shuffle — stopped beating its fixed baseline), ``*.mxdag_wins``
(MXDAG's makespan fell behind a baseline scheduler's on a bake-off
scenario — see benchmarks/bakeoff.py; the headline claim of the
reproduction, gated like any other correctness row), ``*.replan_wins``
(live replanning stopped strictly beating the no-replan arm on a
fault-injection scenario — see benchmarks/nemesis.py), ``*.detected``
(the replan controller missed an injected fault), ``*.jct_wins``
(altruistic admission stopped beating FIFO/fair on p99 JCT in the
oversubscribed online mix — see benchmarks/online.py) and
``*.no_worse``
(the *cost-aware* controller arm lost to doing nothing — the analytic
worth-it model exists precisely so speculation never makes a scenario
worse, ``layered_rand`` included).  ``online.speedup_replan_loop``
(compiled multi-job re-prioritisation vs the dict pipeline in the
service-loop shape, committed ~4x) shares the 3x ``--speedup-floor``.  ``scale.speedup_array_*``
rows (flat-array engine vs the event-calendar core on the Graphene-scale
scenarios, including the ddl(1024) serial-chain trickle that
component-level reallocation + coalesced completion events lifted from
~1.2x) must stay above ``--speedup-floor`` (default 3x — the committed
numbers are 3.8–7.9x, ddl1024 being the tightest; the floor leaves
room for runner noise while still catching the array engine losing its
edge).  Likewise
``scale.speedup_analytic_*`` (compiled analytic passes vs the dict
implementation, committed ≥10x) is floored at 3x and
``scale.speedup_schedule_mr128x128`` (end-to-end schedule() with
compiled analytics vs the dict pipeline) at 2x;
``scale.speedup_schedule_layered20k`` stays informational — that
workload is DES-bound, so its analytic win is real but small.

``scale.speedup_batch_*`` rows (the mega-batch event loop vs the
per-event oracle loop on the same compiled engine) are floored at 1.5x
(ddl1024, committed ~2.0x) and 1.2x (layered20k, committed ~1.3-1.4x);
``scale.speedup_parallel_*`` rows (workers=4 what-if sweeps vs serial)
are floored at 2x, but only when the bench's ``scale.parallel_cores``
row shows >=4 usable cores — on smaller runners the fan-out is
correctness-only and the row is informational.

``--trend REPORT.md --history RUNS.jsonl`` additionally writes a
rolling-window change-detection report: the current rows are appended
to the history and each gated row's median over the most recent window
(default 5 runs) is compared against the median of the window before
it, flagging drifts beyond 1.25x either way.  Median-vs-median sees
through single-run noise the static one-number baseline diff cannot;
the report is informational only — the static gates above stay
authoritative.

Wall-time speed-ups never fail the gate; refresh the baseline with
``--update-baseline`` (regenerates the baseline file in place from the
bench JSON — for intentional optimisations, or when a new runner
generation shifts wall times enough that the committed numbers are
noise) and commit the result.
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys
import time


def load_rows(path: str) -> dict[str, float]:
    with open(path) as f:
        data = json.load(f)
    return {r["name"]: float(r["value"]) for r in data}


def gated(name: str) -> bool:
    # *_seed_us / *_dict_us / *_nobatch_us / *_serial_us rows time
    # frozen "before" implementations (the seed hot paths, the dict
    # analytic passes, the per-event oracle loop, the serial sweep):
    # informational — their drift tracks runner speed, not a code
    # regression.
    return (name.startswith(("micro.", "scale.", "online."))
            and name.endswith("_us")
            and not name.endswith(("_seed_us", "_dict_us",
                                   "_nobatch_us", "_serial_us")))


def update_trend(history_path: str, bench: dict[str, float],
                 out_path: str, window: int = 5,
                 flag_ratio: float = 1.25) -> None:
    """Rolling-window change detection over a run history.

    Appends ``bench`` to the JSONL history (bounded to ``4 * window``
    entries), then compares each gated row's median over the most
    recent ``window`` runs against the median of the ``window`` runs
    before that and writes a markdown report flagging rows whose
    medians moved by more than ``flag_ratio`` either way.  A median-vs-
    median diff sees through single-run noise that the static baseline
    gate (one committed number vs one fresh number) cannot; it is
    *informational only* — the static gates remain authoritative and
    this function never affects the exit code.
    """
    hist: list[dict] = []
    try:
        with open(history_path) as f:
            for line in f:
                line = line.strip()
                if line:
                    hist.append(json.loads(line))
    except (FileNotFoundError, json.JSONDecodeError):
        hist = [h for h in hist if isinstance(h, dict)]
    hist.append({"ts": time.time(), "rows": dict(bench)})
    hist = hist[-(4 * window):]
    with open(history_path, "w") as f:
        for e in hist:
            f.write(json.dumps(e) + "\n")

    lines = [
        "# Perf trend report",
        "",
        f"Rolling {window}-run median change detection over "
        f"{len(hist)} recorded run(s).  Informational only — the "
        f"static baseline gates stay authoritative.",
        "",
    ]
    flagged: list[tuple[str, float, float, float]] = []
    stable = young = 0
    for name in sorted(bench):
        if not (gated(name) or name.startswith("scale.speedup_")):
            continue
        series = [e["rows"][name] for e in hist if name in e["rows"]]
        if len(series) < 2 * window:
            young += 1
            continue
        recent = statistics.median(series[-window:])
        prior = statistics.median(series[-2 * window:-window])
        if prior <= 0:
            continue
        ratio = recent / prior
        if ratio > flag_ratio or ratio < 1.0 / flag_ratio:
            flagged.append((name, prior, recent, ratio))
        else:
            stable += 1
    if flagged:
        lines += ["| row | prior median | recent median | change |",
                  "|---|---:|---:|---:|"]
        for name, prior, recent, ratio in sorted(
                flagged, key=lambda r: -abs(r[3] - 1.0)):
            unit = "us" if name.endswith("_us") else "x"
            lines.append(f"| `{name}` | {prior:.4g}{unit} | "
                         f"{recent:.4g}{unit} | {ratio:.2f}x |")
        lines.append("")
    lines.append(f"{len(flagged)} row(s) drifted beyond "
                 f"{flag_ratio:g}x, {stable} stable, {young} with "
                 f"fewer than {2 * window} recorded runs.")
    with open(out_path, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"trend report written to {out_path} "
          f"({len(flagged)} drifted / {stable} stable / {young} young)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("bench", help="freshly produced bench JSON")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("--threshold", type=float, default=2.0,
                    help="fail on wall-time regressions beyond this "
                         "factor (default 2x)")
    ap.add_argument("--floor-us", type=float, default=50.0,
                    help="ignore rows faster than this in the baseline")
    ap.add_argument("--speedup-floor", type=float, default=3.0,
                    help="fail when a scale.speedup_array_* row drops "
                         "below this ratio")
    ap.add_argument("--update-baseline", action="store_true",
                    help="regenerate the baseline file in place from the "
                         "bench JSON instead of gating against it")
    ap.add_argument("--trend", metavar="REPORT_MD", default=None,
                    help="write a rolling-window trend report (markdown) "
                         "comparing recent run medians against the prior "
                         "window; requires --history")
    ap.add_argument("--history", metavar="JSONL", default=None,
                    help="run-history JSONL the trend report rolls over; "
                         "the current bench rows are appended to it")
    ap.add_argument("--trend-window", type=int, default=5,
                    help="runs per rolling median window (default 5)")
    args = ap.parse_args(argv)

    if args.update_baseline:
        with open(args.bench) as f:
            data = json.load(f)
        # a partial bench (scale.py --only, --no-seed, missing deps)
        # must not silently drop gate rows from the committed baseline
        try:
            old = set(load_rows(args.baseline))
        except FileNotFoundError:
            old = set()
        lost = sorted(old - {r["name"] for r in data})
        if lost:
            print(f"refusing to update {args.baseline}: the bench JSON "
                  f"is missing {len(lost)} baseline row(s) (partial "
                  f"run?): {lost}", file=sys.stderr)
            return 1
        with open(args.baseline, "w") as f:
            json.dump(data, f, indent=2)
            f.write("\n")
        print(f"baseline {args.baseline} regenerated from {args.bench} "
              f"({len(data)} rows)")
        return 0

    bench = load_rows(args.bench)
    base = load_rows(args.baseline)

    if args.trend:
        if not args.history:
            print("--trend requires --history", file=sys.stderr)
            return 2
        # before the gate: the report should exist even on a failing run
        update_trend(args.history, bench, args.trend,
                     window=args.trend_window)

    def speedup_floor(name: str):
        """Gated speedup-claim rows and their floors (None = not a
        gated speedup row)."""
        if name.startswith("scale.speedup_array_"):
            return args.speedup_floor
        if name.startswith("scale.speedup_analytic_"):
            return 3.0
        if name == "scale.speedup_schedule_mr128x128":
            return 2.0
        # mega-batch event loop vs the per-event oracle loop: committed
        # numbers are ~2.0x (ddl1024) and ~1.3-1.4x (layered20k); the
        # floors leave noise headroom while catching the batched loop
        # losing its edge.
        if name == "scale.speedup_batch_ddl1024":
            return 1.5
        if name == "scale.speedup_batch_layered20k":
            return 1.2
        # workers=4 what-if sweep vs serial: only meaningful when the
        # runner actually has >=4 usable cores (the bench records them
        # in scale.parallel_cores); on smaller machines the row stays
        # informational — forked fan-out on 1 core is correctness-only.
        if name.startswith("scale.speedup_parallel_"):
            if bench.get("scale.parallel_cores", 1.0) >= 4:
                return 2.0
            return None
        # the online service-loop re-prioritisation (compiled multi-job
        # passes vs the dict pipeline, sliding-window shape — see
        # benchmarks/online.py; committed ~4x).  The small-job stream
        # variant (speedup_replan_stream) stays informational: tiny
        # jobs leave the compiled passes little to amortize.
        if name == "online.speedup_replan_loop":
            return args.speedup_floor
        return None

    failures = []
    for name in sorted(base):
        if name.endswith(".ref_match"):
            if name not in bench:
                failures.append(f"{name}: equivalence row missing from "
                                f"bench output (check never ran)")
            elif bench[name] != 1.0:
                failures.append(f"{name}: engine under test diverged "
                                f"from its oracle")
            continue
        if name.endswith(".improves"):
            if name not in bench:
                failures.append(f"{name}: claim row missing from bench "
                                f"output (check never ran)")
            elif bench[name] != 1.0:
                failures.append(f"{name}: decision no longer beats its "
                                f"fixed baseline")
            continue
        if name.endswith(".mxdag_wins"):
            if name not in bench:
                failures.append(f"{name}: bake-off claim row missing "
                                f"from bench output (check never ran)")
            elif bench[name] != 1.0:
                failures.append(f"{name}: MXDAG no longer matches or "
                                f"beats every baseline scheduler")
            continue
        if name.endswith(".replan_wins"):
            if name not in bench:
                failures.append(f"{name}: recovery claim row missing "
                                f"from bench output (check never ran)")
            elif bench[name] != 1.0:
                failures.append(f"{name}: replanning no longer strictly "
                                f"beats the no-replan arm")
            continue
        if name.endswith(".detected"):
            if name not in bench:
                failures.append(f"{name}: detection row missing from "
                                f"bench output (check never ran)")
            elif bench[name] != 1.0:
                failures.append(f"{name}: the controller missed an "
                                f"injected fault")
            continue
        if name.endswith(".jct_wins"):
            if name not in bench:
                failures.append(f"{name}: online-admission claim row "
                                f"missing from bench output (check "
                                f"never ran)")
            elif bench[name] != 1.0:
                failures.append(f"{name}: altruistic admission no "
                                f"longer beats FIFO/fair on p99 JCT")
            continue
        if name.endswith(".no_worse"):
            if name not in bench:
                failures.append(f"{name}: cost-model row missing from "
                                f"bench output (check never ran)")
            elif bench[name] != 1.0:
                failures.append(f"{name}: the cost-aware controller "
                                f"lost to doing nothing")
            continue
        floor = speedup_floor(name)
        if floor is not None:
            if name not in bench:
                failures.append(f"{name}: speedup row missing from bench "
                                f"output (check never ran)")
            elif bench[name] < floor:
                failures.append(
                    f"{name}: speedup {bench[name]:.2f}x below the "
                    f"{floor:g}x floor")
            continue
        if not gated(name) or name not in bench:
            continue
        old, new = base[name], bench[name]
        if old < args.floor_us:
            continue
        ratio = new / old if old > 0 else float("inf")
        marker = ""
        if ratio > args.threshold:
            marker = "  <-- REGRESSION"
            failures.append(f"{name}: {old:.0f}us -> {new:.0f}us "
                            f"({ratio:.2f}x > {args.threshold:g}x)")
        print(f"{name}: {old:.0f}us -> {new:.0f}us ({ratio:.2f}x){marker}")

    missing = [n for n in base
               if gated(n) and n not in bench]
    if missing:
        failures.append(f"rows missing from bench output: {missing}")

    if failures:
        print("\nperf gate FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("\nperf gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
