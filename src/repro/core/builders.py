"""MXDAG builders: the paper's worked examples plus parametric generators.

Every figure the paper argues from is constructible here so benchmarks and
tests can validate the claims numerically:

- :func:`fig1_jobs`       — Fig. 1 / Fig. 4(a): two flows leaving host A.
- :func:`fig2a`           — Fig. 2(a): symmetric topology, asymmetric
                            compute times t1/t2.
- :func:`fig2b`           — Fig. 2(b): Wukong-style asymmetric topology
                            with flows f1..f6 (+ the b1/b2/b3 coflow
                            groupings of Fig. 2(b1..b3)).
- :func:`fig3`            — Fig. 3: 4-node DAG with critical path A→B→C
                            used for the three pipelining cases.
- :func:`ddl`             — Fig. 6: layer-wise data-parallel training
                            (BP → push → pull → FP with a parameter server).
- :func:`mapreduce_pair`  — Fig. 7: two map-reduce jobs sharing a host and
                            a NIC.
"""
from __future__ import annotations

import random
from typing import Optional, Sequence

from repro.core.cluster import Cluster
from repro.core.fabric import Topology
from repro.core.graph import MXDAG
from repro.core.task import MXTask, compute, flow


# ----------------------------------------------------------------------
# Fig. 1 / Fig. 4(a)
# ----------------------------------------------------------------------
def fig1_jobs() -> MXDAG:
    """Job X of Fig. 4(a): a@A fans out f1→B and f3→C; b@B sends f2→C;
    c@C joins f2 and f3.  Critical path A→f1→b→f2→c."""
    g = MXDAG("fig1_jobX")
    a = g.add(compute("a", 1.0, "A"))
    b = g.add(compute("b", 1.0, "B"))
    c = g.add(compute("c", 1.0, "C"))
    f1 = g.add(flow("f1", 1.0, "A", "B"))
    f2 = g.add(flow("f2", 1.0, "B", "C"))
    f3 = g.add(flow("f3", 1.0, "A", "C"))
    g.add_edge(a, f1)
    g.add_edge(a, f3)
    g.add_edge(f1, b)
    g.add_edge(b, f2)
    g.add_edge(f2, c)
    g.add_edge(f3, c)
    return g


# ----------------------------------------------------------------------
# Fig. 2(a): symmetric topology, asymmetric compute times
# ----------------------------------------------------------------------
def fig2a(t1: float = 3.0, t2: float = 1.0, fsize: float = 1.0) -> MXDAG:
    """Fig. 2(a): symmetric flows feeding asymmetric compute times."""
    g = MXDAG("fig2a")
    a = g.add(compute("a", 0.0, "A"))
    b = g.add(compute("b", t1, "B"))
    c = g.add(compute("c", t2, "C"))
    d = g.add(compute("d", 1.0, "D"))
    f1 = g.add(flow("f1", fsize, "A", "B"))
    f2 = g.add(flow("f2", fsize, "A", "C"))
    f3 = g.add(flow("f3", fsize, "B", "D"))
    f4 = g.add(flow("f4", fsize, "C", "D"))
    g.add_edge(a, f1)
    g.add_edge(a, f2)
    g.add_edge(f1, b)
    g.add_edge(f2, c)
    g.add_edge(b, f3)
    g.add_edge(c, f4)
    g.add_edge(f3, d)
    g.add_edge(f4, d)
    return g


def fig2a_coflows() -> list[set[str]]:
    """The Fig. 2(c) grouping: broadcast {f1,f2}, aggregation {f3,f4}."""
    return [{"f1", "f2"}, {"f3", "f4"}]


# ----------------------------------------------------------------------
# Fig. 2(b): Wukong-derived asymmetric topology
# ----------------------------------------------------------------------
def fig2b() -> MXDAG:
    """A→f1→B→f2→E; C broadcasts f3→D, f4→E; D→f5→F; E→f6→F; F joins.

    The optimal schedule delays f4 to give f3 the full C-egress bandwidth,
    which cascades so f5 and f6 do not share F's ingress (§2.2).
    """
    g = MXDAG("fig2b")
    a = g.add(compute("a", 1.0, "A"))
    b = g.add(compute("b", 1.0, "B"))
    c = g.add(compute("c", 1.0, "C"))
    d = g.add(compute("d", 1.0, "D"))
    e = g.add(compute("e", 1.0, "E"))
    f = g.add(compute("f", 1.0, "F"))
    f1 = g.add(flow("f1", 1.0, "A", "B"))
    f2 = g.add(flow("f2", 1.0, "B", "E"))
    f3 = g.add(flow("f3", 1.0, "C", "D"))
    f4 = g.add(flow("f4", 1.0, "C", "E"))
    f5 = g.add(flow("f5", 1.0, "D", "F"))
    f6 = g.add(flow("f6", 1.0, "E", "F"))
    g.add_edge(a, f1)
    g.add_edge(f1, b)
    g.add_edge(b, f2)
    g.add_edge(c, f3)
    g.add_edge(c, f4)
    g.add_edge(f3, d)
    g.add_edge(f2, e)
    g.add_edge(f4, e)
    g.add_edge(d, f5)
    g.add_edge(e, f6)
    g.add_edge(f5, f)
    g.add_edge(f6, f)
    return g


def fig2b_coflows(variant: str) -> list[set[str]]:
    """The three ambiguous groupings of Fig. 2(b1), (b2), (b3)."""
    if variant == "b1":    # broadcast from C + aggregation at F
        return [{"f3", "f4"}, {"f5", "f6"}]
    if variant == "b2":    # aggregation at E
        return [{"f2", "f4"}]
    if variant == "b3":    # all flows between {B,C} and {D,E}
        return [{"f2", "f3", "f4"}]
    raise ValueError(variant)


# ----------------------------------------------------------------------
# Fig. 3: pipelineability cases
# ----------------------------------------------------------------------
def fig3(unit: float = 0.25) -> MXDAG:
    """4-host DAG with critical path a→f1→b→f2→c and a side branch
    a→f3→d→f4→c.  All of a, f1, f3, d, f4 are pipelineable with ``unit``.
    """
    g = MXDAG("fig3")
    a = g.add(compute("a", 1.0, "A", unit=unit))
    b = g.add(compute("b", 2.0, "B"))
    c = g.add(compute("c", 1.0, "C"))
    d = g.add(compute("d", 0.5, "D", unit=unit))
    f1 = g.add(flow("f1", 1.0, "A", "B", unit=unit))
    f2 = g.add(flow("f2", 1.0, "B", "C"))
    f3 = g.add(flow("f3", 1.0, "A", "D", unit=unit))
    f4 = g.add(flow("f4", 0.5, "D", "C", unit=unit))
    g.add_edge(a, f1)
    g.add_edge(a, f3)
    g.add_edge(f1, b)
    g.add_edge(b, f2)
    g.add_edge(f2, c)
    g.add_edge(f3, d)
    g.add_edge(d, f4)
    g.add_edge(f4, c)
    return g


def fig3_case(case: int) -> MXDAG:
    """Return Fig. 3 with the pipelining choice of the given case applied.

    0: baseline (no pipelining);  1: pipeline flow4 only (non-critical);
    2: + pipeline flow1 (critical, helps);  3: + pipeline flow3 (critical,
    hurts: f1 and f3 now share A's egress NIC from t≈0)."""
    g = fig3()
    if case >= 1:
        g.set_pipelined("d", "f4", True)
    if case >= 2:
        g.set_pipelined("a", "f1", True)
    if case >= 3:
        g.set_pipelined("a", "f3", True)
    return g


# ----------------------------------------------------------------------
# Fig. 6: data-parallel distributed training (worker + parameter server)
# ----------------------------------------------------------------------
def ddl(n_layers: int = 4, *,
        bp: Sequence[float] | float = 1.0,
        fp: Sequence[float] | float = 1.0,
        push: Sequence[float] | float = 1.0,
        pull: Sequence[float] | float = 1.0,
        unit_frac: Optional[float] = None,
        worker: str = "W", ps: str = "PS", job: str = "job0",
        placed: bool = True, name: Optional[str] = None) -> MXDAG:
    """One boundary iteration of layer-wise data-parallel training.

    BP runs top layer → layer 0 on the worker GPU; each BP_i releases
    push_i (worker→PS) then pull_i (PS→worker); FP of the *next* iteration
    runs layer 0 → top and FP_i requires pull_i and FP_{i-1}.  This is the
    MXDAG of Fig. 6; MXDAG scheduling recovers ByteScheduler's
    lower-layer-first flow priority (§4.1.1).

    ``placed=False`` makes the parameter-server side a scheduling
    decision: push destinations / pull sources are left unbound (each
    layer's push→pull edge keeps its handoff on one host, so the
    scheduler may keep one PS or shard it per layer); the worker stays
    bound — it is where the GPU is.

    :param n_layers: number of model layers.
    :param bp: per-layer backward-pass times (scalar broadcasts).
    :param fp: per-layer forward-pass times (scalar broadcasts).
    :param push: per-layer gradient push sizes (scalar broadcasts).
    :param pull: per-layer parameter pull sizes (scalar broadcasts).
    :param unit_frac: when set, every task gets ``unit = unit_frac *
        size`` (enables pipelining experiments).
    :param worker: the GPU host name.
    :param ps: the parameter-server host name (ignored when
        ``placed=False``).
    :param job: job label stamped on every task.
    :param placed: ``False`` leaves the PS side logical (see above).
    :param name: when set, names the graph and prefixes every task name
        with ``"{name}."`` — required when several ddl jobs share a
        cluster (multi-job task names must be globally unique).
    :returns: the iteration's MXDAG.
    """
    def seq(x, default):
        """Broadcast a scalar to per-layer values (lists pass through)."""
        if isinstance(x, (int, float)):
            return [float(x)] * n_layers
        return [float(v) for v in x]

    bp, fp = seq(bp, 1.0), seq(fp, 1.0)
    push, pull = seq(push, 1.0), seq(pull, 1.0)
    uf = unit_frac
    pre = f"{name}." if name else ""

    g = MXDAG(name or f"ddl{n_layers}")
    bps = [g.add(compute(f"{pre}BP{i}", bp[i], worker, proc="gpu",
                         job=job))
           for i in range(n_layers)]
    fps = [g.add(compute(f"{pre}FP{i}", fp[i], worker, proc="gpu",
                         job=job))
           for i in range(n_layers)]
    ps_host = ps if placed else None
    pushes = [g.add(flow(f"{pre}push{i}", push[i], worker, ps_host,
                         job=job,
                         unit=None if uf is None else uf * push[i]))
              for i in range(n_layers)]
    pulls = [g.add(flow(f"{pre}pull{i}", pull[i], ps_host, worker,
                        job=job,
                        unit=None if uf is None else uf * pull[i]))
             for i in range(n_layers)]
    # BP chain: top layer first
    for i in range(n_layers - 1, 0, -1):
        g.add_edge(bps[i], bps[i - 1])
    for i in range(n_layers):
        g.add_edge(bps[i], pushes[i])
        g.add_edge(pushes[i], pulls[i])
        g.add_edge(pulls[i], fps[i])
    # FP chain: layer 0 first
    for i in range(n_layers - 1):
        g.add_edge(fps[i], fps[i + 1])
    return g


# ----------------------------------------------------------------------
# Fig. 7: two map-reduce jobs sharing a host and a NIC
# ----------------------------------------------------------------------
def mapreduce_pair() -> tuple[MXDAG, MXDAG]:
    """Job1: long map a@Ha + short map b@Hb feeding reduce r1@Hr.
    Job2: map d@Hb (shares Hb's compute slot with b) feeding r2@Hr2 via
    f3 (shares Hb's egress NIC with f2)."""
    j1 = MXDAG("job1")
    a = j1.add(compute("a", 3.0, "Ha", job="job1"))
    b = j1.add(compute("b", 1.0, "Hb", job="job1"))
    f1 = j1.add(flow("f1", 1.0, "Ha", "Hr", job="job1"))
    f2 = j1.add(flow("f2", 1.0, "Hb", "Hr", job="job1"))
    r1 = j1.add(compute("r1", 1.0, "Hr", job="job1"))
    j1.add_edge(a, f1)
    j1.add_edge(b, f2)
    j1.add_edge(f1, r1)
    j1.add_edge(f2, r1)

    j2 = MXDAG("job2")
    d = j2.add(compute("d", 1.0, "Hb", job="job2"))
    f3 = j2.add(flow("f3", 1.0, "Hb", "Hr2", job="job2"))
    r2 = j2.add(compute("r2", 1.0, "Hr2", job="job2"))
    j2.add_edge(d, f3)
    j2.add_edge(f3, r2)
    return j1, j2


# ----------------------------------------------------------------------
# oversubscribed-fabric scenario (multi-tier topology; beyond the paper's
# single-switch figures — the regime where co-scheduling matters most)
# ----------------------------------------------------------------------
def oversubscribed_fanin(n_senders: int = 4, *,
                         oversubscription: float = 4.0,
                         flow_size: float = 1.0,
                         critical_flow_size: Optional[float] = None,
                         critical_compute: float = 8.0,
                         other_compute: float = 1.0,
                         job: str = "job0",
                         placed: bool = True) -> tuple[MXDAG, Cluster]:
    """Cross-rack fan-in on an oversubscribed two-tier core.

    ``n_senders`` hosts in rack 0 each send one flow to a distinct host in
    rack 1; all flows contend on rack 0's shared uplink (capacity
    ``n_senders / oversubscription``).  Flow 0 feeds a *long* compute —
    the critical path — while the rest feed short ones.  Fair sharing
    splits the uplink evenly and delays the critical flow by a factor of
    ``n_senders``; MXDAG priority co-scheduling gives it the whole uplink
    first.

    :param n_senders: hosts per rack (= flows crossing the core).
    :param oversubscription: core ratio; uplink capacity is
        ``n_senders / oversubscription``.
    :param flow_size: size of every non-critical flow.
    :param critical_flow_size: size of the critical flow ``f0``
        (default: ``flow_size``).  Making it *larger* than the rest is
        the configuration that separates DAG-aware from DAG-blind
        schedulers: smallest-bottleneck-first coflow ordering then
        schedules the critical flow *last* (it only sees bytes), while
        slack-driven co-scheduling still sends it first.
    :param critical_compute: duration of the compute fed by ``f0``.
    :param other_compute: duration of every other consumer.
    :param job: job label stamped on every task.
    :param placed: ``False`` keeps the data where it lives (flow
        sources stay on the rack-0 senders) but leaves the consuming
        compute tasks — and hence the flow destinations — logical: a
        placement-aware scheduler may pull the consumers into rack 0
        and never cross the oversubscribed core at all.
    :returns: ``(graph, cluster)``.
    """
    rack0 = [f"s{i}" for i in range(n_senders)]
    rack1 = [f"d{i}" for i in range(n_senders)]
    topo = Topology.two_tier([rack0, rack1],
                             oversubscription=oversubscription)
    g = MXDAG(f"fanin{n_senders}_{oversubscription:g}to1")
    for i in range(n_senders):
        fsize = critical_flow_size if i == 0 \
            and critical_flow_size is not None else flow_size
        f = g.add(flow(f"f{i}", fsize, f"s{i}",
                       f"d{i}" if placed else None, job=job))
        size = critical_compute if i == 0 else other_compute
        c = g.add(compute(f"c{i}", size,
                          f"d{i}" if placed else None, job=job))
        g.add_edge(f, c)
    return g, Cluster.from_topology(topo)


# ----------------------------------------------------------------------
# fat-tree cross-pod shuffle (placement/routing demonstration scenario)
# ----------------------------------------------------------------------
def fat_tree_shuffle(k: int = 8, *, stride: int = 2,
                     map_time: float = 1.0, reduce_time: float = 1.0,
                     shuffle_bytes: float = 1.0,
                     placed: bool = True) -> tuple[MXDAG, Cluster]:
    """Sparse cross-pod shuffle on a full-bisection ``fat_tree(k)``.

    The first ``k³/32`` hosts (exactly pod 0 for ``k=8``: hosts 0..15)
    run mappers; each mapper i shuffles
    ``shuffle_bytes`` split over ``stride`` flows to reducers
    ``i..i+stride-1`` (mod n) on the *next* ``k²/8`` hosts.  Sparse
    shuffles make the fabric, not the NICs, the binding constraint:
    static ECMP hashes several large flows onto the same core link
    (deterministically — crc32), halving their rates, while every NIC
    carries exactly ``shuffle_bytes``.  ``placed=False`` leaves the
    reducers logical: a placement-aware scheduler pulls each reducer
    next to its mappers and never pays the core collisions.

    :param k: fat-tree arity (``k³/4`` hosts, ``k³/32`` mappers).
    :param stride: flows per mapper (shuffle sparsity).
    :param map_time: each mapper's compute time.
    :param reduce_time: each reducer's compute time.
    :param shuffle_bytes: total bytes each mapper emits, split evenly
        over its ``stride`` flows.
    :param placed: ``False`` leaves the reducers logical (see above).
    :returns: ``(graph, cluster)``.
    """
    if stride < 1:
        raise ValueError("stride must be >= 1")
    topo = Topology.fat_tree(k)
    hosts = topo.hosts()
    n = len(hosts) // 8
    g = MXDAG(f"ft{k}_shuffle_s{stride}" + ("" if placed else "_logical"))
    senders, receivers = hosts[:n], hosts[n:2 * n]
    reduces = [g.add(compute(f"r{j}", reduce_time,
                             receivers[j] if placed else None))
               for j in range(n)]
    for i, s in enumerate(senders):
        m = g.add(compute(f"m{i}", map_time, s))
        for jj in range(stride):
            j = (i + jj) % n
            f = g.add(flow(f"s{i}_{j}", shuffle_bytes / stride, s,
                           receivers[j] if placed else None))
            g.add_edge(m, f)
            g.add_edge(f, reduces[j])
    return g, Cluster.from_topology(topo)


# ----------------------------------------------------------------------
# deep serial chain (recursion-depth / event-trickle stress scenario)
# ----------------------------------------------------------------------
def serial_chain(n_tasks: int, *, size: float = 1.0, host: str = "H",
                 pipelined: bool = False, unit: Optional[float] = None,
                 job: str = "job0") -> MXDAG:
    """A single path of ``n_tasks`` compute tasks on one host.

    The degenerate DAG shape that stresses depth-sensitive code:
    recursive path enumeration (``paths_between``/``copaths`` crashed
    with RecursionError beyond ~1000 tasks before being rewritten
    iteratively), the analytic passes' level count (one task per
    level), and the DES event trickle (every completion is its own
    event — the regime the ddl builder hits at 1024 layers).
    """
    if n_tasks < 1:
        raise ValueError("need n_tasks >= 1")
    g = MXDAG(f"chain{n_tasks}")
    prev = None
    for i in range(n_tasks):
        t = g.add(compute(f"t{i:06d}", size, host, unit=unit, job=job))
        if prev is not None:
            g.add_edge(prev, t, pipelined=pipelined)
        prev = t
    return g


# ----------------------------------------------------------------------
# Graphene-style random layered DAG (cluster-scale synthetic workload)
# ----------------------------------------------------------------------
def random_layered(n_tasks: int = 20000, *, n_hosts: int = 256,
                   min_width: int = 64, max_width: int = 256,
                   fanout: int = 2, seed: int = 0,
                   job: str = "job0", name: Optional[str] = None,
                   host_prefix: str = "h") -> MXDAG:
    """Random layered MXDAG of roughly ``n_tasks`` tasks (Graphene scale).

    Graphene ("Do the Hard Stuff First", Grandl et al.) schedules
    production DAGs with tens of thousands of vertices; this generator
    produces comparable synthetic inputs: a chain of stages whose widths
    and task sizes are drawn from a seeded RNG, where every task reads
    from ``fanout`` tasks of the previous stage through an explicit
    shuffle flow.  The randomness is *stage-structured*, mirroring
    production DAGs: each layer draws its width (within
    ``[min_width, max_width]``), one compute size and one flow size
    (stages run many clones of one task), and a random rotation of the
    strided producer→consumer shuffle — rather than sampling every edge
    independently, which would desynchronize every flow completion into
    its own rate-reallocation event and bears no resemblance to staged
    cluster jobs.  Tasks are spread over ``n_hosts`` hosts (one CPU slot
    each); the graph is a pure function of its arguments.

    Stage widths follow production shape: jobs start wide (ingest) and
    narrow through aggregation stages, with occasional re-expansions
    (a new wide input joining).  Mostly non-increasing widths also keep
    the simulation event-dense rather than event-degenerate: a stage no
    wider than its producer keeps per-consumer fan-in the binding
    constraint, so stage flows finish in a bounded number of waves
    instead of splintering into per-flow completion events.

    Total task count is computes + flows ≈ ``n_tasks`` (one compute
    contributes ``1 + fanout`` tasks beyond the first layer).

    :param n_tasks: approximate total task count (computes + flows).
    :param n_hosts: hosts to spread tasks over (one CPU slot each).
    :param min_width: narrowest stage width (computes per layer).
    :param max_width: widest stage width; also the first layer's width.
    :param fanout: producers each consumer reads from (flows per task).
    :param seed: RNG seed — the graph is a pure function of arguments.
    :param job: job label stamped on every task.
    :param name: when set, names the graph and prefixes every task name
        with ``"{name}."`` (multi-job uniqueness, as in :func:`ddl`).
    :param host_prefix: hosts are ``f"{host_prefix}{i}"`` — lets small
        layered jobs land on a shared pool's hosts.
    :returns: the layered MXDAG.
    """
    if n_tasks < 2 or fanout < 1 or min_width < 1 \
            or max_width < min_width or n_hosts < max_width:
        raise ValueError("need n_tasks >= 2, fanout >= 1, "
                         "1 <= min_width <= max_width <= n_hosts")
    rng = random.Random(seed)
    pre = f"{name}." if name else ""
    g = MXDAG(name or f"layered{n_tasks}_s{seed}")
    hosts = [f"{host_prefix}{i}" for i in range(n_hosts)]
    prev: list[MXTask] = []
    total = 0
    layer = 0
    width = 0
    while total < n_tasks:
        if not prev or rng.random() < 0.15:
            width = max_width                    # ingest / re-expansion
        elif rng.random() < 0.5:
            pass                                 # plateau: width persists
        else:
            width = rng.randint(min_width, width)   # aggregation narrows
        csize = round(rng.uniform(0.5, 2.0), 6)
        fsize = round(rng.uniform(0.25, 1.0), 6)
        rot = rng.randrange(len(prev)) if prev else 0
        cur: list[MXTask] = []
        for i in range(width):
            if total >= n_tasks:
                break
            c = g.add(compute(f"{pre}L{layer}c{i}", csize, hosts[i],
                              job=job))
            total += 1
            cur.append(c)
            if prev:
                for j in range(min(fanout, len(prev))):
                    k = (rot + i * fanout + j) % len(prev)
                    p = prev[k]
                    f = g.add(flow(f"{pre}L{layer}c{i}f{k}", fsize,
                                   p.host, c.host, job=job))
                    total += 1
                    g.add_edge(p, f)
                    g.add_edge(f, c)
        if not cur:
            break
        prev = cur
        layer += 1
    return g


# ----------------------------------------------------------------------
# generic map-reduce generator (used by tests/benchmarks beyond the paper)
# ----------------------------------------------------------------------
def mapreduce(name: str, n_map: int, n_reduce: int, *,
              map_time: float = 1.0, shuffle_time: float = 1.0,
              reduce_time: float = 1.0, hosts_per_side: int | None = None,
              unit_frac: Optional[float] = None, job: str | None = None,
              host_prefix: str | None = None,
              placed: bool = True) -> MXDAG:
    """n_map mappers shuffling all-to-all into n_reduce reducers.

    :param name: graph name and default job label / host prefix.
    :param n_map: number of mappers.
    :param n_reduce: number of reducers.
    :param map_time: each mapper's compute time.
    :param shuffle_time: total bytes each mapper emits (split evenly
        over its ``n_reduce`` flows).
    :param reduce_time: each reducer's compute time.
    :param hosts_per_side: wrap mappers/reducers onto this many hosts
        per side (default: one host per task).
    :param unit_frac: when set, every task gets ``unit = unit_frac *
        size`` (enables pipelining experiments).
    :param job: job label; defaults to ``name``.
    :param host_prefix: lets multiple jobs share the same physical
        hosts (multi-job scheduling experiments); default: per-job
        private hosts.
    :param placed: ``False`` leaves every compute task logical and
        every shuffle flow's endpoints unbound (they follow their
        mapper/reducer via ``MXDAG.bind`` inference) — the scheduler
        chooses the hosts.
    :returns: the shuffle MXDAG.
    """
    job = job or name
    hp = host_prefix if host_prefix is not None else name
    g = MXDAG(name)
    nm_hosts = hosts_per_side or n_map
    nr_hosts = hosts_per_side or n_reduce

    def mh(i: int) -> str | None:
        """Mapper ``i``'s host (None when building logical tasks)."""
        return f"{hp}.M{i % nm_hosts}" if placed else None

    def rh(j: int) -> str | None:
        """Reducer ``j``'s host (None when building logical tasks)."""
        return f"{hp}.R{j % nr_hosts}" if placed else None

    maps = [g.add(compute(f"{name}.m{i}", map_time, mh(i), job=job,
                          unit=None if unit_frac is None
                          else unit_frac * map_time))
            for i in range(n_map)]
    reduces = [g.add(compute(f"{name}.r{j}", reduce_time, rh(j), job=job))
               for j in range(n_reduce)]
    for i, m in enumerate(maps):
        for j, r in enumerate(reduces):
            f = g.add(flow(f"{name}.s{i}_{j}", shuffle_time / n_reduce,
                           mh(i), rh(j), job=job,
                           unit=None if unit_frac is None
                           else unit_frac * shuffle_time / n_reduce))
            g.add_edge(m, f)
            g.add_edge(f, r)
    return g


# ----------------------------------------------------------------------
# online arrival stream (multi-job service workload source)
# ----------------------------------------------------------------------
JOB_SHAPES = ("mapreduce", "ddl", "fanin", "layered")


def pool_cluster(n_hosts: int = 8, *, host_prefix: str = "pool",
                 procs: Optional[dict] = None,
                 nic: float = 1.0) -> Cluster:
    """The shared host pool :func:`poisson_jobs` streams land on.

    ``2 * n_hosts`` homogeneous hosts — ``{host_prefix}.M{i}`` (mapper /
    worker side) and ``{host_prefix}.R{i}`` (reducer / parameter-server
    side) — each with a small CPU pool and one GPU slot (the ddl shape
    runs its BP/FP chain on a GPU).

    :param n_hosts: hosts per side.
    :param host_prefix: must match the stream's ``host_prefix``.
    :param procs: per-host processor pools (default
        ``{"cpu": 4, "gpu": 1}``).
    :param nic: per-direction NIC bandwidth.
    :returns: the homogeneous cluster.
    """
    hosts = [f"{host_prefix}.M{i}" for i in range(n_hosts)] \
        + [f"{host_prefix}.R{i}" for i in range(n_hosts)]
    return Cluster.homogeneous(hosts, procs=procs or {"cpu": 4, "gpu": 1},
                               nic=nic)


def poisson_jobs(rate: float, horizon: float, seed: int = 0, *,
                 mix: Sequence[str] = JOB_SHAPES, n_hosts: int = 8,
                 host_prefix: str = "pool",
                 ) -> list[tuple[float, MXDAG]]:
    """Seeded Poisson arrival stream of small jobs on one shared pool.

    Inter-arrival gaps are ``Exp(rate)``; each arrival draws a shape
    uniformly from ``mix`` and sizes it from the same seeded RNG, so the
    stream is a pure function of its arguments — the online benchmark
    and the admission tests share one reproducible workload source.
    Shapes (all on :func:`pool_cluster`'s hosts, so concurrent jobs
    contend for the same NICs and processor pools):

    - ``"mapreduce"`` — a small all-to-all shuffle (2–4 × 2–4);
    - ``"fanin"`` — 4–8 mappers aggregating into one long reducer on
      ``{host_prefix}.R0`` (the oversubscribed aggregation hot spot);
    - ``"ddl"`` — a 2–5 layer training iteration on one worker/PS pair;
    - ``"layered"`` — a 24–48 task random layered DAG over the mapper
      side.

    Task names are prefixed with the per-arrival job name
    (``j00017m`` …), so any subset of the stream merges collision-free.

    :param rate: mean arrivals per unit time.
    :param horizon: stop drawing arrivals at this time.
    :param seed: stream RNG seed.
    :param mix: shapes to draw from (subset of :data:`JOB_SHAPES`).
    :param n_hosts: pool hosts per side (match :func:`pool_cluster`).
    :param host_prefix: pool host name prefix.
    :returns: ``[(arrival_time, graph), ...]`` in arrival order.
    """
    if rate <= 0 or horizon <= 0:
        raise ValueError("need rate > 0 and horizon > 0")
    if not mix or any(s not in JOB_SHAPES for s in mix):
        raise ValueError(f"mix must be a non-empty subset of "
                         f"{JOB_SHAPES}, got {mix!r}")
    rng = random.Random(seed)
    out: list[tuple[float, MXDAG]] = []
    t = 0.0
    i = 0
    while True:
        t += rng.expovariate(rate)
        if t >= horizon:
            break
        shape = mix[rng.randrange(len(mix))]
        nm = f"j{i:05d}{shape[0]}"
        if shape == "mapreduce":
            g = mapreduce(nm, rng.randint(2, 4), rng.randint(2, 4),
                          map_time=round(rng.uniform(0.5, 2.0), 6),
                          shuffle_time=round(rng.uniform(0.5, 2.0), 6),
                          reduce_time=round(rng.uniform(0.25, 1.0), 6),
                          hosts_per_side=n_hosts,
                          host_prefix=host_prefix, job=nm)
        elif shape == "fanin":
            g = mapreduce(nm, rng.randint(4, 8), 1,
                          map_time=round(rng.uniform(0.25, 1.0), 6),
                          shuffle_time=round(rng.uniform(1.0, 2.0), 6),
                          reduce_time=round(rng.uniform(2.0, 4.0), 6),
                          hosts_per_side=n_hosts,
                          host_prefix=host_prefix, job=nm)
        elif shape == "ddl":
            k = rng.randrange(n_hosts)
            g = ddl(rng.randint(2, 5), name=nm, job=nm,
                    worker=f"{host_prefix}.M{k}",
                    ps=f"{host_prefix}.R{k}",
                    bp=round(rng.uniform(0.25, 1.0), 6),
                    fp=round(rng.uniform(0.25, 1.0), 6),
                    push=round(rng.uniform(0.5, 1.5), 6),
                    pull=round(rng.uniform(0.5, 1.5), 6))
        else:       # layered
            g = random_layered(rng.randint(24, 48), name=nm, job=nm,
                               n_hosts=n_hosts, min_width=2,
                               max_width=min(4, n_hosts), fanout=2,
                               seed=rng.randrange(1 << 30),
                               host_prefix=f"{host_prefix}.M")
        out.append((t, g))
        i += 1
    return out
