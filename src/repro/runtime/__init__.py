from repro.runtime.fault import (
    LoopConfig, SimulatedFailure, StepMonitor, StragglerReport, run_training,
)

__all__ = ["LoopConfig", "SimulatedFailure", "StepMonitor",
           "StragglerReport", "run_training"]
