from repro.checkpoint.ckpt import (
    all_steps, latest_step, read_meta, restore, save, save_async,
)

__all__ = ["save", "save_async", "restore", "latest_step", "all_steps",
           "read_meta"]
