"""Deterministic synthetic data pipeline.

Produces a *learnable* token stream (per-sample affine progressions
``tok_t = (phase + stride·t) mod V`` mixed with noise tokens) so the
end-to-end training example exhibits real loss descent, while remaining
fully deterministic in (seed, step) — a restart from a checkpoint resumes
the exact same stream (fault-tolerance requirement), and each (host,
data-shard) can materialize only its slice (multi-pod requirement).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise_prob: float = 0.05


class SyntheticLM:
    """Stateless-per-step synthetic LM stream: ``batch_at(step)``."""

    def __init__(self, cfg: DataConfig,
                 sharding: Optional[jax.sharding.NamedSharding] = None):
        self.cfg = cfg
        self.sharding = sharding

    def _host_batch(self, step: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab_size
        phase = rng.integers(0, V, size=(B, 1))
        stride = rng.integers(1, min(V - 1, 64), size=(B, 1))
        t = np.arange(S)[None, :]
        toks = (phase + stride * t) % V
        noise = rng.random((B, S)) < cfg.noise_prob
        toks = np.where(noise, rng.integers(0, V, size=(B, S)), toks)
        return toks.astype(np.int32)

    def batch_at(self, step: int) -> dict:
        toks_np = self._host_batch(step)
        if self.sharding is not None:
            toks = jax.make_array_from_callback(
                toks_np.shape, self.sharding,
                lambda idx: toks_np[idx])
        else:
            toks = jnp.asarray(toks_np)
        return {"tokens": toks}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
