"""CI perf-regression gate: diff a bench JSON against a committed baseline.

Usage::

    python benchmarks/check_perf.py bench.json benchmarks/baseline.json

Compares every wall-time row (``micro.*`` / ``scale.*`` names ending in
``_us``) present in both files and fails (exit 1) when any row regressed
by more than ``--threshold`` (default 2x).  Rows under ``--floor-us``
(default 50µs) are ignored — at that scale the timer and allocator noise
on shared CI runners dwarfs any real regression.  Rows named
``*.ref_match`` must equal 1.0 (the event-calendar core diverged from the
reference slow path — a correctness failure, not a perf one), as must rows
named ``*.improves`` (a scheduling decision — e.g. placement on the
fat-tree shuffle — stopped beating its fixed baseline).

Speed-ups are reported but never fail the gate; refresh the baseline by
committing the new bench JSON when an intentional optimisation lands.
"""
from __future__ import annotations

import argparse
import json
import sys


def load_rows(path: str) -> dict[str, float]:
    with open(path) as f:
        data = json.load(f)
    return {r["name"]: float(r["value"]) for r in data}


def gated(name: str) -> bool:
    # *_seed_us rows time the frozen seed implementation: informational
    # (their drift tracks runner speed, not a code regression), and
    # optional (the sweep skips them under --no-seed).
    return (name.startswith(("micro.", "scale."))
            and name.endswith("_us")
            and not name.endswith("_seed_us"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("bench", help="freshly produced bench JSON")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("--threshold", type=float, default=2.0,
                    help="fail on wall-time regressions beyond this "
                         "factor (default 2x)")
    ap.add_argument("--floor-us", type=float, default=50.0,
                    help="ignore rows faster than this in the baseline")
    args = ap.parse_args(argv)

    bench = load_rows(args.bench)
    base = load_rows(args.baseline)

    failures = []
    for name in sorted(base):
        if name.endswith(".ref_match"):
            if name not in bench:
                failures.append(f"{name}: equivalence row missing from "
                                f"bench output (check never ran)")
            elif bench[name] != 1.0:
                failures.append(f"{name}: event-calendar core diverged "
                                f"from the reference slow path")
            continue
        if name.endswith(".improves"):
            if name not in bench:
                failures.append(f"{name}: claim row missing from bench "
                                f"output (check never ran)")
            elif bench[name] != 1.0:
                failures.append(f"{name}: decision no longer beats its "
                                f"fixed baseline")
            continue
        if not gated(name) or name not in bench:
            continue
        old, new = base[name], bench[name]
        if old < args.floor_us:
            continue
        ratio = new / old if old > 0 else float("inf")
        marker = ""
        if ratio > args.threshold:
            marker = "  <-- REGRESSION"
            failures.append(f"{name}: {old:.0f}us -> {new:.0f}us "
                            f"({ratio:.2f}x > {args.threshold:g}x)")
        print(f"{name}: {old:.0f}us -> {new:.0f}us ({ratio:.2f}x){marker}")

    missing = [n for n in base
               if gated(n) and n not in bench]
    if missing:
        failures.append(f"rows missing from bench output: {missing}")

    if failures:
        print("\nperf gate FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("\nperf gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
