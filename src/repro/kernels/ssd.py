"""Mamba2 SSD intra-chunk kernel (Pallas TPU).

The chunked SSD algorithm's dominant cost is the intra-chunk quadratic
term: per (batch, head, chunk), with chunk length Q, head dim P and state
dim N —

    cum   = cumsum(dt·A)                          [Q]
    L     = exp(segsum(dt·A)) (lower-triangular)  [Q,Q]
    y     = ((C Bᵀ) ∘ L) (x·dt)                   [Q,P]
    state = (B · exp(cum[-1]−cum))ᵀ (x·dt)        [N,P]  (chunk's state
                                                   contribution)

The whole chunk fits VMEM (Q≤256, P=64, N≤128 ⇒ < 1 MiB fp32), so one
grid step = one (b, h, chunk) tile; group→head broadcast of B/C happens
in the BlockSpec index_map (no repeat materialized).  The linear
inter-chunk recurrence stays outside (a length-nc ``lax.scan`` on
[B,H,P,N] — negligible FLOPs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref,
                y_ref, state_ref, cum_ref):
    Q, P = x_ref.shape[2], x_ref.shape[3]
    N = b_ref.shape[3]
    f32 = jnp.float32

    x = x_ref[0, 0].astype(f32)                    # [Q,P]
    dt = dt_ref[0, 0].astype(f32)                  # [Q]
    A = a_ref[0].astype(f32)                       # scalar (per head)
    Bm = b_ref[0, 0].astype(f32)                   # [Q,N]
    Cm = c_ref[0, 0].astype(f32)                   # [Q,N]

    dA = dt * A                                    # [Q]
    cum = jnp.cumsum(dA)                           # [Q]
    seg = cum[:, None] - cum[None, :]              # [Q,Q]
    ii = jax.lax.iota(jnp.int32, Q)
    tril = ii[:, None] >= ii[None, :]
    Lmat = jnp.where(tril, jnp.exp(jnp.where(tril, seg, 0.0)), 0.0)

    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=f32)  # [Q,Q]
    xdt = x * dt[:, None]                          # [Q,P]
    y = jax.lax.dot(CB * Lmat, xdt, preferred_element_type=f32)

    decay_end = jnp.exp(cum[-1] - cum)             # [Q]
    state = jax.lax.dot_general(Bm * decay_end[:, None], xdt,
                                (((0,), (0,)), ((), ())),
                                preferred_element_type=f32)  # [N,P]

    y_ref[0, 0] = y.astype(y_ref.dtype)
    state_ref[0, 0] = state.astype(state_ref.dtype)
    cum_ref[0, 0] = cum.astype(cum_ref.dtype)


def ssd_intra_chunk(x: jax.Array, dt: jax.Array, A: jax.Array,
                    Bm: jax.Array, Cm: jax.Array, *,
                    interpret: bool = True):
    """x: [BH, nc, Q, P] (batch·heads flattened), dt: [BH, nc, Q],
    A: [BH], Bm/Cm: [BG, nc, Q, N] where BG = BH // heads_per_group
    collapsed the same way.  Group broadcast is expressed through the
    index_map using ``hpg`` = BH // BG.

    Returns (y_intra [BH,nc,Q,P], states [BH,nc,N,P], cum [BH,nc,Q]).
    """
    BH, nc, Q, P = x.shape
    BG, N = Bm.shape[0], Bm.shape[3]
    hpg = BH // BG

    grid = (BH, nc)
    return pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda h, c: (h, c, 0, 0)),
            pl.BlockSpec((1, 1, Q), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1,), lambda h, c: (h,)),
            pl.BlockSpec((1, 1, Q, N), lambda h, c: (h // hpg, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda h, c: (h // hpg, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda h, c: (h, c, 0, 0)),
            pl.BlockSpec((1, 1, N, P), lambda h, c: (h, c, 0, 0)),
            pl.BlockSpec((1, 1, Q), lambda h, c: (h, c, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, nc, Q, P), jnp.float32),
            jax.ShapeDtypeStruct((BH, nc, N, P), jnp.float32),
            jax.ShapeDtypeStruct((BH, nc, Q), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
