"""What-if analysis on a real workload MXDAG (paper §4.3).

Takes the deepseek-coder-33b training step at production scale (256
chips), builds its MXDAG from the roofline constants, and answers the
questions the paper says only MXDAG can answer:

1. would pipelining (chunking) the gradient flows help?  at what unit?
2. what if we re-partition (change TP) — does the network get better
   or worse?
3. which task would a straggler turn critical?

Run:  PYTHONPATH=src python examples/whatif_analysis.py
"""
import sys
sys.path.insert(0, "src")

from repro import configs
from repro.configs.base import SHAPES
from repro.core import Monitor, MXDAGScheduler, WhatIf
from repro.sync.plan import plan_sync, step_mxdag

cfg = configs.get("deepseek-coder-33b")
shape = SHAPES["train_4k"]

# 1. pipelining / chunking sweep ----------------------------------------
plan = plan_sync(cfg, shape)
print(f"{cfg.name} @ 256 chips, {shape.name}:")
print(f"  barrier sync predicted:  {plan.predicted_barrier:.3f} s/step")
print(f"  bucketed (MXDAG plan):   {plan.predicted_bucketed:.3f} s/step "
      f"(+{(plan.predicted_speedup - 1) * 100:.1f}%)")
print(f"  flow priority order: {plan.order[:5]}... "
      "(lower layers first == ByteScheduler, §4.1.1)")

g = step_mxdag(cfg, shape, n_layers=8, unit_frac=0.25)  # 8-layer slice
for i in range(8):                       # stream grads as BP produces them
    g.set_pipelined(f"BP{i}", f"push{i}", True)
    g.set_pipelined(f"push{i}", f"pull{i}", True)
w = WhatIf(g)
print("\n  unit-size sweep on the gradient flows (chunked collectives):")
for unit_frac in (1.0, 0.5, 0.25, 0.125):
    import dataclasses as _dc
    g2 = g.copy()
    for i in range(8):
        for t in (f"push{i}", f"pull{i}"):
            task = g2.tasks[t]
            g2.tasks[t] = _dc.replace(task, unit=task.size * unit_frac)
    ms = WhatIf(g2).baseline()
    print(f"    unit={unit_frac:>5}x  predicted JCT {ms:.4f} s")

# 2. repartition: what if TP were 8 instead of 16? ----------------------
plan8 = plan_sync(cfg, shape, tp=8)
print(f"\n  repartition tp=16 -> tp=8: bucketed "
      f"{plan.predicted_bucketed:.3f} -> {plan8.predicted_bucketed:.3f} "
      f"s/step")

# 3. straggler analysis (monitoring, §4.3) ------------------------------
sched = MXDAGScheduler(try_pipelining=False).schedule(g)
expected = sched.simulate()
mon = Monitor(g, expected)
# a network straggler: push3 at 10% progress well after it should be DONE
dur = expected.finish["push3"] - expected.start["push3"]
t_probe = expected.finish["push3"] + 2 * dur
mon.observe("push3", 0.1, t_probe)
stragglers = mon.stragglers()
print(f"\n  injected slow flow push3 -> monitor reports: "
      f"{[(s.task, s.kind.value) for s in stragglers]}")
print(f"  replanned critical path now runs through: "
      f"{[t for t in mon.replan_critical_path() if 'push' in t or 'pull' in t][:3]}")
print("  (MXDAG distinguishes network from host stragglers — the paper's"
      " monitoring claim)")
