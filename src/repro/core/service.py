"""Online multi-job service: admission, dispatch and live re-planning.

The paper's Principle 2 (§4.2) is altruism *across* jobs sharing a
cluster; this module turns the offline multi-job scheduler into a
service with a request stream (the ROADMAP "millions of users" path).
The front end follows the MDBconductor shape (SNIPPETS.md §3): for each
incoming DAG it estimates a footprint (isolated analytic critical path,
total compute work, total flow volume), grows the placement domain to
cover the job's hosts, and admits, queues or rejects based on the load
already conducted.  Admitted jobs are spliced into one live
:class:`~repro.core.arraysim.ResumableSim` session via
``admit_graph`` — the history is never re-simulated — and on every
admission and completion the altruistic priority classes are recomputed
over the currently-running jobs and swapped in with ``set_priorities``.
Finished jobs are retired from the engine so the hot arrays stay sized
to the running set, not the history.

Everything here is deterministic: the same arrival stream (e.g. from
:func:`repro.core.builders.poisson_jobs`) produces the same admission
log, the same JCTs and the same rejections, run after run.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

from repro.core import arrayanalytic
from repro.core.cluster import Cluster
from repro.core.graph import MXDAG
from repro.core.schedule import AltruisticMultiScheduler
from repro.core.simulator import Simulator
from repro.core.task import TaskKind

EPS = 1e-9

_POLICIES = ("altruistic", "fifo", "fair")


@dataclass
class JobStats:
    """Per-job service record: footprint estimate and observed times."""

    name: str
    submitted: float
    cp: float                 # isolated analytic critical path (seconds)
    work: float               # total compute seconds
    volume: float             # total flow volume (link-seconds)
    status: str = "queued"    # queued | running | done | rejected
    order: int = -1           # admission sequence number (-1 = never)
    admitted: Optional[float] = None
    finished: Optional[float] = None

    @property
    def jct(self) -> Optional[float]:
        """Completion time minus submission time (None while running)."""
        if self.finished is None:
            return None
        return self.finished - self.submitted

    @property
    def queue_delay(self) -> Optional[float]:
        """Admission time minus submission time (None if never admitted)."""
        if self.admitted is None:
            return None
        return self.admitted - self.submitted


def footprint(graph: MXDAG) -> tuple[float, float, float]:
    """Estimate a job's resource footprint from its isolated analytics.

    Returns ``(cp, work, volume)``: the analytic critical-path length
    (the job's lower-bound running time alone on the cluster), the total
    compute seconds and the total flow volume.  This is the
    MDBconductor move — size the request before picking where (and
    whether) to run it — computed from the same compiled forward pass
    the altruistic scheduler uses, so the estimate is free when the job
    is later admitted (the pass is memoized per graph version).
    """
    cp = arrayanalytic.analyze(graph).makespan if graph.tasks else 0.0
    work = 0.0
    volume = 0.0
    for t in graph.tasks.values():
        if t.kind is TaskKind.COMPUTE:
            work += t.size
        else:
            volume += t.size
    return cp, work, volume


def _job_hosts(graph: MXDAG) -> set:
    """Hosts a bound job touches (compute placements + flow endpoints)."""
    hosts = set()
    for t in graph.tasks.values():
        if t.kind is TaskKind.COMPUTE:
            if t.host is not None:
                hosts.add(t.host)
        else:
            if t.src is not None:
                hosts.add(t.src)
            if t.dst is not None:
                hosts.add(t.dst)
    return hosts


def _quantile(sorted_xs: list, q: float) -> float:
    """Nearest-rank quantile of an ascending list (0 on empty)."""
    if not sorted_xs:
        return 0.0
    k = max(0, min(len(sorted_xs) - 1,
                   math.ceil(q * len(sorted_xs)) - 1))
    return sorted_xs[k]


class AdmissionService:
    """MDBconductor-style front end over a live :class:`ResumableSim`.

    Jobs are submitted as ``(graph, at)`` in non-decreasing time order.
    Each submission sizes the job (:func:`footprint`), grows the
    placement domain to its hosts, and either admits it into the running
    engine (``admit_graph`` at the arrival time), parks it in a bounded
    FIFO queue when the cluster is over ``max_backlog`` of estimated
    critical-path work, or rejects it outright when the queue is full
    (or the job alone exceeds the backlog budget and so could never be
    admitted).  Queued jobs are re-considered, in order, at every job
    completion.  After every admission and completion the priority
    classes are recomputed per ``policy`` and swapped in live:

    - ``"altruistic"`` — :class:`AltruisticMultiScheduler` over the
      running jobs (Principle 2 demotion, compiled passes);
    - ``"fifo"`` — strict admission-order classes (earlier job wins
      every resource conflict);
    - ``"fair"`` — no classes, plain max-min fair sharing.

    The whole pipeline is deterministic for a given arrival stream; the
    admission log is exposed as :attr:`log` for exactly that test.
    """

    def __init__(self, cluster: Cluster, *,
                 policy: str = "altruistic",
                 analytic: str = "auto",
                 max_backlog: float = math.inf,
                 queue_limit: Optional[int] = None,
                 batch: bool = True,
                 horizon: float = 1e15):
        """:param cluster: the shared cluster every job runs on.
        :param policy: ``"altruistic"`` | ``"fifo"`` | ``"fair"``
            re-prioritisation run on each admission/completion.
        :param analytic: substrate for the altruistic passes
            (forwarded to :class:`AltruisticMultiScheduler`).
        :param max_backlog: admission budget in estimated critical-path
            seconds; a job is queued while the running backlog plus its
            own critical path exceeds this.  ``inf`` = admit always.
        :param queue_limit: queued jobs beyond this are rejected
            (``None`` = unbounded queue).
        :param batch: forwarded to ``Simulator.resumable``.
        :param horizon: forwarded to ``Simulator.resumable``.
        """
        if policy not in _POLICIES:
            raise ValueError(f"unknown service policy {policy!r}; "
                             f"pick one of {_POLICIES}")
        self.cluster = cluster
        self.policy = policy
        self.max_backlog = float(max_backlog)
        self.queue_limit = queue_limit
        self.stats: dict[str, JobStats] = {}
        self.domain: set = set()
        self.log: list[tuple] = []
        self.restarted: list[str] = []
        self._scheduler = AltruisticMultiScheduler(analytic=analytic)
        self._batch = bool(batch)
        self._horizon = float(horizon)
        self._rs = None
        self._graphs: dict[str, MXDAG] = {}     # admitted, not retired
        self._active: list[str] = []            # admitted, unfinished
        self._zombies: list[str] = []           # finished, not retired
        self._queue: list[str] = []             # waiting, FIFO
        self._revives: list[tuple] = []         # (t, host), time-sorted
        self._seq = 0

    # -- introspection -------------------------------------------------
    @property
    def now(self) -> float:
        """The service clock (the engine's paused clock; 0 if idle)."""
        return self._rs.now if self._rs is not None else 0.0

    @property
    def running(self) -> list[str]:
        """Names of admitted, unfinished jobs (admission order)."""
        return list(self._active)

    @property
    def queued(self) -> list[str]:
        """Names of jobs waiting for admission (FIFO order)."""
        return list(self._queue)

    def backlog(self, at: Optional[float] = None) -> float:
        """Estimated critical-path seconds still in flight at ``at``:
        per running job, the optimistic remainder
        ``max(0, admitted + cp - at)``."""
        t = self.now if at is None else at
        total = 0.0
        for name in self._active:
            s = self.stats[name]
            total += max(0.0, s.admitted + s.cp - t)
        return total

    # -- the request path ----------------------------------------------
    def submit(self, graph: MXDAG, at: float) -> str:
        """Offer a job to the service at time ``at``.

        Advances the engine to ``at`` first (reaping completions, which
        may drain the queue), then admits, queues or rejects per the
        backlog budget.  Returns ``"admitted"``, ``"queued"`` or
        ``"rejected"``.
        """
        name = graph.name
        if name in self.stats:
            raise ValueError(f"duplicate job name {name!r}")
        jobs = {t.job for t in graph.tasks.values()}
        if jobs != {name}:
            raise ValueError(
                f"job {name!r}: every task's job field must equal the "
                f"graph name (got {sorted(jobs)}); pass job={name!r} to "
                f"the builder so retire_job can find the rows")
        at = float(at)
        if at < self.now - EPS:
            raise ValueError(f"submissions must arrive in time order "
                             f"(t={at} < clock {self.now})")
        self._advance(at)
        cp, work, volume = footprint(graph)
        self.stats[name] = JobStats(name=name, submitted=at, cp=cp,
                                    work=work, volume=volume)
        self._graphs[name] = graph
        if not self._queue and self._fits(cp, at):
            self._admit(name, at)
            verdict = "admitted"
        elif cp <= self.max_backlog and (
                self.queue_limit is None
                or len(self._queue) < self.queue_limit):
            self._queue.append(name)
            verdict = "queued"
        else:
            self.stats[name].status = "rejected"
            del self._graphs[name]
            verdict = "rejected"
        self.log.append(("submit", at, name, verdict))
        return verdict

    def kill_host(self, host: str, at: float, *,
                  downtime: Optional[float] = None) -> list:
        """Fail ``host`` at time ``at`` mid-stream: advance to ``at``,
        kill it on the live engine, and re-plan the survivors (the
        recovery-drill hook — jobs keep arriving afterwards).  With
        ``downtime`` the host reboots (``revive_host``) that many
        seconds later; without it the host stays dark, so every job
        bound to it deadlocks — pass a downtime unless the stream
        avoids the host.  Returns the restarted task names."""
        at = float(at)
        self._advance(at)
        restarted = self._rs.kill_host(host) if self._rs is not None \
            else []
        self.restarted.extend(restarted)
        self.log.append(("kill", at, host, len(restarted)))
        if downtime is not None and self._rs is not None:
            self._revives.append((at + float(downtime), host))
            self._revives.sort(key=lambda e: e[0])
        self._replan()
        return restarted

    def finish(self):
        """Drain the engine and the queue to completion; returns self."""
        self._advance(math.inf)
        if self._queue:
            raise RuntimeError(
                f"stream drained with {len(self._queue)} jobs still "
                f"queued — max_backlog too small for the workload")
        return self

    # -- results -------------------------------------------------------
    def jcts(self) -> dict[str, float]:
        """Observed JCT per completed job."""
        return {n: s.jct for n, s in self.stats.items()
                if s.finished is not None}

    def summary(self) -> dict:
        """Aggregate service metrics (the online-benchmark row source):
        submitted/completed/rejected counts, rejection rate, throughput
        (jobs per unit time over the span), and mean/p50/p99 JCT."""
        done = sorted(s.jct for s in self.stats.values()
                      if s.finished is not None)
        n_sub = len(self.stats)
        n_rej = sum(1 for s in self.stats.values()
                    if s.status == "rejected")
        span = max((s.finished for s in self.stats.values()
                    if s.finished is not None), default=0.0)
        return {
            "submitted": n_sub,
            "completed": len(done),
            "rejected": n_rej,
            "rejection_rate": n_rej / n_sub if n_sub else 0.0,
            "makespan": span,
            "throughput": len(done) / span if span > 0 else 0.0,
            "mean_jct": sum(done) / len(done) if done else 0.0,
            "p50_jct": _quantile(done, 0.50),
            "p99_jct": _quantile(done, 0.99),
        }

    # -- internals -----------------------------------------------------
    def _fits(self, cp: float, at: float) -> bool:
        return self.backlog(at) + cp <= self.max_backlog + EPS

    def _grow(self, graph: MXDAG) -> None:
        hosts = _job_hosts(graph)
        unknown = hosts - set(self.cluster.hosts)
        if unknown:
            raise KeyError(
                f"job {graph.name!r} is bound to hosts outside the "
                f"cluster: {sorted(unknown)}")
        grown = hosts - self.domain
        if grown:
            self.domain |= grown
            self.log.append(("grow", self.now, graph.name,
                             tuple(sorted(grown))))

    def _admit(self, name: str, at: float) -> None:
        graph = self._graphs[name]
        self._grow(graph)
        s = self.stats[name]
        s.status = "running"
        s.admitted = at
        s.order = self._seq
        self._seq += 1
        if self._rs is None or at <= 0.0:
            # First job, or an admission at t=0 (where admit_graph has
            # no pre-history to preserve): (re)build the engine over the
            # merged running set with each job released at its admission
            # time — bit-identical to the spliced path by the
            # admit_graph invariant.
            self._active.append(name)
            graphs = [self._graphs[j] for j in self._active]
            merged = AltruisticMultiScheduler._merge(graphs) \
                if len(graphs) > 1 else graphs[0]
            rel = {}
            for j in self._active:
                tj = self.stats[j].admitted
                if tj and tj > 0.0:
                    rel.update({nm: tj for nm in self._graphs[j].tasks})
            sim = Simulator(merged, self.cluster, releases=rel)
            self._rs = sim.resumable(self._horizon, batch=self._batch)
        else:
            self._rs.admit_graph(graph, at=at)
            self._active.append(name)
            self._retire_zombies()
        self.log.append(("admit", at, name))
        self._replan()

    def _retire_zombies(self) -> None:
        # retire_job refuses to empty the engine, so zombies are
        # reaped lazily, right after the next admission.
        while self._zombies and len(self._graphs) > 1:
            z = self._zombies.pop(0)
            self._rs.retire_job(z)
            del self._graphs[z]

    def _replan(self) -> None:
        if self._rs is None or not self._active:
            return
        if self.policy == "fair":
            self._rs.set_priorities({}, "fair")
            self._rs._ops["settle"]()
            return
        if self.policy == "fifo":
            prio = {}
            for j in self._active:
                rank = float(self.stats[j].order)
                for nm in self._graphs[j].tasks:
                    prio[nm] = rank
        else:
            graphs = [self._graphs[j] for j in self._active]
            prio = self._scheduler.schedule(graphs,
                                            self.cluster).priorities
        self._rs.set_priorities(prio, "priority")
        # settle immediately: peek_next does not, and an unsettled
        # re-prioritisation can move the next event earlier
        self._rs._ops["settle"]()

    def _advance(self, t: float) -> None:
        if self._rs is None:
            return
        while True:
            # re-read the handle every iteration: a _reap below can
            # admit a queued job, and admit_graph swaps the engine
            rs = self._rs
            tn = rs._ops["peek"]()
            if self._revives and self._revives[0][0] <= t \
                    and (tn is None or self._revives[0][0] <= tn):
                tr, host = self._revives.pop(0)
                if tr > rs.now:
                    rs.advance_to(tr)
                rs.revive_host(host)
                rs._ops["settle"]()
                self.log.append(("revive", tr, host))
                continue
            if tn is None or tn > t:
                break
            rs.run_until(tn)
            self._reap()
        if t is not math.inf and t > rs.now:
            rs.advance_to(t)
        assert rs is self._rs

    def _reap(self) -> None:
        rs = self._rs
        state = rs._ops["state"]()
        fin = state["finished"]
        idx = rs._idx
        now = state["now"]
        done = []
        for name in self._active:
            fins = [fin[idx[nm]] for nm in self._graphs[name].tasks]
            if all(f is not None for f in fins):
                done.append((name, max(fins)))
        if not done:
            return
        for name, t_done in done:
            s = self.stats[name]
            s.status = "done"
            s.finished = t_done
            self._active.remove(name)
            self._zombies.append(name)
            self.log.append(("done", t_done, name))
        self._replan()
        while self._queue and self._fits(self.stats[self._queue[0]].cp,
                                         now):
            self._admit(self._queue.pop(0), now)


def run_stream(cluster: Cluster, arrivals, *,
               policy: str = "altruistic",
               faults=(), fault_downtime: float = 1.0,
               **kwargs) -> AdmissionService:
    """Feed a ``[(t, graph), ...]`` arrival stream (and optional
    ``[(t, host), ...]`` host-kill faults, each rebooting after
    ``fault_downtime``) through an :class:`AdmissionService` and drain
    it; returns the service with its stats populated.  The one-call
    entry the online benchmark, the determinism tests and the recovery
    drill all share."""
    svc = AdmissionService(cluster, policy=policy, **kwargs)
    events = sorted(
        [(float(t), 0, i, g) for i, (t, g) in enumerate(arrivals)]
        + [(float(t), 1, i, h) for i, (t, h) in enumerate(faults)],
        key=lambda e: e[:3])
    for t, tag, _i, payload in events:
        if tag == 0:
            svc.submit(payload, at=t)
        else:
            svc.kill_host(payload, at=t, downtime=fault_downtime)
    return svc.finish()


def online_recovery_drill(cluster, arrivals, *, host: str, at: float,
                          downtime: float = 1.0,
                          policy: str = "altruistic", **kwargs) -> dict:
    """Smoke-level online fault drill: run the same arrival stream
    twice — clean, and with ``host`` failing at ``at`` (rebooting
    ``downtime`` later) while jobs keep arriving — and report the
    p99-JCT degradation and restart count.  Informational only (no
    gate): the live engine restarts the lost lineage and the service
    re-plans around the hole."""
    clean = run_stream(cluster, arrivals, policy=policy, **kwargs)
    hurt = run_stream(cluster, arrivals, policy=policy,
                      faults=[(at, host)], fault_downtime=downtime,
                      **kwargs)
    cs, hs = clean.summary(), hurt.summary()
    return {
        "clean_p99_jct": cs["p99_jct"],
        "fault_p99_jct": hs["p99_jct"],
        "degradation": (hs["p99_jct"] / cs["p99_jct"]
                        if cs["p99_jct"] > 0 else 1.0),
        "restarted": len(hurt.restarted),
        "clean_completed": cs["completed"],
        "fault_completed": hs["completed"],
    }
