"""MXDAG core: the paper's abstraction, calculus, schedulers and simulator."""
from repro.core.task import MXTask, TaskKind, compute, flow
from repro.core.graph import MXDAG, Edge, NodeTiming
from repro.core.fabric import Link, Topology
from repro.core.cluster import Cluster, Host
from repro.core.arraysim import vectorized_waterfill
from repro.core.simulator import SimResult, Simulator, max_min_rates, simulate
from repro.core.schedule import (
    AltruisticMultiScheduler,
    CoflowConfig,
    FairShareScheduler,
    MXDAGScheduler,
    PlacementScheduler,
    Schedule,
    auto_coflows,
)
from repro.core.baselines import (
    BASELINES,
    DependencyCoflowScheduler,
    GrapheneScheduler,
    MetaflowScheduler,
    SEBFScheduler,
)
from repro.core.service import (
    AdmissionService,
    JobStats,
    online_recovery_drill,
    run_stream,
)
from repro.core.whatif import WhatIf, WhatIfResult
from repro.core.monitor import Monitor, Straggler

__all__ = [
    "MXTask", "TaskKind", "compute", "flow",
    "MXDAG", "Edge", "NodeTiming",
    "Link", "Topology",
    "Cluster", "Host",
    "SimResult", "Simulator", "max_min_rates", "simulate",
    "vectorized_waterfill",
    "FairShareScheduler", "CoflowConfig", "MXDAGScheduler",
    "PlacementScheduler", "AltruisticMultiScheduler", "Schedule",
    "auto_coflows",
    "BASELINES", "SEBFScheduler", "DependencyCoflowScheduler",
    "GrapheneScheduler", "MetaflowScheduler",
    "AdmissionService", "JobStats", "run_stream",
    "online_recovery_drill",
    "WhatIf", "WhatIfResult", "Monitor", "Straggler",
]
