"""Fault-tolerant training runtime.

- periodic (optionally async) checkpointing with atomic rename,
- crash/restart: the loop resumes from the latest checkpoint, and the
  deterministic data pipeline replays the exact step's batch,
- failure injection hooks for tests (``fail_at_step``),
- straggler detection: per-step wall-time EWMA plus MXDAG-based
  attribution (§4.3 of the paper — compute vs network straggler) when a
  step MXDAG is provided,
- elastic restart: a new mesh shape reshards the restored state
  (checkpoint arrays are mesh-agnostic).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax

from repro.checkpoint import ckpt as ckpt_lib
from repro.core.graph import MXDAG
from repro.core.monitor import Monitor
from repro.core.simulator import SimResult


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class StragglerReport:
    step: int
    step_time: float
    ewma: float
    kind: str                  # "step-time" | "compute" | "network"
    detail: str = ""


class StepMonitor:
    """EWMA wall-time monitor; with an expected step MXDAG it attributes
    anomalies to compute vs network (paper §4.3)."""

    def __init__(self, *, alpha: float = 0.2, threshold: float = 1.5,
                 step_graph: Optional[MXDAG] = None,
                 expected: Optional[SimResult] = None):
        self.alpha = alpha
        self.threshold = threshold
        self.ewma: Optional[float] = None
        self.reports: list[StragglerReport] = []
        self.mxdag_monitor = (Monitor(step_graph, expected)
                              if step_graph is not None
                              and expected is not None else None)

    def record(self, step: int, seconds: float,
               task_progress: Optional[dict[str, float]] = None
               ) -> Optional[StragglerReport]:
        if self.ewma is None:
            self.ewma = seconds
            return None
        is_slow = seconds > self.threshold * self.ewma
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * seconds
        if not is_slow:
            return None
        kind, detail = "step-time", ""
        if self.mxdag_monitor is not None and task_progress:
            for task, frac in task_progress.items():
                self.mxdag_monitor.observe(task, frac, seconds)
            hosts = self.mxdag_monitor.host_stragglers()
            nets = self.mxdag_monitor.network_stragglers()
            if nets and (not hosts or nets[0].lag >= hosts[0].lag):
                kind, detail = "network", nets[0].task
            elif hosts:
                kind, detail = "compute", hosts[0].task
        rep = StragglerReport(step=step, step_time=seconds,
                              ewma=self.ewma, kind=kind, detail=detail)
        self.reports.append(rep)
        return rep


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 50
    ckpt_async: bool = False
    keep: int = 3
    fail_at_step: Optional[int] = None      # failure injection (tests)
    max_restarts: int = 3


def recovery_drill(schedule, cluster, *, faults=None, n_faults: int = 2,
                   seed: int = 0, probe_every: float = 0.5,
                   horizon: float = 1e9, campaign: str = "random",
                   cost_aware: bool = False) -> dict:
    """Game-day drill for a step schedule: inject faults into a live DES
    of the step MXDAG and measure recovery with vs without replanning.

    The runtime-side entry point to :mod:`repro.core.nemesis`: given the
    :class:`~repro.core.schedule.Schedule` of one training step (the
    same graph a :class:`StepMonitor` attributes stragglers on), it
    derives a seeded fault schedule (when ``faults`` is not given),
    runs the no-replan, replan, and cost-aware-replan arms, and returns
    a comparable summary — what an SRE would ask of the runtime before
    trusting it: *if a host dies mid-step, does the controller notice,
    and what does the step time become?*

    :param campaign: shape of the derived fault schedule when
        ``faults`` is not given — ``"random"`` (independent faults
        spread over the step, :func:`~repro.core.nemesis.random_faults`)
        or ``"storm"`` (distinct overlapping faults packed into a tight
        window, :func:`~repro.core.nemesis.fault_storm`; on a fabric
        cluster the storm mix also samples correlated ``rack_loss``
        blast-radius faults).
    :param cost_aware: run the *replan* arm with the cost-aware
        controller (analytic worth-it model, hysteresis, bounded
        speculation budget) instead of the always-act one; the
        always-act arm is still reported as ``replan`` and the chosen
        arm's makespan as ``cost_replan``.
    :returns: dict with ``no_replan``/``replan``/``cost_replan``
        makespans, the fault list, ``detection_rate``, ``recovered``,
        and the markdown recovery ``report``.
    """
    from repro.core.nemesis import (BASE_FAULT_KINDS, Nemesis,
                                    fault_storm, random_faults,
                                    tor_groups)

    expected = schedule.simulate(cluster)
    if faults is None:
        if campaign == "storm":
            kinds = BASE_FAULT_KINDS
            if tor_groups(cluster):
                kinds = kinds + ("rack_loss",)
            faults = fault_storm(schedule.graph, cluster,
                                 horizon=expected.makespan,
                                 n=n_faults, seed=seed, kinds=kinds)
        elif campaign == "random":
            faults = random_faults(schedule.graph, cluster,
                                   horizon=expected.makespan,
                                   n=n_faults, seed=seed)
        else:
            raise ValueError(f"unknown campaign {campaign!r} "
                             "(want 'random' or 'storm')")
    arm_no = Nemesis(schedule, cluster, faults=faults, replan=False,
                     probe_every=probe_every,
                     expected=expected).run(horizon)
    arm_yes = Nemesis(schedule, cluster, faults=faults, replan=True,
                      probe_every=probe_every,
                      expected=expected).run(horizon)
    arm_cost = (Nemesis(schedule, cluster, faults=faults, replan=True,
                        probe_every=probe_every, expected=expected,
                        cost_aware=True).run(horizon)
                if cost_aware else arm_yes)
    return {
        "baseline": expected.makespan,
        "faults": [dataclasses.asdict(f) for f in faults],
        "no_replan": arm_no.makespan,
        "replan": arm_yes.makespan,
        "cost_replan": arm_cost.makespan,
        "detection_rate": arm_cost.detection_rate,
        "recovered": arm_cost.completed,
        "report": arm_cost.tracker.report(),
    }


def run_training(loop: LoopConfig, *,
                 train_step: Callable,          # (state, batch) -> (state, metrics)
                 init_state: Callable,          # () -> state pytree
                 batch_at: Callable,            # (step) -> batch
                 state_shardings: Any = None,
                 monitor: Optional[StepMonitor] = None,
                 on_step: Optional[Callable] = None) -> dict:
    """Crash-safe training loop.  Returns summary dict."""
    restarts = 0
    history: list[float] = []
    injected = {"armed": loop.fail_at_step is not None}

    while True:
        # ---- (re)start: restore or init --------------------------------
        last = ckpt_lib.latest_step(loop.ckpt_dir)
        state = init_state()
        start_step = 0
        if last is not None:
            state = ckpt_lib.restore(loop.ckpt_dir, last, state,
                                     shardings=state_shardings)
            start_step = last + 1
        try:
            pending = None
            for step in range(start_step, loop.total_steps):
                if injected["armed"] and step == loop.fail_at_step:
                    injected["armed"] = False
                    raise SimulatedFailure(f"injected at step {step}")
                t0 = time.monotonic()
                batch = batch_at(step)
                state, metrics = train_step(state, batch)
                jax.block_until_ready(jax.tree.leaves(state)[0])
                dt = time.monotonic() - t0
                history.append(float(metrics.get("loss", float("nan"))))
                if monitor is not None:
                    monitor.record(step, dt)
                if on_step is not None:
                    on_step(step, metrics)
                if (step + 1) % loop.ckpt_every == 0 \
                        or step == loop.total_steps - 1:
                    if loop.ckpt_async:
                        pending = ckpt_lib.save_async(
                            loop.ckpt_dir, step, state, keep=loop.keep)
                    else:
                        ckpt_lib.save(loop.ckpt_dir, step, state,
                                      keep=loop.keep)
            if pending is not None:
                pending.join()
            return {"completed": True, "restarts": restarts,
                    "final_step": loop.total_steps - 1,
                    "loss_history": history}
        except SimulatedFailure:
            restarts += 1
            if restarts > loop.max_restarts:
                raise
            # loop re-enters: restore from latest checkpoint
