"""Numerical reproductions of every worked example in the paper.

The paper has no measured-evaluation section; its claims are the five
worked examples (Figs. 1, 2, 3, 6, 7).  Each function below reproduces
one of them in the discrete-event simulator and returns
(name, value_us, derived) rows for the CSV driver, where `derived`
states the claim being validated.
"""
from __future__ import annotations

from repro.core import (
    AltruisticMultiScheduler, CoflowConfig, FairShareScheduler, MXDAG,
    MXDAGScheduler, simulate,
)
from repro.core import builders


def fig1():
    """Fig. 1: network-compute co-scheduling beats fair sharing."""
    g = builders.fig1_jobs()
    fair = FairShareScheduler().schedule(g).simulate()
    mx = MXDAGScheduler().schedule(g).simulate()
    rows = [
        ("fig1.fair_share_T1", fair.makespan,
         "network-aware fair sharing (Fig. 1b)"),
        ("fig1.coschedule_T2", mx.makespan,
         "MXDAG co-scheduling (Fig. 1c)"),
        ("fig1.claim_T2_lt_T1", float(mx.makespan < fair.makespan),
         "paper claim: task on C starts earlier (1.0 = validated)"),
    ]
    return rows


def fig2():
    """Fig. 2: every coflow grouping of an asymmetric DAG is suboptimal."""
    rows = []
    g = builders.fig2a(t1=3.0, t2=1.0)
    mx = MXDAGScheduler().schedule(g).simulate()
    cof = CoflowConfig(builders.fig2a_coflows()).schedule(g).simulate()
    rows += [
        ("fig2a.mxdag", mx.makespan, "per-flow optimal (Fig. 2c left)"),
        ("fig2a.coflow", cof.makespan, "coflow {f1,f2},{f3,f4} (Fig. 2c)"),
        ("fig2a.claim", float(mx.makespan < cof.makespan),
         "asymmetric compute times: coflow suboptimal (1.0 = validated)"),
    ]
    g = builders.fig2b()
    mx = MXDAGScheduler().schedule(g).simulate()
    rows.append(("fig2b.mxdag", mx.makespan,
                 "per-flow optimal (Fig. 2d left)"))
    for v in ("b1", "b2", "b3"):
        cof = CoflowConfig(builders.fig2b_coflows(v)).schedule(g).simulate()
        rows.append((f"fig2b.coflow_{v}", cof.makespan,
                     f"grouping {v} of Fig. 2(b{v[1]})"))
        rows.append((f"fig2b.claim_{v}",
                     float(mx.makespan < cof.makespan),
                     "all three ambiguous groupings suboptimal"))
    return rows


def fig3():
    """Fig. 3: pipelining — no-op off the critical path, win on it,
    loss when it induces NIC contention on it."""
    prio = MXDAGScheduler(try_pipelining=False) \
        .schedule(builders.fig3_case(0)).priorities
    ms = {c: simulate(builders.fig3_case(c), policy="priority",
                      priorities=prio).makespan for c in range(4)}
    sched = MXDAGScheduler(try_pipelining=True).schedule(builders.fig3())
    rows = [
        ("fig3.baseline", ms[0], "no pipelining (Fig. 3b)"),
        ("fig3.case1", ms[1], "pipeline flow4 off critical path (Fig. 3c)"),
        ("fig3.case2", ms[2], "+ pipeline flow1 on critical path (Fig. 3d)"),
        ("fig3.case3", ms[3], "+ pipeline flow3: NIC contention (Fig. 3e)"),
        ("fig3.claim_case1_noop", float(abs(ms[1] - ms[0]) < 1e-9),
         "case1 == baseline (1.0 = validated)"),
        ("fig3.claim_case2_wins", float(ms[2] < ms[0]),
         "case2 < baseline (1.0 = validated)"),
        ("fig3.claim_case3_hurts", float(ms[3] > ms[0]),
         "case3 > baseline (1.0 = validated)"),
        ("fig3.scheduler_choice", sched.simulate().makespan,
         f"Principle-1 greedy keeps only helpful pipelines "
         f"{sched.meta['pipelined']}"),
    ]
    return rows


def fig6():
    """Fig. 6 / §4.1.1: layer-wise DDL sync recovers ByteScheduler."""
    g = builders.ddl(4, push=2.0, pull=2.0)
    fair = FairShareScheduler().schedule(g).simulate()
    sched = MXDAGScheduler(try_pipelining=False).schedule(g)
    mx = sched.simulate()
    pr = {k: v for k, v in sched.priorities.items()
          if k.startswith("push")}
    order = sorted(pr, key=lambda k: pr[k])
    bytescheduler_order = [f"push{i}" for i in range(4)]
    rows = [
        ("fig6.fair", fair.makespan, "FIFO/fair gradient sync"),
        ("fig6.mxdag", mx.makespan, "MXDAG critical-path priorities"),
        ("fig6.claim_order", float(order == bytescheduler_order),
         f"priority order {order} == ByteScheduler lower-layer-first"),
        ("fig6.claim_speedup", fair.makespan / mx.makespan,
         "comm-bound speedup from co-scheduling (>1)"),
    ]
    # the production-scale plan for an assigned arch (sync/plan.py)
    from repro.configs import get, SHAPES
    from repro.sync.plan import plan_sync
    plan = plan_sync(get("deepseek-coder-33b"), SHAPES["train_4k"])
    rows.append(("fig6.plan_33b_speedup", plan.predicted_speedup,
                 f"deepseek-coder-33b train_4k @256 chips: mode="
                 f"{plan.mode}, bucketed {plan.predicted_bucketed:.3f}s "
                 f"vs barrier {plan.predicted_barrier:.3f}s"))
    return rows


def fig7():
    """Fig. 7 / §4.2.1: altruistic multi-job scheduling."""
    j1, j2 = builders.mapreduce_pair()
    merged = MXDAG("merged")
    for t in list(j1) + list(j2):
        merged.add(t)
    for e in list(j1.edges.values()) + list(j2.edges.values()):
        merged.add_edge(e.src, e.dst)
    naive = simulate(merged, policy="fair")
    alt = AltruisticMultiScheduler().schedule([j1, j2]).simulate()
    rows = [
        ("fig7.naive_job1", naive.jct("job1"), "fair sharing"),
        ("fig7.naive_job2_T2", naive.jct("job2"), "fair sharing"),
        ("fig7.altruistic_job1", alt.jct("job1"), "Principle 2"),
        ("fig7.altruistic_job2_T1", alt.jct("job2"), "Principle 2"),
        ("fig7.claim_job2_faster", float(alt.jct("job2") < naive.jct("job2")),
         "job2 finishes at T1 < T2 (1.0 = validated)"),
        ("fig7.claim_job1_unharmed",
         float(alt.jct("job1") <= naive.jct("job1") + 1e-9),
         "job1 completion unchanged (1.0 = validated)"),
    ]
    return rows


ALL = [fig1, fig2, fig3, fig6, fig7]
