"""Attention: GQA (grouped-query) and MLA (multi-head latent), train +
decode (KV cache) + cross-attention.

The core dot-product attention has two implementations selectable per run
(`RunConfig.attn_impl`):

- ``"xla"``   — einsum formulation (memory-efficient GQA grouping, fp32
  softmax).  Used for dry-run lowering: it produces TPU-representative HLO.
- ``"pallas"`` — the flash-attention kernel in ``repro.kernels`` (TPU
  BlockSpec tiling; validated in interpret mode on CPU).

MLA decode uses the *absorbed* formulation: attention runs in the
compressed-KV latent space so the cache holds only kv_lora+rope dims per
token (DeepSeek-V3's memory win).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import apply_rope, dense_init, rmsnorm, rmsnorm_init

Params = dict
NEG_INF = -1e30


# ----------------------------------------------------------------------
# core scaled-dot-product attention with GQA grouping
# ----------------------------------------------------------------------
def _xla_flash(q: jax.Array, k: jax.Array, v: jax.Array, *,
               causal: bool, scale: float, block_q: int = 256) -> jax.Array:
    """Blockwise attention in pure XLA: ``lax.scan`` over query blocks with
    a rematerialized body keeps live memory O(block·T) instead of O(S²) in
    both fwd and bwd — the same asymptotics the Pallas kernel has on TPU,
    so dry-run memory analysis is representative.
    q: [B,S,H,hd]; k,v: [B,T,K,hd]."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    nb = S // block_q
    qb = q.reshape(B, nb, block_q, K, G, hd)
    qb = jnp.moveaxis(qb, 1, 0)                   # [nb,B,blk,K,G,hd]

    @jax.checkpoint
    def body(_, args):
        qi, i = args
        s = jnp.einsum("bskgh,btkh->bkgst", qi, k,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = i * block_q + jnp.arange(block_q)
            mask = qpos[:, None] >= jnp.arange(T)[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        o = jnp.einsum("bkgst,btkh->bskgh", p, v)
        return None, o

    _, ob = jax.lax.scan(body, None, (qb, jnp.arange(nb)))
    return ob.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H, v.shape[-1])


def sdpa(q: jax.Array, k: jax.Array, v: jax.Array, *,
         causal: bool,
         q_positions: Optional[jax.Array] = None,
         k_valid_len: Optional[jax.Array] = None,
         impl: str = "xla",
         scale: Optional[float] = None) -> jax.Array:
    """q: [B,S,H,hd]; k,v: [B,T,K,hd] with H % K == 0.  Returns [B,S,H,hd].

    ``q_positions`` ([S] or [B,S]) anchors causal masking for decode;
    ``k_valid_len`` masks cache slots beyond the current length.
    """
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    if impl == "pallas" and causal and S == T and k_valid_len is None:
        from repro.kernels import ops as _kops
        return _kops.flash_attention(q, k, v, causal=True, scale=scale)

    if impl == "xla_flash" and S == T and k_valid_len is None \
            and (q_positions is None or q_positions.ndim == 1) \
            and S % 256 == 0:
        return _xla_flash(q, k, v, causal=causal, scale=scale)

    qg = q.reshape(B, S, K, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k,
                        preferred_element_type=jnp.float32) * scale
    k_pos = jnp.arange(T)
    mask = None
    if causal:
        q_pos = (jnp.arange(S) if q_positions is None else q_positions)
        if q_pos.ndim == 1:
            m = q_pos[:, None] >= k_pos[None, :]              # [S,T]
            mask = m[None, None, None]
        else:
            m = q_pos[:, :, None] >= k_pos[None, None, :]     # [B,S,T]
            mask = m[:, None, None]
    if k_valid_len is not None:
        lm = k_pos[None, :] < k_valid_len[:, None]            # [B,T]
        lm = lm[:, None, None, None]                          # [B,1,1,1,T]
        mask = lm if mask is None else jnp.logical_and(mask, lm)
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(B, S, H, v.shape[-1])   # v dim may differ (MLA)


# ----------------------------------------------------------------------
# GQA block
# ----------------------------------------------------------------------
def gqa_init(key, cfg: ArchConfig, *, cross: bool = False,
             dtype=jnp.bfloat16) -> Params:
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, H * hd, dtype=dtype),
        "wk": dense_init(ks[1], d, K * hd, dtype=dtype),
        "wv": dense_init(ks[2], d, K * hd, dtype=dtype),
        "wo": dense_init(ks[3], H * hd, d, scale=1.0 / math.sqrt(H * hd),
                         dtype=dtype),
    }


def gqa_apply(p: Params, x: jax.Array, cfg: ArchConfig, *,
              positions: Optional[jax.Array] = None,
              cache: Optional[Params] = None,
              cache_index: Optional[jax.Array] = None,
              kv_src: Optional[jax.Array] = None,
              causal: bool = True,
              use_rope: bool = True,
              impl: str = "xla"):
    """Self- or cross-attention.  Returns (out, new_cache).

    Train/prefill: cache is None, full sequence.
    Decode: cache = {"k": [B,Tmax,K,hd], "v": ...}; x is [B,1,d];
    cache_index is the current write position (scalar int32).
    Cross-attention: kv_src supplies the keys/values source sequence
    (encoder states); no cache update, no causal mask.
    """
    B, S, d = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    src = x if kv_src is None else kv_src
    k = (src @ p["wk"]).reshape(B, src.shape[1], K, hd)
    v = (src @ p["wv"]).reshape(B, src.shape[1], K, hd)

    if use_rope and kv_src is None:
        if positions is not None:
            pos = positions
        elif cache is not None:
            pos = cache_index + jnp.arange(S)
        else:
            pos = jnp.arange(S)
        q = apply_rope(q, pos, cfg.rope_theta, cfg.rotary_fraction)
        k = apply_rope(k, pos, cfg.rope_theta, cfg.rotary_fraction)

    new_cache = cache
    k_valid = None
    q_pos = positions
    if cache is not None:
        idx = cache_index
        k = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, idx, 0, 0))
        v = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, idx, 0, 0))
        new_cache = {"k": k, "v": v}
        k_valid = jnp.full((B,), idx + S, dtype=jnp.int32)
        q_pos = idx + jnp.arange(S)

    out = sdpa(q, k.astype(q.dtype), v.astype(q.dtype),
               causal=causal and kv_src is None,
               q_positions=q_pos, k_valid_len=k_valid, impl=impl)
    return out.reshape(B, S, H * hd) @ p["wo"], new_cache


def gqa_cache_init(cfg: ArchConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> Params:
    K, hd = cfg.n_kv_heads, cfg.head_dim
    return {"k": jnp.zeros((batch, max_len, K, hd), dtype),
            "v": jnp.zeros((batch, max_len, K, hd), dtype)}


# ----------------------------------------------------------------------
# MLA block (deepseek-v3)
# ----------------------------------------------------------------------
def mla_init(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    d, H = cfg.d_model, cfg.n_heads
    nope, rope_d = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    vd, ql, kl = cfg.v_head_dim, cfg.q_lora_rank, cfg.kv_lora_rank
    ks = jax.random.split(key, 6)
    p = {
        "wkv_a": dense_init(ks[2], d, kl + rope_d, dtype=dtype),
        "kv_norm": rmsnorm_init(kl, dtype),
        "wkv_b": dense_init(ks[3], kl, H * (nope + vd), dtype=dtype),
        "wo": dense_init(ks[4], H * vd, d, scale=1.0 / math.sqrt(H * vd),
                         dtype=dtype),
    }
    if ql:
        p["wq_a"] = dense_init(ks[0], d, ql, dtype=dtype)
        p["q_norm"] = rmsnorm_init(ql, dtype)
        p["wq_b"] = dense_init(ks[1], ql, H * (nope + rope_d), dtype=dtype)
    else:
        p["wq"] = dense_init(ks[0], d, H * (nope + rope_d), dtype=dtype)
    return p


def _mla_q(p: Params, x: jax.Array, cfg: ArchConfig, positions: jax.Array):
    B, S, _ = x.shape
    H = cfg.n_heads
    nope, rope_d = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if cfg.q_lora_rank:
        cq = rmsnorm(p["q_norm"], x @ p["wq_a"], cfg.norm_eps)
        q = (cq @ p["wq_b"]).reshape(B, S, H, nope + rope_d)
    else:
        q = (x @ p["wq"]).reshape(B, S, H, nope + rope_d)
    qn, qr = q[..., :nope], q[..., nope:]
    qr = apply_rope(qr, positions, cfg.rope_theta)
    return qn, qr


def mla_apply(p: Params, x: jax.Array, cfg: ArchConfig, *,
              positions: Optional[jax.Array] = None,
              cache: Optional[Params] = None,
              cache_index: Optional[jax.Array] = None,
              impl: str = "xla"):
    """Returns (out, new_cache).  Cache holds the *compressed* latents:
    {"ckv": [B,Tmax,kv_lora], "kr": [B,Tmax,rope_d]}."""
    B, S, d = x.shape
    H = cfg.n_heads
    nope, rope_d = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    vd, kl = cfg.v_head_dim, cfg.kv_lora_rank

    pos = positions if positions is not None else jnp.arange(S)
    if cache is not None:
        pos = cache_index + jnp.arange(S)
    qn, qr = _mla_q(p, x, cfg, pos)

    kv_a = x @ p["wkv_a"]
    ckv = rmsnorm(p["kv_norm"], kv_a[..., :kl], cfg.norm_eps)
    kr = apply_rope(kv_a[..., None, kl:], pos, cfg.rope_theta)[:, :, 0]

    wkv_b = p["wkv_b"].reshape(kl, H, nope + vd)
    wk_b, wv_b = wkv_b[..., :nope], wkv_b[..., nope:]

    if cache is None:
        # naive (train/prefill): expand latents to per-head k,v
        kn = jnp.einsum("btl,lhn->bthn", ckv, wk_b)
        v = jnp.einsum("btl,lhv->bthv", ckv, wv_b)
        k = jnp.concatenate(
            [kn, jnp.broadcast_to(kr[:, :, None], (B, S, H, rope_d))],
            axis=-1)
        q = jnp.concatenate([qn, qr], axis=-1)
        out = sdpa(q, k, v, causal=True, q_positions=pos, impl=impl)
        return out.reshape(B, S, H * vd) @ p["wo"], None

    # absorbed decode: attention entirely in latent space
    idx = cache_index
    ckv_c = jax.lax.dynamic_update_slice(
        cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, idx, 0))
    kr_c = jax.lax.dynamic_update_slice(
        cache["kr"], kr.astype(cache["kr"].dtype), (0, idx, 0))
    new_cache = {"ckv": ckv_c, "kr": kr_c}

    q_lat = jnp.einsum("bshn,lhn->bshl", qn, wk_b)           # [B,S,H,kl]
    scores = (jnp.einsum("bshl,btl->bhst", q_lat,
                         ckv_c.astype(q_lat.dtype),
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bshr,btr->bhst", qr,
                           kr_c.astype(qr.dtype),
                           preferred_element_type=jnp.float32))
    scores = scores / math.sqrt(nope + rope_d)
    T = ckv_c.shape[1]
    k_pos = jnp.arange(T)
    q_pos = idx + jnp.arange(S)
    mask = q_pos[:, None] >= k_pos[None, :]
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhst,btl->bshl", probs.astype(ckv_c.dtype), ckv_c)
    out = jnp.einsum("bshl,lhv->bshv", ctx, wv_b.astype(ctx.dtype))
    return out.reshape(B, S, H * vd) @ p["wo"], new_cache


def mla_cache_init(cfg: ArchConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> Params:
    return {"ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            "kr": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype)}
