"""MXDAG schedulers (paper §4).

- :class:`FairShareScheduler` — the network-aware-DAG baseline of Fig. 1(b):
  every task starts as soon as its dependencies allow; NIC bandwidth is
  max-min fair-shared; no flow-level priorities; no pipelining decisions.

- :class:`CoflowConfig` — the §2.2 baseline: flows grouped into coflows with
  synchronized start, MADD-coupled rates and all-or-nothing gating.

- :class:`MXDAGScheduler` — Principle 1: prioritize the critical path within
  any copath (without letting non-critical paths exceed the critical path),
  and enable pipelining on an edge only when it shrinks the makespan
  (the Fig. 3 analysis, automated as a greedy what-if loop).

- :class:`AltruisticMultiScheduler` — Principle 2: a job delays/demotes its
  non-critical tasks, bounded by their slack, to donate resources to other
  jobs' critical paths without extending its own completion time.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.cluster import Cluster
from repro.core.graph import MXDAG
from repro.core.simulator import SimResult, simulate
from repro.core.task import TaskKind

# priority classes (lower value = more urgent)
CRITICAL = 0.0
NONCRITICAL = 1.0
ALTRUIST_DEMOTED = 2.0


@dataclasses.dataclass
class Schedule:
    """Everything needed to execute a scheduling decision in the DES."""
    graph: MXDAG                        # with pipelining flags applied
    policy: str = "fair"
    priorities: dict[str, float] = dataclasses.field(default_factory=dict)
    releases: dict[str, float] = dataclasses.field(default_factory=dict)
    coflows: Optional[list[set[str]]] = None
    meta: dict = dataclasses.field(default_factory=dict)

    def simulate(self, cluster: Optional[Cluster] = None) -> SimResult:
        return simulate(self.graph, cluster, policy=self.policy,
                        priorities=self.priorities, releases=self.releases,
                        coflows=self.coflows)


class FairShareScheduler:
    """Baseline: dependency-driven start, fair NIC sharing, no priorities."""

    def schedule(self, graph: MXDAG,
                 cluster: Optional[Cluster] = None) -> Schedule:
        return Schedule(graph=graph, policy="fair")


class CoflowConfig:
    """Coflow baseline: caller supplies the grouping (the paper's point in
    §2.2 is precisely that the grouping is ambiguous — Fig. 2(b1..b3));
    :func:`auto_coflows` derives one conventional grouping."""

    def __init__(self, coflows: list[set[str]]):
        self.coflows = coflows

    def schedule(self, graph: MXDAG,
                 cluster: Optional[Cluster] = None) -> Schedule:
        return Schedule(graph=graph, policy="fair", coflows=self.coflows,
                        meta={"coflows": self.coflows})


def auto_coflows(graph: MXDAG) -> list[set[str]]:
    """Conventional stage-grouping: flows sharing the same successor set
    (aggregations) or, failing that, the same predecessor set (broadcasts)."""
    groups: dict[tuple, set[str]] = {}
    for t in graph.network_tasks():
        succ = frozenset(graph.succs(t.name))
        pred = frozenset(graph.preds(t.name))
        key = ("succ", succ) if succ else ("pred", pred)
        groups.setdefault(key, set()).add(t.name)
    return [g for g in groups.values() if len(g) >= 2]


class MXDAGScheduler:
    """Principle 1 (§4.1) — critical-path-first co-scheduling.

    1. Analytic forward/backward pass (contention-free) yields per-task
       slack; zero-slack tasks form the critical path.
    2. Flow & compute priorities: critical tasks get class 0; others are
       ordered by ascending slack within class 1 (a non-critical path is
       never allowed to pre-empt the critical path, but among themselves
       tighter paths go first — "without letting the non-critical paths
       have longer completion time than the critical path").
    3. Pipelining: greedily enable a pipelineable edge only if the
       simulated makespan shrinks (Fig. 3 cases 1–3 automated).

    ``memoize`` caches DES results within one :meth:`schedule` call, keyed
    by (graph signature, policy, priorities), so identical what-if queries
    are simulated once.  ``incremental_pipelining`` replaces the seed's
    fixpoint re-scan of every candidate edge after each accepted decision
    with a worklist that re-evaluates only candidates whose endpoints
    touch resources affected by that decision (a task whose simulated
    start/finish moved, or the accepted edge itself).  Both default on;
    benchmarks flip them off to measure the seed behaviour.
    """

    def __init__(self, *, try_pipelining: bool = True,
                 slack_eps: float = 1e-9, memoize: bool = True,
                 incremental_pipelining: bool = True):
        self.try_pipelining = try_pipelining
        self.slack_eps = slack_eps
        self.memoize = memoize
        self.incremental_pipelining = incremental_pipelining

    def _priorities(self, graph: MXDAG,
                    timing: Optional[dict] = None) -> dict[str, float]:
        timing = timing if timing is not None else graph.with_slack()
        prio: dict[str, float] = {}
        slacks = sorted({round(t.slack, 12) for t in timing.values()})
        rank = {s: i for i, s in enumerate(slacks)}
        denom = max(len(slacks), 1)
        for n, tm in timing.items():
            if tm.slack <= self.slack_eps:
                prio[n] = CRITICAL
            else:
                # rank-normalized slack keeps classes strictly above CRITICAL
                prio[n] = NONCRITICAL + rank[round(tm.slack, 12)] / denom
        return prio

    def _best(self, g: MXDAG, cluster: Optional[Cluster],
              cache: Optional[dict] = None,
              ) -> tuple[str, dict[str, float], float, SimResult]:
        """Principle 1 with its own caveat enforced.

        Strict slack-priority can delay a non-critical path *beyond its
        slack* under contention, which the principle forbids ("without
        letting the non-critical paths have longer completion time than the
        critical path").  So: start from strict priority, iteratively
        promote tasks that the DES shows finishing past their analytic
        latest-completion, and never return anything worse than plain fair
        sharing.  ``cache`` memoizes DES runs across _best calls.
        """
        if cache is not None:
            # intern the graph signature: hash the (large) task/edge tuple
            # once per _best call, not once per memo lookup
            sig_ids = cache.setdefault("sig_ids", {})
            sig = sig_ids.setdefault(g.signature(), len(sig_ids))
        else:
            sig = None

        def sim(policy: str, prio: dict[str, float]) -> SimResult:
            if cache is None:
                return simulate(g, cluster, policy=policy, priorities=prio)
            key = (sig, policy, tuple(sorted(prio.items())))
            res = cache.get(key)
            if res is None:
                res = simulate(g, cluster, policy=policy, priorities=prio)
                cache[key] = res
            return res

        timing = g.with_slack()
        prio = self._priorities(g, timing)
        cands: list[tuple[str, dict[str, float], float, SimResult]] = []
        cur = dict(prio)
        for _ in range(len(g.tasks)):
            res = sim("priority", cur)
            cands.append(("priority", dict(cur), res.makespan, res))
            late = [n for n, tm in timing.items()
                    if cur.get(n, 0.0) > CRITICAL
                    and res.finish[n] > tm.latest_completion + 1e-9]
            if not late:
                break
            for n in late:
                cur[n] = CRITICAL
        fair = sim("fair", {})
        cands.append(("fair", {}, fair.makespan, fair))
        return min(cands, key=lambda c: (c[2], c[0] == "fair"))

    def schedule(self, graph: MXDAG,
                 cluster: Optional[Cluster] = None) -> Schedule:
        g = graph.copy()
        if self.try_pipelining:
            # start from no pipelining: paper applies it only when it helps
            for (s, d) in list(g.edges):
                g.set_pipelined(s, d, False)

        cache: Optional[dict] = {} if self.memoize else None
        policy, prio, best, best_res = self._best(g, cluster, cache)
        decisions: dict[tuple[str, str], bool] = {}

        if self.try_pipelining:
            candidates = sorted(
                ((e.src, e.dst) for e in graph.edges.values()
                 if graph.tasks[e.src].pipelineable
                 and graph.tasks[e.dst].pipelineable),
            )
            if self.incremental_pipelining:
                g, policy, prio, best, best_res = self._greedy_pipeline(
                    g, cluster, cache, candidates, decisions,
                    policy, prio, best, best_res)
            else:
                # seed fixpoint: full candidate re-scan after any accept
                improved = True
                while improved:
                    improved = False
                    for (s, d) in candidates:
                        if decisions.get((s, d)):
                            continue
                        trial = g.copy()
                        trial.set_pipelined(s, d, True)
                        tpolicy, tprio, tms, tres = self._best(
                            trial, cluster, cache)
                        if tms < best - 1e-9:
                            g, best, best_res = trial, tms, tres
                            policy, prio = tpolicy, tprio
                            decisions[(s, d)] = True
                            improved = True
        return Schedule(graph=g, policy=policy, priorities=prio,
                        meta={"pipelined": sorted(k for k, v in
                                                  decisions.items() if v),
                              "critical_path": g.critical_path(),
                              "predicted_makespan": best})

    def _greedy_pipeline(self, g: MXDAG, cluster: Optional[Cluster],
                         cache: Optional[dict],
                         candidates: list[tuple[str, str]],
                         decisions: dict[tuple[str, str], bool],
                         policy: str, prio: dict[str, float],
                         best: float, best_res: SimResult):
        """Worklist greedy: each candidate edge is evaluated once; an
        accepted decision re-enqueues only the rejected candidates whose
        endpoints touch a resource the decision affected (a task whose
        simulated start/finish moved, or the accepted edge's endpoints).

        This is a heuristic pruning of the seed's full fixpoint re-scan:
        a decision can in principle shift analytic slack (and thus _best
        priorities) for tasks whose simulated timing did not move, so a
        far-away rejected candidate could become profitable without being
        requeued.  Makespan monotonicity is unaffected (only improvements
        are ever accepted); pass ``incremental_pipelining=False`` for the
        seed's exhaustive behaviour.
        """
        res_of = {n: (cluster.resources_for(t) if cluster is not None
                      else t.resources())
                  for n, t in g.tasks.items()}
        queue = list(candidates)
        queued = set(candidates)
        rejected: list[tuple[str, str]] = []
        i = 0
        while i < len(queue):
            s, d = queue[i]
            i += 1
            queued.discard((s, d))
            if decisions.get((s, d)):
                continue
            trial = g.copy()
            trial.set_pipelined(s, d, True)
            tpolicy, tprio, tms, tres = self._best(trial, cluster, cache)
            if tms >= best - 1e-9:
                rejected.append((s, d))
                continue
            affected = set(res_of[s]) | set(res_of[d])
            for n in g.tasks:
                if (abs(best_res.start[n] - tres.start[n]) > 1e-9
                        or abs(best_res.finish[n] - tres.finish[n]) > 1e-9):
                    affected.update(res_of[n])
            g, best, best_res = trial, tms, tres
            policy, prio = tpolicy, tprio
            decisions[(s, d)] = True
            requeue = [c for c in rejected
                       if c not in queued and not decisions.get(c)
                       and (affected & set(res_of[c[0]])
                            or affected & set(res_of[c[1]]))]
            rejected = [c for c in rejected if c not in requeue]
            for c in sorted(requeue):
                queue.append(c)
                queued.add(c)
        return g, policy, prio, best, best_res


class AltruisticMultiScheduler:
    """Principle 2 (§4.2) — altruism across MXDAGs sharing a cluster.

    Each job's critical tasks keep class 0.  A job's non-critical task is
    demoted below *other* jobs' critical tasks only when its slack (from the
    isolated analytic pass) covers the foreign critical work queued on the
    same resource — this implements "delaying its non-critical path resource
    allocation ... without increasing its own end-to-end completion time".
    """

    def __init__(self, *, try_pipelining: bool = False):
        self.try_pipelining = try_pipelining

    def schedule(self, graphs: list[MXDAG],
                 cluster: Optional[Cluster] = None) -> Schedule:
        merged = MXDAG("+".join(g.name for g in graphs))
        for g in graphs:
            for t in g:
                merged.add(t)
            for e in g.edges.values():
                merged.add_edge(e.src, e.dst, pipelined=e.pipelined)

        # isolated analytics per job
        prio: dict[str, float] = {}
        slack: dict[str, float] = {}
        critical: dict[str, set[str]] = {}
        for g in graphs:
            timing = g.with_slack()
            crit = {n for n, tm in timing.items() if tm.slack <= 1e-9}
            critical[g.name] = crit
            for n, tm in timing.items():
                slack[n] = tm.slack
                prio[n] = CRITICAL if n in crit else NONCRITICAL

        # altruistic demotion, bounded by slack; fabric-aware when the
        # cluster has a Topology (contention on shared uplinks counts too)
        by_resource = merged.resource_map(cluster)
        res_of = {n: (cluster.resources_for(t) if cluster is not None
                      else t.resources())
                  for n, t in merged.tasks.items()}
        for g in graphs:
            others_crit = set().union(*(critical[o.name] for o in graphs
                                        if o.name != g.name)) \
                if len(graphs) > 1 else set()
            for n in g.tasks:
                if prio[n] != NONCRITICAL:
                    continue
                foreign = 0.0
                for r in res_of[n]:
                    foreign += sum(merged.tasks[m].size
                                   for m in by_resource[r]
                                   if m in others_crit)
                if foreign > 0 and slack[n] >= foreign - 1e-9:
                    prio[n] = ALTRUIST_DEMOTED
        return Schedule(graph=merged, policy="priority", priorities=prio,
                        meta={"critical": critical})
