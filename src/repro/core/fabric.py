"""Link-level network fabric: multi-tier topologies with path-based routing.

The seed cluster model charges a flow only against its endpoint NICs — the
"big switch" simplification that coflow schedulers assume and that loses
in-network contention information.  Real fabrics are multi-tier: flows
crossing racks share ToR uplinks and spine links, and an oversubscribed
core is exactly where co-scheduling decisions matter most.

A :class:`Topology` is a set of named, capacitated, *directed* links plus a
static route table mapping each ``(src_host, dst_host)`` pair to the tuple
of links the flow traverses.  By convention the first link of every path is
the sender's egress NIC ``"<host>.nic_out"`` and the last is the receiver's
ingress NIC ``"<host>.nic_in"`` — so NIC endpoints are just the first/last
links of the path and the seed resource-naming convention is preserved.
Host pairs without an explicit route fall back to the direct NIC-only path,
i.e. the big-switch model.

Builders:

- :meth:`Topology.single_switch` — the seed model as a topology (every path
  is exactly ``(src.nic_out, dst.nic_in)``; simulation results are
  bit-identical to a topology-less cluster),
- :meth:`Topology.two_tier`  — racks under ToR switches joined by a core;
  per-rack uplink/downlink capacity ``hosts * nic / oversubscription``,
- :meth:`Topology.leaf_spine` — each leaf holds one uplink/downlink pair
  per spine; flows pick a spine by ECMP-style static hashing,
- :meth:`Topology.fat_tree`  — the k-ary Clos of Al-Fares et al.; ECMP
  hashing selects the aggregation and core switch per host pair.

Routing *defaults* to static hash-based ECMP (as in flow-level fabric
simulators): the default path of a flow is a pure function of its
endpoints, so the simulator's piecewise-constant-rate integration stays
exact.  But the hash pick is just one member of the candidate set the
fabric actually offers — :meth:`Topology.paths` exposes the full ECMP
group (every spine, every (agg, core) pair) per host pair, and the
scheduler may override a flow's route with any candidate (threaded through
``Cluster.resources_for(task, route=...)`` and ``Simulator(routes=...)``),
making routing a per-flow scheduling decision instead of a frozen input.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Callable, Iterable, Mapping, Optional, Sequence


def nic_out(host: str) -> str:
    """Resource name of ``host``'s egress NIC."""
    return f"{host}.nic_out"


def nic_in(host: str) -> str:
    """Resource name of ``host``'s ingress NIC."""
    return f"{host}.nic_in"


_NIC_SUFFIXES = (".nic_out", ".nic_in")


def is_nic_link(link: str) -> bool:
    """NIC links are endpoint resources; everything else is fabric."""
    return link.endswith(_NIC_SUFFIXES)


def link_flow_index(flows, paths) -> dict[str, list[str]]:
    """Invert flow→path into link→flows, preserving ``flows`` order.

    The waterfill's bottleneck search needs, per link, the flows crossing
    it; scanning every flow's path per link is O(links·flows) per
    iteration, while this index makes it O(flows on the link).  Order
    preservation matters: weight sums and freeze batches must enumerate
    flows exactly as the ordered scan would, so allocations (and their
    floating-point round-off) are unchanged.
    """
    by_link: dict[str, list[str]] = {}
    for n in flows:
        for r in paths[n]:
            by_link.setdefault(r, []).append(n)
    return by_link


def ecmp_choice(src: str, dst: str, n: int) -> int:
    """Deterministic ECMP: stable per host pair across processes/runs."""
    if n <= 1:
        return 0
    return zlib.crc32(f"{src}->{dst}".encode()) % n


@dataclasses.dataclass(frozen=True)
class Link:
    """One directed fabric link with a normalized bandwidth capacity."""
    name: str
    capacity: float

    def __post_init__(self) -> None:
        if self.capacity <= 0:
            raise ValueError(f"link {self.name}: capacity must be > 0")


class Topology:
    """Named links + static per-host-pair routes over them."""

    def __init__(self, name: str = "fabric") -> None:
        self.name = name
        self.links: dict[str, float] = {}
        self._hosts: dict[str, None] = {}          # ordered set
        # explicit routes (add_route) double as the memo cache for _router
        self._routes: dict[tuple[str, str], tuple[str, ...]] = {}
        # host pairs routed explicitly via add_route (a single-member
        # candidate set), as opposed to memoized ECMP picks in _routes
        self._explicit: set[tuple[str, str]] = set()
        # routing function (src, dst) -> fabric via-links, or None for the
        # direct NIC-only path; builders install one so construction stays
        # O(hosts + links) instead of materializing O(hosts^2) routes
        self._router: Optional[
            Callable[[str, str], Optional[Sequence[str]]]] = None
        # multipath router (src, dst) -> the *candidate* via-link tuples
        # (the full ECMP group), or None when only the direct NIC path
        # exists; path() picks member ecmp_choice(src, dst, len) of it, so
        # installing a multipath router reproduces the single-path hash
        # pick exactly while exposing every alternative to the scheduler
        self._multi: Optional[
            Callable[[str, str],
                     Optional[Sequence[tuple[str, ...]]]]] = None

    # -- construction --------------------------------------------------
    def add_host(self, host: str, *, nic_in_cap: float = 1.0,
                 nic_out_cap: float = 1.0) -> None:
        """Add a host plus its two NIC links."""
        if host in self._hosts:
            raise ValueError(f"duplicate host {host}")
        self._hosts[host] = None
        self.add_link(nic_out(host), nic_out_cap)
        self.add_link(nic_in(host), nic_in_cap)

    def add_link(self, name: str, capacity: float) -> None:
        """Add a named link with the given capacity."""
        if name in self.links:
            raise ValueError(f"duplicate link {name}")
        self.links[name] = Link(name, capacity).capacity

    def add_route(self, src: str, dst: str,
                  via: Sequence[str] = ()) -> None:
        """Route src→dst through fabric links ``via`` (NICs are implicit)."""
        for h in (src, dst):
            if h not in self._hosts:
                raise KeyError(f"unknown host {h}")
        for l in via:
            if l not in self.links:
                raise KeyError(f"unknown link {l}")
        self._routes[(src, dst)] = (nic_out(src), *via, nic_in(dst))
        self._explicit.add((src, dst))

    # -- queries -------------------------------------------------------
    def hosts(self) -> list[str]:
        """All host names, insertion order."""
        return list(self._hosts)

    def capacity(self, link: str) -> float:
        """Capacity of ``link`` (KeyError if unknown)."""
        return self.links[link]

    def _via_candidates(self, src: str,
                        dst: str) -> Optional[list[tuple[str, ...]]]:
        """Candidate via-link tuples for a host pair, or None for direct."""
        if self._multi is not None:
            vias = self._multi(src, dst)
            return None if vias is None else [tuple(v) for v in vias]
        if self._router is not None:
            via = self._router(src, dst)
            return None if via is None else [tuple(via)]
        return None

    def path(self, src: str, dst: str) -> tuple[str, ...]:
        """The *default* links a src→dst flow occupies (first = egress
        NIC, last = ingress NIC): the explicit route if one was added,
        else the ECMP-hash member of the candidate set.  Unrouted pairs
        use the direct NIC-only path."""
        route = self._routes.get((src, dst))
        if route is not None:
            return route
        for h in (src, dst):
            if h not in self._hosts:
                raise KeyError(
                    f"unknown host {h!r} in topology {self.name!r}")
        vias = self._via_candidates(src, dst)
        via = None if vias is None \
            else vias[ecmp_choice(src, dst, len(vias))]
        route = (nic_out(src), *(via or ()), nic_in(dst))
        self._routes[(src, dst)] = route
        return route

    def paths(self, src: str, dst: str) -> tuple[tuple[str, ...], ...]:
        """All candidate routes for a host pair (the ECMP group).

        :meth:`path` returns exactly one member of this set (the static
        hash pick), so ``path(s, d) in paths(s, d)`` always holds.  Pairs
        routed explicitly via :meth:`add_route` have a single candidate;
        pairs with no fabric route offer only the direct NIC path.  A
        scheduler treats this set as the decision space for per-flow route
        overrides.
        """
        if (src, dst) in self._explicit:
            return (self._routes[(src, dst)],)
        for h in (src, dst):
            if h not in self._hosts:
                raise KeyError(
                    f"unknown host {h!r} in topology {self.name!r}")
        vias = self._via_candidates(src, dst)
        if vias is None:
            return ((nic_out(src), nic_in(dst)),)
        return tuple((nic_out(src), *v, nic_in(dst)) for v in vias)

    def fabric_links(self) -> list[str]:
        """All non-NIC (in-fabric) link names."""
        return [l for l in self.links if not is_nic_link(l)]

    # -- what-if support ----------------------------------------------
    def resized(self, scale: Optional[float] = None, *,
                links: Optional[Mapping[str, float]] = None) -> "Topology":
        """A copy with fabric link capacities scaled by ``scale`` and/or
        individual links (NICs included) set from ``links``."""
        if links is not None:
            unknown = sorted(set(links) - set(self.links))
            if unknown:
                raise KeyError(f"unknown links in topology "
                               f"{self.name!r}: {unknown}")
        t = Topology(self.name)
        t._hosts = dict(self._hosts)
        t._routes = dict(self._routes)
        t._explicit = set(self._explicit)
        t._router = self._router
        t._multi = self._multi
        for l, cap in self.links.items():
            if links is not None and l in links:
                cap = links[l]
            elif scale is not None and not is_nic_link(l):
                cap = cap * scale
            t.links[l] = Link(l, cap).capacity
        return t

    def __repr__(self) -> str:
        return (f"Topology({self.name}: {len(self._hosts)} hosts, "
                f"{len(self.fabric_links())} fabric links)")

    # ------------------------------------------------------------------
    # builders
    # ------------------------------------------------------------------
    @staticmethod
    def _rack_names(racks, prefix: str = "r") -> list[list[str]]:
        """Accept explicit host-name lists or an (n_racks, per_rack) pair."""
        if (isinstance(racks, tuple) and len(racks) == 2
                and all(isinstance(x, int) for x in racks)):
            n, per = racks
            return [[f"{prefix}{r}h{i}" for i in range(per)]
                    for r in range(n)]
        return [list(r) for r in racks]

    @classmethod
    def single_switch(cls, hosts: Iterable[str], *,
                      nic: float = 1.0) -> "Topology":
        """The seed "big switch": every path is the two endpoint NICs."""
        t = cls("single_switch")
        for h in hosts:
            t.add_host(h, nic_in_cap=nic, nic_out_cap=nic)
        return t

    @classmethod
    def two_tier(cls, racks, *, nic: float = 1.0,
                 oversubscription: float = 1.0) -> "Topology":
        """Racks under ToR switches joined by a non-blocking core.

        ``racks`` is a list of host-name lists or an ``(n_racks,
        hosts_per_rack)`` pair.  Each rack r gets one uplink ``rack<r>.up``
        and one downlink ``rack<r>.down`` of capacity ``len(rack) * nic /
        oversubscription`` — ``oversubscription=4`` is the classic 4:1
        oversubscribed core where only a quarter of the rack's NIC
        bandwidth can leave the rack at once.
        """
        if oversubscription <= 0:
            raise ValueError("oversubscription must be > 0")
        groups = cls._rack_names(racks)
        t = cls(f"two_tier_{oversubscription:g}to1")
        rack_of: dict[str, int] = {}
        for r, hosts in enumerate(groups):
            cap = len(hosts) * nic / oversubscription
            t.add_link(f"rack{r}.up", cap)
            t.add_link(f"rack{r}.down", cap)
            for h in hosts:
                t.add_host(h, nic_in_cap=nic, nic_out_cap=nic)
                rack_of[h] = r
        def routes(s: str, d: str) -> Optional[list[tuple[str, ...]]]:
            """Via-links for s→d (None = intra-rack direct)."""
            rs, rd = rack_of[s], rack_of[d]
            if rs == rd:            # intra-rack: direct NIC-only path
                return None
            return [(f"rack{rs}.up", f"rack{rd}.down")]

        t._multi = routes
        return t

    @classmethod
    def leaf_spine(cls, racks, n_spines: int, *, nic: float = 1.0,
                   uplink: Optional[float] = None,
                   oversubscription: float = 1.0) -> "Topology":
        """Leaf switches fully meshed to ``n_spines`` spines.

        Each leaf l holds one uplink ``leaf<l>.up<s>`` and one downlink
        ``leaf<l>.down<s>`` per spine s, each of capacity ``uplink``
        (default ``len(rack) * nic / (oversubscription * n_spines)``).
        A flow picks its spine by ECMP-style static hashing of the host
        pair, so the route is deterministic and rate integration exact.
        """
        if n_spines < 1:
            raise ValueError("need at least one spine")
        groups = cls._rack_names(racks, prefix="l")
        t = cls(f"leaf_spine_{n_spines}")
        leaf_of: dict[str, int] = {}
        for l, hosts in enumerate(groups):
            cap = uplink if uplink is not None else \
                len(hosts) * nic / (oversubscription * n_spines)
            for s in range(n_spines):
                t.add_link(f"leaf{l}.up{s}", cap)
                t.add_link(f"leaf{l}.down{s}", cap)
            for h in hosts:
                t.add_host(h, nic_in_cap=nic, nic_out_cap=nic)
                leaf_of[h] = l
        def routes(s: str, d: str) -> Optional[list[tuple[str, ...]]]:
            """Per-spine via-link candidates (None = same leaf)."""
            ls, ld = leaf_of[s], leaf_of[d]
            if ls == ld:
                return None
            # one candidate per spine; path() hash-picks index
            # ecmp_choice(s, d, n_spines), exactly the old static route
            return [(f"leaf{ls}.up{sp}", f"leaf{ld}.down{sp}")
                    for sp in range(n_spines)]

        t._multi = routes
        return t

    @classmethod
    def fat_tree(cls, k: int, *, nic: float = 1.0) -> "Topology":
        """k-ary fat-tree (k even): k pods of k/2 edge + k/2 agg switches,
        (k/2)^2 cores, k^3/4 hosts named ``p<pod>e<edge>h<i>``.

        All links have capacity ``nic`` (full bisection).  Core c attaches
        to agg ``c // (k/2)`` of every pod; ECMP hashing picks the agg
        (intra-pod) or core (inter-pod) per host pair.
        """
        if k < 2 or k % 2:
            raise ValueError("fat_tree needs even k >= 2")
        half = k // 2
        t = cls(f"fat_tree_{k}")
        where: dict[str, tuple[int, int]] = {}     # host -> (pod, edge)
        for p in range(k):
            for e in range(half):
                for a in range(half):
                    t.add_link(f"p{p}.e{e}a{a}.up", nic)
                    t.add_link(f"p{p}.e{e}a{a}.down", nic)
                for i in range(half):
                    h = f"p{p}e{e}h{i}"
                    t.add_host(h, nic_in_cap=nic, nic_out_cap=nic)
                    where[h] = (p, e)
            for a in range(half):
                for c in range(a * half, (a + 1) * half):
                    t.add_link(f"p{p}.a{a}c{c}.up", nic)
                    t.add_link(f"p{p}.a{a}c{c}.down", nic)
        def routes(s: str, d: str) -> Optional[list[tuple[str, ...]]]:
            """Clos via-link candidates (None = same edge switch)."""
            (ps, es), (pd, ed) = where[s], where[d]
            if (ps, es) == (pd, ed):                # same edge switch
                return None
            if ps == pd:                            # intra-pod: one per agg
                return [(f"p{ps}.e{es}a{a}.up", f"p{ps}.e{ed}a{a}.down")
                        for a in range(half)]
            # inter-pod: one candidate per core c (agg = c // half);
            # path() hash-picks index ecmp_choice(s, d, half*half)
            return [(f"p{ps}.e{es}a{c // half}.up",
                     f"p{ps}.a{c // half}c{c}.up",
                     f"p{pd}.a{c // half}c{c}.down",
                     f"p{pd}.e{ed}a{c // half}.down")
                    for c in range(half * half)]

        t._multi = routes
        return t
