"""Property-based tests (hypothesis): waterfill invariants on random
topologies.

Weighted max-min fairness has a crisp certificate (the bottleneck
characterization): an allocation is weighted max-min fair iff every flow
crosses a *bottleneck* link — one that is saturated and on which the flow's
normalized rate (rate/weight) is maximal among the link's flows.  These
tests generate random multi-tier fabrics and flow sets and check that
certificate plus the safety invariants directly against
:func:`repro.core.max_min_rates`.
"""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed (pip install -e .[test])")
from hypothesis import given, settings, strategies as st

from repro.core import Cluster, MXDAG, Topology, flow, max_min_rates, simulate
from repro.core.fabric import nic_in, nic_out

TOL = 1e-6

racks_st = st.lists(st.integers(min_value=1, max_value=4),
                    min_size=2, max_size=4)
oversub_st = st.floats(min_value=1.0, max_value=8.0,
                       allow_nan=False, allow_infinity=False)
weights_st = st.floats(min_value=0.25, max_value=4.0,
                       allow_nan=False, allow_infinity=False)


def build_topology(kind: str, racks: list[int], oversub: float) -> Topology:
    if kind == "two_tier":
        return Topology.two_tier([
            [f"r{r}h{i}" for i in range(n)] for r, n in enumerate(racks)],
            oversubscription=oversub)
    return Topology.leaf_spine(
        [[f"l{r}h{i}" for i in range(n)] for r, n in enumerate(racks)],
        n_spines=2, oversubscription=oversub)


def random_flows(topo: Topology, picks: list[int], ws: list[float]):
    """Flow name -> (path, weight) over random host pairs of the fabric."""
    hosts = topo.hosts()
    pairs = [(s, d) for s in hosts for d in hosts if s != d]
    paths, weights = {}, {}
    for k, (pi, w) in enumerate(zip(picks, ws)):
        s, d = pairs[pi % len(pairs)]
        paths[f"f{k}"] = topo.path(s, d)
        weights[f"f{k}"] = w
    return paths, weights


@st.composite
def fabric_case(draw):
    kind = draw(st.sampled_from(["two_tier", "leaf_spine"]))
    racks = draw(racks_st)
    oversub = draw(oversub_st)
    n_flows = draw(st.integers(min_value=1, max_value=10))
    picks = draw(st.lists(st.integers(min_value=0, max_value=10 ** 6),
                          min_size=n_flows, max_size=n_flows))
    ws = draw(st.lists(weights_st, min_size=n_flows, max_size=n_flows))
    topo = build_topology(kind, racks, oversub)
    paths, weights = random_flows(topo, picks, ws)
    return topo, paths, weights


class TestWaterfillInvariants:
    @given(case=fabric_case())
    @settings(max_examples=60, deadline=None)
    def test_no_link_over_capacity(self, case):
        topo, paths, weights = case
        rates = max_min_rates(paths, topo.links, weights)
        load: dict[str, float] = {}
        for n, p in paths.items():
            for l in p:
                load[l] = load.get(l, 0.0) + rates[n]
        for l, total in load.items():
            assert total <= topo.capacity(l) * (1 + TOL) + TOL

    @given(case=fabric_case())
    @settings(max_examples=60, deadline=None)
    def test_every_flow_progresses(self, case):
        topo, paths, weights = case
        rates = max_min_rates(paths, topo.links, weights)
        for n in paths:
            assert rates[n] > 0.0

    @given(case=fabric_case())
    @settings(max_examples=60, deadline=None)
    def test_bottleneck_certificate(self, case):
        """Every flow has a saturated link on its path where its
        normalized share is maximal — the weighted max-min certificate.
        A corollary checked with it: each flow's bottleneck is saturated.
        """
        topo, paths, weights = case
        rates = max_min_rates(paths, topo.links, weights)
        load: dict[str, float] = {}
        for n, p in paths.items():
            for l in p:
                load[l] = load.get(l, 0.0) + rates[n]
        for n, p in paths.items():
            norm = rates[n] / weights[n]
            found = False
            for l in p:
                saturated = load[l] >= topo.capacity(l) * (1 - TOL) - TOL
                is_max = all(rates[m] / weights[m] <= norm * (1 + TOL) + TOL
                             for m in paths if l in paths[m])
                if saturated and is_max:
                    found = True
                    break
            assert found, f"{n} has no bottleneck link on its path"

    @given(case=fabric_case())
    @settings(max_examples=30, deadline=None)
    def test_des_respects_link_capacity_over_time(self, case):
        """End-to-end: simulate the random flow set; completion of each
        link's flow volume can never beat the link's capacity bound."""
        topo, paths, weights = case
        cl = Cluster.from_topology(topo)
        g = MXDAG()
        endpoints = {}
        for n, p in paths.items():
            src = p[0][: -len(".nic_out")]
            dst = p[-1][: -len(".nic_in")]
            endpoints[n] = (src, dst)
            g.add(flow(n, 1.0, src, dst))
        r = simulate(g, cl)
        # per-link volume/capacity is a lower bound on the makespan
        vol: dict[str, float] = {}
        for n, p in paths.items():
            for l in p:
                vol[l] = vol.get(l, 0.0) + 1.0
        lb = max(v / topo.capacity(l) for l, v in vol.items())
        assert r.makespan >= lb * (1 - TOL) - TOL
