"""Validation of the trip-count-aware HLO cost model (the §Roofline
measurement instrument): exact on known-flop programs, exact loop
scaling, collective conventions."""
import os
import subprocess
import sys

import pytest

pytestmark = [pytest.mark.slow, pytest.mark.jax]

_PROBE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.launch.hlo_cost import analyze_text

out = {}

# 1) scan of 7 matmuls 64^3: flops must scale by trip count
def f(x, w):
    def body(c, wi):
        return jnp.tanh(c @ wi), jnp.sum(c)
    c, s = jax.lax.scan(body, x, w)
    return c.sum() + s.sum()
comp = jax.jit(f).lower(jax.ShapeDtypeStruct((64, 64), jnp.float32),
                        jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)
                        ).compile()
out["scan_flops"] = analyze_text(comp.as_text()).flops

# 2) plain matmul: must match XLA's own cost_analysis exactly
def g(a, b):
    return a @ b
comp2 = jax.jit(g).lower(jax.ShapeDtypeStruct((128, 256), jnp.float32),
                         jax.ShapeDtypeStruct((256, 512), jnp.float32)
                         ).compile()
xc = comp2.cost_analysis()
xc = xc[0] if isinstance(xc, list) else xc
out["matmul_flops"] = analyze_text(comp2.as_text()).flops
out["matmul_flops_xla"] = float(xc["flops"])

# 3) psum inside a scan: collective bytes scale by trips
mesh = jax.make_mesh((8,), ("d",))
def h(xs):
    def body(c, x):
        y = shard_map(lambda v: jax.lax.psum(v, "d"), mesh=mesh,
                      in_specs=P("d"), out_specs=P())(x)
        return c + y.sum(), None
    return jax.lax.scan(body, 0.0, xs)[0]
comp3 = jax.jit(h).lower(
    jax.ShapeDtypeStruct((5, 64), jnp.float32)).compile()
out["scan_coll"] = analyze_text(comp3.as_text()).coll

# 4) nested scans: multiplicative trip scaling
def nest(x, w):
    def outer(c, _):
        def inner(ci, wi):
            return ci @ wi, None
        c2, _ = jax.lax.scan(inner, c, w)
        return c2, None
    return jax.lax.scan(outer, x, None, length=3)[0].sum()
comp4 = jax.jit(nest).lower(jax.ShapeDtypeStruct((32, 32), jnp.float32),
                            jax.ShapeDtypeStruct((5, 32, 32), jnp.float32)
                            ).compile()
out["nested_flops"] = analyze_text(comp4.as_text()).flops
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def probe():
    import json
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    res = subprocess.run([sys.executable, "-c", _PROBE],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert res.returncode == 0, res.stderr[-2000:]
    return json.loads(res.stdout.strip().splitlines()[-1])


def test_scan_flops_scaled_by_trip_count(probe):
    assert probe["scan_flops"] == 7 * 2 * 64 ** 3


def test_plain_matmul_matches_xla(probe):
    assert probe["matmul_flops"] == probe["matmul_flops_xla"]
    assert probe["matmul_flops"] == 2 * 128 * 256 * 512


def test_collectives_scaled_by_trip_count(probe):
    # psum of 64 f32 on 8 devices: all-reduce convention 2x input bytes,
    # per shard input = 8 f32 = 32B -> 64B x 5 trips = 320
    assert probe["scan_coll"] == {"all-reduce": 320.0}


def test_nested_scan_multiplicative(probe):
    assert probe["nested_flops"] == 3 * 5 * 2 * 32 ** 3
