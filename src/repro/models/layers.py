"""Shared model layers: norms, RoPE, MLP variants, embeddings.

Pure-functional JAX: every layer is ``f(params, x, ...)`` with params as
plain dicts of arrays, so layer stacks can be scanned with stacked params
(leading layer axis) — the key to small HLO / fast compiles at 512 devices.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

Params = dict


# ----------------------------------------------------------------------
# init helpers
# ----------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, *, scale: Optional[float] = None,
               dtype=jnp.bfloat16) -> jax.Array:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.bfloat16) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
            ).astype(dtype)


# ----------------------------------------------------------------------
# norms
# ----------------------------------------------------------------------
def rmsnorm_init(d: int, dtype=jnp.bfloat16) -> jax.Array:
    return jnp.ones((d,), dtype)


def _rmsnorm_raw(w: jax.Array, x: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _rmsnorm_cvjp(eps: float, w: jax.Array, x: jax.Array) -> jax.Array:
    return _rmsnorm_raw(w, x, eps)


def _rmsnorm_fwd(eps, w, x):
    return _rmsnorm_raw(w, x, eps), (w, x)


def _rmsnorm_bwd(eps, res, g):
    """Analytic backward (fewer fp32 temporaries than autodiff of the
    fp32 forward — those [B,S,d] fusions were the single largest HBM
    term on deepseek-v3 train, §Perf iter 3):

        x̂ = x·rsqrt(mean x² + eps);  y = x̂·w
        dw = Σ_batch g·x̂
        dx = rsqrt(·) · ( g·w − x̂ · mean(g·w·x̂, -1) )
    """
    w, x = res
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    ih = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    xhat = xf * ih
    dw = jnp.sum((gf * xhat).reshape(-1, x.shape[-1]), axis=0)
    gw = gf * wf
    dx = ih * (gw - xhat * jnp.mean(gw * xhat, axis=-1, keepdims=True))
    return dw.astype(w.dtype), dx.astype(x.dtype)


_rmsnorm_cvjp.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


def rmsnorm(w: jax.Array, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm with fp32 internals whose BACKWARD returns dx in x's dtype.

    Without this, the fp32 upcast inside the norm drags the whole
    activation-cotangent chain — and therefore every TP partial-sum
    all-reduce in the block backward — into fp32 (§Perf internvl2
    iter 7: halves those wire bytes; standard mixed-precision practice).
    """
    return _rmsnorm_cvjp(eps, w, x)


# ----------------------------------------------------------------------
# rotary position embeddings (full and partial/2d)
# ----------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float, fraction: float = 1.0
               ) -> jax.Array:
    """Inverse frequencies for the rotated sub-dimension."""
    rot = int(head_dim * fraction)
    rot -= rot % 2
    return 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               fraction: float = 1.0) -> jax.Array:
    """x: [..., S, H, hd]; positions: broadcastable to [..., S].

    With fraction < 1 only the first ``fraction`` of head dims rotate
    (chatglm3's 2d RoPE); the rest pass through.
    """
    hd = x.shape[-1]
    rot = int(hd * fraction)
    rot -= rot % 2
    inv = rope_freqs(hd, theta, fraction)                       # [rot/2]
    ang = positions[..., None].astype(jnp.float32) * inv        # [...,S,rot/2]
    cos = jnp.cos(ang)[..., None, :]                            # [...,S,1,r/2]
    sin = jnp.sin(ang)[..., None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    yr = jnp.stack([y1, y2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([yr.astype(x.dtype), xp], axis=-1)


# ----------------------------------------------------------------------
# MLP variants
# ----------------------------------------------------------------------
def mlp_init(key, d: int, ff: int, kind: str, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 3)
    p = {"w_out": dense_init(ks[2], ff, d, dtype=dtype)}
    if kind == "swiglu":
        p["w_in"] = dense_init(ks[0], d, ff, dtype=dtype)
        p["w_gate"] = dense_init(ks[1], d, ff, dtype=dtype)
    elif kind in ("relu2", "gelu"):
        p["w_in"] = dense_init(ks[0], d, ff, dtype=dtype)
    else:
        raise ValueError(kind)
    return p


def mlp(p: Params, x: jax.Array, kind: str) -> jax.Array:
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_in"])
    elif kind == "relu2":
        h = jnp.square(jax.nn.relu(x @ p["w_in"]))
    elif kind == "gelu":
        h = jax.nn.gelu(x @ p["w_in"])
    else:
        raise ValueError(kind)
    return h @ p["w_out"]


def mlp_flops(d: int, ff: int, kind: str) -> int:
    mats = 3 if kind == "swiglu" else 2
    return 2 * mats * d * ff


# ----------------------------------------------------------------------
# losses
# ----------------------------------------------------------------------
def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None,
                  vocab_sharded: bool = True) -> jax.Array:
    """Token-mean CE; logits [..., V] (any dtype — reduced in fp32),
    labels int [...].

    ``vocab_sharded=True`` (V divides the model axis): the label logit is
    extracted with a one-hot contraction — every vocab-axis op partitions
    cleanly under GSPMD and ``take_along_axis`` (which would all-gather
    the logits) is avoided.  ``False`` (odd vocab, logits replicated on
    V): take_along_axis is cheaper — materializing the [.., V] one-hot in
    fp32 cost ~24 GB/step on internvl2 (vocab 92553; §Perf iter 2).
    """
    logits = logits.astype(jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    shifted = logits - jax.lax.stop_gradient(m)
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    V = logits.shape[-1]
    if vocab_sharded:
        onehot = jax.nn.one_hot(labels, V, dtype=logits.dtype)
        label_logit = jnp.sum(shifted * onehot, axis=-1)
    else:
        label_logit = jnp.take_along_axis(
            shifted, labels[..., None], axis=-1)[..., 0]
    nll = lse - label_logit
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
