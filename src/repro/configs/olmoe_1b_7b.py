"""olmoe-1b-7b — 64-expert top-8 MoE, every layer.

[arXiv:2409.02060; hf]  16L d_model=2048 16H (GQA kv=16) d_ff=1024
vocab=50304, MoE 64e top-8 (dropless in the paper; capacity-based here
with cf=1.25 — see DESIGN.md).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    n_experts=64,
    n_experts_per_tok=8,
    moe_d_ff=1024,
    moe_layer_period=1,
    rope_theta=1e4,
)
