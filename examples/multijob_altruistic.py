"""Altruistic multi-job scheduling (paper §4.2, Fig. 7 + generalization).

Two map-reduce jobs share hosts and NICs.  Principle 2 lets job 1 delay
its slack-rich non-critical tasks so job 2's critical path gets the
resources — job 2 finishes earlier, job 1 is unharmed.  Then the same
principle applied to a 6-job mix.

Run:  PYTHONPATH=src python examples/multijob_altruistic.py
"""
import sys
sys.path.insert(0, "src")

from repro.core import AltruisticMultiScheduler, MXDAG, simulate
from repro.core.builders import mapreduce, mapreduce_pair

# --- the paper's Fig. 7 -------------------------------------------------
j1, j2 = mapreduce_pair()
merged = MXDAG("merged")
for t in list(j1) + list(j2):
    merged.add(t)
for e in list(j1.edges.values()) + list(j2.edges.values()):
    merged.add_edge(e.src, e.dst)

naive = simulate(merged, policy="fair")
alt = AltruisticMultiScheduler().schedule([j1, j2]).simulate()
print("Fig. 7 (two map-reduce jobs):")
print(f"  fair sharing : job1 JCT {naive.jct('job1')},  "
      f"job2 JCT {naive.jct('job2')}  (T2)")
print(f"  altruistic   : job1 JCT {alt.jct('job1')},  "
      f"job2 JCT {alt.jct('job2')}  (T1 < T2, job1 unharmed)")

# --- a 6-job mix --------------------------------------------------------
# each job has a long private map (a_i) and a short map (b_i) on a SHARED
# host, feeding a private reducer through the shared host's NIC — the
# Fig. 7 structure generalized: longer jobs have more slack to donate.
from repro.core import compute, flow

jobs = []
for i in range(6):
    j = MXDAG(f"job{i}")
    a = j.add(compute(f"a{i}", 1.0 + 2 * i, f"Ha{i}", job=f"job{i}"))
    b = j.add(compute(f"b{i}", 0.5, f"Hb{i}", job=f"job{i}"))
    f1 = j.add(flow(f"f1_{i}", 1.0, f"Ha{i}", f"Hr{i}", job=f"job{i}"))
    # every job's shuffle f2 crosses the SHARED host's egress NIC
    f2 = j.add(flow(f"f2_{i}", 2.0, "Hshare", f"Hr{i}", job=f"job{i}"))
    r = j.add(compute(f"r{i}", 1.0, f"Hr{i}", job=f"job{i}"))
    j.add_edge(a, f1); j.add_edge(b, f2)
    j.add_edge(f1, r); j.add_edge(f2, r)
    jobs.append(j)
merged = MXDAG("mix")
for j in jobs:
    for t in j:
        merged.add(t)
    for e in j.edges.values():
        merged.add_edge(e.src, e.dst)
naive = simulate(merged, policy="fair")
alt = AltruisticMultiScheduler().schedule(jobs).simulate()
print("\n6-job mix (per-job JCT, fair -> altruistic):")
wins = 0
for i in range(6):
    a, b = naive.jct(f"job{i}"), alt.jct(f"job{i}")
    mark = "↓" if b < a - 1e-9 else ("=" if abs(a - b) < 1e-9 else "↑")
    wins += b <= a + 1e-9
    print(f"  job{i}: {a:6.2f} -> {b:6.2f}  {mark}")
print(f"  mean JCT: {sum(naive.jct(f'job{i}') for i in range(6))/6:.2f}"
      f" -> {sum(alt.jct(f'job{i}') for i in range(6))/6:.2f}")
