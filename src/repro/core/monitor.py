"""Runtime monitoring & straggler identification over an MXDAG (§4.3).

Because MXDAG distinguishes compute from network tasks, lagging progress on
a node immediately identifies *which kind* of straggler it is — "traditional
DAG cannot distinguish those two kinds of stragglers".  The monitor also
re-estimates task sizes from observed progress and recomputes the critical
path so the scheduler can replan at runtime.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.graph import MXDAG
from repro.core.simulator import SimResult
from repro.core.task import MXTask, TaskKind


@dataclasses.dataclass
class Straggler:
    """A task projected to finish later than its expected schedule."""

    task: str
    kind: TaskKind          # host straggler vs network straggler
    expected_finish: float
    projected_finish: float

    @property
    def lag(self) -> float:
        """Projected minus expected finish time (seconds late)."""
        return self.projected_finish - self.expected_finish


@dataclasses.dataclass
class Observation:
    """One runtime progress report for a task."""

    time: float
    fraction: float         # fraction of the task's work completed


class Monitor:
    """Runtime introspection: progress reports vs the expected schedule."""

    def __init__(self, graph: MXDAG, expected: SimResult,
                 *, threshold: float = 0.2):
        """``threshold``: relative lag beyond which a task is a straggler."""
        self.graph = graph
        self.expected = expected
        self.threshold = threshold
        self.obs: dict[str, Observation] = {}

    def observe(self, task: str, fraction: float, time: float) -> None:
        """Record that ``task`` had completed ``fraction`` at ``time``.

        ``fraction`` is clamped to [0, 1]: progress probes built on
        noisy byte/FLOP counters routinely report slightly-negative or
        >100% fractions at the edges, and a negative fraction would
        otherwise poison :meth:`projected_finish`'s rate estimate with a
        negative rate (projecting finish into the past).
        """
        if task not in self.graph.tasks:
            raise KeyError(task)
        self.obs[task] = Observation(time=time,
                                     fraction=min(1.0, max(0.0, fraction)))

    # ------------------------------------------------------------------
    def projected_finish(self, task: str) -> Optional[float]:
        """Linear extrapolation from observed progress."""
        o = self.obs.get(task)
        if o is None:
            return None
        if o.fraction >= 1.0:
            return o.time
        exp_start = self.expected.start[task]
        if o.fraction <= 0.0:
            # not started: shift the expected duration to start "now"
            dur = self.expected.finish[task] - exp_start
            return max(o.time, exp_start) + dur
        rate = o.fraction / max(o.time - exp_start, 1e-12)
        return o.time + (1.0 - o.fraction) / rate

    def stragglers(self) -> list[Straggler]:
        """Observed tasks lagging beyond the relative threshold."""
        out = []
        for name, o in sorted(self.obs.items()):
            proj = self.projected_finish(name)
            exp = self.expected.finish[name]
            dur = max(exp - self.expected.start[name], 1e-12)
            if proj is not None and proj > exp + self.threshold * dur:
                out.append(Straggler(task=name,
                                     kind=self.graph.tasks[name].kind,
                                     expected_finish=exp,
                                     projected_finish=proj))
        return out

    def host_stragglers(self) -> list[Straggler]:
        """Stragglers among compute tasks."""
        return [s for s in self.stragglers() if s.kind is TaskKind.COMPUTE]

    def network_stragglers(self) -> list[Straggler]:
        """Stragglers among flows."""
        return [s for s in self.stragglers() if s.kind is TaskKind.NETWORK]

    # ------------------------------------------------------------------
    def reestimated_graph(self) -> MXDAG:
        """Graph with task sizes re-scaled by observed progress rates."""
        g = self.graph.copy()
        for name, o in self.obs.items():
            proj = self.projected_finish(name)
            if proj is None or o.fraction >= 1.0:
                continue
            t = g.tasks[name]
            exp_start = self.expected.start[name]
            new_size = max(proj - exp_start, 1e-12)
            unit = t.unit
            if unit is not None:
                unit = unit * new_size / max(t.size, 1e-12)
            g.replace_task(dataclasses.replace(t, size=new_size, unit=unit))
        return g

    def replan_critical_path(self, release: Optional[dict[str, float]]
                             = None) -> list[str]:
        """New critical path after folding in runtime observations.

        Observed tasks are pinned to their starts: each one's planned
        start is threaded into the analytic pass as a ``release`` (the
        progress-rate re-estimation already extrapolates from that
        start), so a branch that began late stays late in the replanned
        path instead of being evaluated as if it could restart at t=0.
        Pass ``release`` explicitly to override — e.g. with actually
        observed start times when they diverge from the plan.
        """
        if release is None:
            release = {n: self.expected.start[n] for n in self.obs}
        return self.reestimated_graph().critical_path(release=release)
