"""Training step assembly + CLI driver.

``make_train_step`` wires model.loss → grads → (optional fp8
error-feedback compression) → AdamW into a single jit-able function whose
state is {"params", "opt"[, "err"]}.  The gradient-sync *structure*
(barrier vs MXDAG-planned layer-wise overlap) is selected by
``RunConfig.sync_mode`` inside the model (see repro/sync/overlap.py).

CLI:  PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
          --steps 200 --batch 8 --seq 256
runs a real (CPU-sized) training with checkpoint/restart support.
"""
from __future__ import annotations

import argparse
import time
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import ArchConfig, RunConfig, ShapeConfig
from repro.data import DataConfig, SyntheticLM
from repro.launch import sharding as shard_lib
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.models import Model
from repro.optim import AdamW, AdamWConfig, compression, cosine_schedule


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """6·N·D (train) / 2·N·tokens (inference), N = active params."""
    n = cfg.param_counts()["active"]
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch        # decode: one token


def make_train_step(model: Model, optimizer: AdamW, run: RunConfig):
    grad_fn = jax.value_and_grad(
        lambda p, b: model.loss(p, b), has_aux=True)

    def compute_grads(params, batch):
        """Optionally gradient-accumulated over microbatches: peak
        activation memory scales 1/k while grads accumulate sharded."""
        k = run.microbatches
        if k <= 1:
            (_, metrics), grads = grad_fn(params, batch)
            return grads, metrics

        B = batch["tokens"].shape[0]
        mb = jax.tree.map(
            lambda x: x.reshape(k, B // k, *x.shape[1:]), batch)
        if model.mesh is not None:
            # PERF (hillclimb iter: internvl2#1): the reshape splits the
            # data-sharded batch dim; without a constraint GSPMD reshards
            # batch onto a 4-way slice of the mesh and REPLICATES
            # activations 4x across the rest (measured: per-layer
            # [B,S,d] all-gathers).  Pin: mb dim replicated, batch dim
            # sharded over dp.
            from jax.sharding import NamedSharding, PartitionSpec as P
            dp = model.dp_axes
            mb = jax.tree.map(
                lambda x: jax.lax.with_sharding_constraint(
                    x, NamedSharding(model.mesh,
                                     P(None, dp,
                                       *([None] * (x.ndim - 2))))), mb)

        def body(gacc, mbatch):
            (_, metrics), g = grad_fn(params, mbatch)
            gacc = jax.tree.map(
                lambda a, x: a + x.astype(jnp.float32), gacc, g)
            return gacc, metrics

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                          params)
        gsum, metrics_all = jax.lax.scan(body, g0, mb)
        grads = jax.tree.map(lambda g: g / k, gsum)
        metrics = jax.tree.map(lambda m: jnp.mean(m), metrics_all)
        return grads, metrics

    def train_step(state: dict, batch: dict):
        grads, metrics = compute_grads(state["params"], batch)

        new_state = dict(state)
        if run.grad_compression:
            g8, scales, new_err = compression.compress_tree(
                grads, state["err"])
            grads = compression.decompress_tree(g8, scales)
            new_state["err"] = new_err

        new_params, new_opt = optimizer.update(
            grads, state["opt"], state["params"])
        new_state["params"] = new_params
        new_state["opt"] = new_opt
        return new_state, metrics

    return train_step


def init_train_state(model: Model, optimizer: AdamW, run: RunConfig,
                     rng) -> dict:
    params = model.init(rng)
    state = {"params": params, "opt": optimizer.init(params)}
    if run.grad_compression:
        state["err"] = compression.init_error_state(params)
    return state


def state_shardings(state_shapes: dict, cfg: ArchConfig, run: RunConfig,
                    mesh) -> dict:
    out = {"params": shard_lib.param_shardings(
        state_shapes["params"], cfg, run, mesh)}
    out["opt"] = shard_lib.opt_state_shardings(
        state_shapes["opt"], state_shapes["params"], cfg, run, mesh)
    if "err" in state_shapes:
        out["err"] = shard_lib.param_shardings(
            state_shapes["err"], cfg, run, mesh)
    return out


# ----------------------------------------------------------------------
def main(argv: Optional[list[str]] = None) -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="mamba2-130m")
    p.add_argument("--smoke", action="store_true",
                   help="use the reduced config")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=256)
    p.add_argument("--lr", type=float, default=3e-3)
    p.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--sync-mode", default="bucketed",
                   choices=["bucketed", "barrier"])
    p.add_argument("--mesh", default="1x1",
                   help="dataxmodel, e.g. 2x1")
    args = p.parse_args(argv)

    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = jax.make_mesh((d, m), ("data", "model"))
    cfg = configs.get_smoke(args.arch) if args.smoke \
        else configs.get(args.arch)
    run = RunConfig(sync_mode=args.sync_mode, remat=True)
    model = Model(cfg, run, mesh=mesh, dp_axes=dp_axes(mesh))
    opt = AdamW(AdamWConfig(
        lr=cosine_schedule(args.lr, warmup=20, total=args.steps)))

    data = SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch))

    from repro.runtime import LoopConfig, StepMonitor, run_training

    step_fn = jax.jit(make_train_step(model, opt, run), donate_argnums=0)
    monitor = StepMonitor()

    def on_step(step, metrics):
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:5d}  loss {float(metrics['loss']):.4f}")

    t0 = time.monotonic()
    summary = run_training(
        LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                   ckpt_every=args.ckpt_every),
        train_step=step_fn,
        init_state=lambda: init_train_state(
            model, opt, run, jax.random.PRNGKey(0)),
        batch_at=data.batch_at,
        monitor=monitor,
        on_step=on_step)
    dt = time.monotonic() - t0
    print(f"done: {summary['final_step'] + 1} steps in {dt:.1f}s, "
          f"restarts={summary['restarts']}, "
          f"loss {summary['loss_history'][0]:.3f} -> "
          f"{summary['loss_history'][-1]:.3f}")


if __name__ == "__main__":
    main()
