"""Compiled analytic layer: the scheduler's forward/reverse passes on
flat arrays.

:meth:`MXDAG.evaluate` / :meth:`MXDAG.with_slack` /
:meth:`MXDAG.critical_path` key every intermediate by task-name strings
and allocate one ``NodeTiming`` per task.  At Graphene scale (tens of
thousands of vertices) those dict-per-task passes dominate
``MXDAGScheduler.schedule()`` — exactly the per-DAG overhead DAGPS /
Graphene-style schedulers need to keep negligible.  This module compiles
one graph into integer-interned flat arrays once per graph version and
runs the *same* recursions as level-batched vectorized passes:

- :class:`CompiledAnalytic` — insertion-order task ids, lexicographic
  ``name_rank`` (reproducing every name-ordered tie-break on ints),
  per-task ``size`` / ``effective_unit`` scalars, predecessor and
  successor CSR with per-edge effective-pipelining flags, and a
  longest-path *level* partition of the topological order (every node's
  predecessors live in strictly lower levels, so one level is one
  vectorized step).  Cached on the graph as ``_analytic_cache`` keyed by
  the graph version; :func:`repro.core.arraysim._compile` reuses the
  same interning, so the scheduler's analytic passes and its DES runs
  share one compile.
- :func:`analyze` — forward (``ready`` / ``first_out`` / ``completion``)
  plus reverse (``latest_completion`` ⇒ slack) passes over the arrays,
  returning an :class:`AnalyticTiming` of flat per-task vectors.
- :func:`critical_path` — the same longest-path walk-back as the dict
  implementation, on interned ids.

Bit-exactness: every arithmetic step is the same IEEE-754 operation the
dict implementation performs (``max``/``min`` are exact, and each
``+``/``-``/``/`` maps one-to-one), so the results are *bit-equal* —
not merely close — to ``MXDAG.evaluate``/``with_slack``/
``critical_path`` on every graph; the golden equivalence tests assert
``==``, not ``approx``.  NumPy is optional and import-guarded (the core
CI lane runs pure-stdlib): without it the same compiled arrays are
walked by scalar loops that mirror the dict recursion exactly.
"""
from __future__ import annotations

import math
from typing import Optional

try:
    import numpy as np
except ImportError:                      # pure-stdlib core lane
    np = None

from repro.core.task import TaskKind


class CompiledAnalytic:
    """Flat-array form of one MXDAG (analytic-pass substrate)."""

    __slots__ = (
        "n", "names", "idx", "name_rank", "size", "eunit", "nu",
        "is_compute", "job",
        # pred/succ adjacency: per-node tuples (stdlib fallback + shared
        # with the arraysim compile) and the matching pipelined flags
        "pred_lists", "pred_pipe", "succ_lists", "succ_pipe",
        "any_pipe", "sinks", "order", "lvl_ptr",
        # NumPy mirrors (None when NumPy is absent): CSR aligned to the
        # level order so one level is one reduceat
        "np_ready", "size_a", "eunit_a", "order_a",
        "pred_ptr_a", "pred_flat_a", "pred_pipe_a",
        # reverse pass: nodes with successors, sorted by descending
        # level, with succ CSR aligned to that order
        "rev_nodes_a", "rev_ptr_a", "rev_flat_a", "rev_pipe_a",
        "rev_lvl_ptr", "sinks_a",
    )


def compile_analytic(g) -> CompiledAnalytic:
    """Compiled analytic arrays for ``g``, cached per graph version."""
    cached = g.__dict__.get("_analytic_cache")
    if cached is not None and cached[0] == g._version:
        return cached[1]
    comp = _compile(g)
    g._analytic_cache = (g._version, comp)
    return comp


def _compile(g) -> CompiledAnalytic:
    tasks = g.tasks
    comp = CompiledAnalytic()
    names = list(tasks)
    idx = {nm: i for i, nm in enumerate(names)}
    n = len(names)
    comp.n, comp.names, comp.idx = n, names, idx

    rank = [0] * n
    for r, nm in enumerate(sorted(names)):
        rank[idx[nm]] = r
    comp.name_rank = rank

    size = [0.0] * n
    eunit = [0.0] * n
    nu = [1] * n
    is_compute = [False] * n
    job = [""] * n
    pipeable = [False] * n
    ceil = math.ceil
    for i, t in enumerate(tasks.values()):
        sz = t.size
        u = t.unit
        size[i] = sz
        eu = u if u is not None else sz
        eunit[i] = eu
        if sz > 0:                  # MXTask.n_units, inlined
            k = int(ceil(sz / eu - 1e-12))
            nu[i] = k if k > 1 else 1
        is_compute[i] = t.kind is TaskKind.COMPUTE
        job[i] = t.job
        pipeable[i] = u is not None and u < sz
    comp.size, comp.eunit, comp.nu = size, eunit, nu
    comp.is_compute, comp.job = is_compute, job

    # adjacency with effective-pipelining flags, resolved in ONE pass
    # over the edge dict (the dict passes call effective_pipelined per
    # edge per pass; add_edge appends to _pred/_succ in edge-insertion
    # order, so this reproduces the per-node adjacency order exactly)
    pred_lists: list[list[int]] = [[] for _ in range(n)]
    pred_pipe: list[list[bool]] = [[] for _ in range(n)]
    succ_lists: list[list[int]] = [[] for _ in range(n)]
    succ_pipe: list[list[bool]] = [[] for _ in range(n)]
    any_pipe = False
    for (s, d), e in g.edges.items():
        si, di = idx[s], idx[d]
        f = e.pipelined and pipeable[si] and pipeable[di]
        if f:
            any_pipe = True
        pred_lists[di].append(si)
        pred_pipe[di].append(f)
        succ_lists[si].append(di)
        succ_pipe[si].append(f)
    comp.pred_lists, comp.pred_pipe = pred_lists, pred_pipe
    comp.succ_lists, comp.succ_pipe = succ_lists, succ_pipe
    comp.any_pipe = any_pipe
    comp.sinks = [i for i in range(n) if not succ_lists[i]]

    # longest-path levels: every predecessor of a level-l node lives in
    # a level < l, so the forward pass is one batched step per level
    # (and level 0 ⇔ no predecessors, so deeper pred segments are never
    # empty).  Kahn by waves: a node is released only after its last —
    # i.e. deepest — predecessor's wave, so wave k IS longest-path
    # depth k.
    indeg = [len(pred_lists[i]) for i in range(n)]
    frontier = [i for i in range(n) if not indeg[i]]
    order: list[int] = []
    lvl_ptr = [0]
    while frontier:
        order.extend(frontier)
        lvl_ptr.append(len(order))
        nxt: list[int] = []
        for i in frontier:
            for s in succ_lists[i]:
                indeg[s] -= 1
                if not indeg[s]:
                    nxt.append(s)
        frontier = nxt
    if len(order) != n:
        raise ValueError("graph has a cycle")
    comp.order = order
    comp.lvl_ptr = lvl_ptr

    comp.np_ready = np is not None
    if comp.np_ready:
        comp.size_a = np.array(size, dtype=np.float64)
        comp.eunit_a = np.array(eunit, dtype=np.float64)
        comp.order_a = np.array(order, dtype=np.int64)
        ptr = [0]
        flat: list[int] = []
        pipe: list[bool] = []
        for v in order:
            flat.extend(pred_lists[v])
            pipe.extend(pred_pipe[v])
            ptr.append(len(flat))
        comp.pred_ptr_a = np.array(ptr, dtype=np.int64)
        comp.pred_flat_a = np.array(flat, dtype=np.int64)
        comp.pred_pipe_a = np.array(pipe, dtype=bool)
        # reverse structures: nodes with successors by descending level
        # (an edge u→v implies level(v) > level(u), so every successor
        # is finalized — as a deeper node or a sink — before u runs)
        rev: list[int] = []
        rlvl = [0]
        for li in range(len(lvl_ptr) - 2, -1, -1):
            for p in range(lvl_ptr[li], lvl_ptr[li + 1]):
                v = order[p]
                if succ_lists[v]:
                    rev.append(v)
            if len(rev) != rlvl[-1]:
                rlvl.append(len(rev))
        rptr = [0]
        rflat: list[int] = []
        rpipe: list[bool] = []
        for v in rev:
            rflat.extend(succ_lists[v])
            rpipe.extend(succ_pipe[v])
            rptr.append(len(rflat))
        comp.rev_nodes_a = np.array(rev, dtype=np.int64)
        comp.rev_ptr_a = np.array(rptr, dtype=np.int64)
        comp.rev_flat_a = np.array(rflat, dtype=np.int64)
        comp.rev_pipe_a = np.array(rpipe, dtype=bool)
        comp.rev_lvl_ptr = rlvl
        comp.sinks_a = np.array(comp.sinks, dtype=np.int64)
    else:
        comp.size_a = comp.eunit_a = comp.order_a = None
        comp.pred_ptr_a = comp.pred_flat_a = comp.pred_pipe_a = None
        comp.rev_nodes_a = comp.rev_ptr_a = None
        comp.rev_flat_a = comp.rev_pipe_a = None
        comp.rev_lvl_ptr = comp.sinks_a = None
    return comp


class AnalyticTiming:
    """Per-task analytic timing as flat vectors (indexed like
    ``CompiledAnalytic.names``); the array counterpart of the
    ``{name: NodeTiming}`` dicts the MXDAG methods return."""

    __slots__ = ("names", "idx", "ready", "first_out", "completion",
                 "latest", "slack", "makespan")

    def __init__(self, names, idx, ready, first_out, completion,
                 latest, slack, makespan):
        self.names = names
        self.idx = idx
        self.ready = ready
        self.first_out = first_out
        self.completion = completion
        self.latest = latest
        self.slack = slack
        self.makespan = makespan

    def to_dict(self):
        """The equivalent ``MXDAG.with_slack()`` dict (tests, adapters)."""
        from repro.core.graph import NodeTiming
        out = {}
        for i, nm in enumerate(self.names):
            out[nm] = NodeTiming(ready=self.ready[i],
                                 first_out=self.first_out[i],
                                 completion=self.completion[i],
                                 latest_completion=self.latest[i])
        return out


def _times(comp: CompiledAnalytic, rsrc: Optional[dict]):
    """(completion-time, unit-time) vectors under ``rsrc``.

    ``x / 1.0 == x`` bitwise, so the unscaled vectors are shared as-is;
    scaled entries perform the identical per-element division the dict
    passes run through ``MXTask.time`` / ``unit_time`` (including their
    argument validation)."""
    if not rsrc:
        return comp.size, comp.eunit, comp.size_a, comp.eunit_a
    times = list(comp.size)
    utimes = list(comp.eunit)
    idx = comp.idx
    for nm, f in rsrc.items():
        i = idx.get(nm)
        if i is None:
            continue
        if not (0 < f <= 1.0 + 1e-12):
            raise ValueError(f"rsrc must be in (0,1], got {f}")
        times[i] = times[i] / f
        utimes[i] = utimes[i] / f
    if comp.np_ready and np is not None:
        return times, utimes, np.array(times), np.array(utimes)
    return times, utimes, None, None


def _release_vec(comp: CompiledAnalytic, release: Optional[dict]):
    rel = [0.0] * comp.n
    if release:
        idx = comp.idx
        for nm, v in release.items():
            i = idx.get(nm)
            if i is not None:
                rel[i] = v
    return rel


def forward(g, rsrc: Optional[dict] = None,
            release: Optional[dict] = None):
    """The :meth:`MXDAG.evaluate` recursion on compiled arrays.

    Returns ``(comp, times, utimes, ready, first_out, completion)``
    where the last three are per-task float lists.
    """
    return _forward(g, rsrc, release)[:6]


def _forward(g, rsrc: Optional[dict], release: Optional[dict]):
    """forward() plus, on the NumPy path, the ndarray forms of
    (completion, times, utimes) so analyze() reuses them instead of
    round-tripping the lists back through np.array (None on the
    stdlib path)."""
    comp = compile_analytic(g)
    times, utimes, times_a, utimes_a = _times(comp, rsrc)
    rel = _release_vec(comp, release)
    n = comp.n
    if comp.np_ready and np is not None and n:
        fo = np.empty(n)
        cpl = np.empty(n)
        rdy = np.empty(n)
        rel_a = np.array(rel)
        order_a, lvl = comp.order_a, comp.lvl_ptr
        pptr, pflat, ppipe = (comp.pred_ptr_a, comp.pred_flat_a,
                              comp.pred_pipe_a)
        if times_a is None:
            times_a, utimes_a = comp.size_a, comp.eunit_a
        any_pipe = comp.any_pipe
        for li in range(len(lvl) - 1):
            a, b = lvl[li], lvl[li + 1]
            vs = order_a[a:b]
            if li == 0:                      # roots: release only
                r = rel_a[vs]
            else:
                off = pptr[a:b] - pptr[a]
                pf = pflat[pptr[a]:pptr[b]]
                pp = ppipe[pptr[a]:pptr[b]]
                vals = np.where(pp, fo[pf], cpl[pf])
                r = np.maximum(rel_a[vs], np.maximum.reduceat(vals, off))
            ut = utimes_a[vs]
            c = r + times_a[vs]
            if any_pipe and li > 0 and pp.any():
                counts = pptr[a + 1:b + 1] - pptr[a:b]
                vals2 = np.where(pp, cpl[pf] + np.repeat(ut, counts), 0.0)
                c = np.maximum(c, np.maximum.reduceat(vals2, off))
            else:
                c = np.maximum(c, 0.0)       # dict floor starts at 0.0
            rdy[vs] = r
            fo[vs] = r + ut
            cpl[vs] = c
        return (comp, times, utimes, rdy.tolist(), fo.tolist(),
                cpl.tolist(), (cpl, times_a, utimes_a))

    # pure-stdlib: the dict recursion on interned ids
    rdy = [0.0] * n
    fo = [0.0] * n
    cpl = [0.0] * n
    pred_lists, pred_pipe = comp.pred_lists, comp.pred_pipe
    for v in comp.order:
        ready = rel[v]
        floor = 0.0
        ut = utimes[v]
        preds = pred_lists[v]
        if preds:
            for p, pipe in zip(preds, pred_pipe[v]):
                if pipe:
                    x = fo[p]
                    if x > ready:
                        ready = x
                    c2 = cpl[p] + ut
                    if c2 > floor:
                        floor = c2
                else:
                    x = cpl[p]
                    if x > ready:
                        ready = x
        c = ready + times[v]
        if floor > c:
            c = floor
        rdy[v] = ready
        fo[v] = ready + ut
        cpl[v] = c
    return comp, times, utimes, rdy, fo, cpl, None


def analyze(g, rsrc: Optional[dict] = None,
            release: Optional[dict] = None) -> AnalyticTiming:
    """Forward + reverse pass: the array form of
    :meth:`MXDAG.with_slack` (bit-equal values)."""
    comp, times, utimes, rdy, fo, cpl, fwd_np = _forward(g, rsrc, release)
    n = comp.n
    ms = max(cpl, default=0.0)
    if fwd_np is not None and np is not None and n:
        cpl_a, times_a, utimes_a = fwd_np
        latest = np.empty(n)
        latest[comp.sinks_a] = ms
        rptr, rflat, rpipe = comp.rev_ptr_a, comp.rev_flat_a, \
            comp.rev_pipe_a
        rl = comp.rev_lvl_ptr
        nodes = comp.rev_nodes_a
        need = np.where(rpipe, utimes_a[rflat], times_a[rflat])
        for li in range(len(rl) - 1):
            a, b = rl[li], rl[li + 1]
            vs = nodes[a:b]
            off = rptr[a:b] - rptr[a]
            vals = latest[rflat[rptr[a]:rptr[b]]] \
                - need[rptr[a]:rptr[b]]
            latest[vs] = np.minimum.reduceat(vals, off)
        latest_l = latest.tolist()
        slack = (latest - cpl_a).tolist()
        return AnalyticTiming(comp.names, comp.idx, rdy, fo, cpl,
                              latest_l, slack, ms)

    latest_l = [0.0] * n
    succ_lists, succ_pipe = comp.succ_lists, comp.succ_pipe
    for v in reversed(comp.order):
        succs = succ_lists[v]
        if not succs:
            latest_l[v] = ms
            continue
        lc = math.inf
        for s, pipe in zip(succs, succ_pipe[v]):
            x = latest_l[s] - (utimes[s] if pipe else times[s])
            if x < lc:
                lc = x
        latest_l[v] = lc
    slack = [latest_l[i] - cpl[i] for i in range(n)]
    return AnalyticTiming(comp.names, comp.idx, rdy, fo, cpl,
                          latest_l, slack, ms)


def critical_path(g, rsrc: Optional[dict] = None,
                  release: Optional[dict] = None) -> list[str]:
    """:meth:`MXDAG.critical_path` on compiled arrays (identical walk,
    identical lexicographic tie-breaks via ``name_rank``)."""
    comp, times, utimes, rdy, fo, cpl = forward(g, rsrc, release)
    if not comp.n:
        raise ValueError("empty graph has no critical path")
    rank = comp.name_rank
    # max(sinks, key=(completion, name)): strictly-greater keeps the
    # first maximal item, exactly like the dict walk
    cur = comp.sinks[0]
    for v in comp.sinks[1:]:
        if (cpl[v], rank[v]) > (cpl[cur], rank[cur]):
            cur = v
    path = [cur]
    pred_lists, pred_pipe = comp.pred_lists, comp.pred_pipe
    while pred_lists[cur]:
        t_time = times[cur]
        t_unit = utimes[cur]
        best, best_val = -1, -1.0
        for p, pipe in zip(pred_lists[cur], pred_pipe[cur]):
            if pipe:
                v = fo[p] + t_time
                v2 = cpl[p] + t_unit
                if v2 > v:
                    v = v2
            else:
                v = cpl[p] + t_time
            if v > best_val + 1e-12 or (abs(v - best_val) <= 1e-12
                                        and (best < 0
                                             or rank[p] < rank[best])):
                best, best_val = p, v
        # only follow preds that actually bind the completion
        if best < 0 or best_val + 1e-9 < cpl[cur]:
            break
        cur = best
        path.append(cur)
    path.reverse()
    names = comp.names
    return [names[i] for i in path]
