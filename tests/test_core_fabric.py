"""Unit tests: link-level fabric topologies and path-based rate allocation."""
import pytest

from repro.core import (
    Cluster, FairShareScheduler, MXDAG, MXDAGScheduler, Topology, WhatIf,
    flow, max_min_rates, simulate,
)
from repro.core import builders
from repro.core.fabric import ecmp_choice, is_nic_link, nic_in, nic_out


def hosts_of(g: MXDAG) -> list[str]:
    names: set[str] = set()
    for t in g:
        if t.host is not None:
            names.add(t.host)
        else:
            names.update((t.src, t.dst))
    return sorted(names)


class TestTopologyBuilders:
    def test_single_switch_paths_are_endpoint_nics(self):
        t = Topology.single_switch(["A", "B"], nic=2.0)
        assert t.path("A", "B") == ("A.nic_out", "B.nic_in")
        assert t.capacity("A.nic_out") == 2.0
        assert t.fabric_links() == []

    def test_two_tier_links_and_routes(self):
        t = Topology.two_tier([["a0", "a1"], ["b0", "b1"]],
                              oversubscription=2.0)
        # uplink = 2 hosts * 1.0 nic / 2.0 oversub
        assert t.capacity("rack0.up") == pytest.approx(1.0)
        assert t.capacity("rack1.down") == pytest.approx(1.0)
        # intra-rack: direct; inter-rack: via up+down
        assert t.path("a0", "a1") == ("a0.nic_out", "a1.nic_in")
        assert t.path("a0", "b1") == (
            "a0.nic_out", "rack0.up", "rack1.down", "b1.nic_in")

    def test_two_tier_accepts_int_pair(self):
        t = Topology.two_tier((3, 2), oversubscription=4.0)
        assert len(t.hosts()) == 6
        assert t.capacity("rack2.up") == pytest.approx(0.5)

    def test_leaf_spine_ecmp_static_and_valid(self):
        t = Topology.leaf_spine((2, 4), 2, oversubscription=2.0)
        # per-spine uplink = 4 * 1.0 / (2.0 * 2)
        assert t.capacity("leaf0.up0") == pytest.approx(1.0)
        t2 = Topology.leaf_spine((2, 4), 2, oversubscription=2.0)
        for s in t.hosts():
            for d in t.hosts():
                if s == d:
                    continue
                p = t.path(s, d)
                assert p == t2.path(s, d)          # deterministic ECMP
                assert p[0] == nic_out(s) and p[-1] == nic_in(d)
                assert all(l in t.links for l in p)
        # with enough pairs, the hash should use more than one spine
        spines = {t.path(s, d)[1] for s in t.hosts() for d in t.hosts()
                  if s != d and len(t.path(s, d)) == 4}
        assert len(spines) > 1

    def test_fat_tree_structure(self):
        t = Topology.fat_tree(4)
        assert len(t.hosts()) == 16                # k^3/4
        # same edge: 2 links; intra-pod: 4; inter-pod: 6
        assert len(t.path("p0e0h0", "p0e0h1")) == 2
        assert len(t.path("p0e0h0", "p0e1h0")) == 4
        assert len(t.path("p0e0h0", "p2e1h1")) == 6
        for s in t.hosts():
            for d in t.hosts():
                if s != d:
                    assert all(l in t.links for l in t.path(s, d))

    def test_fat_tree_rejects_odd_k(self):
        with pytest.raises(ValueError):
            Topology.fat_tree(3)

    def test_ecmp_choice_deterministic(self):
        assert ecmp_choice("a", "b", 7) == ecmp_choice("a", "b", 7)
        assert ecmp_choice("x", "y", 1) == 0

    def test_is_nic_link(self):
        assert is_nic_link("h.nic_out") and is_nic_link("h.nic_in")
        assert not is_nic_link("rack0.up")

    def test_resized(self):
        t = Topology.two_tier((2, 2), oversubscription=4.0)
        r = t.resized(4.0)
        assert r.capacity("rack0.up") == pytest.approx(2.0)
        assert r.capacity("r0h0.nic_out") == pytest.approx(1.0)  # NIC kept
        r2 = t.resized(links={"rack1.down": 9.0})
        assert r2.capacity("rack1.down") == pytest.approx(9.0)
        assert r2.capacity("rack0.up") == pytest.approx(0.5)
        assert r.path("r0h0", "r1h1") == t.path("r0h0", "r1h1")

    def test_resized_rejects_unknown_link(self):
        t = Topology.two_tier((2, 2))
        with pytest.raises(KeyError, match="rack0.uplink"):
            t.resized(links={"rack0.uplink": 4.0})   # typo for rack0.up

    def test_path_rejects_unknown_host(self):
        t = Topology.two_tier((2, 2))
        with pytest.raises(KeyError, match="zzz"):
            t.path("r0h0", "zzz")

    def test_routing_is_lazy(self):
        # construction must not materialize O(hosts^2) routes
        t = Topology.fat_tree(8)                   # 128 hosts
        assert len(t._routes) == 0
        p = t.path("p0e0h0", "p7e3h3")
        assert len(p) == 6 and len(t._routes) == 1
        assert t.path("p0e0h0", "p7e3h3") is p     # memoized


class TestCluster:
    def test_from_topology_reads_nic_caps(self):
        t = Topology.single_switch(["A", "B"], nic=2.5)
        cl = Cluster.from_topology(t)
        assert cl.hosts["A"].nic_out == 2.5
        assert cl.bandwidth("A.nic_out") == 2.5

    def test_bandwidth_fabric_link(self):
        t = Topology.two_tier((2, 2), oversubscription=2.0)
        cl = Cluster.from_topology(t)
        assert cl.bandwidth("rack0.up") == pytest.approx(1.0)

    def test_resources_for_routes_flows(self):
        t = Topology.two_tier([["a"], ["b"]])
        cl = Cluster.from_topology(t)
        f = flow("f", 1.0, "a", "b")
        assert cl.resources_for(f) == (
            "a.nic_out", "rack0.up", "rack1.down", "b.nic_in")
        # without a topology: endpoint NICs only (seed model)
        cl0 = Cluster.homogeneous(["a", "b"])
        assert cl0.resources_for(f) == ("a.nic_out", "b.nic_in")

    def test_rejects_host_missing_from_topology(self):
        t = Topology.single_switch(["A"])
        with pytest.raises(ValueError):
            Cluster.homogeneous(["A", "B"]).with_topology(t)

    def test_for_graph_rejects_nic_with_topology(self):
        g = builders.fig1_jobs()
        topo = Topology.single_switch(["A", "B", "C"])
        with pytest.raises(ValueError, match="topology"):
            Cluster.for_graph(g, nic=2.0, topology=topo)


class TestSingleSwitchEquivalence:
    """A single-switch Topology must reproduce the seed (endpoint-NIC)
    simulator results exactly, across policies and features."""

    CASES = [
        ("fig1", lambda: builders.fig1_jobs(), {}),
        ("fig2a_coflows", lambda: builders.fig2a(),
         {"coflows": builders.fig2a_coflows()}),
        ("fig2b", lambda: builders.fig2b(), {}),
        ("fig3_pipelined", lambda: builders.fig3_case(3), {}),
        ("ddl", lambda: builders.ddl(4, push=2.0, pull=2.0,
                                     unit_frac=0.25), {}),
    ]

    @pytest.mark.parametrize("name,make,kw",
                             CASES, ids=[c[0] for c in CASES])
    @pytest.mark.parametrize("policy", ["fair", "priority"])
    def test_exact_equivalence(self, name, make, kw, policy):
        g = make()
        prio = None
        if policy == "priority":
            if kw.get("coflows"):
                pytest.skip("coflows use fair policy")
            prio = MXDAGScheduler(try_pipelining=False) \
                ._priorities(g)
        seed = simulate(g, policy=policy, priorities=prio, **kw)
        topo = Topology.single_switch(hosts_of(g))
        cl = Cluster.for_graph(g, topology=topo)
        fab = simulate(g, cl, policy=policy, priorities=prio, **kw)
        assert fab.start == seed.start
        assert fab.finish == seed.finish
        assert fab.makespan == seed.makespan


class TestFabricContention:
    def test_hand_computed_two_tier(self):
        """Exactness on a hand-solved 2-tier case (oversub 2:1, uplink 1).

        f1: a0→b0 (size 2), f2: a1→b1 (1), f3: b0→b1 (1), all released
        at t=0.  Waterfill: rack0.up is the bottleneck for f1, f2 (rate
        0.5 each); f3 then gets b1.in's residual 0.5.  At t=2, f2 and f3
        finish; f1 (1 unit of work left) takes the whole uplink, rate 1,
        finishing at t=3.
        """
        t = Topology.two_tier([["a0", "a1"], ["b0", "b1"]],
                              oversubscription=2.0)
        cl = Cluster.from_topology(t)
        g = MXDAG()
        g.add(flow("f1", 2.0, "a0", "b0"))
        g.add(flow("f2", 1.0, "a1", "b1"))
        g.add(flow("f3", 1.0, "b0", "b1"))
        r = simulate(g, cl)
        assert r.finish["f1"] == pytest.approx(3.0)
        assert r.finish["f2"] == pytest.approx(2.0)
        assert r.finish["f3"] == pytest.approx(2.0)
        assert r.makespan == pytest.approx(3.0)
        # the big-switch model misses the uplink: f1 would finish at 2
        r0 = simulate(g, Cluster.homogeneous(["a0", "a1", "b0", "b1"]))
        assert r0.finish["f1"] == pytest.approx(2.0)
        assert r0.makespan == pytest.approx(2.0)

    def test_priority_beats_fair_on_oversubscribed_core(self):
        """The acceptance scenario: 4 cross-rack flows on a 4:1 core;
        MXDAG priorities give the critical flow the whole uplink first."""
        g, cl = builders.oversubscribed_fanin(
            n_senders=4, oversubscription=4.0)
        fair = FairShareScheduler().schedule(g, cl).simulate(cl)
        mx = MXDAGScheduler(try_pipelining=False) \
            .schedule(g, cl).simulate(cl)
        # fair: uplink (cap 1) split 4 ways -> flows done at 4, +8 compute
        assert fair.makespan == pytest.approx(12.0)
        # priority: f0 takes the uplink alone -> done at 1, +8 compute
        assert mx.makespan == pytest.approx(9.0)
        assert mx.makespan < fair.makespan - 1e-9

    def test_max_min_rates_pure(self):
        rates = max_min_rates(
            {"f1": ("a.out", "up"), "f2": ("b.out", "up")},
            {"a.out": 1.0, "b.out": 1.0, "up": 1.0})
        assert rates == {"f1": pytest.approx(0.5),
                         "f2": pytest.approx(0.5)}
        # weighted: f1 gets 2/3 of the shared bottleneck
        rates = max_min_rates(
            {"f1": ("a.out", "up"), "f2": ("b.out", "up")},
            {"a.out": 1.0, "b.out": 1.0, "up": 1.0},
            weights={"f1": 2.0})
        assert rates["f1"] == pytest.approx(2 / 3)
        assert rates["f2"] == pytest.approx(1 / 3)

    def test_resource_map_fabric_aware(self):
        g, cl = builders.oversubscribed_fanin(n_senders=2)
        m = g.resource_map(cl)
        assert m["rack0.up"] == ["f0", "f1"]       # shared uplink visible
        m0 = g.resource_map()
        assert "rack0.up" not in m0                # big-switch: invisible


class TestWhatIfResizeFabric:
    def test_fair_sharing_is_core_bound(self):
        g, cl = builders.oversubscribed_fanin()
        w = WhatIf(g, cl, scheduler=FairShareScheduler())
        r = w.resize_fabric(scale=4.0)
        assert r.baseline == pytest.approx(12.0)
        assert r.variant == pytest.approx(9.0)
        assert r.helps

    def test_coscheduling_already_at_full_bisection(self):
        g, cl = builders.oversubscribed_fanin()
        r = WhatIf(g, cl).resize_fabric(scale=4.0)
        assert r.variant == pytest.approx(r.baseline)
        assert not r.helps

    def test_individual_link_override(self):
        g, cl = builders.oversubscribed_fanin()
        w = WhatIf(g, cl, scheduler=FairShareScheduler())
        r = w.resize_fabric(links={"rack0.up": 4.0})
        assert r.variant == pytest.approx(12.0)   # rack1.down still caps at 1
        r = w.resize_fabric(links={"rack0.up": 4.0, "rack1.down": 4.0})
        assert r.variant == pytest.approx(9.0)

    def test_requires_topology(self):
        g = builders.fig1_jobs()
        with pytest.raises(ValueError):
            WhatIf(g, Cluster.for_graph(g)).resize_fabric(scale=2.0)
