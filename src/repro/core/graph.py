"""MXDAG: directed acyclic graph of MXTasks (paper §3.1–§3.2).

Implements:

- the graph itself (explicit compute *and* network nodes, dummy start/end),
- edge-level pipelineability (an edge may stream units instead of barriers),
- the path-length calculus of §3.2:
    Eq.(1)  Len(P_seq)  = Σ Size(v_i)/Rsrc(v_i)
    Eq.(2)  Len(P_pipe) = Σ Unit(v_i)/Rsrc(v_i) + max_i Size(v_i)/Rsrc(v_i)
                          − max_i Unit(v_i)/Rsrc(v_i)
- a contention-free analytic evaluator (earliest first-unit-out / completion
  recursion) that is exact for deterministic pipelines with unbounded
  buffers and reduces to Eq.(1)/(2) on chains,
- critical-path extraction and per-task slack (drives Principle 1/2),
- copath detection (groups of paths sharing head and tail; §3.2).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Iterable, Iterator, Optional

from repro.core.task import MXTask, TaskKind

START = "__start__"
END = "__end__"


@dataclasses.dataclass(frozen=True)
class Edge:
    """A precedence (optionally streaming) edge between two tasks."""

    src: str
    dst: str
    pipelined: bool = False  # stream units across this edge when both ends allow


@dataclasses.dataclass
class NodeTiming:
    """Analytic timing for one task under a given resource assignment."""
    ready: float        # earliest time the first unit of input is available
    first_out: float    # earliest time the first output unit is emitted
    completion: float   # earliest completion of the whole task
    latest_completion: float = float("inf")  # from reverse pass (slack calc)

    @property
    def slack(self) -> float:
        """How late completion may slip without moving the makespan."""
        return self.latest_completion - self.completion


class MXDAG:
    """A directed acyclic graph over MXTasks with pipelineable edges."""

    def __init__(self, name: str = "mxdag") -> None:
        self.name = name
        self.tasks: dict[str, MXTask] = {}
        self.edges: dict[tuple[str, str], Edge] = {}
        self._succ: dict[str, list[str]] = {}
        self._pred: dict[str, list[str]] = {}
        # bumped by every mutator; keys the signature and simulator-static
        # caches.  Mutate tasks only through the MXDAG API (or on a fresh
        # copy()) so cached derived state is never stale.
        self._version = 0

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    def add(self, task: MXTask) -> MXTask:
        """Add a task (its name must be new) and return it."""
        if task.name in self.tasks:
            raise ValueError(f"duplicate task {task.name}")
        self.tasks[task.name] = task
        self._succ[task.name] = []
        self._pred[task.name] = []
        self._version += 1
        return task

    def add_edge(self, src: str | MXTask, dst: str | MXTask,
                 *, pipelined: bool = False) -> Edge:
        """Add the edge src→dst, rejecting duplicates and cycles."""
        s = src.name if isinstance(src, MXTask) else src
        d = dst.name if isinstance(dst, MXTask) else dst
        for n in (s, d):
            if n not in self.tasks:
                raise KeyError(f"unknown task {n}")
        if (s, d) in self.edges:
            raise ValueError(f"duplicate edge {s}->{d}")
        self._check_no_cycle_via(s, d)
        e = Edge(s, d, pipelined)
        self.edges[(s, d)] = e
        self._succ[s].append(d)
        self._pred[d].append(s)
        self._version += 1
        return e

    def chain(self, *tasks: MXTask, pipelined: bool = False) -> None:
        """Add tasks (if new) and connect them in sequence."""
        for t in tasks:
            if t.name not in self.tasks:
                self.add(t)
        for a, b in zip(tasks, tasks[1:]):
            self.add_edge(a, b, pipelined=pipelined)

    def set_pipelined(self, src: str, dst: str, pipelined: bool) -> None:
        """Flip one existing edge's streaming flag."""
        e = self.edges[(src, dst)]
        self.edges[(src, dst)] = Edge(e.src, e.dst, pipelined)
        self._version += 1

    def replace_task(self, task: MXTask) -> MXTask:
        """Swap in a new MXTask under its existing name (what-if resizing,
        monitor re-estimation).  The supported way to mutate a task:
        assigning ``g.tasks[name]`` directly would leave the version-keyed
        signature/simulator caches stale."""
        if task.name not in self.tasks:
            raise KeyError(f"unknown task {task.name}")
        self.tasks[task.name] = task
        self._version += 1
        return task

    @classmethod
    def union(cls, graphs: Iterable["MXDAG"],
              name: Optional[str] = None) -> "MXDAG":
        """Disjoint union of whole DAGs (the multi-job merge), bulk.

        Equivalent to ``add``-ing every task and ``add_edge``-ing every
        edge job by job, but skips the per-edge cycle walk: task names
        must be globally unique (checked — ``ValueError`` on collision),
        so every edge stays inside its own already-acyclic input graph
        and the union cannot create a cycle.  This is the hot path of
        the online service loop, where the running job set is re-merged
        on every admission and completion.
        """
        graphs = list(graphs)
        m = cls(name if name is not None
                else "+".join(g.name for g in graphs))
        owner: dict[str, str] = {}
        for g in graphs:
            for nm, t in g.tasks.items():
                if nm in m.tasks:
                    raise ValueError(
                        f"cross-job task name collision: {nm!r} is "
                        f"defined by both {owner[nm]} and "
                        f"{g.name!r} (job {t.job!r}); task names must "
                        f"be unique across the jobs sharing a cluster "
                        f"(prefix them with the job name, as "
                        f"builders.mapreduce does)")
                m.tasks[nm] = t
                owner[nm] = f"{g.name!r} (job {t.job!r})"
            m.edges.update(g.edges)
            for nm, ss in g._succ.items():
                m._succ[nm] = list(ss)
            for nm, ps in g._pred.items():
                m._pred[nm] = list(ps)
        m._version = len(graphs)
        return m

    def copy(self) -> "MXDAG":
        """Independent shallow copy (tasks are frozen; structure is new)."""
        g = MXDAG(self.name)
        g.tasks = dict(self.tasks)
        g.edges = dict(self.edges)
        g._succ = {k: list(v) for k, v in self._succ.items()}
        g._pred = {k: list(v) for k, v in self._pred.items()}
        return g

    # ------------------------------------------------------------------
    # logical placement (late binding of hosts / flow endpoints)
    # ------------------------------------------------------------------
    def unbound(self) -> list[str]:
        """Names of tasks whose placement is still undecided."""
        return [n for n, t in self.tasks.items() if not t.bound]

    def _location_vars(self):
        """Union-find over placement variables, with dataflow constraints.

        Variables: ``("c", task)`` for a compute task's host, ``("s", f)``
        / ``("d", f)`` for a flow's endpoints.  Edges impose co-location:
        a compute→flow edge pins the flow's source to the producer's host,
        flow→compute pins the destination to the consumer's host, and
        flow→flow means the data lands where the next hop departs from.
        Returns ``(find, vars)`` where ``find`` maps a variable to its
        class representative.
        """
        parent: dict[tuple, tuple] = {}

        def find(v: tuple) -> tuple:
            """Union-find root of ``v`` with path compression."""
            root = v
            while parent.setdefault(root, root) != root:
                root = parent[root]
            while parent[v] != root:            # path compression
                parent[v], v = root, parent[v]
            return root

        def union(a: tuple, b: tuple) -> None:
            """Merge the classes of ``a`` and ``b`` (smaller root wins)."""
            ra, rb = find(a), find(b)
            if ra != rb:
                parent[max(ra, rb)] = min(ra, rb)

        variables: list[tuple] = []
        for n, t in self.tasks.items():
            if t.kind is TaskKind.COMPUTE:
                variables.append(("c", n))
            else:
                variables.append(("s", n))
                variables.append(("d", n))
        for (p, n) in self.edges:
            tp, tn = self.tasks[p], self.tasks[n]
            if tp.kind is TaskKind.COMPUTE and tn.kind is TaskKind.NETWORK:
                union(("c", p), ("s", n))
            elif tp.kind is TaskKind.NETWORK \
                    and tn.kind is TaskKind.COMPUTE:
                union(("d", p), ("c", n))
            elif tp.kind is TaskKind.NETWORK \
                    and tn.kind is TaskKind.NETWORK:
                union(("d", p), ("s", n))
        return find, variables

    def bind(self, assignment: "dict[str, object]") -> "MXDAG":
        """A copy with the placement ``assignment`` applied.

        ``assignment`` maps task names to placements: a host string for a
        compute task, or an ``(src, dst)`` pair for a flow (either element
        may be ``None`` to leave it to inference).  Unassigned endpoints
        are inferred by co-location: a flow departs from its producing
        compute task's host, arrives at its consuming compute task's host,
        and a flow feeding another flow hands its data off at a common
        host.  Raises if an assignment targets an already-bound task (use
        :meth:`replace_task` / what-if ``move_task`` for re-placement), if
        inference meets two conflicting anchors, or if any placement is
        still undecided after inference.
        """
        find, variables = self._location_vars()
        value: dict[tuple, str] = {}       # class representative -> host

        # classes holding at least one undecided variable; only those are
        # anchored and consistency-checked, so a fully-bound graph — even
        # one whose bound endpoints disagree with the co-location rules —
        # binds to itself untouched
        open_classes: set[tuple] = set()
        for n, t in self.tasks.items():
            if t.kind is TaskKind.COMPUTE:
                if t.host is None:
                    open_classes.add(find(("c", n)))
            else:
                if t.src is None:
                    open_classes.add(find(("s", n)))
                if t.dst is None:
                    open_classes.add(find(("d", n)))

        def anchor(var: tuple, host: str, why: str) -> None:
            """Pin a location class to ``host``, rejecting conflicts."""
            root = find(var)
            if root not in open_classes:
                return
            old = value.get(root)
            if old is not None and old != host:
                raise ValueError(
                    f"conflicting placement for {why}: {old!r} vs {host!r}")
            value[root] = host

        for n, t in self.tasks.items():
            if t.kind is TaskKind.COMPUTE:
                if t.host is not None:
                    anchor(("c", n), t.host, f"compute {n}")
            else:
                if t.src is not None:
                    anchor(("s", n), t.src, f"flow {n} src")
                if t.dst is not None:
                    anchor(("d", n), t.dst, f"flow {n} dst")

        for name, placement in assignment.items():
            t = self.tasks.get(name)
            if t is None:
                raise KeyError(f"unknown task {name}")
            if t.bound:
                raise ValueError(
                    f"{name} is already bound; bind() only places logical "
                    f"tasks (use replace_task to re-place)")
            if t.kind is TaskKind.COMPUTE:
                if not isinstance(placement, str):
                    raise ValueError(f"{name}: compute placement must be "
                                     f"a host name")
                anchor(("c", name), placement, f"compute {name}")
            else:
                src, dst = placement          # type: ignore[misc]
                # an endpoint that is already bound on the task itself is
                # not up for (re)assignment — its class may be closed, so
                # anchor() would silently drop a conflicting value
                if src is not None:
                    if t.src is not None and t.src != src:
                        raise ValueError(
                            f"flow {name} src is already bound to "
                            f"{t.src!r}; bind() cannot move it to {src!r}")
                    anchor(("s", name), src, f"flow {name} src")
                if dst is not None:
                    if t.dst is not None and t.dst != dst:
                        raise ValueError(
                            f"flow {name} dst is already bound to "
                            f"{t.dst!r}; bind() cannot move it to {dst!r}")
                    anchor(("d", name), dst, f"flow {name} dst")

        unresolved = [v for v in variables
                      if find(v) in open_classes and find(v) not in value]
        if unresolved:
            names = sorted({v[1] for v in unresolved})
            raise ValueError(f"placement still undecided for: {names}")

        g = self.copy()
        for n, t in self.tasks.items():
            if t.bound:
                continue
            if t.kind is TaskKind.COMPUTE:
                g.replace_task(dataclasses.replace(
                    t, host=value[find(("c", n))]))
            else:
                src = t.src if t.src is not None else value[find(("s", n))]
                dst = t.dst if t.dst is not None else value[find(("d", n))]
                g.replace_task(dataclasses.replace(t, src=src, dst=dst))
        return g

    # ------------------------------------------------------------------
    # structure
    # ------------------------------------------------------------------
    def preds(self, name: str) -> list[str]:
        """Direct predecessors of ``name`` (insertion order)."""
        return self._pred[name]

    def succs(self, name: str) -> list[str]:
        """Direct successors of ``name`` (insertion order)."""
        return self._succ[name]

    def sources(self) -> list[str]:
        """Tasks with no predecessors."""
        return [n for n in self.tasks if not self._pred[n]]

    def sinks(self) -> list[str]:
        """Tasks with no successors."""
        return [n for n in self.tasks if not self._succ[n]]

    def topo_order(self) -> list[str]:
        """Deterministic topological order (lexicographic Kahn)."""
        # heap-based Kahn: lexicographically smallest available task first
        # (identical order to the seed's re-sorted frontier list, without
        # its O(V² log V) repeated sorting)
        indeg = {n: len(self._pred[n]) for n in self.tasks}
        frontier = [n for n, d in indeg.items() if d == 0]
        heapq.heapify(frontier)
        order: list[str] = []
        while frontier:
            n = heapq.heappop(frontier)
            order.append(n)
            for s in self._succ[n]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    heapq.heappush(frontier, s)
        if len(order) != len(self.tasks):
            raise ValueError("graph has a cycle")
        return order

    def _check_no_cycle_via(self, src: str, dst: str) -> None:
        """Adding src→dst creates a cycle iff dst already reaches src.

        Checked *before* mutating, by DFS from dst — O(V+E) worst case but
        O(1) in the common build order where dst has no successors yet
        (the seed instead re-ran a full topological sort per edge, making
        graph construction quadratic in the edge count).
        """
        if src == dst:
            raise ValueError("graph has a cycle")
        stack = [dst]
        seen = {dst}
        while stack:
            for s in self._succ[stack.pop()]:
                if s == src:
                    raise ValueError("graph has a cycle")
                if s not in seen:
                    seen.add(s)
                    stack.append(s)

    def signature(self) -> tuple:
        """Hashable identity: tasks, edges and their pipelining flags.

        Deliberately insertion-order-sensitive — the DES breaks ties
        (residual link order, start dispatch) by task order, so graphs
        with identical content but different construction order are
        distinct simulation inputs.  Keys the scheduler's and WhatIf's
        simulation memo caches.  Cached per graph version.
        """
        cached = self.__dict__.get("_sig_cache")
        if cached is not None and cached[0] == self._version:
            return cached[1]
        sig = (tuple(self.tasks.values()),
               tuple((e.src, e.dst, e.pipelined)
                     for e in self.edges.values()))
        self._sig_cache = (self._version, sig)
        return sig

    def effective_pipelined(self, e: Edge) -> bool:
        """An edge streams units only if marked AND both endpoints can.

        A non-pipelineable consumer needs its full input before starting, so
        a pipelined edge into it degenerates to a barrier (paper §3.1).
        """
        return (e.pipelined
                and self.tasks[e.src].pipelineable
                and self.tasks[e.dst].pipelineable)

    # ------------------------------------------------------------------
    # §3.2 path-length calculus (explicit-path form, Eqs. 1 & 2)
    # ------------------------------------------------------------------
    @staticmethod
    def len_sequential(tasks: Iterable[MXTask],
                       rsrc: Optional[dict[str, float]] = None) -> float:
        """Eq. (1): length of a sequential-only path."""
        r = rsrc or {}
        return sum(t.time(r.get(t.name, 1.0)) for t in tasks)

    @staticmethod
    def len_pipelined(tasks: Iterable[MXTask],
                      rsrc: Optional[dict[str, float]] = None) -> float:
        """Eq. (2): length of a pipelineable-only path."""
        ts = list(tasks)
        r = rsrc or {}
        units = [t.unit_time(r.get(t.name, 1.0)) for t in ts]
        sizes = [t.time(r.get(t.name, 1.0)) for t in ts]
        return sum(units) + max(sizes) - max(units)

    # ------------------------------------------------------------------
    # analytic evaluator (contention-free; exact on chains, lower bound
    # in general — the DES in simulator.py adds resource contention)
    # ------------------------------------------------------------------
    def evaluate(self, rsrc: Optional[dict[str, float]] = None,
                 release: Optional[dict[str, float]] = None,
                 ) -> dict[str, NodeTiming]:
        """Earliest-time recursion over the DAG.

        ready(v)      = max over in-edges e=(p,v):
                          first_out(p) if e streams else completion(p)
        first_out(v)  = ready(v) + unit_time(v)
        completion(v) = max( ready(v) + time(v),
                             max over streaming preds: completion(p) + unit_time(v) )

        For deterministic unit pipelines with unbounded buffers this is exact
        and reproduces Eq. (2) on pipelineable chains.
        """
        r = rsrc or {}
        rel = release or {}
        # per-task times resolved once: t.time()/t.unit_time() validate
        # their argument per call, which dominates on large DAGs
        times = {n: t.time(r.get(n, 1.0)) for n, t in self.tasks.items()}
        utimes = {n: t.unit_time(r.get(n, 1.0))
                  for n, t in self.tasks.items()}
        out: dict[str, NodeTiming] = {}
        for n in self.topo_order():
            ready = rel.get(n, 0.0)
            comp_floor = 0.0
            ut = utimes[n]
            for p in self._pred[n]:
                e = self.edges[(p, n)]
                pt = out[p]
                if self.effective_pipelined(e):
                    ready = max(ready, pt.first_out)
                    comp_floor = max(comp_floor, pt.completion + ut)
                else:
                    ready = max(ready, pt.completion)
            completion = max(ready + times[n], comp_floor)
            out[n] = NodeTiming(ready=ready,
                                first_out=ready + ut,
                                completion=completion)
        return out

    def makespan(self, rsrc: Optional[dict[str, float]] = None,
                 release: Optional[dict[str, float]] = None) -> float:
        """Analytic (contention-free) makespan under ``rsrc``/``release``."""
        timing = self.evaluate(rsrc, release)
        return max((t.completion for t in timing.values()), default=0.0)

    def with_slack(self, rsrc: Optional[dict[str, float]] = None,
                   release: Optional[dict[str, float]] = None,
                   ) -> dict[str, NodeTiming]:
        """Forward + reverse pass: fills ``latest_completion`` (⇒ slack).

        ``release`` threads per-task earliest start times through the
        forward pass, exactly as :meth:`evaluate`/:meth:`makespan`
        accept them — without it the slack of a late-released branch is
        overstated (its completion is computed as if it could start at
        t=0 while the makespan it is compared against cannot shrink).
        """
        timing = self.evaluate(rsrc, release)
        ms = max((t.completion for t in timing.values()), default=0.0)
        r = rsrc or {}
        times = {n: t.time(r.get(n, 1.0)) for n, t in self.tasks.items()}
        utimes = {n: t.unit_time(r.get(n, 1.0))
                  for n, t in self.tasks.items()}
        for n in reversed(self.topo_order()):
            if not self._succ[n]:
                timing[n].latest_completion = ms
                continue
            lc = float("inf")
            for s in self._succ[n]:
                e = self.edges[(n, s)]
                if self.effective_pipelined(e):
                    # successor needs our first unit by latest_start(s);
                    # conservative: our completion by its latest_completion
                    # minus one of its units.
                    lc = min(lc, timing[s].latest_completion - utimes[s])
                else:
                    lc = min(lc, timing[s].latest_completion - times[s])
            timing[n].latest_completion = lc
        return timing

    def critical_path(self, rsrc: Optional[dict[str, float]] = None,
                      release: Optional[dict[str, float]] = None,
                      ) -> list[str]:
        """Longest path under the analytic evaluator (ties: lexicographic).

        ``release`` carries per-task earliest starts into the forward
        pass (e.g. observed starts from a runtime monitor); the
        walk-back stops where a release, rather than a predecessor,
        binds the completion.
        """
        timing = self.evaluate(rsrc, release)
        r = rsrc or {}
        # walk back from the sink with max completion
        cur = max(self.sinks(), key=lambda n: (timing[n].completion, n))
        path = [cur]
        while self._pred[cur]:
            t = self.tasks[cur]
            f = r.get(cur, 1.0)
            best, best_val = None, -1.0
            for p in self._pred[cur]:
                e = self.edges[(p, cur)]
                pt = timing[p]
                if self.effective_pipelined(e):
                    v = max(pt.first_out + t.time(f),
                            pt.completion + t.unit_time(f))
                else:
                    v = pt.completion + t.time(f)
                if v > best_val + 1e-12 or (abs(v - best_val) <= 1e-12
                                            and (best is None or p < best)):
                    best, best_val = p, v
            # only follow preds that actually bind the completion
            if best is None or best_val + 1e-9 < timing[cur].completion:
                break
            cur = best
            path.append(cur)
        path.reverse()
        return path

    # ------------------------------------------------------------------
    # copaths (§3.2): groups of ≥2 distinct paths with same head & tail
    # ------------------------------------------------------------------
    def paths_between(self, head: str, tail: str,
                      limit: int = 10000) -> list[list[str]]:
        """All directed paths head→tail, in DFS (adjacency) order.

        Iterative: the previous recursive DFS hit Python's recursion
        limit (RecursionError) on chains deeper than ~1000 tasks —
        ``ddl(1024)``-scale serial DAGs exceed it.  The explicit stack
        reproduces the recursive enumeration order exactly.
        """
        out: list[list[str]] = []
        # stack of (node, #successors already expanded); path mirrors it
        path = [head]
        stack = [(head, 0)]
        while stack:
            if len(out) >= limit:
                break
            n, child = stack[-1]
            if n == tail and child == 0:
                out.append(list(path))
                stack.pop()
                path.pop()
                continue
            succs = self._succ[n]
            if child >= len(succs):
                stack.pop()
                path.pop()
                continue
            stack[-1] = (n, child + 1)
            s = succs[child]
            stack.append((s, 0))
            path.append(s)
        return out

    def copaths(self, limit: int = 10000) -> dict[tuple[str, str], list[list[str]]]:
        """All (head, tail) pairs joined by ≥2 distinct paths."""
        # count paths between all pairs via DP to avoid useless DFS
        order = self.topo_order()
        idx = {n: i for i, n in enumerate(order)}
        npaths: dict[tuple[str, str], int] = {}
        for h in order:
            counts = {h: 1}
            for n in order[idx[h]:]:
                c = counts.get(n, 0)
                if not c:
                    continue
                for s in self._succ[n]:
                    counts[s] = counts.get(s, 0) + c
            for t, c in counts.items():
                if t != h and c >= 2:
                    npaths[(h, t)] = c
        return {pair: self.paths_between(*pair, limit=limit)
                for pair in sorted(npaths)}

    # ------------------------------------------------------------------
    def resource_map(self, cluster=None) -> dict[str, list[str]]:
        """Resource → tasks occupying it, in task-insertion order.

        With a :class:`~repro.core.cluster.Cluster` carrying a fabric
        :class:`~repro.core.fabric.Topology`, flows are charged against
        every link on their path — so schedulers see in-network contention
        (shared ToR uplinks, spine links) and not just endpoint NICs.
        """
        out: dict[str, list[str]] = {}
        for n, t in self.tasks.items():
            res = cluster.resources_for(t) if cluster is not None \
                else t.resources()
            for r in res:
                out.setdefault(r, []).append(n)
        return out

    # ------------------------------------------------------------------
    def network_tasks(self) -> list[MXTask]:
        """All flow tasks, insertion order."""
        return [t for t in self.tasks.values() if t.kind is TaskKind.NETWORK]

    def compute_tasks(self) -> list[MXTask]:
        """All compute tasks, insertion order."""
        return [t for t in self.tasks.values() if t.kind is TaskKind.COMPUTE]

    def pipelineable_edges(self) -> list[Edge]:
        """Edges whose both endpoints carry unit structure."""
        return [e for e in self.edges.values()
                if self.tasks[e.src].pipelineable
                and self.tasks[e.dst].pipelineable]

    def __len__(self) -> int:
        return len(self.tasks)

    def __iter__(self) -> Iterator[MXTask]:
        return iter(self.tasks.values())

    def __repr__(self) -> str:
        return (f"MXDAG({self.name}: {len(self.tasks)} tasks, "
                f"{len(self.edges)} edges, "
                f"{len(self.network_tasks())} network)")
