from repro.optim.adamw import AdamW, AdamWConfig, cosine_schedule
from repro.optim import compression

__all__ = ["AdamW", "AdamWConfig", "cosine_schedule", "compression"]
