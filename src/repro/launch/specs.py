"""ShapeDtypeStruct stand-ins for every model input (no allocation).

``input_specs(cfg, shape)`` returns the abstract batch for a train/prefill
cell; ``decode_specs`` additionally returns the abstract KV/SSM cache via
``jax.eval_shape`` over ``Model.init_cache`` (zero bytes allocated).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig

S = jax.ShapeDtypeStruct


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    B = shape.global_batch
    batch = {"tokens": S((B, shape.seq_len), jnp.int32)}
    if cfg.encoder_layers:
        batch["audio_embeds"] = S(
            (B, cfg.max_source_positions, cfg.d_model), jnp.bfloat16)
    if cfg.vision_embed_dim:
        batch["vision_embeds"] = S(
            (B, cfg.vision_seq, cfg.vision_embed_dim), jnp.bfloat16)
    return batch


def decode_specs(model, cfg: ArchConfig, shape: ShapeConfig):
    """(tokens, cache, index) stand-ins for one decode step with a
    KV cache of seq_len."""
    B = shape.global_batch
    cache = jax.eval_shape(
        lambda: model.init_cache(B, shape.seq_len))
    tokens = S((B, 1), jnp.int32)
    index = S((), jnp.int32)
    return tokens, cache, index
