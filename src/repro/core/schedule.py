"""MXDAG schedulers (paper §4).

- :class:`FairShareScheduler` — the network-aware-DAG baseline of Fig. 1(b):
  every task starts as soon as its dependencies allow; NIC bandwidth is
  max-min fair-shared; no flow-level priorities; no pipelining decisions.

- :class:`CoflowConfig` — the §2.2 baseline: flows grouped into coflows with
  synchronized start, MADD-coupled rates and all-or-nothing gating.

- :class:`MXDAGScheduler` — Principle 1: prioritize the critical path within
  any copath (without letting non-critical paths exceed the critical path),
  and enable pipelining on an edge only when it shrinks the makespan
  (the Fig. 3 analysis, automated as a greedy what-if loop).

- :class:`AltruisticMultiScheduler` — Principle 2: a job delays/demotes its
  non-critical tasks, bounded by their slack, to donate resources to other
  jobs' critical paths without extending its own completion time.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.cluster import Cluster
from repro.core.graph import MXDAG
from repro.core.simulator import SimResult, simulate
from repro.core.task import TaskKind

# priority classes (lower value = more urgent)
CRITICAL = 0.0
NONCRITICAL = 1.0
ALTRUIST_DEMOTED = 2.0


@dataclasses.dataclass
class Schedule:
    """Everything needed to execute a scheduling decision in the DES."""
    graph: MXDAG                        # with pipelining flags applied
    policy: str = "fair"
    priorities: dict[str, float] = dataclasses.field(default_factory=dict)
    releases: dict[str, float] = dataclasses.field(default_factory=dict)
    coflows: Optional[list[set[str]]] = None
    meta: dict = dataclasses.field(default_factory=dict)

    def simulate(self, cluster: Optional[Cluster] = None) -> SimResult:
        return simulate(self.graph, cluster, policy=self.policy,
                        priorities=self.priorities, releases=self.releases,
                        coflows=self.coflows)


class FairShareScheduler:
    """Baseline: dependency-driven start, fair NIC sharing, no priorities."""

    def schedule(self, graph: MXDAG,
                 cluster: Optional[Cluster] = None) -> Schedule:
        return Schedule(graph=graph, policy="fair")


class CoflowConfig:
    """Coflow baseline: caller supplies the grouping (the paper's point in
    §2.2 is precisely that the grouping is ambiguous — Fig. 2(b1..b3));
    :func:`auto_coflows` derives one conventional grouping."""

    def __init__(self, coflows: list[set[str]]):
        self.coflows = coflows

    def schedule(self, graph: MXDAG,
                 cluster: Optional[Cluster] = None) -> Schedule:
        return Schedule(graph=graph, policy="fair", coflows=self.coflows,
                        meta={"coflows": self.coflows})


def auto_coflows(graph: MXDAG) -> list[set[str]]:
    """Conventional stage-grouping: flows sharing the same successor set
    (aggregations) or, failing that, the same predecessor set (broadcasts)."""
    groups: dict[tuple, set[str]] = {}
    for t in graph.network_tasks():
        succ = frozenset(graph.succs(t.name))
        pred = frozenset(graph.preds(t.name))
        key = ("succ", succ) if succ else ("pred", pred)
        groups.setdefault(key, set()).add(t.name)
    return [g for g in groups.values() if len(g) >= 2]


class MXDAGScheduler:
    """Principle 1 (§4.1) — critical-path-first co-scheduling.

    1. Analytic forward/backward pass (contention-free) yields per-task
       slack; zero-slack tasks form the critical path.
    2. Flow & compute priorities: critical tasks get class 0; others are
       ordered by ascending slack within class 1 (a non-critical path is
       never allowed to pre-empt the critical path, but among themselves
       tighter paths go first — "without letting the non-critical paths
       have longer completion time than the critical path").
    3. Pipelining: greedily enable a pipelineable edge only if the
       simulated makespan shrinks (Fig. 3 cases 1–3 automated).
    """

    def __init__(self, *, try_pipelining: bool = True,
                 slack_eps: float = 1e-9):
        self.try_pipelining = try_pipelining
        self.slack_eps = slack_eps

    def _priorities(self, graph: MXDAG) -> dict[str, float]:
        timing = graph.with_slack()
        prio: dict[str, float] = {}
        slacks = sorted({round(t.slack, 12) for t in timing.values()})
        for n, tm in timing.items():
            if tm.slack <= self.slack_eps:
                prio[n] = CRITICAL
            else:
                # rank-normalized slack keeps classes strictly above CRITICAL
                rank = slacks.index(round(tm.slack, 12))
                prio[n] = NONCRITICAL + rank / max(len(slacks), 1)
        return prio

    def _best(self, g: MXDAG, cluster: Optional[Cluster]
              ) -> tuple[str, dict[str, float], float]:
        """Principle 1 with its own caveat enforced.

        Strict slack-priority can delay a non-critical path *beyond its
        slack* under contention, which the principle forbids ("without
        letting the non-critical paths have longer completion time than the
        critical path").  So: start from strict priority, iteratively
        promote tasks that the DES shows finishing past their analytic
        latest-completion, and never return anything worse than plain fair
        sharing.
        """
        prio = self._priorities(g)
        timing = g.with_slack()
        cands: list[tuple[str, dict[str, float], float]] = []
        cur = dict(prio)
        for _ in range(len(g.tasks)):
            res = simulate(g, cluster, policy="priority", priorities=cur)
            cands.append(("priority", dict(cur), res.makespan))
            late = [n for n, tm in timing.items()
                    if cur.get(n, 0.0) > CRITICAL
                    and res.finish[n] > tm.latest_completion + 1e-9]
            if not late:
                break
            for n in late:
                cur[n] = CRITICAL
        fair = simulate(g, cluster, policy="fair")
        cands.append(("fair", {}, fair.makespan))
        return min(cands, key=lambda c: (c[2], c[0] == "fair"))

    def schedule(self, graph: MXDAG,
                 cluster: Optional[Cluster] = None) -> Schedule:
        g = graph.copy()
        if self.try_pipelining:
            # start from no pipelining: paper applies it only when it helps
            for (s, d) in list(g.edges):
                g.set_pipelined(s, d, False)

        policy, prio, best = self._best(g, cluster)
        decisions: dict[tuple[str, str], bool] = {}

        if self.try_pipelining:
            candidates = sorted(
                ((e.src, e.dst) for e in graph.edges.values()
                 if graph.tasks[e.src].pipelineable
                 and graph.tasks[e.dst].pipelineable),
            )
            improved = True
            while improved:
                improved = False
                for (s, d) in candidates:
                    if decisions.get((s, d)):
                        continue
                    trial = g.copy()
                    trial.set_pipelined(s, d, True)
                    tpolicy, tprio, tms = self._best(trial, cluster)
                    if tms < best - 1e-9:
                        g, best = trial, tms
                        policy, prio = tpolicy, tprio
                        decisions[(s, d)] = True
                        improved = True
        return Schedule(graph=g, policy=policy, priorities=prio,
                        meta={"pipelined": sorted(k for k, v in
                                                  decisions.items() if v),
                              "critical_path": g.critical_path(),
                              "predicted_makespan": best})


class AltruisticMultiScheduler:
    """Principle 2 (§4.2) — altruism across MXDAGs sharing a cluster.

    Each job's critical tasks keep class 0.  A job's non-critical task is
    demoted below *other* jobs' critical tasks only when its slack (from the
    isolated analytic pass) covers the foreign critical work queued on the
    same resource — this implements "delaying its non-critical path resource
    allocation ... without increasing its own end-to-end completion time".
    """

    def __init__(self, *, try_pipelining: bool = False):
        self.try_pipelining = try_pipelining

    def schedule(self, graphs: list[MXDAG],
                 cluster: Optional[Cluster] = None) -> Schedule:
        merged = MXDAG("+".join(g.name for g in graphs))
        for g in graphs:
            for t in g:
                merged.add(t)
            for e in g.edges.values():
                merged.add_edge(e.src, e.dst, pipelined=e.pipelined)

        # isolated analytics per job
        prio: dict[str, float] = {}
        slack: dict[str, float] = {}
        critical: dict[str, set[str]] = {}
        for g in graphs:
            timing = g.with_slack()
            crit = {n for n, tm in timing.items() if tm.slack <= 1e-9}
            critical[g.name] = crit
            for n, tm in timing.items():
                slack[n] = tm.slack
                prio[n] = CRITICAL if n in crit else NONCRITICAL

        # altruistic demotion, bounded by slack; fabric-aware when the
        # cluster has a Topology (contention on shared uplinks counts too)
        by_resource = merged.resource_map(cluster)
        res_of = {n: (cluster.resources_for(t) if cluster is not None
                      else t.resources())
                  for n, t in merged.tasks.items()}
        for g in graphs:
            others_crit = set().union(*(critical[o.name] for o in graphs
                                        if o.name != g.name)) \
                if len(graphs) > 1 else set()
            for n in g.tasks:
                if prio[n] != NONCRITICAL:
                    continue
                foreign = 0.0
                for r in res_of[n]:
                    foreign += sum(merged.tasks[m].size
                                   for m in by_resource[r]
                                   if m in others_crit)
                if foreign > 0 and slack[n] >= foreign - 1e-9:
                    prio[n] = ALTRUIST_DEMOTED
        return Schedule(graph=merged, policy="priority", priorities=prio,
                        meta={"critical": critical})
