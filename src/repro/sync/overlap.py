"""Layer-wise overlapped gradient sync: the Fig. 6 schedule, realized.

``make_synced_scan`` replaces a plain ``lax.scan`` over layer blocks with
a custom-vjp scan whose *backward* emits each layer's parameter-gradient
collective INSIDE the reverse loop body:

- forward: scan saving only each layer's input (== remat by construction),
- backward: reverse scan; per layer, ``jax.vjp`` recomputes the block and
  the layer's dparams are immediately sharding-constrained to a
  data-sharded spec — GSPMD therefore emits a per-layer reduce-scatter
  *inside* the while body, which XLA's async collective scheduler overlaps
  with the next (earlier) layer's backward compute.

This is the paper's co-scheduling insight mapped to TPU semantics
(DESIGN.md §2): the network task (the per-layer RS) becomes an explicit,
ordered, overlappable op instead of one barrier all-reduce after the whole
backward (``sync_mode="barrier"``, the coflow-like baseline).
``tests/test_sync.py`` verifies both the HLO structure (RS inside the loop
vs AR outside) and numerical equality of the gradients.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, RunConfig


def make_grad_sync_fn(mesh, cfg: ArchConfig, run: RunConfig,
                      dp_axes: tuple[str, ...]) -> Callable:
    """Returns sync(dparams_tree) applying a reduce-scatter-inducing
    sharding constraint: the grad keeps its param sharding plus data-
    sharding on the first free, divisible dim."""
    from repro.launch.sharding import param_spec_for, _axsize

    dpsize = 1
    for a in dp_axes:
        dpsize *= mesh.shape[a]

    def one(path, g):
        # Constrain each layer grad to its parameter's sharding.  NOTE: an
        # earlier version additionally injected a dp-sharded dim hoping
        # GSPMD would emit a reduce-scatter (ZeRO-1); measurement showed
        # it lowers as all-reduce + dynamic-slice — same wire bytes — so
        # the hypothesis was refuted and dropped (EXPERIMENTS.md §Perf).
        base = param_spec_for(path, g.shape, cfg, run, mesh)
        entries = list(base) + [None] * (g.ndim - len(base))
        return jax.lax.with_sharding_constraint(
            g, NamedSharding(mesh, P(*entries[:g.ndim])))

    def sync(tree):
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        return jax.tree_util.tree_unflatten(
            treedef, [one(path, g) for path, g in flat])

    return sync


def make_synced_scan(body: Callable, sync: Optional[Callable]):
    """body(bp, x) -> (x_out, aux).  Returns scan(params_stack, x) ->
    (x_final, aux_sum) whose bwd applies ``sync`` to each layer's dparams
    inside the reverse loop."""

    @jax.custom_vjp
    def scan_fn(params_stack, x):
        def step(carry, bp):
            xc, aux = carry
            x2, a = body(bp, xc)
            return (x2, aux + a.astype(jnp.float32)), None

        (xf, aux), _ = jax.lax.scan(
            step, (x, jnp.zeros((), jnp.float32)), params_stack)
        return xf, aux

    def fwd(params_stack, x):
        def step(carry, bp):
            xc, aux = carry
            x2, a = body(bp, xc)
            return (x2, aux + a.astype(jnp.float32)), xc   # save input

        (xf, aux), xs = jax.lax.scan(
            step, (x, jnp.zeros((), jnp.float32)), params_stack)
        return (xf, aux), (params_stack, xs)

    def bwd(res, cts):
        params_stack, xs = res
        dxf, daux = cts

        def step(dx, inp):
            bp, x_in = inp
            _, vjp_fn = jax.vjp(lambda p, xx: body(p, xx), bp, x_in)
            dp, dxin = vjp_fn((dx, daux.astype(jnp.float32)))
            # cast cotangents to the param dtype BEFORE the data-axis
            # reduction: the in-loop grad all-reduce then runs in bf16
            # instead of f32 — halved wire bytes (measured in §Perf)
            dp = jax.tree.map(lambda g, p: g.astype(p.dtype), dp, bp)
            # §Perf iter 6: the inter-layer activation cotangent carries
            # the TP partial-sum ARs; keeping it in the activation dtype
            # (bf16) halves those wire bytes (standard mixed precision)
            dxin = dxin.astype(x_in.dtype)
            if sync is not None:
                dp = sync(dp)
            return dxin, dp

        dx0, dps = jax.lax.scan(step, dxf, (params_stack, xs),
                                reverse=True)
        return dps, dx0

    scan_fn.defvjp(fwd, bwd)
    return scan_fn
