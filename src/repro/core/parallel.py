"""Process-parallel evaluation of independent DES trials.

What-if sweeps and the scheduler's candidate evaluations are
embarrassingly parallel: every trial is an independent simulation of a
variant graph, and the pipeline only *compares* their results.  This
module fans such trials across worker processes while keeping the
outcome bit-identical to the serial loop:

- **fork-shared compiled arrays**: workers are forked, so the parent's
  version-keyed compiled caches (``compile_sim`` arrays, analytic
  passes, resource maps) are inherited copy-on-write — no per-trial
  recompile and no serialization of the graph.  Callers should warm the
  caches (e.g. evaluate the baseline) before fanning out.
- **deterministic order**: results are returned in trial order no
  matter which worker finishes first, so downstream argmin/tie-break
  logic sees exactly the serial sequence.
- **crash containment**: a worker dying (OOM kill, hard crash) breaks
  the pool — the survivors' results are kept and every missing trial is
  re-evaluated serially in order, with a :class:`RuntimeWarning`; a
  sweep never hangs on a dead worker and never silently drops a trial.

On platforms without ``fork`` (or with ``workers<=1``) everything runs
serially in-process; there is no behavioural difference, only wall
time.  The trial callable is shipped to workers through the pool
initializer (inherited through fork, never pickled), so closures over
graphs and schedulers are fine; trial *inputs* and *results* cross the
process boundary and must pickle (indices, floats, small tuples).
"""
from __future__ import annotations

import os
import warnings
from typing import Callable, Iterable, Optional

try:  # pragma: no cover - stdlib, but keep the numpy-free core lane honest
    import multiprocessing as _mp
    from concurrent.futures import ProcessPoolExecutor
    from concurrent.futures.process import BrokenProcessPool
except ImportError:  # pragma: no cover
    _mp = None
    ProcessPoolExecutor = None  # type: ignore[assignment]

    class BrokenProcessPool(Exception):  # type: ignore[no-redef]
        """Stand-in so the except clause below still parses."""


_TRIAL_FN: Optional[Callable] = None


def _init_worker(fn: Callable) -> None:
    global _TRIAL_FN
    _TRIAL_FN = fn


def _run_trial(payload):
    i, item = payload
    return i, _TRIAL_FN(item)


def cpu_count() -> int:
    """Usable cores (affinity-aware where the platform exposes it)."""
    try:
        return len(os.sched_getaffinity(0))
    except (AttributeError, OSError):
        return os.cpu_count() or 1


def effective_workers(workers: Optional[int]) -> int:
    """How many processes a ``workers=`` request actually yields: 1
    (serial) unless a count > 1 is requested and ``fork`` pools exist."""
    if not workers or workers <= 1:
        return 1
    if _mp is None or ProcessPoolExecutor is None:
        return 1
    if "fork" not in _mp.get_all_start_methods():
        return 1
    return int(workers)


def trial_map(fn: Callable, items: Iterable, workers: Optional[int] = None,
              *, label: str = "trials") -> list:
    """``[fn(x) for x in items]`` fanned across forked workers.

    Results come back in ``items`` order regardless of completion
    order.  Any pool failure (worker crash, broken pipe) degrades to
    serial evaluation of the missing trials with a warning — identical
    results, just slower.  ``workers`` <= 1, a single item, or a
    platform without fork short-circuits to the plain serial loop.
    """
    items = list(items)
    w = min(effective_workers(workers), len(items))
    if w <= 1:
        return [fn(it) for it in items]
    results: list = [None] * len(items)
    done = [False] * len(items)
    try:
        ctx = _mp.get_context("fork")
        with ProcessPoolExecutor(max_workers=w, mp_context=ctx,
                                 initializer=_init_worker,
                                 initargs=(fn,)) as pool:
            futures = [pool.submit(_run_trial, (i, it))
                       for i, it in enumerate(items)]
            for fut in futures:
                i, r = fut.result()
                results[i] = r
                done[i] = True
    except (BrokenProcessPool, OSError, RuntimeError) as exc:
        warnings.warn(
            f"parallel {label}: worker pool failed ({exc!r}); "
            f"re-running the incomplete trials serially",
            RuntimeWarning, stacklevel=2)
    for i, ok in enumerate(done):
        if not ok:
            results[i] = fn(items[i])
    return results


def speedup_workers(n_trials: int, workers: Optional[int]) -> float:
    """Ideal-speedup bound for diagnostics: ``min(workers, n_trials)``
    capped by the machine's cores (a 4-worker sweep on 1 core is 1x)."""
    w = min(effective_workers(workers), max(1, n_trials))
    return float(min(w, cpu_count()))


__all__ = ["trial_map", "effective_workers", "cpu_count",
           "speedup_workers"]
