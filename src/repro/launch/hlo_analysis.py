"""Roofline-term extraction from a lowered/compiled SPMD module.

``collective_bytes`` is NOT in ``cost_analysis()`` — we parse the
post-partitioning HLO text and sum the operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
/ ragged-all-to-all.  The SPMD module is the *per-device* program, so the
sum is per-chip bytes on the wire; with the spec's convention
(collective term = Σ_global / (chips × link_bw)) the chips cancel:
term = per-chip bytes / link_bw.

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
HBM_PER_CHIP = 16 * 1024 ** 3      # v5e: 16 GiB

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

# e.g.  bf16[8,128,512]{2,1,0}
_TYPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _type_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-collective-kind operand bytes summed over the module."""
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # match ' = <type> <op>(' and op-start variants
        m = re.search(r"=\s*(?:\([^)]*\)|\S+)\s+([a-z-]+)(?:-start|-done)?\(",
                      stripped)
        if not m:
            continue
        op = m.group(1)
        base = op[:-6] if op.endswith("-start") else op
        if base not in _COLLECTIVES:
            continue
        if op.endswith("-done"):
            continue                      # counted at -start
        # operands are inside the call parens; types printed inline
        paren = stripped[stripped.index(op) + len(op):]
        total = 0
        for dt, dims in _TYPE_RE.findall(paren):
            total += _type_bytes(dt, dims)
        out[base] += total
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                # per-device HLO flops
    hbm_bytes: float            # per-device bytes accessed
    coll_bytes: float           # per-device collective operand bytes
    coll_breakdown: dict
    chips: int
    model_flops: float = 0.0    # 6·N·D (global)

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs × chips): remat/redundancy waste."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / bound time — the score we hillclimb."""
        if self.bound_s <= 0:
            return 0.0
        useful_s = self.model_flops / (self.chips * PEAK_FLOPS)
        return useful_s / self.bound_s

    def to_dict(self) -> dict:
        return {
            "flops_per_dev": self.flops,
            "hbm_bytes_per_dev": self.hbm_bytes,
            "coll_bytes_per_dev": self.coll_bytes,
            "coll_breakdown": self.coll_breakdown,
            "chips": self.chips,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def analyze(compiled, chips: int, model_flops: float = 0.0,
            hlo_text: Optional[str] = None) -> Roofline:
    """Trip-count-aware totals via repro.launch.hlo_cost (XLA's own
    cost_analysis() visits while bodies once — see that module)."""
    from repro.launch import hlo_cost
    text = hlo_text if hlo_text is not None else compiled.as_text()
    cost = hlo_cost.analyze_text(text)
    return Roofline(flops=cost.flops, hbm_bytes=cost.bytes,
                    coll_bytes=cost.coll_bytes,
                    coll_breakdown={k: v for k, v in cost.coll.items()},
                    chips=chips, model_flops=model_flops)


def memory_summary(compiled) -> dict:
    ma = compiled.memory_analysis()
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        out[k] = int(getattr(ma, k, 0) or 0)
    out["peak_estimate_bytes"] = (out["argument_size_in_bytes"]
                                  + out["temp_size_in_bytes"]
                                  - out.get("alias_size_in_bytes", 0))
    out["fits_hbm"] = out["peak_estimate_bytes"] <= HBM_PER_CHIP
    return out
