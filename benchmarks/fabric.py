"""Fabric benchmark: co-scheduling vs fair sharing on oversubscribed cores.

The single-switch figures can't show this regime at all — the whole point
of the link-level fabric.  Sweeps the two-tier oversubscription knob on the
cross-rack fan-in scenario and reports both schedulers' makespans, plus a
wall-time micro for path-based allocation on a fat-tree shuffle.
"""
from __future__ import annotations

from benchmarks._util import timeit_us


def bench_rows():
    from repro.core import (
        Cluster, FairShareScheduler, MXDAG, MXDAGScheduler, Topology,
        compute, flow, simulate,
    )
    from repro.core.builders import oversubscribed_fanin

    rows = []
    for oversub in (1.0, 2.0, 4.0, 8.0):
        g, cl = oversubscribed_fanin(n_senders=4, oversubscription=oversub)
        fair = FairShareScheduler().schedule(g, cl).simulate(cl)
        mx = MXDAGScheduler(try_pipelining=False).schedule(g, cl) \
            .simulate(cl)
        tag = f"{oversub:g}to1"
        rows.append((f"fabric.fanin4_{tag}.fair", fair.makespan,
                     f"fair sharing on a {tag} oversubscribed core"))
        rows.append((f"fabric.fanin4_{tag}.mxdag", mx.makespan,
                     "MXDAG priority co-scheduling, same fabric"))
        rows.append((f"fabric.fanin4_{tag}.speedup",
                     fair.makespan / mx.makespan,
                     "co-scheduling gain (grows with oversubscription)"))

    # DES wall-time with path-based allocation on a k=4 fat-tree shuffle
    topo = Topology.fat_tree(4)
    cl = Cluster.from_topology(topo)
    hosts = topo.hosts()
    g = MXDAG("ft_shuffle")
    senders = hosts[:8]
    receivers = hosts[8:]
    for i, s in enumerate(senders):
        m = g.add(compute(f"m{i}", 1.0, s))
        for j, d in enumerate(receivers):
            f = g.add(flow(f"s{i}_{j}", 0.125, s, d))
            g.add_edge(m, f)
    rows.append(("fabric.micro.simulate_ft4_shuffle_us",
                 timeit_us(lambda: simulate(g, cl)),
                 "DES of an 8x8 shuffle on a k=4 fat-tree (72 tasks, "
                 "6-link paths)"))
    return rows
