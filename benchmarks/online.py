"""Online multi-job service benchmark: sustained Poisson arrivals.

Drives :mod:`repro.core.service` — the MDBconductor-style admission
front end over the live ``admit_graph``/``retire_job`` engine — with
seeded arrival streams from :func:`repro.core.builders.poisson_jobs`
and emits the paper-facing online metrics plus the CI gate rows.

Row families:

- ``online.altruistic_<mix>.ref_match`` — 1.0 iff the compiled
  altruistic multi-job pass (``analytic="array"``) produces the exact
  priority map of the retained dict oracle on that builder mix
  (gated: must equal 1.0),
- ``online.oversub.jct_wins`` — 1.0 iff altruistic admission beats
  both FIFO and fair admission on p99 JCT in the oversubscribed mix
  (gated; the Principle-2 claim in the online regime),
- ``online.<cfg>.<policy>.{throughput,mean_jct,p50_jct,p99_jct,
  rejection_rate}`` — service metrics per admission policy (model
  time; informational),
- ``online.replan_loop_us`` / ``online.replan_loop_dict_us`` — wall
  time of the service-loop re-prioritisation (a sliding window of jobs
  re-scheduled per admission/completion) on the compiled and dict
  substrates; ``online.speedup_replan_loop`` is gated at >= 3x,
- ``online.speedup_replan_stream`` — the same ratio on the small-job
  Poisson stream (informational: tiny jobs leave little for the
  compiled passes to amortize),
- ``online.sustained_us`` — wall time of the full altruistic service
  run on the oversubscribed mix (regression-tracked like any other
  wall-time row),
- ``online.drill.*`` — the mid-stream host-kill recovery drill
  (informational only): p99 degradation, restart count, completions.

``--smoke`` keeps the streams CI-sized (tens of jobs); the full sweep
runs hundreds.  ``--json PATH`` dumps rows for the artifact/baseline
diff, as in the sibling benchmarks.
"""
from __future__ import annotations

import os
import sys

_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)        # so `python benchmarks/online.py` works

from benchmarks._util import timeit_pair_us, timeit_us  # noqa: E402

#: builder mixes for the dict-vs-array golden rows
MIXES = {
    "mr": ("mapreduce",),
    "ddl": ("ddl",),
    "fanin": ("fanin",),
    "layered": ("layered",),
    "zoo": None,     # the full JOB_SHAPES default
}


def ref_match_rows():
    """``online.altruistic_<mix>.ref_match``: compiled vs dict priority
    maps, exact dict equality, one row per builder mix."""
    from repro.core import builders
    from repro.core.schedule import AltruisticMultiScheduler

    cl = builders.pool_cluster(8)
    rows = []
    for label, mix in MIXES.items():
        kw = {} if mix is None else {"mix": mix}
        graphs = [g for _, g in builders.poisson_jobs(
            2.0, 10.0, seed=23, n_hosts=8, **kw)]
        pa = AltruisticMultiScheduler(
            analytic="array").schedule(graphs, cl).priorities
        pd = AltruisticMultiScheduler(
            analytic="dict").schedule(graphs, cl).priorities
        rows.append((f"online.altruistic_{label}.ref_match",
                     1.0 if pa == pd else 0.0,
                     f"array == dict priority map over {len(graphs)} "
                     f"{label} jobs (1.0 = bit-exact)"))
    return rows


def _window_jobs(n, size):
    """Identical mid-size layered jobs pool for the replan-loop timing."""
    from repro.core import builders
    return [builders.random_layered(
        size, seed=i, name=f"w{i:02d}", job=f"w{i:02d}",
        host_prefix="pool.M", n_hosts=8, min_width=4, max_width=8)
        for i in range(n)]


def speedup_rows(smoke: bool = True):
    """The compiled-vs-dict wall-time rows for the multi-job pass.

    The gated shape is the *service loop*: one scheduler instance
    re-prioritising a sliding window of jobs call after call, which is
    exactly what the admission service does on every admission and
    completion.  The compiled path's per-job memoization (analytics and
    resource fragments keyed on graph version) plus the bulk merged
    view clear 3x over the dict pipeline, which re-runs ``with_slack``
    per job per call.
    """
    from repro.core import builders
    from repro.core.schedule import AltruisticMultiScheduler

    cl = builders.pool_cluster(8)
    calls, window = (16, 8) if smoke else (48, 8)
    pool = _window_jobs(16, 500)

    def loop(analytic, jobs, ncalls, win):
        sch = AltruisticMultiScheduler(analytic=analytic)
        for i in range(ncalls):
            active = jobs[i % len(jobs):][:win]
            if len(active) < win:
                active = active + jobs[:win - len(active)]
            sch.schedule(active, cl)

    ta, td = timeit_pair_us(lambda: loop("array", pool, calls, window),
                            lambda: loop("dict", pool, calls, window))
    rows = [
        ("online.replan_loop_us", ta,
         f"{calls} service-loop re-prioritisations, sliding window of "
         f"{window} x 500-task jobs, compiled passes ({ta.note})"),
        ("online.replan_loop_dict_us", td,
         f"same loop on the dict pipeline ({td.note})"),
        ("online.speedup_replan_loop", td / ta,
         f"dict {td / 1e3:.1f}ms / array {ta / 1e3:.1f}ms "
         f"(gated >= 3x)"),
    ]

    stream = [g for _, g in builders.poisson_jobs(
        4.0, 16.0, seed=5, n_hosts=8)]
    ta2, td2 = timeit_pair_us(
        lambda: loop("array", stream, 24, 12),
        lambda: loop("dict", stream, 24, 12))
    rows.append(("online.speedup_replan_stream", td2 / ta2,
                 f"same loop over the small-job Poisson stream "
                 f"(informational: dict {td2 / 1e3:.1f}ms / "
                 f"array {ta2 / 1e3:.1f}ms)"))
    return rows


def service_rows(smoke: bool = True):
    """Sustained-arrival sweep: throughput / JCT / rejection per
    admission policy, the gated p99 win row, and the wall-time row."""
    from repro.core import builders, service

    cl = builders.pool_cluster(8)
    horizon = 20.0 if smoke else 120.0
    arrivals = builders.poisson_jobs(3.0, horizon, seed=11, n_hosts=8)
    cfg = {"max_backlog": 12.0}

    rows = []
    summaries = {}
    for pol in ("altruistic", "fifo", "fair"):
        s = service.run_stream(cl, arrivals, policy=pol, **cfg).summary()
        summaries[pol] = s
        for metric in ("throughput", "mean_jct", "p50_jct", "p99_jct",
                       "rejection_rate"):
            rows.append((f"online.oversub.{pol}.{metric}", s[metric],
                         f"{pol} admission over {len(arrivals)} Poisson "
                         f"jobs, backlog budget 12 (model time)"))
    alt, fifo, fair = (summaries[p]["p99_jct"]
                       for p in ("altruistic", "fifo", "fair"))
    rows.append(("online.oversub.jct_wins",
                 1.0 if alt <= fifo + 1e-9 and alt <= fair + 1e-9
                 else 0.0,
                 f"altruistic p99 {alt:.4g} <= fifo {fifo:.4g} and "
                 f"fair {fair:.4g} (1.0 = validated)"))
    rows.append(("online.oversub.completed",
                 float(summaries["altruistic"]["completed"]),
                 "jobs completed by the altruistic service"))

    tw = timeit_us(lambda: service.run_stream(
        cl, arrivals, policy="altruistic", **cfg), repeat=3)
    rows.append(("online.sustained_us", tw,
                 f"altruistic service end to end, {len(arrivals)} jobs "
                 f"({tw.note})"))
    return rows


def drill_rows(smoke: bool = True):
    """The mid-stream host-kill recovery drill (informational)."""
    from repro.core import builders, service

    cl = builders.pool_cluster(4)
    arrivals = builders.poisson_jobs(1.5, 12.0, seed=7, n_hosts=4)
    d = service.online_recovery_drill(cl, arrivals, host="pool.M1",
                                      at=2.0, downtime=1.0)
    return [
        ("online.drill.degradation", d["degradation"],
         f"fault p99 {d['fault_p99_jct']:.4g} / clean p99 "
         f"{d['clean_p99_jct']:.4g} with pool.M1 down 1s at t=2"),
        ("online.drill.restarted", float(d["restarted"]),
         "tasks restarted by the kill (lineage included)"),
        ("online.drill.completed", float(d["fault_completed"]),
         f"jobs completed under the fault (clean run: "
         f"{d['clean_completed']})"),
    ]


def bench_rows(smoke: bool = True):
    """All ``online.*`` (name, value, derived) rows for run.py/CI."""
    return (ref_match_rows() + speedup_rows(smoke)
            + service_rows(smoke) + drill_rows(smoke))


def main() -> None:
    """CLI driver: CSV rows by default, ``--json`` for the artifact."""
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized streams (tens of jobs, not hundreds)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the rows as JSON to PATH")
    args = ap.parse_args()

    rows = bench_rows(smoke=args.smoke)
    if args.json:        # artifact first: survives a closed stdout pipe
        with open(args.json, "w") as f:
            json.dump([{"name": n, "value": v, "derived": str(d)}
                       for n, v, d in rows], f, indent=2)
    print("name,value,derived")
    for name, value, derived in rows:
        print(f"{name},{value:.6g},{str(derived).replace(',', ';')}")


if __name__ == "__main__":
    main()
