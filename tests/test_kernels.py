"""Pallas kernel validation: shape/dtype sweeps vs the jnp oracles
(interpret mode executes kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("S,hd,H,K", [
        (128, 32, 2, 2),    # MHA
        (128, 64, 4, 2),    # GQA 2:1
        (256, 32, 4, 1),    # MQA
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_ref(self, S, hd, H, K, causal, dtype):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        B = 2
        q = rand(ks[0], (B, S, H, hd), dtype)
        k = rand(ks[1], (B, S, K, hd), dtype)
        v = rand(ks[2], (B, S, K, hd), dtype)
        out = ops.flash_attention(q, k, v, causal=causal)
        want = ref.flash_attention_ref(
            jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
            jnp.swapaxes(v, 1, 2), causal=causal)
        want = jnp.swapaxes(want, 1, 2)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(want, np.float32),
            **TOL[dtype])

    def test_block_size_invariance(self):
        """Result must not depend on the tiling."""
        from repro.kernels.flash_attention import flash_attention_bhsd
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = rand(ks[0], (1, 2, 256, 32), jnp.float32)
        k = rand(ks[1], (1, 2, 256, 32), jnp.float32)
        v = rand(ks[2], (1, 2, 256, 32), jnp.float32)
        a = flash_attention_bhsd(q, k, v, block_q=64, block_k=64)
        b = flash_attention_bhsd(q, k, v, block_q=128, block_k=32)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)

    def test_gradient_flows(self):
        """custom_vjp: kernel fwd + recompute bwd."""
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q = rand(ks[0], (1, 128, 2, 32), jnp.float32)
        k = rand(ks[1], (1, 128, 2, 32), jnp.float32)
        v = rand(ks[2], (1, 128, 2, 32), jnp.float32)

        def loss_kernel(q, k, v):
            return jnp.sum(ops.flash_attention(q, k, v) ** 2)

        def loss_ref(q, k, v):
            o = ref.flash_attention_ref(
                jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                jnp.swapaxes(v, 1, 2))
            return jnp.sum(jnp.swapaxes(o, 1, 2) ** 2)

        g1 = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-4)


class TestSSD:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("Q,P,N,G,H", [
        (16, 16, 8, 1, 2),
        (32, 32, 16, 2, 4),
    ])
    def test_intra_chunk_matches_ref(self, Q, P, N, G, H, dtype):
        ks = jax.random.split(jax.random.PRNGKey(3), 4)
        BH, BG, nc = 2 * H, 2 * G, 3
        x = rand(ks[0], (BH, nc, Q, P), dtype)
        dt = jax.nn.softplus(rand(ks[1], (BH, nc, Q), jnp.float32))
        A = -jnp.abs(rand(ks[2], (BH,), jnp.float32)) - 0.1
        Bm = rand(ks[3], (BG, nc, Q, N), dtype)
        Cm = rand(ks[0], (BG, nc, Q, N), dtype)
        from repro.kernels.ssd import ssd_intra_chunk
        y, st, cum = ssd_intra_chunk(x, dt, A, Bm, Cm, interpret=True)
        yr, str_, cumr = ref.ssd_intra_chunk_ref(x, dt, A, Bm, Cm)
        tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
            else dict(rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr), **tol)
        np.testing.assert_allclose(np.asarray(st), np.asarray(str_), **tol)
        np.testing.assert_allclose(np.asarray(cum), np.asarray(cumr),
                                   rtol=1e-5, atol=1e-5)

    def test_full_chunked_layer_matches_sequential(self):
        """State-space duality: chunked(kernel) == sequential recurrence."""
        ks = jax.random.split(jax.random.PRNGKey(4), 5)
        B, L, H, P, G, N, chunk = 2, 64, 4, 16, 2, 8, 16
        x = rand(ks[0], (B, L, H, P), jnp.float32)
        dt = jax.nn.softplus(rand(ks[1], (B, L, H), jnp.float32))
        A = -jnp.abs(rand(ks[2], (H,), jnp.float32)) - 0.1
        Bm = rand(ks[3], (B, L, G, N), jnp.float32)
        Cm = rand(ks[4], (B, L, G, N), jnp.float32)
        y, final = ops.ssd_chunked_pallas(x, dt, A, Bm, Cm, chunk)
        yr, finalr = ref.ssd_sequential_ref(x, dt, A, Bm, Cm)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(np.asarray(final), np.asarray(finalr),
                                   rtol=1e-3, atol=1e-3)

    def test_jnp_chunked_model_path_matches_sequential(self):
        """models.ssm.ssd_chunked (the XLA train path) vs the recurrence."""
        from repro.models.ssm import ssd_chunked
        ks = jax.random.split(jax.random.PRNGKey(5), 5)
        B, L, H, P, G, N, chunk = 2, 64, 2, 8, 1, 8, 16
        x = rand(ks[0], (B, L, H, P), jnp.float32)
        dt = jax.nn.softplus(rand(ks[1], (B, L, H), jnp.float32))
        A = -jnp.abs(rand(ks[2], (H,), jnp.float32)) - 0.1
        Bm = rand(ks[3], (B, L, G, N), jnp.float32)
        Cm = rand(ks[4], (B, L, G, N), jnp.float32)
        y, final = ssd_chunked(x, dt, A, Bm, Cm, chunk)
        yr, finalr = ref.ssd_sequential_ref(x, dt, A, Bm, Cm)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(np.asarray(final), np.asarray(finalr),
                                   rtol=1e-3, atol=1e-3)


class TestGMM:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("E,C,d,f", [
        (2, 16, 32, 32),
        (4, 64, 128, 64),
        (3, 32, 96, 48),
    ])
    def test_matches_ref(self, E, C, d, f, dtype):
        ks = jax.random.split(jax.random.PRNGKey(6), 2)
        x = rand(ks[0], (E, C, d), dtype)
        w = rand(ks[1], (E, d, f), dtype)
        out = ops.grouped_matmul(x, w, block_c=16, block_f=16, block_d=32)
        want = ref.gmm_ref(x, w)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(want, np.float32),
            **TOL[dtype])

    def test_tiling_invariance(self):
        ks = jax.random.split(jax.random.PRNGKey(7), 2)
        x = rand(ks[0], (2, 64, 64), jnp.float32)
        w = rand(ks[1], (2, 64, 32), jnp.float32)
        a = ops.grouped_matmul(x, w, block_c=64, block_f=32, block_d=64)
        b = ops.grouped_matmul(x, w, block_c=16, block_f=16, block_d=16)
        # summation order differs across block_d -> fp32 noise only
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)
