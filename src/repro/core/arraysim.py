"""Flat-array DES engine: the compiled fast path behind ``Simulator.run``.

The event-calendar core in :mod:`repro.core.simulator` keys every piece of
run state by task-name strings in dicts.  At Graphene scale (tens of
thousands of vertices; Grandl et al., OSDI'16) the hashing, string
comparisons and per-task Python loops dominate the wall time.  This module
compiles one (MXDAG, Cluster, coflows, routes) quadruple into
integer-interned flat arrays, then runs the *same* event-calendar
algorithm on top of them.

Compiled layout (:class:`CompiledSim`, cached on the graph keyed by graph
version + cluster identity + coflow/route keys, so scheduler and what-if
sweeps that vary only priorities/releases compile once per graph version):

- task ids are insertion-order integers; ``names``/``idx`` map back and
  forth, ``name_rank`` is each task's rank in lexicographic name order
  (dispatch and waterfill orders sort by name — ranks reproduce the
  string sorts on ints);
- per-task scalars ``size``/``unit``/``nu``/``is_compute``/``job`` as flat
  lists (mirrored as float64/int64 NumPy arrays when NumPy is present);
- flow→link incidence in CSR form: ``flow_links[p]`` is the interned link
  tuple of the flow at net position ``p``; ``fl_ptr``/``fl_flat`` are the
  NumPy CSR mirror used by the vectorized waterfill; ``link_bw`` the
  per-link capacities;
- streaming-predecessor adjacency (``stream_in``/``stream_out``) and
  start-gate structure compiled to one fused *counter* per task:
  ``init_gate[i]`` counts unmet barrier + coflow + member-sync
  preconditions (all non-negative and all required, so their sum gates
  identically), and ``gate_dec``/``cof_dec`` say which counters each
  completion (or coflow completion) decrements — start gating is
  monotone, so counter-zero is equivalent to the calendar core's
  re-scan of its gate lists;
- coflow membership (``coflow_of``/``coflows``/``coflow_fed_by``) and
  per-flow priority-class inputs (``stream_fed``).

The run state is float64 ``work``/``rate`` vectors, int heap entries
``(time, kind, task_id, stamp)``, and integer slot/link indices.  Rate
(re)allocation per priority class goes through the vectorized waterfill:
bottleneck search is a NumPy reduction over the link arrays, with the
scalar scan's first-within-EPS tie-break reproduced exactly by scanning
only the strict prefix minima of the ratio vector, and whole freeze
batches are subtracted via bincounts on the incidence CSR.

Two compile-time structures keep reallocation local (the fix for the
ddl-style serial-chain trickle, which previously saw only ~1.2x from
the arrays because every completion re-filled and re-heaped every
runnable flow):

- **contention components** — union-find over the flow→link incidence;
  flows in different components share no links, so ``allocate()``
  refills only *dirty* components (per-component lowest-dirty-class
  replay logs included) and untouched components' rates — provably what
  a global refill would recompute, since fills only read their own
  links — are skipped outright.  Coflows collapse the split into one
  component: MADD weights couple every rate and re-dirty every event.
- **coalesced completion events** — a flow with no streaming role and
  no unit boundaries (``unit >= size``) can only ever complete, so each
  component carries *one* heap entry (min next-completion over its
  runnable "simple" flows, kind 2, stamped per component) instead of
  one entry per flow per rate change.  The entry's time is exactly the
  min of the per-flow times schedule_event would have pushed, so the
  event calendar — and therefore every result — is unchanged; only the
  stale-entry volume drops from O(flows) to O(1) per reallocation.

The analytic compile (:mod:`repro.core.arrayanalytic`) shares this
module's interning: ``_compile`` reuses its name table, per-task
scalars and int adjacency, so one per-task/per-edge traversal per graph
version serves both the scheduler's slack passes and the DES.

NumPy-optional policy: ``import numpy`` is guarded at module import.  The
core CI lane runs pure-stdlib — without NumPy the same compiled engine
runs list-backed kernels and the waterfill falls back to a scalar
progressive fill (a port of :func:`repro.core.simulator.waterfill` to the
interned domain, same freeze order and arithmetic), so results are
engine-identical either way.  The golden differential tests assert the
array engine reproduces the calendar core — and hence the retained
``_reference_run`` seed oracle — on every scenario.
"""
from __future__ import annotations

import heapq
import math
from bisect import bisect_left
from itertools import chain

try:
    import numpy as np
except ImportError:                      # pure-stdlib core lane
    np = None

from repro.core.arrayanalytic import compile_analytic
from repro.core.task import TaskKind

EPS = 1e-9


class CompiledSim:
    """Flat-array form of one (graph, cluster, coflows, routes)."""

    __slots__ = (
        "n", "names", "idx", "name_rank", "size", "unit", "nu",
        "is_compute", "job", "slot_of", "slot_cap", "slot_ids",
        "net_ids", "net_pos",
        "n_net", "flow_links", "n_links", "link_bw", "link_ids", "succ",
        "gate_dec", "init_gate", "gate_stream", "stream_in",
        "stream_out",
        "has_streaming", "stream_fed", "coflow_of", "coflows", "cof_dec",
        "coflow_fed_by", "nu_sum", "np_ready", "single_job", "roots",
        # contention components: union-find over the flow→link incidence
        # (disjoint link/flow sets fill independently); ``simple`` marks
        # tasks whose only possible event is completion (flows with no
        # streaming role, no unit boundaries) — their events coalesce
        # into one per-component next-completion entry
        "n_comps", "comp_of_net", "simple",
        # NumPy mirrors (None when NumPy is absent)
        "size_a", "name_rank_a", "net_ids_a", "fl_ptr", "fl_flat",
        "link_bw_a",
        # precomputed fill structures for the full flow set (the common
        # fair-mode group: every flow runnable, none starved)
        "full_sorted_ids", "full_sg_pos", "full_row_links",
        "full_by_link", "full_counts",
    )


def compile_sim(sim) -> CompiledSim:
    """Compiled arrays for ``sim``, cached on the graph.

    Key: (graph version, cluster identity) owns a small dict keyed by
    (coflow grouping, route overrides) — the two Simulator inputs that
    change the incidence/gating structure.  Priorities, releases and
    policy are per-run inputs and never invalidate the compile.
    """
    g = sim.g
    sub = (tuple(tuple(sorted(c)) for c in sim.coflows),
           tuple(sorted(sim.routes.items())) if sim.routes else None)
    cache = g.__dict__.get("_array_compiled")
    if cache is not None and cache[0] == g._version \
            and cache[1] is sim.cluster:
        comp = cache[2].get(sub)
        if comp is not None:
            return comp
    else:
        cache = (g._version, sim.cluster, {})
        g._array_compiled = cache
    comp = _compile(sim)
    cache[2][sub] = comp
    return comp


def _compile(sim) -> CompiledSim:
    g, cluster = sim.g, sim.cluster
    tasks = g.tasks
    comp = CompiledSim()
    # the analytic compile (arrayanalytic) interns the same graph for
    # the scheduler's forward/reverse passes; reuse its name table,
    # per-task scalars and int adjacency so the two compiles share one
    # per-task/per-edge traversal per graph version
    an = compile_analytic(g)
    names, idx, n = an.names, an.idx, an.n
    comp.n, comp.names, comp.idx = n, names, idx
    comp.name_rank = an.name_rank
    comp.size = an.size
    comp.unit = an.eunit
    comp.nu = an.nu
    comp.nu_sum = sum(an.nu)
    comp.is_compute = an.is_compute
    comp.job = an.job
    comp.single_job = len(set(an.job)) <= 1
    comp.succ = an.succ_lists

    # compute slots (a pool absent from the cluster has 0 slots, exactly
    # like the calendar core's slots_free.get(r, 0))
    slot_ids: dict[tuple, int] = {}
    comp.slot_of = [-1] * n
    comp.slot_cap = []
    hosts = cluster.hosts
    is_compute = an.is_compute
    for i, t in enumerate(tasks.values()):
        if is_compute[i]:
            key = (t.host, t.proc)
            si = slot_ids.get(key)
            if si is None:
                si = slot_ids[key] = len(comp.slot_cap)
                h = hosts.get(t.host)
                comp.slot_cap.append(
                    int(h.procs.get(t.proc, 0)) if h is not None else 0)
            comp.slot_of[i] = si
    comp.slot_ids = slot_ids
    # flow→link incidence over interned links.  Without a fabric or
    # route overrides a flow's path is exactly (src NIC-out, dst NIC-in)
    # — intern those from the task fields directly, skipping the
    # string-keyed resource map (same first-seen interning order, same
    # capacities as Cluster.bandwidth on the NIC names).
    link_ids: dict = {}
    comp.flow_links = []
    comp.net_ids = []
    comp.net_pos = [-1] * n
    if cluster.topology is None and not sim.routes:
        link_bw: list[float] = []
        for i, t in enumerate(tasks.values()):
            if not is_compute[i]:
                comp.net_pos[i] = len(comp.net_ids)
                comp.net_ids.append(i)
                ko = ("o", t.src)
                lo = link_ids.get(ko)
                if lo is None:
                    lo = link_ids[ko] = len(link_bw)
                    link_bw.append(float(hosts[t.src].nic_out))
                kd = ("i", t.dst)
                ld = link_ids.get(kd)
                if ld is None:
                    ld = link_ids[kd] = len(link_bw)
                    link_bw.append(float(hosts[t.dst].nic_in))
                comp.flow_links.append((lo, ld))
        comp.n_links = len(link_bw)
        comp.link_bw = link_bw
    else:
        res = sim._res
        for i, (nm, t) in enumerate(tasks.items()):
            if not is_compute[i]:
                comp.net_pos[i] = len(comp.net_ids)
                comp.net_ids.append(i)
                ids = []
                for l in res[nm]:
                    li = link_ids.get(l)
                    if li is None:
                        li = link_ids[l] = len(link_ids)
                    ids.append(li)
                comp.flow_links.append(tuple(ids))
        comp.n_links = len(link_ids)
        bw = cluster.bandwidths(link_ids)
        comp.link_bw = [0.0] * comp.n_links
        for l, li in link_ids.items():
            comp.link_bw[li] = float(bw[l])
    comp.link_ids = link_ids
    comp.n_net = len(comp.net_ids)

    # coflows (members in sorted-name order: iteration order never
    # affects results — membership tests and maxima are commutative)
    comp.coflows = [[idx[m] for m in sorted(c)] for c in sim.coflows]
    comp.coflow_of = [-1] * n
    for ci, c in enumerate(comp.coflows):
        for m in c:
            comp.coflow_of[m] = ci

    pred_lists, pred_pipe = an.pred_lists, an.pred_pipe
    if not comp.coflows and not an.any_pipe:
        # barrier-only fast path: every edge gates at completion, so the
        # fused counter is the in-degree and the decrement list is
        # exactly the successor list (aliased, read-only)
        empty: tuple = ()
        comp.stream_in = [empty] * n
        comp.stream_out = [empty] * n
        comp.stream_fed = [False] * n
        comp.has_streaming = False
        comp.init_gate = [len(pl) for pl in pred_lists]
        comp.gate_dec = an.succ_lists
        comp.cof_dec = []
        comp.gate_stream = [empty] * n
        comp.coflow_fed_by = [empty] * n
    else:
        # streaming adjacency (coflow producers gate at start instead)
        stream_in: list[list[int]] = [[] for _ in range(n)]
        stream_out: list[list[int]] = [[] for _ in range(n)]
        comp.stream_fed = [False] * n
        # start gating compiled to counters + decrement lists: one fused
        # start-gate counter per task — unmet barrier preds + coflow
        # preconditions + member-sync preds (all non-negative and all
        # required to reach zero, so their sum gates identically)
        comp.init_gate = [0] * n
        gate_dec: list[list[int]] = [[] for _ in range(n)]
        cof_dec: list[list[int]] = [[] for _ in range(len(comp.coflows))]
        gate_stream: list[tuple[int, ...]] = [()] * n
        coflow_of = comp.coflow_of
        for i in range(n):
            stream = []
            for pi, pipe in zip(pred_lists[i], pred_pipe[i]):
                ci = coflow_of[pi]
                if ci >= 0:
                    comp.init_gate[i] += 1
                    cof_dec[ci].append(i)
                elif pipe:
                    stream.append(pi)
                    stream_in[i].append(pi)
                    stream_out[pi].append(i)
                else:
                    comp.init_gate[i] += 1
                    gate_dec[pi].append(i)
            if stream:
                gate_stream[i] = tuple(stream)
            ci = coflow_of[i]
            if ci >= 0:
                # synchronized start: every member's preds must be done
                for m in comp.coflows[ci]:
                    for p in pred_lists[m]:
                        comp.init_gate[i] += 1
                        gate_dec[p].append(i)
        # any effectively-pipelined in-edge marks the consumer
        # stream-fed (top-priority class) — including one from a coflow
        # member, whose edge otherwise gates at start
        for i in range(n):
            if pred_pipe[i] and any(pred_pipe[i]):
                comp.stream_fed[i] = True
        comp.stream_in = [tuple(v) for v in stream_in]
        comp.stream_out = [tuple(v) for v in stream_out]
        comp.has_streaming = any(stream_out)
        comp.gate_dec = [tuple(v) for v in gate_dec]
        comp.cof_dec = [tuple(v) for v in cof_dec]
        comp.gate_stream = gate_stream

        coflow_fed_by: list[list[int]] = [[] for _ in range(n)]
        for ci, c in enumerate(comp.coflows):
            for m in c:
                for p in pred_lists[m]:
                    coflow_fed_by[p].append(ci)
        comp.coflow_fed_by = [tuple(v) for v in coflow_fed_by]

    # tasks whose start-gate counters begin at zero: the only candidates
    # that can possibly pass the t=0 gating filter (everything else is
    # re-enqueued by the completion that decrements its counter)
    comp.roots = [i for i in range(n) if not comp.init_gate[i]]

    # contention components: union-find over the interned flow→link
    # incidence.  Flows in different components never share a link, so
    # a completion/start/starvation flip re-waterfills only its own
    # component (rates elsewhere are provably unchanged).  Coflows
    # disable the split: MADD weights couple rates across the whole
    # flow set and re-dirty every event, so everything collapses into
    # one component (which reproduces the global fill exactly).
    if comp.coflows:
        comp.n_comps = 1 if comp.n_net else 0
        comp.comp_of_net = [0] * comp.n_net
        comp.simple = [False] * n
    else:
        parent = list(range(comp.n_links))

        def find(x: int) -> int:
            while parent[x] != x:
                parent[x] = parent[parent[x]]
                x = parent[x]
            return x

        for links in comp.flow_links:
            if len(links) > 1:
                r0 = find(links[0])
                for l in links[1:]:
                    r = find(l)
                    if r != r0:
                        if r < r0:
                            parent[r0] = r
                            r0 = r
                        else:
                            parent[r] = r0
        comp_ids: dict = {}
        comp_of: list[int] = []
        for pos, links in enumerate(comp.flow_links):
            key = find(links[0]) if links else ("lone", pos)
            k = comp_ids.get(key)
            if k is None:
                k = comp_ids[key] = len(comp_ids)
            comp_of.append(k)
        comp.comp_of_net = comp_of
        comp.n_comps = len(comp_ids)
        simple = [False] * n
        unit, size = comp.unit, comp.size
        for i in comp.net_ids:
            simple[i] = (not comp.stream_in[i]
                         and not comp.stream_out[i]
                         and unit[i] >= size[i])
        comp.simple = simple

    comp.np_ready = np is not None
    if comp.np_ready:
        comp.size_a = np.array(comp.size, dtype=np.float64)
        comp.name_rank_a = np.array(comp.name_rank, dtype=np.int64)
        comp.net_ids_a = np.array(comp.net_ids, dtype=np.int64)
        ptr = [0]
        flat: list[int] = []
        for links in comp.flow_links:
            flat.extend(links)
            ptr.append(len(flat))
        comp.fl_ptr = np.array(ptr, dtype=np.int64)
        comp.fl_flat = np.array(flat, dtype=np.int64)
        comp.link_bw_a = np.array(comp.link_bw, dtype=np.float64)
        # full-group fill structures: sorted rows / incidence / link
        # index for the group "every flow", bit-identical to what the
        # fill would build for it per call
        order = sorted(range(comp.n_net),
                       key=lambda p: comp.name_rank[comp.net_ids[p]])
        comp.full_sg_pos = np.array(order, dtype=np.int64)
        comp.full_sorted_ids = [comp.net_ids[p] for p in order]
        comp.full_row_links = [list(comp.flow_links[p]) for p in order]
        by_link: dict[int, list[int]] = {}
        for r, links in enumerate(comp.full_row_links):
            for l in links:
                by_link.setdefault(l, []).append(r)
        comp.full_by_link = by_link
        comp.full_counts = np.bincount(
            _gather(comp.fl_ptr, comp.fl_flat, comp.full_sg_pos),
            minlength=comp.n_links).astype(np.float64)
    else:
        comp.size_a = comp.name_rank_a = comp.net_ids_a = None
        comp.fl_ptr = comp.fl_flat = comp.link_bw_a = None
        comp.full_sorted_ids = comp.full_sg_pos = None
        comp.full_row_links = comp.full_by_link = comp.full_counts = None
    return comp


def _gather(ptr, flat, pos):
    """Concatenate CSR segments ``flat[ptr[p]:ptr[p+1]]`` for ``pos``."""
    lens = ptr[pos + 1] - ptr[pos]
    total = int(lens.sum())
    if total == 0:
        return np.empty(0, dtype=flat.dtype)
    prefix = np.concatenate(([0], np.cumsum(lens)[:-1]))
    out_idx = np.repeat(ptr[pos] - prefix, lens) \
        + np.arange(total, dtype=np.int64)
    return flat[out_idx]


def _pick_bottleneck(ratio, eps=EPS):
    """The scalar waterfill's bottleneck scan, batched.

    The scalar loop keeps the first link whose ratio beats the running
    best by more than EPS; every accepted update is a strict prefix
    minimum of the ratio sequence, so scanning only those (a handful —
    ~H(n) of a random order) reproduces the selection bit-exactly.
    """
    pm = np.minimum.accumulate(ratio)
    cmask = np.empty(len(ratio), dtype=bool)
    cmask[0] = True
    cmask[1:] = ratio[1:] < pm[:-1]
    best_ratio, best = math.inf, -1
    for j in np.nonzero(cmask)[0].tolist():
        rj = ratio[j]
        if rj < best_ratio - eps:
            best_ratio, best = rj, j
    return best, float(best_ratio)


def _wf_core_np(sg_ids, fl_ptr, fl_flat, sg_pos, link_order, residual,
                rate, weights, seq, prep=None):
    """Vectorized progressive fill of one sorted flow group.

    ``sg_ids[r]`` is the id written into ``rate``/``seq`` for sorted row
    ``r``; ``sg_pos[r]`` its CSR row.  ``link_order`` fixes the bottleneck
    iteration order (the calendar core's residual insertion order) and
    ``residual`` is the full per-link array, mutated in place.  Freeze
    order is identical to the scalar waterfill: batches come off the
    bottleneck link's flow list in sorted-group order.  ``rate`` may be a
    list or an array — frozen batches write scalars.  ``seq`` may be
    None when the caller never replays the freeze log (fair policy).
    ``prep`` optionally supplies precomputed ``(row_links, by_link,
    counts)`` for this exact group (the compile-level full-flow-set
    structures), skipping the per-call incidence builds.
    """
    k = len(sg_pos)
    if k == 0:
        return
    L = len(residual)
    if prep is not None and weights is None:
        row_links, by_link, counts0 = prep
        wsum = counts0.copy()
    else:
        cat = _gather(fl_ptr, fl_flat, sg_pos)
        lens = fl_ptr[sg_pos + 1] - fl_ptr[sg_pos]
        if weights is None:
            wsum = np.bincount(cat, minlength=L).astype(np.float64)
        else:
            row = np.repeat(np.arange(k, dtype=np.int64), lens)
            wsum = np.zeros(L)
            np.add.at(wsum, cat, weights[row])
        cat_list = cat.tolist()
        ptr_list = np.concatenate(([0], np.cumsum(lens))).tolist()
        row_links = [cat_list[ptr_list[r]:ptr_list[r + 1]]
                     for r in range(k)]
        by_link = {}
        for r in range(k):             # row order == sorted-group order
            for l in row_links[r]:
                by_link.setdefault(l, []).append(r)
    unfrozen = [True] * k
    remaining = k

    def rows_on(link: int) -> list[int]:
        """Unfrozen group rows occupying ``link``."""
        fl = by_link.get(link)
        if not fl:
            return []
        return [r for r in fl if unfrozen[r]]

    def freeze_unit(rows: list[int], alloc: float) -> int:
        """Freeze ``rows`` at rate ``alloc``; returns rows frozen."""
        if seq is None:
            for r in rows:
                rate[sg_ids[r]] = alloc
                unfrozen[r] = False
        else:
            for r in rows:
                fid = sg_ids[r]
                rate[fid] = alloc
                seq.append((fid, alloc))
                unfrozen[r] = False
        if len(rows) >= 32:
            sub = _gather(fl_ptr, fl_flat,
                          sg_pos[np.array(rows, dtype=np.int64)])
            delta = np.bincount(sub, minlength=L).astype(np.float64)
            tl = np.nonzero(delta)[0]
            residual[tl] = np.maximum(residual[tl] - alloc * delta[tl],
                                      0.0)
            wsum[tl] -= delta[tl]
        else:
            for r in rows:
                for l in row_links[r]:
                    v = residual[l] - alloc
                    residual[l] = v if v > 0.0 else 0.0
                    wsum[l] -= 1.0
        return len(rows)

    while remaining:
        rsel = residual[link_order]
        wsel = wsum[link_order]
        vidx = np.nonzero(wsel > EPS)[0]
        if len(vidx) == 0:
            for r in range(k):
                if unfrozen[r]:
                    fid = sg_ids[r]
                    rate[fid] = 0.0
                    if seq is not None:
                        seq.append((fid, 0.0))
            return
        ratio = rsel[vidx] / wsel[vidx]
        bj, best_ratio = _pick_bottleneck(ratio)
        if weights is None:
            # Freeze the whole run of links tied bitwise with the pick,
            # in link order.  After freezing a bottleneck at ratio a,
            # every remaining link's ratio stays >= a, and an exactly
            # tied link stays exactly tied under exact arithmetic — the
            # scalar fill would select precisely these links on its next
            # iterations.  Each link is re-checked before freezing; any
            # floating-point drift breaks out to a full rescan, which
            # re-derives the scalar scan's choice.
            froze_any = False
            for t in np.nonzero(ratio == best_ratio)[0].tolist():
                if t < bj:
                    continue
                link = int(link_order[vidx[t]])
                w_t = wsum[link]
                if w_t <= EPS:
                    continue
                if not froze_any:
                    froze_any = True       # the pick itself: no recheck
                elif residual[link] / w_t != best_ratio:
                    break
                rows = rows_on(link)
                if len(rows) == 0:         # numerical guard; wsum tracks
                    wsum[link] = 0.0       # unfrozen, so normally nonzero
                    continue
                remaining -= freeze_unit(rows, best_ratio)
                if not remaining:
                    break
            if not froze_any:              # guard: stale wsum on the pick
                wsum[int(link_order[vidx[bj]])] = 0.0
            continue
        best_link = int(link_order[vidx[bj]])
        rows = rows_on(best_link)
        if not rows:                       # numerical guard (see above)
            wsum[best_link] = 0.0
            continue
        for r in rows:
            fid = sg_ids[r]
            alloc = float(weights[r]) * best_ratio
            rate[fid] = alloc
            if seq is not None:
                seq.append((fid, alloc))
            unfrozen[r] = False
            for l in row_links[r]:
                v = residual[l] - alloc
                residual[l] = v if v > 0.0 else 0.0
        remaining -= len(rows)
        if remaining:
            # the scalar fill re-sums weights per iteration — recompute
            # (not decrement) so the accumulation order matches
            um = np.array(unfrozen, dtype=bool)[row]
            wsum = np.zeros(L)
            np.add.at(wsum, cat[um], weights[row[um]])


def _wf_core_py(sg_ids, flow_links, sg_pos, link_order, residual, rate,
                weights, seq):
    """Pure-stdlib fallback: simulator.waterfill ported to interned ids.

    Same freeze order and per-flow sequential subtraction as the scalar
    string-domain fill; ``link_order`` plays the residual dict's
    insertion-order role.
    """
    k = len(sg_pos)
    if k == 0:
        return
    unfrozen = list(range(k))
    unfrozen_set = set(unfrozen)
    by_link: dict[int, list[int]] = {}
    for r in unfrozen:
        for l in flow_links[sg_pos[r]]:
            by_link.setdefault(l, []).append(r)
    if weights is None:
        counts = {l: float(len(fl)) for l, fl in by_link.items()}
    while unfrozen:
        best_l, best_ratio = None, math.inf
        for l in link_order:
            fl = by_link.get(l)
            if not fl:
                continue
            if weights is None:
                w = counts[l]
            else:
                w = sum(weights[r] for r in fl if r in unfrozen_set)
            if w > EPS:
                ratio = residual[l] / w
                if ratio < best_ratio - EPS:
                    best_l, best_ratio = l, ratio
        if best_l is None:
            for r in unfrozen:
                fid = sg_ids[r]
                rate[fid] = 0.0
                if seq is not None:
                    seq.append((fid, 0.0))
            return
        best_ratio = float(best_ratio)   # residual may be an ndarray —
        #             keep rates/seq native floats for the event loop
        frozen_now = [r for r in by_link[best_l] if r in unfrozen_set]
        for r in frozen_now:
            alloc = best_ratio if weights is None \
                else weights[r] * best_ratio
            fid = sg_ids[r]
            rate[fid] = alloc
            if seq is not None:
                seq.append((fid, alloc))
            for l in flow_links[sg_pos[r]]:
                v = residual[l] - alloc
                residual[l] = v if v > 0.0 else 0.0
                if weights is None:
                    counts[l] -= 1.0
        unfrozen_set.difference_update(frozen_now)
        unfrozen = [r for r in unfrozen if r in unfrozen_set]


def _wf_fill_batch(net_ids_a, flow_links, fl_ptr, fl_flat, sg_pos,
                   link_order, residual, rate, seq, bl, unfrozen):
    """Batch-mode progressive fill: scalar-granular core on Python state.

    Unweighted groups only (coflow-weighted groups stay on
    :func:`_wf_core_np`).  Freezes touch one to a handful of links per
    row, a granularity where Python-list scalar ops beat NumPy scalar
    indexing by an order of magnitude — so the fill runs on Python
    mirrors of ``residual``/``wsum`` and the frozen rates scatter back
    in one vectorized write.  Bottleneck picks and tie-run freezes
    follow :func:`_wf_core_np` (EPS-hysteresis first-min pick,
    bitwise-tied run frozen in link order with a sequential-exact
    recheck per link); the per-row sequential subtraction matches the
    scalar oracle :func:`_wf_core_py` exactly, and freezes of >=32 rows
    collapse to one bincount (the same association order — and ulp
    drift, covered by the equivalence tolerance — as the old array
    fill's >=32 path).

    Rows are NET POSITIONS, so the incidence needs no per-call build:
    ``bl`` maps each link to the rank-sorted positions of the group
    (the caller passes the incrementally maintained per-component/class
    structure, or a per-call build when that isn't valid), ``sg_pos``
    is the rank-sorted position array, and ``unfrozen`` is a shared
    all-zero bytearray over net positions (restored to all-zero on
    return — every group member is frozen by some path).
    """
    k = len(sg_pos)
    if k == 0:
        return
    sg_list = sg_pos.tolist()
    for p in sg_list:
        unfrozen[p] = 1
    res = residual.tolist()
    ws = [0.0] * len(res)
    for l, fl in bl.items():
        if fl:
            ws[l] = float(len(fl))
    remaining = k
    frozen_pos: list[int] = []
    frozen_allocs: list[float] = []
    fp_append = frozen_pos.append
    fa_append = frozen_allocs.append
    inf = math.inf
    # links whose rows are all frozen get compacted out of the walk
    # once they are a third of it (same pick: a dead link can never
    # win); ``dead`` counts ws hitting zero in the freeze updates
    live = link_order
    dead = 0
    while remaining:
        if dead * 3 > len(live):
            live = [l for l in live if ws[l] > EPS]
            dead = 0
        # single walk: first-min scan with EPS hysteresis in link_order
        # order (== _pick_bottleneck over valid links), collecting the
        # links tied bitwise with the running best as it goes.  A link
        # bitwise-equal to the final best can never precede the pick
        # (it would have been accepted, or the pick rejected), so the
        # tie list is exactly the per-index candidate run ("pre-round
        # ratio == best, at or after the pick") of the two-pass form,
        # in order.
        best_ratio = inf
        ties: list = []
        for l in live:
            w = ws[l]
            if w <= EPS:
                continue
            q = res[l] / w
            if q < best_ratio - EPS:
                best_ratio = q
                ties = [l]
            elif q == best_ratio:
                ties.append(l)
        if not ties:
            for p in sg_list:              # rank order, like the scalar
                if unfrozen[p]:            # fill's exhaustion pass
                    unfrozen[p] = 0
                    fp_append(p)
                    fa_append(0.0)
            break
        # freeze the pick, then the run of links tied bitwise with it,
        # in link order; each later link rechecks against the current
        # (sequentially updated) residual and breaks on any drift —
        # exactly the scalar fill's iteration, with the rescans skipped
        froze_any = False
        for link in ties:
            w_t = ws[link]
            if w_t <= EPS:
                continue
            if not froze_any:
                froze_any = True           # the pick itself: no recheck
            elif res[link] / w_t != best_ratio:
                break
            # ws > 0 ==> the link is in bl with unfrozen rows (ws and
            # the incidence share bookkeeping), so index directly
            rows = [p for p in bl[link] if unfrozen[p]]
            nr = len(rows)
            if not nr:                     # numerical guard; ws tracks
                ws[link] = 0.0             # unfrozen, so normally nonzero
                dead += 1
                continue
            if nr >= 32:
                for p in rows:
                    unfrozen[p] = 0
                frozen_pos.extend(rows)
                frozen_allocs.extend([best_ratio] * nr)
                sub = _gather(fl_ptr, fl_flat,
                              np.array(rows, dtype=np.int64))
                delta = np.bincount(sub)
                for ll in np.nonzero(delta)[0].tolist():
                    c = int(delta[ll])
                    v = res[ll] - best_ratio * c
                    res[ll] = v if v > 0.0 else 0.0
                    w = ws[ll] - c
                    ws[ll] = w
                    if w <= EPS:
                        dead += 1
            else:
                for p in rows:
                    unfrozen[p] = 0
                    fp_append(p)
                    fa_append(best_ratio)
                    for ll in flow_links[p]:
                        v = res[ll] - best_ratio
                        res[ll] = v if v > 0.0 else 0.0
                        w = ws[ll] - 1.0
                        ws[ll] = w
                        if w <= EPS:
                            dead += 1
            remaining -= nr
            if not remaining:
                break
        if not froze_any:                  # guard: stale ws on the pick
            link = ties[0]
            if ws[link] > EPS:
                dead += 1
            ws[link] = 0.0
    residual[:] = res
    ia = net_ids_a[np.array(frozen_pos, dtype=np.int64)]
    rate[ia] = frozen_allocs
    if seq is not None:
        seq.extend(zip(ia.tolist(), frozen_allocs))


def vectorized_waterfill(group, paths, weight, residual, rates):
    """Drop-in vectorized :func:`repro.core.simulator.waterfill`.

    Same contract: mutates ``residual`` (a dict whose insertion order is
    the bottleneck iteration order) and ``rates``; returns the freeze
    sequence in identical order.  Values agree with the scalar fill to
    within EPS (batched subtraction associates differently at the last
    ulp); the freeze order is identical.  Falls back to the scalar fill
    when NumPy is absent.
    """
    if np is None:
        from repro.core.simulator import waterfill
        return waterfill(group, paths, weight, residual, rates)
    names_sorted = sorted(group)
    k = len(names_sorted)
    if k == 0:
        return []
    link_ids = {l: i for i, l in enumerate(residual)}
    res_arr = np.array([float(v) for v in residual.values()])
    ptr = [0]
    flat: list[int] = []
    for nm in names_sorted:
        for l in paths[nm]:
            flat.append(link_ids[l])
        ptr.append(len(flat))
    fl_ptr = np.array(ptr, dtype=np.int64)
    fl_flat = np.array(flat, dtype=np.int64)
    sg_ids = list(range(k))
    sg_pos = np.arange(k, dtype=np.int64)
    link_order = np.arange(len(link_ids), dtype=np.int64)
    rate_arr = [0.0] * k
    weights = None if weight is None \
        else np.array([float(weight(nm)) for nm in names_sorted])
    seq_ids: list[tuple[int, float]] = []
    _wf_core_np(sg_ids, fl_ptr, fl_flat, sg_pos, link_order, res_arr,
                rate_arr, weights, seq_ids)
    for l, li in link_ids.items():
        residual[l] = float(res_arr[li])
    seq = [(names_sorted[i], float(a)) for i, a in seq_ids]
    for nm, a in seq:
        rates[nm] = a
    return seq


class ResurrectConflict(RuntimeError):
    """``resurrect`` refused: started consumers hold the task's output.

    Raised when un-finishing a task whose data is still being consumed
    by one or more *started, unfinished* tasks — they would be running
    on data that no longer exists.  ``task`` names the resurrection
    target and ``consumers`` every offending consumer (sorted), so a
    lineage-closure caller (``kill_host``) can kill exactly those
    consumers and retry.
    """

    def __init__(self, task: str, consumers):
        self.task = task
        self.consumers = tuple(consumers)
        super().__init__(
            f"resurrect({task}): consumer(s) "
            f"{', '.join(self.consumers)} running on its output — "
            f"kill them first")


def array_run(sim, horizon: float = 1e15, batch: bool = True):
    """Run ``sim`` to completion on the compiled flat arrays.

    A faithful translation of ``Simulator.calendar_run`` — same event
    structure, gating semantics, allocation and tie-breaking orders — on
    integer-indexed state.  See the module docstring for where the two
    may differ in floating-point association (last-ulp only).

    ``batch=False`` disables the mega-batch vectorized passes (NumPy
    state vectors, batched fills/integration/completion scans and the
    per-component event heaps) and runs the retained per-event paths —
    the differential oracle the batched loop is tested against, and the
    "before" arm of the ``scale.speedup_batch_*`` benchmark rows.

    Implemented as one uninterrupted :class:`ResumableSim` session, so
    the pausable fault-capable engine and this hot path are a single
    implementation that cannot drift apart (the zero-fault differential
    tests pin the equivalence regardless).
    """
    rs = ResumableSim(sim, horizon, batch=batch)
    rs.run_until(math.inf)
    return rs.result()


class ResumableSim:
    """A pausable array-DES session: run, pause, mutate, resume.

    Construction compiles (or reuses the cached compile of) ``sim`` and
    materialises the exact run state ``array_run`` uses — flat
    work/rate/cap vectors, the event heap, per-component allocation
    state — as closure cells shared by one ``advance`` loop and a set of
    mutators.  With no mutations applied, pausing and resuming is
    bit-exact against the uninterrupted run: ``run_until`` only ever
    stops *between* events (the next event strictly after ``t_stop``
    stays in the heap), so no partial-interval work integration is
    introduced.  ``advance_to`` moves the clock into the gap before the
    next event (integrating work) so a fault can land at its exact
    scheduled time.

    Mutators implement the fault model of :mod:`repro.core.nemesis`:

    - ``set_speed`` — per-task rate multiplier (straggler / slow
      executor).  Speeds multiply at use (``rate[i] * speed[i]``), so
      the all-ones default is IEEE-exact against the plain engine.  A
      straggling flow still *holds* its waterfilled share — slow
      delivery wastes the allocation, as on a real fabric.
    - ``set_link_bw`` / ``scale_link`` — link degradation or failure.
      Components touching the link are re-waterfilled through the
      existing component-level reallocation (dirtied at class ``-inf``).
    - ``kill_task`` / ``kill_host`` — progress loss.  ``kill_host``
      computes the lineage closure: finished tasks whose output data
      resided on the dead host (computes placed there, flows delivered
      there) and is still needed by an unfinished data consumer are
      resurrected (gate counters restored) so the data is reproduced.
      Compute→compute edges are treated as control-only dependencies;
      their data, if any, is assumed durable.
    - ``move_task`` / ``repath_flow`` — the replanner's recovery
      actions: re-place a compute (restarting it if begun), re-path a
      flow without recompiling, merging contention components when the
      new path bridges previously disjoint ones.
    - ``set_priorities`` — re-prioritise (and optionally switch policy)
      mid-run; freeze-sequence replay logs are invalidated and dirty
      components refill from scratch.

    Mutations queue against the paused clock and are *settled* (restart
    gating, starvation flips, component refills, event rescheduling —
    exactly the passes one event iteration runs) before the next
    advance.  ``checkpoint``/``restore`` snapshot the whole mutable
    state so scenario arms can fork from one shared pre-fault prefix.
    Resurrecting a coflow member rewinds the group's MADD bookkeeping:
    the unfinished-member count re-opens, and when the group had
    already completed, its consumers' start gates are restored (the
    all-or-nothing output is no longer complete) — so fault scenarios
    may kill coflow-coupled lineage freely.  Started consumers of a
    resurrection target raise :class:`ResurrectConflict` (naming every
    offender); ``kill_host`` catches it and kills exactly those
    consumers before retrying.
    """

    def __init__(self, sim, horizon: float = 1e15, batch: bool = True):
        from repro.core.simulator import SimResult

        comp = compile_sim(sim)
        use_np = comp.np_ready and np is not None
        # mega-batch mode: NumPy-backed state vectors and vectorized
        # event-batch passes (fills, integration, completion scans,
        # per-component heaps).  Off — or NumPy absent — runs the
        # retained per-event scalar paths, which double as the
        # differential oracle for the batched loop.
        use_batch = bool(batch) and use_np
        n = comp.n
        names = comp.names
        size, unit, nu = comp.size, comp.unit, comp.nu
        is_comp = comp.is_compute
        net_pos, net_ids = comp.net_pos, comp.net_ids
        flow_links = comp.flow_links
        stream_in, stream_out = comp.stream_in, comp.stream_out
        gate_stream = comp.gate_stream
        coflow_of, coflows = comp.coflow_of, comp.coflows
        succ = comp.succ
        policy = sim.policy
        prio_get = sim.prio.get
        inf = math.inf
        heappush, heappop = heapq.heappush, heapq.heappop
        cluster = sim.cluster
        hosts = cluster.hosts

        # -- per-run priority/release arrays ---------------------------
        if policy == "fair":
            cls_net: list = [None] * comp.n_net
        else:
            cls_net = [0.0 if comp.stream_fed[i]
                       else prio_get(names[i], 0.0)
                       for i in net_ids]
        cls_net_a = np.array(cls_net, dtype=np.float64) \
            if use_batch and policy != "fair" else None
        prio_arr = [prio_get(nm, 0.0) for nm in names]
        if use_np:
            order = np.lexsort((comp.name_rank_a, np.array(prio_arr)))
            dr = np.empty(n, dtype=np.int64)
            dr[order] = np.arange(n, dtype=np.int64)
            dispatch_rank = dr.tolist()
        else:
            order = sorted(range(n),
                           key=lambda i: (prio_arr[i], comp.name_rank[i]))
            dispatch_rank = [0] * n
            for r, i in enumerate(order):
                dispatch_rank[i] = r
        rel = [0.0] * n
        for nm, v in sim.releases.items():
            rel[comp.idx[nm]] = v

        # -- dynamic state (batch mode: float64/bool NumPy vectors so
        # the fill / integration / completion passes run as array math;
        # otherwise flat lists — scalar access in the branchy event
        # code is list-speed, batch math converts on demand) -----------
        if use_batch:
            work = np.zeros(n)
            rate = np.zeros(n)
            speed = np.ones(n)           # fault-model rate multipliers
            starved_net = np.zeros(comp.n_net, dtype=bool)
            simple_a = np.array(comp.simple, dtype=bool)
            link_bw_a_run = comp.link_bw_a.copy()
            # incremental fill incidence: (K, cls) -> {link: rank-sorted
            # positions of that component/class's runnable flows}.
            # Built lazily at the first big fill, then maintained by
            # inc_add/inc_remove as flows start and complete, so the
            # steady-state fill skips the O(group x links) rebuild.
            # Cleared wholesale on anything non-incremental (restore,
            # repath, priority swaps, fault mutators).
            inc_bylink: dict = {}
            unfrozen_pos = bytearray(comp.n_net)   # all-zero between fills
            pos_rank = comp.name_rank_a[comp.net_ids_a].tolist()
        else:
            work = [0.0] * n
            rate = [0.0] * n
            speed = [1.0] * n
            starved_net = [False] * comp.n_net
            simple_a = link_bw_a_run = None
            inc_bylink = unfrozen_pos = pos_rank = None
        vcopy = (lambda a: a.copy()) if use_batch else (lambda a: a[:])
        cap = list(size)                 # cap_of default = size
        speed_on = False                 # sticky: any speed ever != 1.0
        started: list = [None] * n
        finished: list = [None] * n
        has_slot = [False] * n
        starved = [False] * n
        d_units = [0] * n
        slots_free = list(comp.slot_cap)
        cof_left = [len(c) for c in coflows]
        n_gate = list(comp.init_gate)
        active: set[int] = set()
        waiting_slot: dict[int, set[int]] = {}
        candidates: set[int] = set()
        freed: set[int] = set()
        touched: set[int] = set()        # needs a starvation re-check
        touched_sched: set[int] = set()  # only needs schedule_event
        #   (fresh capless starts, rate changes: their starvation state
        #   provably cannot have flipped, so the re-check loop skips
        #   them)
        # component state: per contention component, the runnable net
        # positions, the started-unfinished *simple* flows (whose
        # completion events coalesce into one heap entry per component),
        # the (class -> freeze sequence) replay log, and the lowest
        # dirty priority class (fair: 0.0) since the last fill
        comp_of = comp.comp_of_net
        simple = comp.simple
        n_comps = comp.n_comps
        comp_runnable: list = [set() for _ in range(n_comps)]
        comp_simple_active: list = [set() for _ in range(n_comps)]
        comp_log: list = [None] * n_comps
        comp_stamp = [0] * n_comps
        comp_dirty: dict = {}
        comp_resched: set[int] = set()
        # mutators patch link capacities in place — run-owned copy, so
        # the compile cached on the graph is never poisoned
        link_bw = list(comp.link_bw)
        residual = comp.link_bw_a.copy() if use_np else list(link_bw)
        heap: list = []
        # per-component event heaps (batch mode, >=2 components): a
        # component's kind-1/2 entries live in comp_heaps[K] and the
        # global heap carries only releases, compute-task entries, and
        # kind-3 meta hints ``(t, 3, K, 0)`` — one per component head.
        # A hint is pushed whenever a push lowers a component's head,
        # so min(hints for K) <= head(K) always holds and the global
        # heap never misses a component event; stale hints (head moved
        # by lazy pruning or draining) are refreshed on pop.  Net
        # effect: a huge component's churn (thousands of stale entries
        # per reallocation) stops inflating every other component's
        # push/pop cost.
        use_cheaps = use_batch and n_comps >= 2
        comp_heaps: list = \
            [[] for _ in range(n_comps)] if use_cheaps else None
        stamp = [0] * n
        unfinished = n
        now = 0.0
        needs_settle = False

        # copy-on-write structural state: repath/move rebind these to
        # run-local copies on first mutation; until then the compile's
        # arrays are shared read-only
        slot_of = comp.slot_of
        slot_ids_run = comp.slot_ids
        fl_ptr, fl_flat = comp.fl_ptr, comp.fl_flat
        full_sg_pos = comp.full_sg_pos
        full_sorted_ids = comp.full_sorted_ids
        full_row_links = comp.full_row_links
        full_by_link = comp.full_by_link
        full_counts = comp.full_counts

        # link-name interning (big-switch compiles key links by endpoint
        # tuples; surface the NIC resource names either way) and current
        # placement/endpoints (the graph's Task objects are never
        # mutated — moves and repaths live here)
        link_names: list = [None] * len(link_bw)
        link_name_id: dict[str, int] = {}
        for k, li in comp.link_ids.items():
            lname = k if isinstance(k, str) else \
                (k[1] + ".nic_out" if k[0] == "o" else k[1] + ".nic_in")
            link_names[li] = lname
            link_name_id[lname] = li
        cur_host: list = [None] * n
        cur_src: list = [None] * comp.n_net
        cur_dst: list = [None] * comp.n_net
        for i, t in enumerate(sim.g.tasks.values()):
            if is_comp[i]:
                cur_host[i] = t.host
            else:
                p = net_pos[i]
                cur_src[p] = t.src
                cur_dst[p] = t.dst

        def dirty_net(pos: int) -> None:
            """Mark flow ``pos``'s component dirty at its class."""
            K = comp_of[pos]
            c = cls_net[pos]
            if c is None:                # fair policy: one class
                c = 0.0
            cur = comp_dirty.get(K)
            if cur is None or c < cur:
                comp_dirty[K] = c

        def delivered_fraction(p: int) -> float:
            """Fraction of ``p``'s output delivered (unit granularity)."""
            if finished[p] is not None:
                return 1.0
            sz = size[p]
            if sz <= 0:
                return 1.0
            u = unit[p]
            return min(1.0, math.floor(work[p] / u + EPS) * u / sz)

        def start_gate_ok(i: int) -> bool:
            """Gate counter zero and first streamed unit available?"""
            if n_gate[i]:
                return False
            for p in gate_stream[i]:
                if delivered_fraction(p) + EPS < 1.0 / nu[i]:
                    return False
            return True

        def recompute_cap(i: int) -> float:
            """Work cap from streaming predecessors' delivered units."""
            c = size[i]
            nui = nu[i]
            eu = unit[i]
            for p in stream_in[i]:
                if finished[p] is None:
                    enabled = math.floor(delivered_fraction(p) * nui
                                         + EPS)
                    c2 = enabled * eu
                    if c2 < c:
                        c = c2
            return c

        pending: list = []               # kind-1 entries awaiting the heap
        _defer = pending.append

        def schedule_event(i: int) -> None:
            """(Re)compute task ``i``'s next unit/cap/completion event."""
            stamp[i] += 1
            r = rate[i]
            if speed_on:
                r = r * speed[i]
            if finished[i] is not None or started[i] is None or r <= EPS:
                active.discard(i)
                return
            active.add(i)
            sz = size[i]
            w = work[i]
            u = unit[i]
            if u >= sz and cap[i] >= sz:
                # common case: no unit boundaries, cap at size — the
                # sole target is completion (bit-identical to the
                # general fold)
                if sz > w + EPS:
                    _defer((float(now + (sz - w) / r), 1, i, stamp[i]))
                return
            if u < sz:
                tgt = (math.floor(w / u + EPS) + 1) * u
                if tgt > sz:
                    tgt = sz
            else:
                tgt = sz
            best = inf
            if tgt > w + EPS:
                best = (tgt - w) / r
            if sz > w + EPS:
                d = (sz - w) / r
                if d < best:
                    best = d
            c = cap[i]
            if c > w + EPS:
                d = (c - w) / r
                if d < best:
                    best = d
            if best < inf:
                _defer((float(now + best), 1, i, stamp[i]))

        def flush_events() -> None:
            """Move deferred entries into the heap: one heapify for a
            mega-batch (same entry set, so the event calendar is
            unchanged — only the arbitrary pop order of equal-time
            entries may differ, which batch collection absorbs),
            individual pushes otherwise.  With per-component heaps,
            flow entries route to their component's heap instead, with
            a meta hint on the global heap whenever a push lowers that
            component's head."""
            if comp_heaps is not None:
                for e in pending:
                    if e[1] == 2:
                        K = e[2]
                    else:
                        i2 = e[2]
                        if is_comp[i2]:
                            heappush(heap, e)
                            continue
                        K = comp_of[net_pos[i2]]
                    ch = comp_heaps[K]
                    if not ch or e[0] < ch[0][0]:
                        heappush(heap, (e[0], 3, K, 0))
                    heappush(ch, e)
                pending.clear()
                return
            if len(pending) > 1024 and len(pending) * 2 > len(heap):
                heap.extend(pending)
                heapq.heapify(heap)
            else:
                for e in pending:
                    heappush(heap, e)
            pending.clear()

        def meta_head(K: int):
            """Validate a kind-3 meta hint: prune component ``K``'s
            stale entries and return its true head time (None when it
            has no live events).  The caller drops the hint when this
            returns None and refreshes it when the head disagrees."""
            ch = comp_heaps[K]
            while ch:
                t2, k2, i2, s2 = ch[0]
                if k2 == 1 and (stamp[i2] != s2
                                or finished[i2] is not None):
                    heappop(ch)
                    continue
                if k2 == 2 and comp_stamp[i2] != s2:
                    heappop(ch)
                    continue
                return t2
            return None

        gate_dec = comp.gate_dec

        def schedule_comp(K: int) -> None:
            """(Re)compute a component's next *completion* among its
            simple flows: one heap entry per component instead of one
            per flow.  Each candidate time is the exact float
            schedule_event would compute (``now + (size-work)/rate``),
            and min over them is the earliest per-flow entry — so the
            event calendar is unchanged; only the stale-entry volume
            shrinks from O(flows) to O(1) per reallocation."""
            st = comp_stamp[K] + 1
            comp_stamp[K] = st
            csa = comp_simple_active[K]
            if use_batch and len(csa) >= 48:
                # same per-flow divisions elementwise, same min — the
                # candidate times are bit-identical to the scalar scan
                ids = np.fromiter(csa, dtype=np.int64, count=len(csa))
                r = rate[ids]
                if speed_on:
                    r = r * speed[ids]
                on = r > EPS
                if on.any():
                    sel = ids[on]
                    d = (comp.size_a[sel] - work[sel]) / r[on]
                    _defer((float(now + d.min()), 2, K, st))
                return
            best = inf
            for i in csa:
                r = rate[i]
                if speed_on:
                    r = r * speed[i]
                if r > EPS:
                    d = (size[i] - work[i]) / r
                    if d < best:
                        best = d
            if best < inf:
                _defer((float(now + best), 2, K, st))

        def inc_add(pos: int) -> None:
            """A flow became runnable: insert it (rank-ordered) into its
            component/class's incremental fill incidence, if built."""
            bl = inc_bylink.get(
                (comp_of[pos], None if policy == "fair" else cls_net[pos]))
            if bl is None:
                return
            rk = pos_rank[pos]
            for l in flow_links[pos]:
                fl = bl.get(l)
                if fl is None:
                    bl[l] = [pos]
                    continue
                if not fl:
                    fl.append(pos)
                    continue
                last = fl[-1]
                if last == pos:                         # tolerate re-adds
                    continue
                if pos_rank[last] < rk:                 # common: in-order
                    fl.append(pos)
                else:
                    j = bisect_left(fl, rk, key=pos_rank.__getitem__)
                    if j == len(fl) or fl[j] != pos:   # tolerate re-adds
                        fl.insert(j, pos)

        def inc_remove(pos: int) -> None:
            """A flow left the runnable set: drop it from the incidence
            (tolerant — absent positions are a no-op)."""
            bl = inc_bylink.get(
                (comp_of[pos], None if policy == "fair" else cls_net[pos]))
            if bl is None:
                return
            rk = pos_rank[pos]
            for l in flow_links[pos]:
                fl = bl.get(l)
                if fl:
                    if fl[-1] == pos:                   # common: tail pop
                        fl.pop()
                    else:
                        j = bisect_left(fl, rk,
                                        key=pos_rank.__getitem__)
                        if j < len(fl) and fl[j] == pos:
                            del fl[j]

        def complete(i: int) -> None:
            """Finish ``i``: free resources, trigger gated candidates."""
            nonlocal unfinished
            finished[i] = now
            unfinished -= 1
            active.discard(i)
            if has_slot[i]:
                si = slot_of[i]
                slots_free[si] += 1
                has_slot[i] = False
                freed.add(si)
            if is_comp[i]:
                rate[i] = 0.0
            else:
                pos = net_pos[i]
                K = comp_of[pos]
                comp_runnable[K].discard(pos)
                if inc_bylink:
                    inc_remove(pos)
                if simple[i]:
                    comp_simple_active[K].discard(i)
                if rate[i]:
                    rate[i] = 0.0
                    dirty_net(pos)
            candidates.update(succ[i])
            for s in gate_dec[i]:
                n_gate[s] -= 1
            for c in stream_out[i]:
                if started[c] is not None and finished[c] is None:
                    nc = recompute_cap(c)
                    if nc != cap[c]:
                        cap[c] = nc
                        touched.add(c)
            if coflows:
                ci = coflow_of[i]
                if ci >= 0:
                    cof_left[ci] -= 1
                    if cof_left[ci] == 0:
                        for t in comp.cof_dec[ci]:
                            n_gate[t] -= 1
                        for m in coflows[ci]:
                            candidates.update(succ[m])
                for ci2 in comp.coflow_fed_by[i]:
                    candidates.update(coflows[ci2])

        def complete_bulk(ids: list[int]) -> None:
            """complete() over a large batch: per-task effects are
            identical (each is independent of the others' — see
            complete()), but the set-membership bookkeeping is batched
            through C-level updates."""
            nonlocal unfinished
            unfinished -= len(ids)
            active.difference_update(ids)
            succs: list = []
            for i in ids:
                finished[i] = now
                if has_slot[i]:
                    si = slot_of[i]
                    slots_free[si] += 1
                    has_slot[i] = False
                    freed.add(si)
                if is_comp[i]:
                    rate[i] = 0.0
                else:
                    pos = net_pos[i]
                    K = comp_of[pos]
                    comp_runnable[K].discard(pos)
                    if inc_bylink:
                        inc_remove(pos)
                    if simple[i]:
                        comp_simple_active[K].discard(i)
                    if rate[i]:
                        rate[i] = 0.0
                        dirty_net(pos)
                if succ[i]:
                    succs.append(succ[i])
                for s in gate_dec[i]:
                    n_gate[s] -= 1
                for c in stream_out[i]:
                    if started[c] is not None and finished[c] is None:
                        nc = recompute_cap(c)
                        if nc != cap[c]:
                            cap[c] = nc
                            touched.add(c)
                if coflows:
                    ci = coflow_of[i]
                    if ci >= 0:
                        cof_left[ci] -= 1
                        if cof_left[ci] == 0:
                            for t in comp.cof_dec[ci]:
                                n_gate[t] -= 1
                            for m in coflows[ci]:
                                candidates.update(succ[m])
                    for ci2 in comp.coflow_fed_by[i]:
                        candidates.update(coflows[ci2])
            candidates.update(chain.from_iterable(succs))

        def on_start(i: int) -> None:
            """Initialize ``i``'s streaming caps/counters at start."""
            c = size[i]
            if stream_in[i]:
                c = recompute_cap(i)
                cap[i] = c
            if stream_out[i]:
                d_units[i] = 0
                for c2 in stream_out[i]:
                    candidates.add(c2)  # first-unit gate may already pass
            is_starved = c <= work[i] + EPS
            starved[i] = is_starved
            if is_comp[i]:
                rate[i] = 0.0 if is_starved else 1.0
            else:
                pos = net_pos[i]
                starved_net[pos] = is_starved
                K = comp_of[pos]
                comp_runnable[K].add(pos)
                if inc_bylink:
                    inc_add(pos)
                dirty_net(pos)
                if simple[i]:
                    # coalesced: activation and the completion event
                    # ride on the component refill this dirty_net just
                    # forced
                    comp_simple_active[K].add(i)
                    return
            # only a pipelined-input cap can move between now and the
            # starvation pass — capless tasks can't flip
            (touched if stream_in[i] else touched_sched).add(i)

        def process_starts() -> None:
            """Start every candidate whose gates and slots allow it."""
            while True:
                # gate counters inlined; stream-fraction gates (rare) go
                # through start_gate_ok
                startable = [i for i in candidates
                             if started[i] is None
                             and rel[i] <= now + EPS
                             and not n_gate[i]
                             and (not gate_stream[i] or start_gate_ok(i))]
                candidates.clear()
                if not startable:
                    return
                zero_done = False
                if not any(map(is_comp.__getitem__, startable)):
                    # flow-only pass: no slot contention, so dispatch
                    # order is immaterial (all effects are commutative
                    # set/flag updates) — skip the sort, inline the
                    # common case and batch the set bookkeeping
                    for i in startable:
                        started[i] = now
                        if stream_in[i] or stream_out[i] \
                                or size[i] <= EPS:
                            on_start(i)
                            if size[i] <= EPS:
                                complete(i)
                                zero_done = True
                            continue
                        pos = net_pos[i]
                        starved[i] = False
                        starved_net[pos] = False
                        K = comp_of[pos]
                        comp_runnable[K].add(pos)
                        if inc_bylink:
                            inc_add(pos)
                        dirty_net(pos)
                        if simple[i]:
                            comp_simple_active[K].add(i)
                        else:
                            touched_sched.add(i)
                else:
                    for i in sorted(startable,
                                    key=dispatch_rank.__getitem__):
                        if is_comp[i]:
                            si = slot_of[i]
                            if slots_free[si] >= 1:
                                slots_free[si] -= 1
                                has_slot[i] = True
                                started[i] = now
                                w = waiting_slot.get(si)
                                if w is not None:
                                    w.discard(i)
                            else:
                                waiting_slot.setdefault(si, set()).add(i)
                                continue
                        else:
                            started[i] = now
                        on_start(i)
                        if size[i] <= EPS:
                            complete(i)
                            zero_done = True
                for si in freed:
                    candidates.update(waiting_slot.get(si, ()))
                freed.clear()
                if not zero_done and not candidates:
                    return

        def group_weights(fids):
            """MADD weights (∝ remaining work) for a coflow group."""
            out = []
            for fid in fids:
                ci = coflow_of[fid]
                if ci < 0:
                    out.append(1.0)
                    continue
                rem = {m: size[m] - work[m] for m in coflows[ci]
                       if finished[m] is None}
                mx = max(rem.values(), default=1.0)
                out.append(max(rem.get(fid, 0.0) / mx, 1e-6)
                           if mx > 0 else 1.0)
            return out

        any_coflow = bool(coflows)

        def allocate() -> list:
            """Waterfill every *dirty component*, classes from that
            component's lowest dirty one up (replaying the logged freeze
            sequences of its unchanged classes below), exactly as the
            calendar core's global allocate() — components share no
            links, so an untouched component's rates (and the residual
            its links hold) are provably the ones a full refill would
            recompute, and it is skipped entirely.  Groups of ≥48 flows
            over ≥48 links use the vectorized fill; smaller groups stay
            on the scalar port, whose constant factors beat NumPy-call
            overhead at that size."""
            changed: list = []
            fast_groups = use_batch and not any_coflow
            for K in sorted(comp_dirty):
                pos_a = None
                if fast_groups:
                    m = len(comp_runnable[K])
                    old_log = comp_log[K]
                    if m == 0:
                        comp_log[K] = None
                        continue
                    ps = np.fromiter(comp_runnable[K], dtype=np.int64,
                                     count=m)
                    ps.sort()
                    pos_a = ps[~starved_net[ps]]
                    if len(pos_a) == 0:
                        comp_log[K] = None
                        continue
                    positions = pos_a.tolist()
                    # first-seen link order over the sorted positions:
                    # the concatenated incidence is exactly the scalar
                    # append order, so sorting the unique links by first
                    # occurrence reproduces it
                    cat_k = _gather(fl_ptr, fl_flat, pos_a)
                    uniq, first = np.unique(cat_k, return_index=True)
                    lo_arr = uniq[np.argsort(first, kind="stable")]
                    residual[lo_arr] = link_bw_a_run[lo_arr]
                    link_order = lo_arr.tolist()
                else:
                    positions = [p for p in sorted(comp_runnable[K])
                                 if not starved_net[p]]
                    old_log = comp_log[K]
                    if not positions:
                        comp_log[K] = None
                        continue
                    seen: set[int] = set()
                    link_order = []
                    for p in positions:
                        for l in flow_links[p]:
                            if l not in seen:
                                seen.add(l)
                                link_order.append(l)
                    for l in link_order:  # reset only this comp's links
                        residual[l] = link_bw[l]
                    lo_arr = None
                if policy == "fair":
                    classes: list = [None]
                    lowest = None
                    pos_cls = None
                elif fast_groups:
                    pos_cls = cls_net_a[pos_a]
                    classes = np.unique(pos_cls).tolist()
                    lowest = comp_dirty[K]
                else:
                    classes = sorted({cls_net[p] for p in positions})
                    lowest = comp_dirty[K]
                    pos_cls = None
                new_log: dict = {}
                for cls in classes:
                    if lowest is None or cls >= lowest \
                            or old_log is None or cls not in old_log:
                        # the freeze log is only ever replayed under the
                        # priority policy (fair always refills) — skip
                        # building it when it can never be read
                        seq = None if policy == "fair" else []
                        if fast_groups:
                            gpa = pos_a if cls is None \
                                else pos_a[pos_cls == cls]
                            gpos = gpa.tolist()
                        else:
                            gpa = None
                            gpos = positions if cls is None else \
                                [p for p in positions
                                 if cls_net[p] == cls]
                        # batch mode drops the link-count requirement:
                        # the scalar fill's only remaining edge is tiny
                        # groups, where NumPy call overhead dominates
                        big = use_np and len(gpos) >= 48 \
                            and (use_batch or len(link_order) >= 48)
                        full = big and full_counts is not None \
                            and len(gpos) == comp.n_net
                        if full:
                            sg_pos_a = full_sg_pos
                            sg_ids = full_sorted_ids
                        elif big:
                            ga = gpa if gpa is not None \
                                else np.array(gpos, dtype=np.int64)
                            o = np.argsort(
                                comp.name_rank_a[comp.net_ids_a[ga]],
                                kind="stable")
                            sg_pos_a = ga[o]
                            sg_ids = comp.net_ids_a[sg_pos_a].tolist()
                        else:
                            sg_pos = sorted(
                                gpos,
                                key=lambda p: comp.name_rank[net_ids[p]])
                            sg_ids = [net_ids[p] for p in sg_pos]
                        if fast_groups:
                            gids_a = comp.net_ids_a[gpa]
                            old_a = rate[gids_a].copy()
                            gids = old = None
                        else:
                            gids_a = None
                            gids = [net_ids[p] for p in gpos]
                            old = [rate[f] for f in gids]
                        weights = None
                        if any_coflow \
                                and any(coflow_of[f] >= 0
                                        for f in sg_ids):
                            weights = group_weights(sg_ids)
                        # the scalar-granular batch fill wins when
                        # rounds freeze a handful of rows each (layered
                        # / trickle shapes); huge uniform groups
                        # (all-to-all shuffles) freeze thousands of
                        # rows in a round or two, where the vectorized
                        # np rounds are far cheaper — route those there
                        if big and use_batch and weights is None \
                                and len(gpos) < 2048:
                            # per-(component, class) link incidence:
                            # valid exactly when the group is the whole
                            # runnable membership (no starved members),
                            # which is when inc_add/inc_remove have been
                            # tracking it; otherwise build per-call
                            use_inc = pos_a is not None \
                                and len(pos_a) == m
                            bl = inc_bylink.get((K, cls)) \
                                if use_inc else None
                            if bl is None:
                                bl = {}
                                bget = bl.get
                                # rank-sorted positions -> plain appends
                                # yield the rank-sorted per-link lists
                                # the incremental hooks maintain
                                for p in sg_pos_a.tolist():
                                    for l in flow_links[p]:
                                        fl2 = bget(l)
                                        if fl2 is None:
                                            bl[l] = [p]
                                        else:
                                            fl2.append(p)
                                if use_inc:
                                    inc_bylink[(K, cls)] = bl
                            _wf_fill_batch(comp.net_ids_a, flow_links,
                                           fl_ptr, fl_flat, sg_pos_a,
                                           link_order, residual, rate,
                                           seq, bl, unfrozen_pos)
                        elif big:
                            if lo_arr is None:
                                lo_arr = np.array(link_order,
                                                  dtype=np.int64)
                            _wf_core_np(sg_ids, fl_ptr, fl_flat,
                                        sg_pos_a, lo_arr, residual,
                                        rate,
                                        None if weights is None
                                        else np.array(weights), seq,
                                        prep=((full_row_links,
                                               full_by_link,
                                               full_counts)
                                              if full
                                              and weights is None
                                              else None))
                        else:
                            _wf_core_py(sg_ids, flow_links, sg_pos,
                                        link_order, residual, rate,
                                        weights, seq)
                        if fast_groups:
                            chm = rate[gids_a] != old_a
                            if chm.any():
                                changed.extend(gids_a[chm].tolist())
                        else:
                            changed.extend(f for f, o in zip(gids, old)
                                           if rate[f] != o)
                        new_log[cls] = seq
                    else:
                        # unchanged class: replay the logged freeze seq
                        for fid, alloc in old_log[cls]:
                            rate[fid] = alloc
                            for l in flow_links[net_pos[fid]]:
                                v = residual[l] - alloc
                                residual[l] = v if v > 0.0 else 0.0
                        new_log[cls] = old_log[cls]
                comp_log[K] = new_log
            comp_resched.update(comp_dirty)
            comp_dirty.clear()
            return changed

        def apply_changed(changed) -> None:
            """Route freshly waterfilled rates to their event mechanism:
            coalesced (simple) flows only need their ``active``
            membership maintained — their component's next-completion
            entry is being recomputed by schedule_comp — while
            everything else re-derives its per-task event."""
            if use_batch and len(changed) >= 64:
                ca = np.array(changed, dtype=np.int64)
                sm = simple_a[ca]
                simp = ca[sm]
                on = rate[simp] > EPS
                active.update(simp[on].tolist())
                active.difference_update(simp[~on].tolist())
                touched_sched.update(ca[~sm].tolist())
                return
            for i in changed:
                if simple[i]:
                    if rate[i] > EPS:
                        active.add(i)
                    else:
                        active.discard(i)
                else:
                    touched_sched.add(i)

        # -- initialisation --------------------------------------------
        for nm, v in sim.releases.items():
            if v > EPS:
                heappush(heap, (float(v), 0, comp.idx[nm], 0))
        candidates.update(comp.roots)
        process_starts()
        if comp_dirty:
            apply_changed(allocate())
        for i in touched:
            schedule_event(i)
        for i in touched_sched:
            if i not in touched:
                schedule_event(i)
        for K in comp_resched:
            schedule_comp(K)
        comp_resched.clear()
        flush_events()
        touched.clear()
        touched_sched.clear()

        guard = 0
        max_iters = 10000 * (n + 1) + comp.nu_sum

        # -- settle: post-mutation fixup at a frozen clock -------------
        def settle() -> None:
            """Apply queued mutations' consequences at time ``now``:
            the completion/start/starvation/reallocation/reschedule
            passes one event iteration runs, with an empty event batch.
            Called automatically before the next advance."""
            nonlocal needs_settle
            needs_settle = False
            done_now = [i for i in active if work[i] >= size[i] - EPS]
            for i in done_now:
                complete(i)
            for si in freed:
                candidates.update(waiting_slot.get(si, ()))
            freed.clear()
            if candidates:
                process_starts()
            for i in list(touched):
                if started[i] is None or finished[i] is not None:
                    continue
                is_starved = cap[i] <= work[i] + EPS
                if is_starved != starved[i]:
                    starved[i] = is_starved
                    if is_comp[i]:
                        rate[i] = 0.0 if is_starved else 1.0
                    else:
                        pos = net_pos[i]
                        starved_net[pos] = is_starved
                        if is_starved:
                            rate[i] = 0.0
                        dirty_net(pos)
            if coflows:
                for ci, c in enumerate(coflows):
                    if any(started[m] is not None and finished[m] is None
                           for m in c):
                        for m in c:
                            dirty_net(net_pos[m])
            if comp_dirty:
                apply_changed(allocate())
            for i in touched:
                schedule_event(i)
            for i in touched_sched:
                if i not in touched:
                    schedule_event(i)
            for K in comp_resched:
                schedule_comp(K)
            comp_resched.clear()
            flush_events()
            touched.clear()
            touched_sched.clear()

        # -- main loop, pausable ---------------------------------------
        def advance(t_stop: float, allow_stall: bool) -> str:
            """Process events up to ``t_stop`` (inclusive); returns
            ``"done"`` (all tasks finished), ``"paused"`` (next event
            strictly after ``t_stop``) or, with ``allow_stall``,
            ``"stalled"`` (unfinished tasks but no events — e.g. every
            runnable task starved by a fault and nobody replanning)."""
            nonlocal now, guard
            if needs_settle:
                settle()
            while unfinished:
                t_next = None
                while heap:
                    tm, kind, i, stp = heap[0]
                    if kind == 1 and (stamp[i] != stp
                                      or finished[i] is not None):
                        heappop(heap)
                        continue
                    if kind == 0 and started[i] is not None:
                        heappop(heap)
                        continue
                    if kind == 2 and comp_stamp[i] != stp:
                        heappop(heap)
                        continue
                    if kind == 3:
                        th = meta_head(i)
                        if th is None:
                            heappop(heap)
                            continue
                        if th != tm:         # stale hint: refresh
                            heappop(heap)
                            heappush(heap, (th, 3, i, 0))
                            continue
                    t_next = tm
                    break
                if t_next is None:
                    if allow_stall:
                        return "stalled"
                    pend = [names[i] for i in range(n)
                            if finished[i] is None]
                    raise RuntimeError(f"deadlock at t={now:.6g}: {pend}")
                if t_next > t_stop:
                    return "paused"
                guard += 1
                if guard > max_iters:
                    raise RuntimeError(
                        "simulator did not converge (livelock?)")
                if t_next > horizon:
                    t_next = horizon
                dt = t_next - now
                act_arr = None
                if use_batch and len(active) >= 64:
                    act_arr = np.fromiter(active, dtype=np.int64,
                                          count=len(active))
                if dt > 0.0:
                    if act_arr is not None:
                        # same elementwise arithmetic as the scalar
                        # loop (w + r*dt, clamp to size == the
                        # conditional store), one array pass
                        r = rate[act_arr]
                        if speed_on:
                            r = r * speed[act_arr]
                        w = work[act_arr] + r * dt
                        np.minimum(w, comp.size_a[act_arr], out=w)
                        work[act_arr] = w
                    elif speed_on:
                        for i in active:
                            w = work[i] + rate[i] * speed[i] * dt
                            sz = size[i]
                            work[i] = sz if w > sz else w
                    else:
                        for i in active:
                            w = work[i] + rate[i] * dt
                            sz = size[i]
                            work[i] = sz if w > sz else w
                now = t_next

                batch: list[int] = []
                while heap and heap[0][0] <= t_next:
                    tm, kind, i, stp = heappop(heap)
                    if kind == 1 and stamp[i] == stp \
                            and finished[i] is None:
                        batch.append(i)
                    elif kind == 0 and started[i] is None:
                        candidates.add(i)
                    elif kind == 2 and comp_stamp[i] == stp:
                        # a component's next-completion fired; re-derive
                        # it even if no completion/reallocation follows
                        # (FP shortfall)
                        comp_resched.add(i)
                    elif kind == 3:
                        # drain the component's due events; leave one
                        # fresh hint behind if any remain
                        ch = comp_heaps[i]
                        while ch and ch[0][0] <= t_next:
                            t2, k2, i2, s2 = heappop(ch)
                            if k2 == 1 and stamp[i2] == s2 \
                                    and finished[i2] is None:
                                batch.append(i2)
                            elif k2 == 2 and comp_stamp[i2] == s2:
                                comp_resched.add(i2)
                        if ch:
                            heappush(heap, (ch[0][0], 3, i, 0))

                # completions (a task reaching its cap/size keeps
                # rate > 0 until this very event — scan the active set)
                if act_arr is not None:
                    finished_now = act_arr[
                        work[act_arr] >= comp.size_a[act_arr] - EPS
                    ].tolist()
                else:
                    finished_now = [i for i in active
                                    if work[i] >= size[i] - EPS]
                if len(finished_now) >= 128:
                    complete_bulk(finished_now)
                else:
                    for i in finished_now:
                        complete(i)

                # unit-boundary crossings feed streaming consumers
                if comp.has_streaming:
                    for i in batch:
                        if not stream_out[i] or finished[i] is not None:
                            continue
                        du = math.floor(work[i] / unit[i] + EPS)
                        if du != d_units[i]:
                            d_units[i] = du
                            for c in stream_out[i]:
                                if started[c] is None:
                                    candidates.add(c)
                                elif finished[c] is None:
                                    nc = recompute_cap(c)
                                    if nc != cap[c]:
                                        cap[c] = nc
                                        touched.add(c)

                for si in freed:
                    candidates.update(waiting_slot.get(si, ()))
                freed.clear()
                if candidates:
                    process_starts()

                # starvation flips (cap moved, or work caught up)
                for i in touched.union(x for x in batch
                                       if finished[x] is None):
                    if started[i] is None or finished[i] is not None:
                        continue
                    is_starved = cap[i] <= work[i] + EPS
                    if is_starved != starved[i]:
                        starved[i] = is_starved
                        if is_comp[i]:
                            rate[i] = 0.0 if is_starved else 1.0
                        else:
                            pos = net_pos[i]
                            starved_net[pos] = is_starved
                            if is_starved:
                                rate[i] = 0.0
                            dirty_net(pos)
                    touched.add(i)

                # MADD weights drift with remaining work (coflows
                # collapse the component split, so this dirties the
                # single component at the members' lowest class — the
                # global lowest, as before)
                if coflows:
                    for ci, c in enumerate(coflows):
                        if any(started[m] is not None
                               and finished[m] is None for m in c):
                            for m in c:
                                dirty_net(net_pos[m])

                if comp_dirty:
                    apply_changed(allocate())

                for i in touched:
                    schedule_event(i)
                for i in touched_sched:
                    if i not in touched:
                        schedule_event(i)
                for i in batch:
                    if finished[i] is None and i not in touched \
                            and i not in touched_sched:
                        schedule_event(i)
                for K in comp_resched:
                    schedule_comp(K)
                comp_resched.clear()
                flush_events()
                touched.clear()
                touched_sched.clear()
            return "done"

        def peek_next():
            """Earliest valid event time (stale entries are popped);
            None when the calendar is empty."""
            while heap:
                tm, kind, i, stp = heap[0]
                if kind == 1 and (stamp[i] != stp
                                  or finished[i] is not None):
                    heappop(heap)
                    continue
                if kind == 0 and started[i] is not None:
                    heappop(heap)
                    continue
                if kind == 2 and comp_stamp[i] != stp:
                    heappop(heap)
                    continue
                if kind == 3:
                    th = meta_head(i)
                    if th is None:
                        heappop(heap)
                        continue
                    if th != tm:             # stale hint: refresh
                        heappop(heap)
                        heappush(heap, (th, 3, i, 0))
                        continue
                return tm
            return None

        def advance_to(t: float) -> None:
            """Integrate active work up to ``t`` and move the clock
            there, without processing any event — ``t`` must lie in the
            gap before the next event (run_until(t) returned "paused"),
            so a mutation can land at its exact scheduled time."""
            nonlocal now
            if needs_settle:
                settle()
            if t <= now:
                return
            tn = peek_next()
            if tn is not None and tn < t:
                raise ValueError(f"advance_to({t!r}) would skip the "
                                 f"event at t={tn!r}")
            dt = t - now
            for i in active:
                w = work[i] + rate[i] * speed[i] * dt
                sz = size[i]
                work[i] = sz if w > sz else w
            now = t

        def result():
            """SimResult for the completed run (raises if unfinished)."""
            if unfinished:
                raise RuntimeError(
                    f"simulation incomplete: {unfinished} unfinished "
                    f"task(s) at t={now:.6g}")
            start = dict(zip(names, started))
            finish = dict(zip(names, finished))
            makespan = max(finished, default=0.0)
            if comp.single_job:
                jobs = {comp.job[0]: makespan} if n else {}
            else:
                jobs = {}
                for i in range(n):
                    j = comp.job[i]
                    f = finished[i]
                    if f > jobs.get(j, -1.0):
                        jobs[j] = f
            return SimResult(start=start, finish=finish,
                             makespan=makespan, job_completion=jobs)

        def progress(at=None):
            """Per-task completed fraction, projected to time ``at``
            (default: the paused clock) — read-only, no state change."""
            t = now if at is None else at
            ext = t - now
            out = {}
            for i in range(n):
                if finished[i] is not None:
                    out[names[i]] = 1.0
                elif started[i] is None:
                    out[names[i]] = 0.0
                else:
                    w = work[i]
                    if ext > 0.0 and i in active:
                        w = w + rate[i] * speed[i] * ext
                    sz = size[i]
                    out[names[i]] = 1.0 if sz <= 0 \
                        else (1.0 if w >= sz else w / sz)
            return out

        # -- fault-model mutators --------------------------------------
        def kill(i: int) -> None:
            """Reset an unfinished task to unstarted with zero progress
            (its slot is freed; its component's bandwidth refills)."""
            nonlocal needs_settle
            if finished[i] is not None:
                raise ValueError(f"{names[i]} already finished "
                                 f"(use resurrect)")
            if inc_bylink:
                inc_bylink.clear()     # non-incremental runnable edit
            stamp[i] += 1
            active.discard(i)
            if has_slot[i]:
                si = slot_of[i]
                slots_free[si] += 1
                has_slot[i] = False
                freed.add(si)
            if is_comp[i]:
                w = waiting_slot.get(slot_of[i])
                if w is not None:
                    w.discard(i)
            else:
                pos = net_pos[i]
                K = comp_of[pos]
                if pos in comp_runnable[K] or rate[i]:
                    comp_dirty[K] = -inf
                comp_runnable[K].discard(pos)
                comp_simple_active[K].discard(i)
                comp_resched.add(K)
                starved_net[pos] = False
            rate[i] = 0.0
            work[i] = 0.0
            cap[i] = size[i]
            d_units[i] = 0
            starved[i] = False
            started[i] = None
            candidates.add(i)
            touched.discard(i)
            touched_sched.discard(i)
            for c in stream_out[i]:
                if started[c] is not None and finished[c] is None:
                    nc = recompute_cap(c)
                    if nc != cap[c]:
                        cap[c] = nc
                        touched.add(c)
            needs_settle = True

        def resurrect(i: int) -> None:
            """Un-finish a task whose output data was lost: restore its
            consumers' gate counters and reset it to unstarted.  Started
            barrier/coflow consumers raise :class:`ResurrectConflict`
            (they would be running on data that no longer exists; the
            exception names all of them so the caller can kill exactly
            those and retry).  For a coflow member the group's MADD
            bookkeeping is rewound: ``cof_left`` re-opens, and when the
            group had completed, every start gate its all-or-nothing
            output had released is re-armed.  Started *streaming*
            consumers are handled like ``kill`` handles them — their
            caps shrink back to the (now zero) delivered units and they
            stall until re-delivery."""
            nonlocal unfinished, needs_settle
            if finished[i] is None:
                return
            if inc_bylink:
                inc_bylink.clear()     # non-incremental runnable edit
            ci = coflow_of[i]
            group_done = ci >= 0 and cof_left[ci] == 0
            # gate_dec[i] holds every counter i's own completion
            # decremented (barrier successors + member-sync gates of
            # coflows i feeds); a completed group's cof_dec adds the
            # consumers its *group* completion released
            held = set(gate_dec[i])
            if group_done:
                held.update(comp.cof_dec[ci])
            running = sorted(
                names[s] for s in held
                if started[s] is not None and finished[s] is None)
            if running:
                raise ResurrectConflict(names[i], running)
            finished[i] = None
            unfinished += 1
            for s in gate_dec[i]:
                n_gate[s] += 1
            if ci >= 0:
                if group_done:
                    # mirror of the group-completion decrement: one per
                    # member-pred edge in cof_dec (entries repeat)
                    for t in comp.cof_dec[ci]:
                        n_gate[t] += 1
                cof_left[ci] += 1
            stamp[i] += 1
            started[i] = None
            work[i] = 0.0
            rate[i] = 0.0
            cap[i] = size[i]
            d_units[i] = 0
            starved[i] = False
            if not is_comp[i]:
                starved_net[net_pos[i]] = False
            for c in stream_out[i]:
                if started[c] is not None and finished[c] is None:
                    nc = recompute_cap(c)
                    if nc != cap[c]:
                        cap[c] = nc
                        touched.add(c)
            candidates.add(i)
            touched.discard(i)
            touched_sched.discard(i)
            needs_settle = True

        def kill_or_resurrect(i: int) -> None:
            """Restart ``i`` from zero whatever its current state."""
            if finished[i] is not None:
                resurrect(i)
            else:
                kill(i)

        def set_speed(i: int, s: float) -> None:
            """Set task ``i``'s rate multiplier (1.0 = nominal)."""
            nonlocal speed_on, needs_settle
            s = float(s)
            if s < 0.0:
                raise ValueError("speed must be >= 0")
            speed[i] = s
            if s != 1.0:
                speed_on = True
            if started[i] is not None and finished[i] is None:
                if not is_comp[i] and simple[i]:
                    comp_resched.add(comp_of[net_pos[i]])
                else:
                    touched_sched.add(i)
            needs_settle = True

        def set_link_bw(li: int, bw: float) -> None:
            """Patch link ``li``'s capacity; dirty touched components."""
            nonlocal needs_settle
            link_bw[li] = float(bw)
            if use_batch:
                link_bw_a_run[li] = float(bw)
            for pos in range(len(flow_links)):
                if li in flow_links[pos] \
                        and finished[net_ids[pos]] is None:
                    comp_dirty[comp_of[pos]] = -inf
            needs_settle = True

        def link_id(lname: str):
            """Interned id of a link resource name (None when the link
            never appears in any compiled flow path)."""
            return link_name_id.get(lname)

        def move(i: int, host: str, proc) -> None:
            """Re-place compute ``i`` onto ``host`` (restarting it if it
            had begun — speculative re-execution)."""
            nonlocal slot_of, slot_ids_run, needs_settle
            if not is_comp[i]:
                raise ValueError(f"{names[i]} is not a compute task")
            if proc is None:
                proc = sim.g.tasks[names[i]].proc
            kill_or_resurrect(i)
            if slot_of is comp.slot_of:
                slot_of = list(comp.slot_of)
            if slot_ids_run is comp.slot_ids:
                slot_ids_run = dict(comp.slot_ids)
            key = (host, proc)
            si = slot_ids_run.get(key)
            if si is None:
                si = slot_ids_run[key] = len(slots_free)
                h = hosts.get(host)
                slots_free.append(
                    int(h.procs.get(proc, 0)) if h is not None else 0)
            slot_of[i] = si
            cur_host[i] = host
            needs_settle = True

        def rebuild_csr() -> None:
            """Refresh the NumPy CSR mirror after a structural patch and
            drop the (now stale) full-group fill prep."""
            nonlocal fl_ptr, fl_flat, full_sg_pos, full_sorted_ids, \
                full_row_links, full_by_link, full_counts
            full_sg_pos = full_sorted_ids = None
            full_row_links = full_by_link = full_counts = None
            if use_np:
                ptr = [0]
                flat: list[int] = []
                for links in flow_links:
                    flat.extend(links)
                    ptr.append(len(flat))
                fl_ptr = np.array(ptr, dtype=np.int64)
                fl_flat = np.array(flat, dtype=np.int64)

        def repath(i: int, route, reset: bool, src2, dst2) -> None:
            """Re-path flow ``i`` onto ``route`` (link resource names,
            endpoint NICs included), merging contention components the
            new path bridges.  ``reset`` restarts an in-flight transfer
            from zero; a finished flow is resurrected (re-delivery)."""
            nonlocal flow_links, comp_of, residual, needs_settle, \
                link_bw_a_run
            if is_comp[i]:
                raise ValueError(f"{names[i]} is not a flow")
            pos = net_pos[i]
            if finished[i] is not None:
                resurrect(i)
            elif reset and started[i] is not None:
                kill(i)
            ids = []
            for lname in route:
                li = link_name_id.get(lname)
                if li is None:
                    li = len(link_bw)
                    link_name_id[lname] = li
                    link_names.append(lname)
                    link_bw.append(float(cluster.bandwidth(lname)))
                    if use_np:
                        residual = np.append(residual, 0.0)
                    else:
                        residual.append(0.0)
                    if use_batch:
                        link_bw_a_run = np.append(link_bw_a_run,
                                                  link_bw[-1])
                ids.append(li)
            if flow_links is comp.flow_links:
                flow_links = list(comp.flow_links)
            if comp_of is comp.comp_of_net:
                comp_of = list(comp.comp_of_net)
            old_k = comp_of[pos]
            flow_links[pos] = tuple(ids)
            if src2 is not None:
                cur_src[pos] = src2
            if dst2 is not None:
                cur_dst[pos] = dst2
            # merge every component sharing a link with the new path:
            # the disjointness invariant (no link in two components)
            # must hold or the waterfill double-books bandwidth
            idset = set(ids)
            ks = {old_k}
            for p2, links2 in enumerate(flow_links):
                if p2 != pos and comp_of[p2] not in ks \
                        and not idset.isdisjoint(links2):
                    ks.add(comp_of[p2])
            kt = min(ks)
            if len(ks) > 1:
                for p2 in range(len(comp_of)):
                    if comp_of[p2] in ks:
                        comp_of[p2] = kt
                for k2 in ks:
                    if k2 == kt:
                        continue
                    comp_runnable[kt] |= comp_runnable[k2]
                    comp_runnable[k2] = set()
                    comp_simple_active[kt] |= comp_simple_active[k2]
                    comp_simple_active[k2] = set()
                    comp_log[k2] = None
                    comp_stamp[k2] += 1
            else:
                comp_of[pos] = kt
            comp_log[kt] = None
            comp_log[old_k] = None
            comp_stamp[kt] += 1
            comp_resched.add(kt)
            if old_k != kt:
                comp_stamp[old_k] += 1
                comp_resched.add(old_k)
            comp_dirty[kt] = -inf
            if comp_runnable[old_k]:
                comp_dirty[old_k] = -inf
            if inc_bylink:
                inc_bylink.clear()     # incidence/component maps changed
            rebuild_csr()
            needs_settle = True

        def kill_host(host: str) -> list:
            """Fail ``host``: zero its slots and NIC links, restart its
            unfinished tasks, and resurrect the lineage closure —
            finished tasks whose output data resided there (computes
            placed on it, flows delivered to it) and is still needed by
            an unfinished data consumer.  Started consumers of the
            resurrected data (even on healthy hosts) are killed too —
            they were running on output that no longer exists.  Returns
            the restarted task names (sorted); the replanner must
            re-place/re-path them."""
            nonlocal needs_settle
            resident: list[int] = []
            direct: set[int] = set()
            for i in range(n):
                if is_comp[i]:
                    if cur_host[i] == host:
                        if finished[i] is None:
                            direct.add(i)
                        else:
                            resident.append(i)
                else:
                    pos = net_pos[i]
                    if finished[i] is None:
                        if cur_src[pos] == host or cur_dst[pos] == host:
                            direct.add(i)
                    elif cur_dst[pos] == host:
                        resident.append(i)
            # lineage fixpoint: a finished resident task re-runs when a
            # *data* consumer of its output is (or becomes) unfinished —
            # for computes that means NETWORK successors (data leaves
            # via flows; compute→compute edges are control-only), for
            # delivered flows any successor
            need = set(direct)
            changed = True
            while changed:
                changed = False
                for i in resident:
                    if i in need:
                        continue
                    for s in succ[i]:
                        if is_comp[i] and is_comp[s]:
                            continue
                        if finished[s] is None or s in need:
                            need.add(i)
                            changed = True
                            break
            for i in sorted(need):
                if finished[i] is None:
                    kill(i)
            idx = comp.idx
            for i in sorted(need):
                while finished[i] is not None:
                    try:
                        resurrect(i)
                    except ResurrectConflict as e:
                        # a started consumer on a *healthy* host is
                        # running on the data being resurrected: kill
                        # exactly the named offenders (they join the
                        # restarted set) and retry — each retry strictly
                        # shrinks the running-consumer set, so this
                        # terminates
                        for nm in e.consumers:
                            j = idx[nm]
                            if finished[j] is None:
                                kill(j)
                            need.add(j)
            for (h, _proc), si in slot_ids_run.items():
                if h == host:
                    slots_free[si] = 0
            for lname in (host + ".nic_out", host + ".nic_in"):
                li = link_name_id.get(lname)
                if li is not None:
                    set_link_bw(li, 0.0)
            needs_settle = True
            return sorted(names[i] for i in need)

        def revive_host(host: str) -> None:
            """Bring a killed host back (the reboot model): slot pools
            to full capacity and NICs to nominal.  Prior progress stays
            lost — ``kill_host`` already restarted the lineage.  Only
            valid on a host with nothing running (guaranteed after
            ``kill_host``: zero slots stop computes, zero NICs leave
            flows parked at rate 0 — those resume on revive)."""
            nonlocal needs_settle
            if host not in sim.cluster.hosts:
                raise KeyError(host)
            if slot_ids_run is not comp.slot_ids:
                raise RuntimeError(
                    "revive_host is not supported after move_task "
                    "(slot pools diverged from the compiled capacities)")
            for i in range(n):
                if is_comp[i] and cur_host[i] == host \
                        and started[i] is not None and finished[i] is None:
                    raise RuntimeError(
                        f"revive_host({host!r}): {names[i]!r} is "
                        f"running there — revive only a killed host")
            for (h, _proc), si in slot_ids_run.items():
                if h == host:
                    slots_free[si] = comp.slot_cap[si]
                    freed.add(si)    # tasks parked in waiting_slot
                    # must be reconsidered at the next settle
            for lname in (host + ".nic_out", host + ".nic_in"):
                li = link_name_id.get(lname)
                if li is not None:
                    set_link_bw(li, sim.cluster.bandwidth(lname))
            needs_settle = True

        def set_priorities(prio: dict, new_policy) -> None:
            """Swap in a replanned priority map (optionally switching
            policy); rebuilt classes/dispatch ranks, invalidated replay
            logs, runnable components refill from scratch."""
            nonlocal policy, cls_net, prio_arr, dispatch_rank, \
                needs_settle, cls_net_a
            if new_policy is not None:
                if new_policy not in ("fair", "priority"):
                    raise ValueError(f"unknown policy {new_policy}")
                policy = new_policy
            pget = prio.get
            if policy == "fair":
                cls_net = [None] * comp.n_net
            else:
                cls_net = [0.0 if comp.stream_fed[i]
                           else pget(names[i], 0.0)
                           for i in net_ids]
            cls_net_a = np.array(cls_net, dtype=np.float64) \
                if use_batch and policy != "fair" else None
            prio_arr = [pget(nm, 0.0) for nm in names]
            if use_np:
                o = np.lexsort((comp.name_rank_a, np.array(prio_arr)))
                dr = np.empty(n, dtype=np.int64)
                dr[o] = np.arange(n, dtype=np.int64)
                dispatch_rank = dr.tolist()
            else:
                o = sorted(range(n),
                           key=lambda i: (prio_arr[i],
                                          comp.name_rank[i]))
                dispatch_rank = [0] * n
                for r2, i2 in enumerate(o):
                    dispatch_rank[i2] = r2
            if inc_bylink:
                inc_bylink.clear()     # classes re-keyed
            for K in range(n_comps):
                comp_log[K] = None
                if comp_runnable[K]:
                    comp_dirty[K] = -inf
            needs_settle = True

        # -- checkpoint / restore --------------------------------------
        def snapshot() -> dict:
            """Copy every piece of mutable run state (compile-owned
            arrays are immutable and shared by reference).  Taken at a
            settled boundary; heap tuples and logged freeze sequences
            are never mutated in place, so shallow copies suffice."""
            if needs_settle:
                settle()
            return {
                "work": vcopy(work), "rate": vcopy(rate), "cap": cap[:],
                "speed": vcopy(speed), "speed_on": speed_on,
                "starved_net": vcopy(starved_net), "started": started[:],
                "finished": finished[:], "has_slot": has_slot[:],
                "starved": starved[:], "d_units": d_units[:],
                "slots_free": slots_free[:], "cof_left": cof_left[:],
                "n_gate": n_gate[:], "stamp": stamp[:],
                "active": set(active),
                "waiting_slot": {k2: set(v)
                                 for k2, v in waiting_slot.items()},
                "candidates": set(candidates),
                "comp_runnable": [set(s) for s in comp_runnable],
                "comp_simple_active": [set(s)
                                       for s in comp_simple_active],
                "comp_log": [None if lg is None else dict(lg)
                             for lg in comp_log],
                "comp_stamp": comp_stamp[:],
                "heap": heap[:],
                "comp_heaps": (None if comp_heaps is None
                               else [h[:] for h in comp_heaps]),
                "unfinished": unfinished, "now": now,
                "guard": guard,
                "policy": policy, "cls_net": cls_net[:],
                "prio_arr": prio_arr[:],
                "dispatch_rank": dispatch_rank[:],
                "link_bw": link_bw[:],
                "residual": residual.copy() if use_np else residual[:],
                "flow_links": flow_links[:], "comp_of": comp_of[:],
                "slot_of": slot_of[:],
                "slot_ids": dict(slot_ids_run),
                "link_names": link_names[:],
                "link_name_id": dict(link_name_id),
                "cur_host": cur_host[:], "cur_src": cur_src[:],
                "cur_dst": cur_dst[:],
                "csr": (fl_ptr, fl_flat, full_sg_pos, full_sorted_ids,
                        full_row_links, full_by_link, full_counts),
            }

        def restore(snap: dict) -> None:
            """Reset the run state to a snapshot() (which survives and
            may be restored again)."""
            nonlocal work, rate, cap, speed, speed_on, starved_net, \
                started, finished, has_slot, starved, d_units, \
                slots_free, cof_left, n_gate, stamp, active, \
                waiting_slot, candidates, comp_runnable, \
                comp_simple_active, comp_log, comp_stamp, heap, \
                comp_heaps, \
                unfinished, now, guard, policy, cls_net, prio_arr, \
                dispatch_rank, link_bw, residual, flow_links, \
                comp_of, slot_of, slot_ids_run, link_names, \
                link_name_id, cur_host, cur_src, cur_dst, fl_ptr, \
                fl_flat, full_sg_pos, full_sorted_ids, \
                full_row_links, full_by_link, full_counts, \
                needs_settle, link_bw_a_run, cls_net_a
            work = vcopy(snap["work"])
            rate = vcopy(snap["rate"])
            cap = snap["cap"][:]
            speed = vcopy(snap["speed"])
            speed_on = snap["speed_on"]
            starved_net = vcopy(snap["starved_net"])
            started = snap["started"][:]
            finished = snap["finished"][:]
            has_slot = snap["has_slot"][:]
            starved = snap["starved"][:]
            d_units = snap["d_units"][:]
            slots_free = snap["slots_free"][:]
            cof_left = snap["cof_left"][:]
            n_gate = snap["n_gate"][:]
            stamp = snap["stamp"][:]
            active = set(snap["active"])
            waiting_slot = {k2: set(v)
                            for k2, v in snap["waiting_slot"].items()}
            candidates = set(snap["candidates"])
            comp_runnable = [set(s) for s in snap["comp_runnable"]]
            comp_simple_active = [set(s)
                                  for s in snap["comp_simple_active"]]
            comp_log = [None if lg is None else dict(lg)
                        for lg in snap["comp_log"]]
            comp_stamp = snap["comp_stamp"][:]
            if inc_bylink:
                inc_bylink.clear()     # rebuilt lazily from new state
            heap = snap["heap"][:]
            ch_snap = snap["comp_heaps"]
            comp_heaps = None if ch_snap is None \
                else [h[:] for h in ch_snap]
            unfinished = snap["unfinished"]
            now = snap["now"]
            guard = snap["guard"]
            policy = snap["policy"]
            cls_net = snap["cls_net"][:]
            prio_arr = snap["prio_arr"][:]
            dispatch_rank = snap["dispatch_rank"][:]
            link_bw = snap["link_bw"][:]
            residual = snap["residual"].copy() if use_np \
                else snap["residual"][:]
            flow_links = snap["flow_links"][:]
            comp_of = snap["comp_of"][:]
            slot_of = snap["slot_of"][:]
            slot_ids_run = dict(snap["slot_ids"])
            link_names = snap["link_names"][:]
            link_name_id = dict(snap["link_name_id"])
            cur_host = snap["cur_host"][:]
            cur_src = snap["cur_src"][:]
            cur_dst = snap["cur_dst"][:]
            (fl_ptr, fl_flat, full_sg_pos, full_sorted_ids,
             full_row_links, full_by_link, full_counts) = snap["csr"]
            if use_batch:
                link_bw_a_run = np.array(link_bw, dtype=np.float64)
                cls_net_a = np.array(cls_net, dtype=np.float64) \
                    if policy != "fair" else None
            comp_dirty.clear()
            comp_resched.clear()
            touched.clear()
            touched_sched.clear()
            freed.clear()
            pending.clear()
            needs_settle = False

        def state_view() -> dict:
            """Light read-only view of scalar run state plus shared
            handles on the per-task vectors (do not mutate)."""
            return {"now": now, "unfinished": unfinished,
                    "started": started, "finished": finished,
                    "work": work, "speed": speed}

        def free_slots() -> dict:
            """Free slot count per (host, proc) pool."""
            return {key: slots_free[si]
                    for key, si in slot_ids_run.items()}

        def flow_route(i: int) -> tuple:
            """Current link-name path of flow ``i``."""
            return tuple(link_names[l]
                         for l in flow_links[net_pos[i]])

        def flow_ends(i: int) -> tuple:
            """Current (src, dst) endpoints of flow ``i``."""
            pos = net_pos[i]
            return (cur_src[pos], cur_dst[pos])

        # -- live admission / departure (name-keyed state transfer) ----
        def export_admission() -> dict:
            """Name-keyed dump of the dynamic run state, for transfer
            into a recompiled session over a merged (admit) or shrunk
            (retire) graph.  Keys are task names, (host, proc) slot
            pools, link names and sorted coflow member tuples, so the
            receiving compile maps them onto its own interning — ids
            never cross the boundary.  Settles queued mutations first
            (like snapshot); structural mutations (move/repath) have no
            name-stable representation and refuse the export."""
            if needs_settle:
                settle()
            if list(slot_of) != list(comp.slot_of) \
                    or list(flow_links) != list(comp.flow_links):
                raise RuntimeError(
                    "cannot admit/retire after move_task/repath_flow: "
                    "the session's placement no longer matches the "
                    "graph, so a recompiled merge cannot represent it")
            key_of_slot = {si: key for key, si in slot_ids_run.items()}
            tasks = {}
            for i in range(n):
                tasks[names[i]] = (
                    float(work[i]), started[i], finished[i], cap[i],
                    d_units[i], has_slot[i], starved[i],
                    float(speed[i]), n_gate[i], rel[i], float(rate[i]))
            # the live event calendar: per-task next-event times and
            # per-component coalesced next-completion times, exported
            # verbatim.  Recomputing them after the transfer would
            # re-anchor ``now + (size-work)/rate`` at the admission
            # instant and shift every float by ulps — the receiving
            # session pushes these exact times instead, so untouched
            # tasks keep the calendar a from-scratch merged run carries.
            ev1: dict = {}
            ev2: list = []

            def _scan(entries) -> None:
                for e in entries:
                    tm, kind, i2, stp = e
                    if kind == 1 and stamp[i2] == stp \
                            and finished[i2] is not None:
                        continue
                    if kind == 1 and stamp[i2] == stp:
                        ev1[names[i2]] = tm
                    elif kind == 2 and comp_stamp[i2] == stp:
                        ev2.append((tuple(sorted(
                            names[m] for m in comp_simple_active[i2])),
                            tm))
            _scan(heap)
            if comp_heaps is not None:
                for ch in comp_heaps:
                    _scan(ch)
            return {
                "ev1": ev1, "ev2": ev2,
                "now": now, "speed_on": speed_on, "policy": policy,
                "prio": {names[i]: prio_arr[i] for i in range(n)
                         if prio_arr[i]},
                "tasks": tasks,
                "slots": {key: slots_free[si]
                          for key, si in slot_ids_run.items()},
                "waiting": {key_of_slot[si]: [names[i] for i in s]
                            for si, s in waiting_slot.items() if s},
                "links": {link_names[li]: link_bw[li]
                          for li in range(len(link_bw))},
                "cof_left": {tuple(sorted(names[m] for m in c)):
                             cof_left[ci]
                             for ci, c in enumerate(coflows)},
                "candidates": [names[i] for i in candidates],
            }

        def transplant(st: dict) -> None:
            """Load an export_admission() dump into this freshly built
            session: wipe the t=0 initialisation, overlay the exported
            per-task/slot/link state by name (names absent from this
            compile — retired rows — are skipped), re-register in-flight
            work, and leave everything dirty for one settle().  The
            settle at the admission instant then completes exact-time
            tasks and runs one combined dispatch pass, exactly the event
            batch a from-scratch run of the merged graph would execute
            there."""
            nonlocal now, unfinished, speed_on, guard, needs_settle
            # wipe: the constructor already started roots at t=0
            heap.clear()
            pending.clear()
            if comp_heaps is not None:
                for ch in comp_heaps:
                    ch.clear()
            active.clear()
            waiting_slot.clear()
            candidates.clear()
            freed.clear()
            touched.clear()
            touched_sched.clear()
            comp_dirty.clear()
            comp_resched.clear()
            if inc_bylink:
                inc_bylink.clear()
            for K in range(n_comps):
                comp_runnable[K].clear()
                comp_simple_active[K].clear()
                comp_log[K] = None
                comp_stamp[K] += 1
            if use_batch:
                work[:] = 0.0
                rate[:] = 0.0
                speed[:] = 1.0
                starved_net[:] = False
            else:
                for i in range(n):
                    work[i] = 0.0
                    rate[i] = 0.0
                    speed[i] = 1.0
                for p in range(len(starved_net)):
                    starved_net[p] = False
            for i in range(n):
                started[i] = None
                finished[i] = None
                has_slot[i] = False
                starved[i] = False
                d_units[i] = 0
                cap[i] = size[i]
                stamp[i] += 1
            slots_free[:] = list(comp.slot_cap)
            cof_left[:] = [len(c) for c in coflows]
            n_gate[:] = list(comp.init_gate)
            link_bw[:] = list(comp.link_bw)
            if use_batch:
                link_bw_a_run[:] = comp.link_bw_a
            now = st["now"]
            speed_on = st["speed_on"]
            guard = 0
            unfinished = n
            # overlay the exported state by name
            idx_get = comp.idx.get
            for nm, ts in st["tasks"].items():
                i = idx_get(nm)
                if i is None:
                    continue
                (w, s0, f0, cp, du, hs, sv, spd, ng, _r, rt) = ts
                work[i] = w
                started[i] = s0
                finished[i] = f0
                cap[i] = cp
                d_units[i] = du
                has_slot[i] = hs
                starved[i] = sv
                speed[i] = spd
                n_gate[i] = ng
                rate[i] = rt
                if f0 is not None:
                    unfinished -= 1
            for key, v in st["slots"].items():
                si = slot_ids_run.get(key)
                if si is not None:
                    slots_free[si] = v
            lid_get = link_name_id.get
            for lname, bw in st["links"].items():
                li = lid_get(lname)
                if li is not None:
                    link_bw[li] = bw
                    if use_batch:
                        link_bw_a_run[li] = bw
            if use_np:
                residual[:] = np.asarray(link_bw, dtype=np.float64)
            else:
                residual[:] = link_bw
            if coflows:
                ci_of = {tuple(sorted(names[m] for m in c)): ci
                         for ci, c in enumerate(coflows)}
                for ckey, left in st["cof_left"].items():
                    ci = ci_of.get(ckey)
                    if ci is not None:
                        cof_left[ci] = left
            # streaming bookkeeping is a pure function of work — derive
            # it rather than trusting a dump taken one event earlier
            if comp.has_streaming:
                for i in range(n):
                    if started[i] is None or finished[i] is not None:
                        continue
                    if stream_out[i]:
                        d_units[i] = math.floor(work[i] / unit[i] + EPS)
                for i in range(n):
                    if started[i] is None or finished[i] is not None:
                        continue
                    if stream_in[i]:
                        cap[i] = recompute_cap(i)
            # re-register in-flight tasks: rates and the exported
            # calendar carry over verbatim — nothing is re-anchored at
            # the admission instant unless the merged run would have
            # re-anchored it there too.  A task whose recomputed cap
            # contradicts its exported starvation flag (a streaming
            # boundary landing exactly at the admission time) goes
            # through settle's starvation pass, which is where the
            # from-scratch run flips it as well.
            for i in range(n):
                if started[i] is None or finished[i] is not None:
                    continue
                active.add(i)
                if not is_comp[i]:
                    pos = net_pos[i]
                    starved_net[pos] = starved[i]
                    K = comp_of[pos]
                    comp_runnable[K].add(pos)
                    if simple[i]:
                        comp_simple_active[K].add(i)
                if (cap[i] <= work[i] + EPS) != starved[i]:
                    touched.add(i)
            for key, nms in st["waiting"].items():
                si = slot_ids_run.get(key)
                if si is None:
                    continue
                ws = waiting_slot.setdefault(si, set())
                for nm in nms:
                    i = idx_get(nm)
                    if i is not None:
                        ws.add(i)
            # future releases re-enter via the calendar; everything
            # gate-ready (new-job roots included) via candidates — the
            # settle's dispatch pass sorts them all together
            for i in range(n):
                if started[i] is not None:
                    continue
                if rel[i] > now + EPS:
                    heappush(heap, (float(rel[i]), 0, i, 0))
                elif not n_gate[i]:
                    candidates.add(i)
            for nm in st["candidates"]:
                i = idx_get(nm)
                if i is not None:
                    candidates.add(i)
            # replant the exported calendar at its original anchors.
            # Coalesced (kind-2) entries are keyed by their member set:
            # admission can merge the owning components (the entry lands
            # on the union — an early fire just triggers a rescan, as
            # the merged run's own coalesced entry does) and retirement
            # can split them (the entry is replanted on every component
            # holding survivors)
            for nm, tv in st["ev1"].items():
                i = idx_get(nm)
                if i is None or started[i] is None \
                        or finished[i] is not None:
                    continue
                _defer((tv, 1, i, stamp[i]))
            for members, tv in st["ev2"]:
                ks = set()
                for nm in members:
                    i = idx_get(nm)
                    if i is None or started[i] is None \
                            or finished[i] is not None:
                        continue
                    ks.add(comp_of[net_pos[i]])
                for K in ks:
                    _defer((tv, 2, K, comp_stamp[K]))
            flush_events()
            needs_settle = True

        self._sim = sim
        self._names = names
        self._idx = comp.idx
        self._horizon = horizon
        self._batch = bool(batch)
        self._ops = {
            "advance": advance, "advance_to": advance_to,
            "settle": settle, "result": result, "progress": progress,
            "peek": peek_next, "export_admission": export_admission,
            "transplant": transplant,
            "snapshot": snapshot, "restore": restore,
            "state": state_view, "free_slots": free_slots,
            "flow_route": flow_route, "flow_ends": flow_ends,
            "set_speed": set_speed, "set_link_bw": set_link_bw,
            "link_id": link_id, "link_bw_of": link_bw.__getitem__,
            "kill": kill_or_resurrect, "kill_host": kill_host,
            "revive_host": revive_host,
            "move": move, "repath": repath,
            "set_priorities": set_priorities,
            "cur_host": lambda i: cur_host[i],
        }

    # -- session control -----------------------------------------------
    def run_until(self, t_stop: float, *,
                  allow_stall: bool = False) -> str:
        """Advance through every event at time <= ``t_stop``.

        Returns ``"done"``, ``"paused"`` (next event is strictly later
        — the clock rests at the last processed event), or
        ``"stalled"`` when ``allow_stall`` is set and unfinished tasks
        remain with an empty event calendar (without ``allow_stall``
        that raises, as the plain engine's deadlock check does).
        """
        return self._ops["advance"](t_stop, allow_stall)

    def run(self):
        """Run to completion and return the SimResult."""
        self._ops["advance"](math.inf, False)
        return self._ops["result"]()

    def advance_to(self, t: float) -> None:
        """Move the paused clock to ``t`` (before the next event),
        integrating in-flight work, so a mutation lands exactly there."""
        self._ops["advance_to"](t)

    def result(self):
        """SimResult of the finished run (raises while unfinished)."""
        return self._ops["result"]()

    # -- introspection -------------------------------------------------
    @property
    def now(self) -> float:
        """The paused simulation clock."""
        return self._ops["state"]()["now"]

    @property
    def unfinished(self) -> int:
        """Number of tasks not yet finished."""
        return self._ops["state"]()["unfinished"]

    def progress(self, at: float | None = None) -> dict:
        """Completed fraction per task, projected to ``at`` (read-only;
        defaults to the paused clock)."""
        return self._ops["progress"](at)

    def started_at(self, name: str):
        """Observed start time of ``name`` (None if not started)."""
        return self._ops["state"]()["started"][self._idx[name]]

    def finished_at(self, name: str):
        """Observed finish time of ``name`` (None if unfinished)."""
        return self._ops["state"]()["finished"][self._idx[name]]

    def unfinished_tasks(self) -> list:
        """Names of tasks not yet finished, in id (insertion) order."""
        fin = self._ops["state"]()["finished"]
        return [nm for nm, f in zip(self._names, fin) if f is None]

    def task_host(self, name: str):
        """Current placement of a compute task (tracks move_task)."""
        return self._ops["cur_host"](self._idx[name])

    def flow_route(self, name: str) -> tuple:
        """Current link-name path of a flow (tracks repath_flow)."""
        return self._ops["flow_route"](self._idx[name])

    def flow_ends(self, name: str) -> tuple:
        """Current (src, dst) of a flow (tracks repath_flow)."""
        return self._ops["flow_ends"](self._idx[name])

    def free_slots(self) -> dict:
        """Free slot count per (host, proc) pool, moves included."""
        return self._ops["free_slots"]()

    def link_capacity(self, name: str) -> float:
        """Current capacity of link ``name`` (mutations included).
        A cluster link no compiled flow path traverses reports its
        static capacity (it was never interned)."""
        li = self._ops["link_id"](name)
        if li is None:
            return self._sim.cluster.bandwidth(name)
        return self._ops["link_bw_of"](li)

    # -- fault-model mutators ------------------------------------------
    def set_speed(self, name: str, s: float) -> None:
        """Set ``name``'s rate multiplier (straggler model; 1.0 resets
        to nominal).  Effective progress rate is ``rate * speed``."""
        self._ops["set_speed"](self._idx[name], s)

    def set_link_bw(self, name: str, bw: float) -> None:
        """Set link ``name``'s capacity (0.0 = failed link).  Degrading
        a cluster link that no compiled flow path traverses is a no-op
        (it carries nothing, so it cannot affect the run) — but the
        name must at least be a real link of the cluster."""
        li = self._ops["link_id"](name)
        if li is None:
            self._sim.cluster.bandwidth(name)   # KeyError on garbage
            return
        self._ops["set_link_bw"](li, bw)

    def scale_link(self, name: str, factor: float) -> None:
        """Multiply link ``name``'s current capacity by ``factor``
        (no-op on an untraversed link, like :meth:`set_link_bw`)."""
        li = self._ops["link_id"](name)
        if li is None:
            self._sim.cluster.bandwidth(name)
            return
        self._ops["set_link_bw"](li, self._ops["link_bw_of"](li) * factor)

    def kill_task(self, name: str) -> None:
        """Lose ``name``'s progress (and output, if finished): reset to
        unstarted, restoring consumers' start gates as needed."""
        self._ops["kill"](self._idx[name])

    def kill_host(self, host: str) -> list:
        """Fail ``host`` (slots and NICs to zero); returns the names of
        every task restarted, including the resurrected lineage of data
        that lived on it.  See the class docstring for the fault model."""
        return self._ops["kill_host"](host)

    def revive_host(self, host: str) -> None:
        """Bring a killed host back online (reboot model): slot pools
        restored to capacity, NICs to nominal.  Progress lost to the
        kill stays lost; flows parked at rate 0 resume."""
        self._ops["revive_host"](host)

    def move_task(self, name: str, host: str,
                  proc: str | None = None) -> None:
        """Re-place compute ``name`` onto ``host`` (restarts it if it
        had begun — speculative re-execution)."""
        self._ops["move"](self._idx[name], host, proc)

    def repath_flow(self, name: str, route, *, reset: bool = False,
                    src: str | None = None,
                    dst: str | None = None) -> None:
        """Re-path flow ``name`` onto ``route`` (full link-name path,
        endpoint NICs included).  ``reset`` restarts an in-flight
        transfer; ``src``/``dst`` record re-pointed endpoints after a
        consumer/producer move."""
        self._ops["repath"](self._idx[name], route, reset, src, dst)

    def set_priorities(self, priorities: dict,
                       policy: str | None = None) -> None:
        """Swap in a replanned priority map (optionally switching the
        allocation policy) without recompiling."""
        self._ops["set_priorities"](dict(priorities), policy)

    # -- checkpoint / restore ------------------------------------------
    def checkpoint(self) -> dict:
        """Snapshot the mutable run state (settling queued mutations
        first); pass to :meth:`restore` to fork arms from one prefix."""
        return self._ops["snapshot"]()

    def restore(self, snap: dict) -> None:
        """Reset the session to a :meth:`checkpoint` snapshot."""
        self._ops["restore"](snap)

    # -- live admission / departure ------------------------------------
    def _adopt(self, other: "ResumableSim") -> None:
        """Swap this session's engine for ``other``'s: every public
        method dispatches through ``_ops``, so rebinding the handles is
        a full engine replacement (prior checkpoints no longer apply)."""
        self._sim = other._sim
        self._names = other._names
        self._idx = other._idx
        self._ops = other._ops

    def admit_graph(self, graph, at: float | None = None, *,
                    priorities: dict | None = None) -> None:
        """Splice a new job's DAG into the running session at time
        ``at`` (default: the paused clock), warm-starting from the
        current state — the history is never re-simulated.

        Events strictly before ``at`` are processed first, then the
        merged graph is compiled (the new job's rows extend the interned
        name table, gates, CSR incidence and contention components; the
        old rows keep their ids) and the dynamic state carries over
        name-keyed.  Bit-exact invariant: after ``admit_graph(g, at=t)``
        the session evolves exactly as a fresh session over the merged
        graph with every new task released at ``t``.  ``priorities``
        overlays priority classes for the new tasks (``set_priorities``
        re-ranks everything later, as the service layer does on each
        admission).

        Not supported after ``move_task``/``repath_flow`` (the placement
        diverged from the graph), nor at ``t == 0`` (build the merged
        simulation directly — the constructor has already dispatched the
        t=0 starts without the new job).
        """
        from repro.core.graph import MXDAG
        from repro.core.simulator import Simulator

        ops = self._ops
        sim = self._sim
        at = self.now if at is None else float(at)
        if at < self.now - EPS:
            raise ValueError(f"admit_graph at t={at!r}: the clock is "
                             f"already at {self.now!r}")
        if at <= 0.0:
            raise ValueError(
                "admit_graph at t=0: all jobs are known upfront — "
                "simulate the merged graph directly")
        jobs_old = {t.job for t in sim.g.tasks.values()}
        jobs_new = {t.job for t in graph.tasks.values()}
        taken = jobs_old & jobs_new
        if taken:
            raise ValueError(f"admitted job name(s) already running: "
                             f"{sorted(taken)}")
        # drive to the admission instant: events strictly before ``at``
        while True:
            tn = ops["peek"]()
            if tn is None or tn >= at:
                break
            ops["advance"](tn, True)
        ops["advance_to"](at)
        st = ops["export_admission"]()
        merged = MXDAG(sim.g.name)
        for t in sim.g.tasks.values():
            merged.add(t)
        for nm, t in graph.tasks.items():
            if nm in merged.tasks:
                raise ValueError(
                    f"admitted task name {nm!r} collides with the "
                    f"running graph (prefix task names with the job "
                    f"name, as builders.poisson_jobs does)")
            merged.add(t)
        for e in sim.g.edges.values():
            merged.add_edge(e.src, e.dst, pipelined=e.pipelined)
        for e in graph.edges.values():
            merged.add_edge(e.src, e.dst, pipelined=e.pipelined)
        releases = {nm: ts[9] for nm, ts in st["tasks"].items()
                    if ts[9] > 0.0}
        for nm in graph.tasks:
            releases[nm] = at
        prio = dict(st["prio"])
        if priorities:
            prio.update(priorities)
        fresh = ResumableSim(
            Simulator(merged, sim.cluster, policy=st["policy"],
                      priorities=prio, releases=releases,
                      coflows=sim.coflows, routes=sim.routes,
                      engine="array"),
            self._horizon, batch=self._batch)
        fresh._ops["transplant"](st)
        self._adopt(fresh)

    def retire_job(self, job: str) -> None:
        """Free a finished job's rows: recompile the session over the
        graph without ``job``'s tasks and carry the dynamic state over
        name-keyed.  Every task of the job must be finished, and the
        job must share no edges or coflows with the survivors (its
        completed outputs have already released all gates).  The job's
        start/finish times leave the session with it — record them (the
        service layer does) before retiring.
        """
        from repro.core.graph import MXDAG
        from repro.core.simulator import Simulator

        sim = self._sim
        doomed = {nm for nm, t in sim.g.tasks.items() if t.job == job}
        if not doomed:
            raise KeyError(f"unknown job {job!r}")
        if len(doomed) == len(sim.g.tasks):
            raise ValueError("cannot retire the only job in the "
                             "session")
        st = self._ops["export_admission"]()
        for nm in sorted(doomed):
            if st["tasks"][nm][2] is None:
                raise RuntimeError(f"retire_job({job!r}): task {nm} "
                                   f"has not finished")
        for e in sim.g.edges.values():
            if (e.src in doomed) != (e.dst in doomed):
                raise ValueError(f"retire_job({job!r}): cross-job edge "
                                 f"{e.src} -> {e.dst}")
        coflows = []
        for c in sim.coflows:
            inside = c & doomed
            if inside and inside != c:
                raise ValueError(f"retire_job({job!r}): coflow "
                                 f"{sorted(c)} spans the retired job")
            if not inside:
                coflows.append(c)
        shrunk = MXDAG(sim.g.name)
        for nm, t in sim.g.tasks.items():
            if nm not in doomed:
                shrunk.add(t)
        for e in sim.g.edges.values():
            if e.src not in doomed and e.dst not in doomed:
                shrunk.add_edge(e.src, e.dst, pipelined=e.pipelined)
        releases = {nm: ts[9] for nm, ts in st["tasks"].items()
                    if ts[9] > 0.0 and nm not in doomed}
        prio = {nm: v for nm, v in st["prio"].items()
                if nm not in doomed}
        routes = {nm: p for nm, p in sim.routes.items()
                  if nm not in doomed}
        fresh = ResumableSim(
            Simulator(shrunk, sim.cluster, policy=st["policy"],
                      priorities=prio, releases=releases,
                      coflows=coflows, routes=routes, engine="array"),
            self._horizon, batch=self._batch)
        fresh._ops["transplant"](st)
        self._adopt(fresh)
