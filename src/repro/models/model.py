"""Model assembly: every assigned architecture as one composable stack.

A config is compiled into *segments*: maximal runs of a repeating layer
pattern (e.g. jamba's period-8 [m m m m a m m m] × 4, deepseek-v3's
3 dense + 58 MoE).  Each segment's parameters are stacked on a leading
repeat axis and executed with ``jax.lax.scan`` — the HLO contains each
distinct block *once*, which keeps 512-device compiles tractable
(DESIGN.md §6).

The Model exposes:
- ``init(rng)``                     → params pytree
- ``loss(params, batch)``           → (scalar loss, metrics) for train_step
- ``forward(params, batch)``        → logits (prefill)
- ``init_cache(batch, max_len)``    → decode cache pytree
- ``decode_step(params, cache, tokens, index)`` → (logits, cache)
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, RunConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    cross_entropy, dense_init, embed_init, mlp, mlp_init, rmsnorm,
    rmsnorm_init,
)

Params = dict


# ----------------------------------------------------------------------
# segment derivation
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class BlockSpec:
    mixer: str           # "attn" | "mamba"
    ffn: str             # "dense" | "moe" | "none"
    causal: bool = True
    cross: bool = False  # decoder cross-attention (whisper)


@dataclasses.dataclass(frozen=True)
class Segment:
    pattern: tuple[BlockSpec, ...]
    repeats: int


def _lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)


def derive_segments(cfg: ArchConfig, *, cross: bool = False,
                    causal: bool = True) -> list[Segment]:
    def spec(i: int) -> BlockSpec:
        mixer = cfg.pattern[i % len(cfg.pattern)]
        if cfg.is_moe_layer(i):
            ffn = "moe"
        elif cfg.d_ff > 0:
            ffn = "dense"
        else:
            ffn = "none"
        if mixer == "mamba":
            ffn = ffn if cfg.family == "hybrid" else \
                ("none" if cfg.d_ff == 0 else ffn)
        return BlockSpec(mixer=mixer, ffn=ffn, causal=causal, cross=cross)

    regions = []
    if cfg.first_dense_layers:
        regions.append((0, cfg.first_dense_layers))
        regions.append((cfg.first_dense_layers, cfg.n_layers))
    else:
        regions.append((0, cfg.n_layers))

    segments = []
    for (lo, hi) in regions:
        n = hi - lo
        if n <= 0:
            continue
        period = _lcm(len(cfg.pattern),
                      cfg.moe_layer_period if cfg.n_experts else 1)
        if n % period != 0:
            period = n
        pat = tuple(spec(lo + j) for j in range(period))
        segments.append(Segment(pattern=pat, repeats=n // period))
    return segments


# ----------------------------------------------------------------------
# per-block init / apply
# ----------------------------------------------------------------------
def _block_init(key, spec: BlockSpec, cfg: ArchConfig, dtype) -> Params:
    ks = jax.random.split(key, 6)
    p: Params = {"ln1": rmsnorm_init(cfg.d_model, dtype)}
    if spec.mixer == "attn":
        if cfg.attn_type == "mla":
            p["attn"] = attn.mla_init(ks[0], cfg, dtype=dtype)
        else:
            p["attn"] = attn.gqa_init(ks[0], cfg, dtype=dtype)
    else:
        p["ssm"] = ssm_mod.ssm_init(ks[0], cfg, dtype=dtype)
    if spec.cross:
        p["ln_x"] = rmsnorm_init(cfg.d_model, dtype)
        p["xattn"] = attn.gqa_init(ks[1], cfg, cross=True, dtype=dtype)
    if spec.ffn == "dense":
        p["ln2"] = rmsnorm_init(cfg.d_model, dtype)
        p["mlp"] = mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.mlp_type,
                            dtype=dtype)
    elif spec.ffn == "moe":
        p["ln2"] = rmsnorm_init(cfg.d_model, dtype)
        p["moe"] = moe_mod.moe_init(ks[3], cfg, dtype=dtype)
    return p


class Model:
    def __init__(self, cfg: ArchConfig, run: RunConfig = RunConfig(), *,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 dp_axes: tuple[str, ...] = ("data",),
                 dtype=jnp.bfloat16):
        self.cfg = cfg
        self.run = run
        self.mesh = mesh
        self.dp_axes = dp_axes
        self.dtype = dtype
        self.segments = derive_segments(cfg)
        self.enc_segments: list[Segment] = []
        if cfg.encoder_layers:
            self.enc_segments = [Segment(
                pattern=(BlockSpec("attn", "dense", causal=False),),
                repeats=cfg.encoder_layers)]
            # decoder blocks get cross-attention
            self.segments = [Segment(
                pattern=tuple(dataclasses.replace(s, cross=True)
                              for s in seg.pattern),
                repeats=seg.repeats) for seg in self.segments]

    # ------------------------------------------------------------------
    def init(self, rng) -> Params:
        cfg, dtype = self.cfg, self.dtype
        keys = jax.random.split(rng, 8)
        p: Params = {
            "embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype),
            "final_norm": rmsnorm_init(cfg.d_model, dtype),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = dense_init(keys[1], cfg.d_model,
                                      self._vocab_padded(), dtype=dtype)
        p["segments"] = []
        for i, seg in enumerate(self.segments):
            skeys = jax.random.split(jax.random.fold_in(keys[2], i),
                                     seg.repeats)

            def init_one(k, seg=seg):
                pks = jax.random.split(k, len(seg.pattern))
                return [_block_init(pk, sp, cfg, dtype)
                        for pk, sp in zip(pks, seg.pattern)]

            p["segments"].append(jax.vmap(init_one)(skeys))
        if cfg.encoder_layers:
            ekeys = jax.random.split(keys[3], cfg.encoder_layers)
            espec = self.enc_segments[0].pattern[0]
            p["encoder"] = jax.vmap(
                lambda k: _block_init(k, espec, cfg, dtype))(ekeys)
            p["enc_norm"] = rmsnorm_init(cfg.d_model, dtype)
        if cfg.vision_embed_dim:
            p["vis_proj"] = dense_init(keys[4], cfg.vision_embed_dim,
                                       cfg.d_model, dtype=dtype)
        if cfg.mtp:
            p["mtp"] = {
                "proj": dense_init(keys[5], 2 * cfg.d_model, cfg.d_model,
                                   dtype=dtype),
                "block": _block_init(keys[6],
                                     BlockSpec("attn", "dense"), cfg, dtype),
                "ln": rmsnorm_init(cfg.d_model, dtype),
            }
        return p

    # ------------------------------------------------------------------
    def _apply_block(self, bp: Params, spec: BlockSpec, x, *,
                     positions=None, cache=None, cache_index=None,
                     enc_out=None):
        cfg, run = self.cfg, self.run
        aux = jnp.zeros((), jnp.float32)
        new_cache = {}
        h = rmsnorm(bp["ln1"], x, cfg.norm_eps)
        if spec.mixer == "attn":
            c = cache.get("attn") if cache else None
            if cfg.attn_type == "mla":
                out, nc = attn.mla_apply(bp["attn"], h, cfg,
                                         positions=positions, cache=c,
                                         cache_index=cache_index,
                                         impl=run.attn_impl)
            else:
                out, nc = attn.gqa_apply(bp["attn"], h, cfg,
                                         positions=positions, cache=c,
                                         cache_index=cache_index,
                                         causal=spec.causal,
                                         impl=run.attn_impl)
            if nc is not None:
                new_cache["attn"] = nc
        else:
            c = cache.get("ssm") if cache else None
            out, nc = ssm_mod.ssm_apply(bp["ssm"], h, cfg, cache=c,
                                        chunk=run.ssm_chunk or None)
            if nc is not None:
                new_cache["ssm"] = nc
        x = x + out

        if spec.cross and enc_out is not None:
            h = rmsnorm(bp["ln_x"], x, cfg.norm_eps)
            out, _ = attn.gqa_apply(bp["xattn"], h, cfg, kv_src=enc_out,
                                    causal=False, use_rope=False,
                                    impl=run.attn_impl)
            x = x + out

        if spec.ffn == "dense":
            h = rmsnorm(bp["ln2"], x, cfg.norm_eps)
            x = x + mlp(bp["mlp"], h, cfg.mlp_type)
        elif spec.ffn == "moe":
            h = rmsnorm(bp["ln2"], x, cfg.norm_eps)
            y, a = moe_mod.moe_apply(bp["moe"], h, cfg, mesh=self.mesh,
                                     dp_axes=self.dp_axes,
                                     combine=run.moe_combine)
            x = x + y
            aux = aux + a
        # §Perf iter 5: pin the block output while it is still bf16 so
        # the TP partial-sum all-reduce runs on the bf16 residual rather
        # than sinking past the next layer's fp32 norm upcast.
        if self.mesh is not None and cache is None and x.ndim == 3:
            from jax.sharding import NamedSharding, PartitionSpec as P
            B = x.shape[0]
            dpsz = 1
            for a_ in self.dp_axes:
                dpsz *= self.mesh.shape[a_]
            if B % max(dpsz, 1) == 0:
                x = jax.lax.with_sharding_constraint(
                    x, NamedSharding(self.mesh,
                                     P(self.dp_axes, None, None)))
        return x, aux, new_cache

    def _grad_sync_fn(self):
        """MXDAG-planned layer-wise gradient sync (repro/sync/overlap)."""
        if self.mesh is None or self.run.sync_mode != "bucketed":
            return None
        if getattr(self, "_sync_cache", None) is None:
            from repro.sync.overlap import make_grad_sync_fn
            self._sync_cache = make_grad_sync_fn(
                self.mesh, self.cfg, self.run, self.dp_axes)
        return self._sync_cache

    def _run_segments(self, segments, seg_params, x, *, positions=None,
                      caches=None, cache_index=None, enc_out=None):
        """Scan each segment over its repeats.  Returns (x, aux, caches).

        Training path with ``sync_mode="bucketed"``: the scan is replaced
        by the custom-vjp synced scan whose backward emits each layer's
        gradient reduce-scatter inside the reverse loop (Fig. 6 realized;
        see repro/sync/overlap.py).  ``"barrier"`` keeps the plain scan:
        XLA then reduces the stacked grads once after the loop — the
        coflow-like baseline.
        """
        total_aux = jnp.zeros((), jnp.float32)
        new_caches = []
        sync = self._grad_sync_fn() if caches is None else None
        if sync is not None:
            from repro.sync.overlap import make_synced_scan
            for si, seg in enumerate(segments):
                def body2(bps, xc, seg=seg):
                    aux = jnp.zeros((), jnp.float32)
                    for j, spec in enumerate(seg.pattern):
                        xc, a, _ = self._apply_block(
                            bps[j], spec, xc, positions=positions,
                            enc_out=enc_out)
                        aux = aux + a
                    return xc, aux

                scan_fn = make_synced_scan(body2, sync)
                x, aux_seg = scan_fn(seg_params[si], x)
                total_aux = total_aux + aux_seg
                new_caches.append(None)
            return x, total_aux, new_caches
        for si, seg in enumerate(segments):
            params_stack = seg_params[si]
            cache_stack = caches[si] if caches is not None else None

            def body(carry, xs, seg=seg):
                xc, auxc = carry
                bps, cs = xs
                ncs = []
                for j, spec in enumerate(seg.pattern):
                    xc, a, nc = self._apply_block(
                        bps[j], spec, xc, positions=positions,
                        cache=cs[j] if cs is not None else None,
                        cache_index=cache_index, enc_out=enc_out)
                    auxc = auxc + a
                    ncs.append(nc)
                return (xc, auxc), ncs

            if self.run.remat:
                body = jax.checkpoint(body)
            (x, total_aux), nc_stack = jax.lax.scan(
                body, (x, total_aux),
                (params_stack,
                 cache_stack if cache_stack is not None
                 else [None] * len(seg.pattern)))
            new_caches.append(nc_stack)
        return x, total_aux, new_caches

    # ------------------------------------------------------------------
    def _encode(self, params, batch):
        """Whisper encoder over precomputed frame embeddings (stub)."""
        cfg = self.cfg
        x = batch["audio_embeds"].astype(self.dtype)
        espec = self.enc_segments[0].pattern[0]

        def body(carry, bp):
            xc, = carry
            xc, _, _ = self._apply_block(bp, espec, xc)
            return (xc,), None

        b = jax.checkpoint(body) if self.run.remat else body
        (x,), _ = jax.lax.scan(b, (x,), params["encoder"])
        return rmsnorm(params["enc_norm"], x, cfg.norm_eps)

    def _embed_inputs(self, params, batch):
        """Token (+ modality prefix) embedding.  Returns (x, n_prefix)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = jnp.take(params["embed"], tokens, axis=0).astype(self.dtype)
        n_prefix = 0
        if cfg.vision_embed_dim and "vision_embeds" in batch:
            v = batch["vision_embeds"].astype(self.dtype) @ params["vis_proj"]
            x = jnp.concatenate([v, x], axis=1)
            n_prefix = v.shape[1]
        if self.run.seq_shard and self.mesh is not None \
                and x.shape[1] % self.mesh.shape.get("model", 1) == 0:
            # sequence parallelism over the unused "model" axis (§Perf
            # mamba2 follow-up): pointwise projections, the conv (halo via
            # collective-permute) and the chunk-parallel SSD intra terms
            # all shard over seq; only the tiny inter-chunk state scan
            # crosses shards.
            from jax.sharding import NamedSharding, PartitionSpec as P
            dp = tuple(a for a in self.dp_axes if a != "model")
            x = jax.lax.with_sharding_constraint(
                x, NamedSharding(self.mesh,
                                 P(dp if dp else None, "model", None)))
        return x, n_prefix

    def _tp(self) -> int:
        return self.mesh.shape.get("model", 1) if self.mesh is not None \
            else 1

    def _vocab_padded(self) -> int:
        # §Perf internvl2 iter 3: pad the LM head to a TP multiple so the
        # head stays vocab-sharded for odd vocabs (92553 -> 92560 @tp=16)
        # instead of replicating (iter 2's local contraction doubled head
        # flops) or all-reducing [B,S,V] logits (baseline).
        tp = self._tp()
        v = self.cfg.vocab_size
        return -(-v // tp) * tp

    def _vocab_sharded(self) -> bool:
        return True     # padding guarantees divisibility

    def _head(self, params, x):
        cfg = self.cfg
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = x @ params["embed"].T
        else:
            logits = x @ params["lm_head"]
        vp = logits.shape[-1]
        if vp != cfg.vocab_size:
            # mask padded vocab columns (elementwise; partitions cleanly)
            neg = jnp.where(jnp.arange(vp) < cfg.vocab_size,
                            0.0, -1e30).astype(logits.dtype)
            logits = logits + neg
        return logits

    # ------------------------------------------------------------------
    def forward(self, params, batch) -> jax.Array:
        enc_out = self._encode(params, batch) if self.cfg.encoder_layers \
            else None
        x, n_prefix = self._embed_inputs(params, batch)
        positions = jnp.arange(x.shape[1])
        x, aux, _ = self._run_segments(self.segments, params["segments"], x,
                                       positions=positions, enc_out=enc_out)
        return self._head(params, x)

    def loss(self, params, batch) -> tuple[jax.Array, dict]:
        cfg = self.cfg
        enc_out = self._encode(params, batch) if cfg.encoder_layers else None
        x, n_prefix = self._embed_inputs(params, batch)
        positions = jnp.arange(x.shape[1])
        x, aux, _ = self._run_segments(self.segments, params["segments"], x,
                                       positions=positions, enc_out=enc_out)
        tokens = batch["tokens"]
        h = x[:, n_prefix:]                       # text region only
        logits = self._head(params, h[:, :-1])
        if self.run.logits_fp32:
            logits = logits.astype(jnp.float32)
        ce = cross_entropy(logits, tokens[:, 1:],
                           vocab_sharded=self._vocab_sharded())
        loss = ce + cfg.router_aux_weight * aux
        metrics = {"ce": ce, "aux": aux}
        if cfg.mtp:
            mtp = params["mtp"]
            # predict t+2 from [h_t ; emb(t_{+1})] through one extra block
            h_in = rmsnorm(mtp["ln"], h[:, :-1], cfg.norm_eps)
            nxt = jnp.take(params["embed"], tokens[:, 1:], axis=0
                           ).astype(self.dtype)
            z = jnp.concatenate([h_in, nxt], axis=-1) @ mtp["proj"]
            z, _, _ = self._apply_block(mtp["block"],
                                        BlockSpec("attn", "dense"), z,
                                        positions=positions[: z.shape[1]])
            mtp_logits = self._head(params, z[:, :-1])
            mtp_ce = cross_entropy(mtp_logits.astype(jnp.float32),
                                   tokens[:, 2:],
                                   vocab_sharded=self._vocab_sharded())
            loss = loss + 0.3 * mtp_ce
            metrics["mtp_ce"] = mtp_ce
        metrics["loss"] = loss
        return loss, metrics

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def init_cache(self, batch_size: int, max_len: int) -> Params:
        cfg = self.cfg
        caches = []
        for seg in self.segments:
            seg_caches = []
            for spec in seg.pattern:
                c: Params = {}
                if spec.mixer == "attn":
                    if cfg.attn_type == "mla":
                        one = attn.mla_cache_init(cfg, batch_size, max_len,
                                                  dtype=self.dtype)
                    else:
                        one = attn.gqa_cache_init(cfg, batch_size, max_len,
                                                  dtype=self.dtype)
                    c["attn"] = one
                else:
                    c["ssm"] = ssm_mod.ssm_cache_init(cfg, batch_size)
                seg_caches.append(jax.tree.map(
                    lambda a, R=seg.repeats: jnp.zeros(
                        (R,) + a.shape, a.dtype), c))
            caches.append(seg_caches)
        return caches

    def decode_step(self, params, caches, tokens, index, *,
                    enc_out=None):
        """One token step.  tokens: [B,1]; index: scalar int32 position."""
        x = jnp.take(params["embed"], tokens, axis=0).astype(self.dtype)
        x, _, new_caches = self._run_segments(
            self.segments, params["segments"], x,
            caches=caches, cache_index=index, enc_out=enc_out)
        return self._head(params, x), new_caches
